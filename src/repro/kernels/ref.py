"""Pure-jnp oracles for the MMA kernels — independent of the kernel code.

Plane truncation oracle: consuming only the ``b`` MSB planes of the offset
activation ``u = x + 128`` equals masking off the low ``8-b`` bits of ``u``:

    S_b * 2^(8-b) = (u & ~(2^(8-b)-1)) @ w  -  128 * colsum(w)

so the oracle needs no Horner loop at all — one masked exact matmul.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

N_BITS = 8


def mma_matmul_ref(
    x: jax.Array,
    w: jax.Array,
    *,
    planes: int = N_BITS,
    signed: bool = True,
    midpoint: bool = False,
) -> jax.Array:
    """Oracle for kernels.mma_matmul: (..., K) int8 @ (K, N) int8 -> int32."""
    u = x.astype(jnp.int32)
    if signed:
        u = u + 128
    dropped = N_BITS - planes
    mask = ~((1 << dropped) - 1)
    u = u & mask
    out = jax.lax.dot_general(
        u, w.astype(jnp.int32), (((u.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    colsum = jnp.sum(w.astype(jnp.int32), axis=0)
    if midpoint and dropped:
        out = out + ((2**dropped - 1) * colsum) // 2
    if signed:
        out = out - 128 * colsum
    return out


def mma_conv2d_ref(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    pad: int = 1,
    planes: int = N_BITS,
    signed: bool = True,
) -> jax.Array:
    """Oracle for the KPB-style conv: NHWC int8 x (kh, kw, Cin, Cout) int8.

    Built from the *matmul* oracle via explicit patch extraction so it shares
    no code with the conv implementation under test.
    """
    n, h, w_, c = x.shape
    kh, kw, cin, cout = w.shape
    assert c == cin
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w_ + 2 * pad - kw) // stride + 1
    patches = []
    for i in range(kh):
        for j in range(kw):
            patches.append(
                xp[:, i : i + oh * stride : stride, j : j + ow * stride : stride, :]
            )
    patches = jnp.concatenate(patches, axis=-1)  # (n, oh, ow, kh*kw*cin)
    wm = w.transpose(0, 1, 2, 3).reshape(kh * kw * cin, cout)
    out = mma_matmul_ref(
        patches.reshape(-1, kh * kw * cin), wm, planes=planes, signed=signed
    )
    return out.reshape(n, oh, ow, cout)
