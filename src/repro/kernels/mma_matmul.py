"""Pallas TPU kernel: the merged multiply-add (MMA) as a fused bit-plane matmul.

FPGA -> TPU mapping (DESIGN.md Sec. 2).  The FPGA MMA streams activation bits
MSB-first through an AND array and keeps a left-shifted *residual* inside the
unit, so the whole inner product pays one initial delay.  The TPU analogue of
"initial delay" is an HBM round-trip: an un-fused bit-plane implementation
writes 8 plane partial products to HBM and re-reads them to reduce.  This
kernel keeps the Horner accumulator (the residual) in VMEM scratch for the
whole (bm, bn) output tile: x and w are read from HBM exactly once, partial
sums never leave VMEM — the merged pipeline.

Datapath per grid step (m, n, k):
    u      = x_block + 128                 (offset two's-complement -> 0..255)
    acc    = 0
    for b in MSB..(MSB-planes+1):          (static unroll, 8 iterations max)
        plane = (u >> b) & 1               (VPU)
        acc   = 2*acc + plane @ w_block    (MXU, bf16 x bf16 -> f32)
    acc   *= 2**dropped                    (early-termination rescale)
    acc   -= 128 * colsum(w_block)         (exact signed correction)
    out   += acc                           (k-accumulation in VMEM scratch,
                                            written to HBM on the last k step)

Exactness of the bf16 MXU path: plane is {0,1} (exact), |w| <= 127 needs 7
mantissa bits (bf16 has 8 -> exact), products accumulate in f32 with
|partial| <= K * 127 * 255 < 2^24 for K <= 512 per block (exact f32 ints).
The k-grid accumulation is int32.  dimension_semantics marks m, n parallel
and k arbitrary (sequential accumulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

N_BITS = 8

# Default MXU-aligned tile shapes: (bm x bk) int8 + (bk x bn) int8 + f32/i32
# accumulators comfortably fit VMEM (~16 MiB/core on v5e):
#   x: 128*512 = 64 KiB, w: 512*128 = 64 KiB, acc: 128*128*4*2 = 128 KiB.
BM, BK, BN = 128, 512, 128


def _mma_kernel(x_ref, w_ref, *refs, planes: int, signed: bool, n_k: int,
                scaled: bool):
    if scaled:
        xs_ref, ws_ref, out_ref, acc_ref = refs
    else:
        out_ref, acc_ref = refs
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    u = x_ref[...].astype(jnp.int32)
    if signed:
        u = u + 128
    w = w_ref[...].astype(jnp.bfloat16)

    acc = jnp.zeros(acc_ref.shape, jnp.float32)
    for i in range(planes):
        b = N_BITS - 1 - i  # MSB first — the digit-serial streaming order
        plane = ((u >> b) & 1).astype(jnp.bfloat16)
        part = jax.lax.dot_general(
            plane, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc = acc * 2.0 + part  # the left-shifted residual recurrence

    dropped = N_BITS - planes
    acc = acc * float(2**dropped)
    if signed:
        colsum = jnp.sum(w_ref[...].astype(jnp.int32), axis=0, keepdims=True)
        acc = acc - 128.0 * colsum.astype(jnp.float32)

    acc_ref[...] += acc.astype(jnp.int32)

    @pl.when(k == n_k - 1)
    def _flush():
        if scaled:
            # fused dequant epilogue (the OGF of the TPU datapath): the int32
            # accumulator leaves VMEM already in float form — no extra HBM
            # pass for the x_scale * w_scale[n] multiply.
            out_ref[...] = (
                acc_ref[...].astype(jnp.float32)
                * xs_ref[0] * ws_ref[...][0][None, :]
            )
        else:
            out_ref[...] = acc_ref[...]


def _compiler_params():
    try:
        return pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    except AttributeError:  # older pallas API
        return pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )


def _mma_matmul_impl(
    x: jax.Array,
    w: jax.Array,
    *,
    planes: int,
    signed: bool,
    interpret: bool,
    bm: int,
    bk: int,
    bn: int,
) -> jax.Array:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and m % bm == 0 and k % bk == 0 and n % bn == 0, (
        f"unpadded shapes {x.shape} x {w.shape} for blocks {(bm, bk, bn)}"
    )
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)

    kernel = functools.partial(
        _mma_kernel, planes=planes, signed=signed, n_k=n_k, scaled=False
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(x, w)


def _mma_matmul_scaled_impl(
    x: jax.Array,
    w: jax.Array,
    x_scale: jax.Array,
    w_scale: jax.Array,
    *,
    planes: int,
    signed: bool,
    interpret: bool,
    bm: int,
    bk: int,
    bn: int,
) -> jax.Array:
    m, k = x.shape
    _, n = w.shape
    assert m % bm == 0 and k % bk == 0 and n % bn == 0
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    kernel = functools.partial(
        _mma_kernel, planes=planes, signed=signed, n_k=n_k, scaled=True
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(x, w, x_scale.reshape(1), w_scale.reshape(1, n))


@functools.lru_cache(maxsize=None)
def plane_variant(
    planes: int,
    signed: bool = True,
    *,
    scaled: bool = False,
    interpret: bool = False,
    bm: int = BM,
    bk: int = BK,
    bn: int = BN,
):
    """Cached jitted kernel variant specialized to one plane budget.

    The plane count is a *specialization axis*: the kernel body unrolls
    ``planes`` Horner steps, so a 4-plane variant issues exactly half the MXU
    work of the 8-plane one — a dynamic-precision schedule that assigns a
    layer 4 planes genuinely runs a smaller kernel, not a masked full-width
    one.  Each distinct (planes, signed, block) tuple compiles once and is
    reused across layers and calls; ``plane_variant.cache_info()`` exposes
    the variant table for tests and benchmarks.
    """
    impl = _mma_matmul_scaled_impl if scaled else _mma_matmul_impl
    fn = functools.partial(
        impl, planes=planes, signed=signed, interpret=interpret,
        bm=bm, bk=bk, bn=bn,
    )
    # name the variant so it is identifiable in HLO dumps / profiles
    fn.__name__ = (
        f"mma_matmul{'_scaled' if scaled else ''}_pallas_p{planes}"
        f"{'u' if not signed else ''}"
    )
    return jax.jit(fn)


def mma_matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    planes: int = N_BITS,
    signed: bool = True,
    interpret: bool = False,
    bm: int = BM,
    bk: int = BK,
    bn: int = BN,
) -> jax.Array:
    """(M, K) int8 @ (K, N) int8 -> (M, N) int32, fused bit-plane Horner.

    Shapes must be multiples of the block shape — ``ops.mma_matmul`` pads.
    Dispatches through the per-plane-count variant cache.
    """
    return plane_variant(
        planes, signed, interpret=interpret, bm=bm, bk=bk, bn=bn
    )(x, w)


def mma_matmul_scaled_pallas(
    x: jax.Array,
    w: jax.Array,
    x_scale: jax.Array,
    w_scale: jax.Array,
    *,
    planes: int = N_BITS,
    signed: bool = True,
    interpret: bool = False,
    bm: int = BM,
    bk: int = BK,
    bn: int = BN,
) -> jax.Array:
    """Quantized-serving form with the dequant epilogue fused into the
    flush: (M,K) int8 @ (K,N) int8 -> (M,N) f32 = acc * x_scale * w_scale[n].

    x_scale: () f32 (dynamic per-tensor); w_scale: (N,) f32 (per-channel).
    """
    return plane_variant(
        planes, signed, scaled=True, interpret=interpret, bm=bm, bk=bk, bn=bn
    )(x, w, x_scale, w_scale)
