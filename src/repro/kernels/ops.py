"""Jitted public wrappers around the Pallas MMA kernels.

Handles: CPU-vs-TPU dispatch (interpret mode on CPU so the kernel body runs
everywhere), padding to MXU-aligned block shapes, arbitrary leading batch
dims, and the KPB-style conv mapping (taps folded into the contraction dim —
the Pallas analogue of grouping k*k MMA units into a Kernel Processing
Block).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .mma_matmul import BK, BM, BN, N_BITS, mma_matmul_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(v: int, b: int) -> int:
    return (v + b - 1) // b * b


def _normalize_planes(x, planes, *, signed: bool):
    """Static plane budgets specialize the kernel (cached variant per count,
    fewer unrolled MXU steps, validated 1..8); traced budgets fold into the
    data via the exact bit-mask identity and run the full-width variant."""
    from repro.core import bitplane  # lazy: core.mma imports this module lazily

    return bitplane.normalize_planes(x, planes, signed=signed)


def mma_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    planes: int | jax.Array = N_BITS,
    signed: bool = True,
    interpret: bool | None = None,
    block: tuple[int, int, int] | None = None,
) -> jax.Array:
    """(..., K) int8 @ (K, N) int8 -> (..., N) int32 via the fused kernel."""
    if interpret is None:
        interpret = _on_cpu()
    x, planes = _normalize_planes(x, planes, signed=signed)
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[-1]
    m = 1
    for d in lead:
        m *= d
    x2 = x.reshape(m, k)

    bm, bk, bn = block if block is not None else (BM, BK, BN)
    # Shrink blocks for small problems (keeps interpret-mode tests fast);
    # int8 sublane tiling on TPU wants the second-minor dim in multiples of 32.
    bm, bk, bn = min(bm, _pad_to(m, 32)), min(bk, _pad_to(k, 128)), min(bn, _pad_to(n, 128))
    mp, kp, np_ = _pad_to(m, bm), _pad_to(k, bk), _pad_to(n, bn)
    # Zero-padding K is exact: padded w rows are 0, so both the dot and the
    # signed colsum correction are unaffected (see kernel docstring).
    x2 = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
    w2 = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    out = mma_matmul_pallas(
        x2, w2, planes=planes, signed=signed, interpret=interpret, bm=bm, bk=bk, bn=bn
    )
    return out[:m, :n].reshape(*lead, n)


def mma_matmul_scaled(
    x: jax.Array,
    w: jax.Array,
    x_scale: jax.Array,
    w_scale: jax.Array,
    *,
    planes: int | jax.Array = N_BITS,
    signed: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Quantized-serving matmul with the dequant epilogue fused in-kernel:
    (..., K) int8 @ (K, N) int8 -> (..., N) f32 scaled by x_scale*w_scale."""
    from .mma_matmul import mma_matmul_scaled_pallas

    if interpret is None:
        interpret = _on_cpu()
    x, planes = _normalize_planes(x, planes, signed=signed)
    lead = x.shape[:-1]
    k, n = x.shape[-1], w.shape[-1]
    m = 1
    for d in lead:
        m *= d
    x2 = x.reshape(m, k)
    bm, bk, bn = min(BM, _pad_to(m, 32)), min(BK, _pad_to(k, 128)), min(BN, _pad_to(n, 128))
    mp, kp, np_ = _pad_to(m, bm), _pad_to(k, bk), _pad_to(n, bn)
    x2 = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
    w2 = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    ws = jnp.pad(w_scale.reshape(-1), (0, np_ - n))
    out = mma_matmul_scaled_pallas(
        x2, w2, x_scale, ws, planes=planes, signed=signed, interpret=interpret,
        bm=bm, bk=bk, bn=bn,
    )
    return out[:m, :n].reshape(*lead, n)


def mma_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    pad: int = 1,
    pad_mode: str = "zero",
    planes: int | jax.Array = N_BITS,
    signed: bool = True,
    interpret: bool | None = None,
    impl: str = "pallas",
) -> jax.Array:
    """KPB conv: NHWC int8 x (kh, kw, Cin, Cout) int8 -> NHWC int32.

    The k*k spatial taps fold into the contraction dim exactly like the KPB
    groups k*k MMA units over one window (Eq. 1): patches (n*oh*ow, kh*kw*cin)
    @ weights (kh*kw*cin, cout).  ``impl`` selects the matmul datapath:
    'pallas' (the fused kernel), or any of the ``core.mma`` paths
    ('xla' | 'cascade' | 'int8') for baselines and CPU-only runs.

    ``pad_mode`` selects what fills the ``pad`` border ring: 'zero' (the
    FBGEMM/XLA SAME convention), or 'edge' / 'reflect' (replicate /
    mirror the boundary row).  Non-zero modes serve halo-free image tiles
    (``repro.segserve``): a tile cut from a larger image has real content
    past its edge, and replicating the boundary row approximates it far
    better than a hard zero seam.
    """
    n, h, w_, c = x.shape
    kh, kw, cin, cout = w.shape
    assert c == cin
    pad_widths = ((0, 0), (pad, pad), (pad, pad), (0, 0))
    if pad_mode == "zero":
        xp = jnp.pad(x, pad_widths)
    elif pad_mode in ("edge", "reflect"):
        xp = jnp.pad(x, pad_widths, mode=pad_mode)
    else:
        raise ValueError(f"unknown pad_mode {pad_mode!r}")
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w_ + 2 * pad - kw) // stride + 1
    patches = [
        xp[:, i : i + oh * stride : stride, j : j + ow * stride : stride, :]
        for i in range(kh)
        for j in range(kw)
    ]
    patches = jnp.concatenate(patches, axis=-1)
    wm = w.reshape(kh * kw * cin, cout)
    pm = patches.reshape(-1, kh * kw * cin)
    if impl == "pallas":
        out = mma_matmul(
            pm, wm, planes=planes, signed=signed, interpret=interpret
        )
    else:
        from repro.core import mma  # lazy: core.mma imports this module lazily

        out = mma.mma_dot(pm, wm, planes=planes, signed=signed, impl=impl)
    return out.reshape(n, oh, ow, cout)
