"""Declarative SLOs + online burn-rate monitoring on the event bus.

:class:`SloSpec` declares one QoS class's objective: a latency target at
a percentile, and a *deadline-miss budget* (the fraction of requests
allowed to finish past their per-request deadline — traces carry
``deadline_cycles``, the gateway stamps an absolute deadline on every
request).  :class:`SloMonitor` is an event-bus sink (:mod:`repro.obs
.events`) that watches the stream a gateway or fabric already emits and
maintains, **online and in bounded memory**:

* cumulative per-class completion / deadline-miss / latency-miss
  counters, per shard and fleet-aggregated — the miss counts are gated
  *integer-exactly* equal to the offline span-derived counts
  (:func:`repro.obs.attrib.span_misses` over
  :func:`repro.obs.spans.assemble`), because both fold the identical
  ``submit``/``import``/``admit``/``exec``/``complete`` stream;
* a streaming miss-attribution histogram (:mod:`repro.obs.attrib`
  classes: queued / preempted / service / overdraft) built from the same
  integer segments span assembly would produce — state per *in-flight*
  request only, dropped at completion, so a million-request run holds a
  live table bounded by concurrency, never by trace length;
* rolling **multi-window burn rates** on the modeled cycle clock: for
  each window (in cycles) a bucketed ring holds completion/miss counts,
  and the burn rate is ``(miss fraction in window) / miss_budget`` —
  the multi-window alerting shape (fast window pages, slow window
  tickets).  Window rates are bucket-granular approximations; the
  *cumulative* counters are exact, and they are what reconciliation
  gates on.

Arm the monitor before traffic (``gateway.set_sink(monitor)`` or tee it
with a :class:`~repro.obs.events.RecordingSink`); completions whose
submit the monitor never saw are counted ``untracked`` and excluded
from miss accounting — exactness is guaranteed for streams observed
from the first arrival.

A stolen request is handled exactly like span assembly handles it: the
donor-side record is dropped on the ``export`` event and the thief-side
``import`` (re-keyed rid, original arrival and deadline traveling with
it) opens the record that will complete — so online and offline miss
counts agree even under work stealing.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core import cycle_model as cm

from .attrib import ATTRIB_CLASSES, attribution_shares, classify_segments
from .events import ShardSink, TeeSink

#: Scope key for the fleet-wide aggregate (individual shards key by their
#: integer index; an unsharded gateway's events key by ``None``).
FLEET = "fleet"


@dataclass(frozen=True)
class SloSpec:
    """One QoS class's declarative objective.

    Args:
      qos: the class label (a gateway ``shares`` key).
      pct: the latency percentile the target applies to (exact order
        statistic, :func:`~repro.serve.clock.exact_percentile`).
      latency_target_ms: modeled-latency target at ``pct`` (None: no
        latency objective, deadline budget only).
      miss_budget: allowed deadline-miss fraction in (0, 1] — the burn
        rate denominator; burn 1.0 means missing exactly at budget.
    """

    qos: str
    pct: float = 99.0
    latency_target_ms: float | None = None
    miss_budget: float = 0.01

    def __post_init__(self):
        if not 0 < self.pct <= 100:
            raise ValueError(f"pct {self.pct} not in (0, 100]")
        if not 0 < self.miss_budget <= 1:
            raise ValueError(
                f"miss_budget {self.miss_budget} not in (0, 1]"
            )
        if self.latency_target_ms is not None and self.latency_target_ms <= 0:
            raise ValueError(
                f"latency_target_ms {self.latency_target_ms} <= 0"
            )

    @property
    def latency_target_cycles(self) -> int | None:
        if self.latency_target_ms is None:
            return None
        return int(round(self.latency_target_ms * cm.FREQ_HZ / 1e3))

    def to_dict(self) -> dict:
        return dict(
            qos=self.qos, pct=self.pct,
            latency_target_ms=self.latency_target_ms,
            miss_budget=self.miss_budget,
        )


class _Window:
    """Bucketed ring over one rolling window of the modeled clock:
    completion and miss counts per bucket, expired buckets zeroed as the
    clock advances.  Rates are exact at bucket granularity."""

    __slots__ = ("window", "buckets", "width", "n", "miss", "_cur")

    def __init__(self, window: int, buckets: int):
        self.window = int(window)
        self.buckets = int(buckets)
        self.width = max(self.window // self.buckets, 1)
        self.n = [0] * self.buckets
        self.miss = [0] * self.buckets
        self._cur = None  # absolute index of the newest bucket

    def record(self, cycle: int, miss: bool) -> None:
        b = cycle // self.width
        if self._cur is None:
            self._cur = b
        elif b > self._cur:
            # zero every bucket the clock skipped over (ring-capped)
            for k in range(self._cur + 1,
                           min(b, self._cur + self.buckets) + 1):
                self.n[k % self.buckets] = 0
                self.miss[k % self.buckets] = 0
            self._cur = b
        # late cross-shard events (bounded by one lock-step round) fold
        # into their own bucket if still live, else the oldest kept one
        idx = (b if self._cur - b < self.buckets else
               self._cur - self.buckets + 1) % self.buckets
        self.n[idx] += 1
        self.miss[idx] += 1 if miss else 0

    def rate(self) -> float:
        """Miss fraction over the live window (0.0 when empty)."""
        n = sum(self.n)
        return sum(self.miss) / n if n else 0.0


class _ClassState:
    """One (scope, qos) accumulator: exact cumulative counters + the
    rolling windows + streaming attribution histogram."""

    __slots__ = ("completions", "deadline_misses", "latency_misses",
                 "untracked", "attribution", "windows")

    def __init__(self, windows, buckets):
        self.completions = 0
        self.deadline_misses = 0
        self.latency_misses = 0
        self.untracked = 0
        self.attribution = {c: 0 for c in ATTRIB_CLASSES}
        self.windows = {w: _Window(w, buckets) for w in windows}


class _Live:
    """One in-flight request's streaming span state (dropped at
    completion — the live table is bounded by concurrency)."""

    __slots__ = ("arrival", "admitted", "deadline", "exec_cycles", "qos")

    def __init__(self, arrival, deadline, qos):
        self.arrival = arrival
        self.admitted = None
        self.deadline = deadline
        self.exec_cycles = 0
        self.qos = qos


class SloMonitor:
    """Event-bus sink computing online SLO state (module docstring).

    Args:
      specs: :class:`SloSpec` per monitored class.  Classes without a
        spec are still counted (budget defaults to ``default_budget``
        for burn rates) — observation must not require declaration.
      windows: rolling window lengths in modeled cycles, fast to slow.
      buckets: ring granularity per window (rate error ≤ 1 bucket).
      default_budget: miss budget applied to unspecified classes.
    """

    enabled = True

    def __init__(self, specs=(), *, windows=(2_000_000, 16_000_000),
                 buckets: int = 32, default_budget: float = 0.01):
        self.specs = {s.qos: s for s in specs}
        if not windows:
            raise ValueError("need at least one burn-rate window")
        self.windows = tuple(sorted(int(w) for w in windows))
        if any(w <= 0 for w in self.windows):
            raise ValueError(f"windows must be positive: {windows}")
        self.buckets = int(buckets)
        if not 0 < default_budget <= 1:
            raise ValueError(
                f"default_budget {default_budget} not in (0, 1]"
            )
        self.default_budget = float(default_budget)
        self._live: dict[tuple, _Live] = {}
        self._scopes: dict[object, dict[str, _ClassState]] = {}
        self.last_cycle = 0

    # ------------------------------------------------------------- sink

    def emit(self, event) -> None:
        et = event.etype
        if et not in ("submit", "import", "admit", "exec", "complete",
                      "export"):
            return
        d = event.data
        shard = d.get("shard")
        key = (shard, d["rid"])
        if event.cycle > self.last_cycle:
            self.last_cycle = event.cycle
        if et in ("submit", "import"):
            # import re-keys a stolen request; its original arrival and
            # absolute deadline travel with it (span-assembly semantics)
            self._live[key] = _Live(
                int(d.get("arrival", event.cycle)), d.get("deadline"),
                d.get("qos"),
            )
        elif et == "export":
            # donor side of a steal: this rid will never complete here
            self._live.pop(key, None)
        elif et == "admit":
            rec = self._live.get(key)
            if rec is not None:
                rec.admitted = event.cycle
        elif et == "exec":
            rec = self._live.get(key)
            if rec is not None:
                rec.exec_cycles += int(d["cycles"])
        else:  # complete
            self._complete(shard, key, event)

    def _complete(self, shard, key, event) -> None:
        rec = self._live.pop(key, None)
        qos = event.data.get("qos") or (rec.qos if rec else None)
        if rec is None or rec.admitted is None:
            # submit/admit predates the monitor: count, don't guess
            for scope in (shard, FLEET):
                self._state(scope, qos).untracked += 1
            return
        finished = event.cycle
        total = finished - rec.arrival
        # effective admission never precedes arrival (round-start stamps)
        queued = max(rec.admitted, rec.arrival) - rec.arrival
        preempted = total - queued - rec.exec_cycles
        miss = rec.deadline is not None and finished > rec.deadline
        spec = self.specs.get(qos)
        target = spec.latency_target_cycles if spec else None
        lat_miss = target is not None and total > target
        attrib = classify_segments(queued, rec.exec_cycles, preempted) \
            if miss else None
        for scope in (shard, FLEET):
            st = self._state(scope, qos)
            st.completions += 1
            if miss:
                st.deadline_misses += 1
                st.attribution[attrib] += 1
            if lat_miss:
                st.latency_misses += 1
            for w in st.windows.values():
                w.record(finished, miss)

    def _state(self, scope, qos) -> _ClassState:
        per_class = self._scopes.setdefault(scope, {})
        st = per_class.get(qos)
        if st is None:
            st = per_class[qos] = _ClassState(self.windows, self.buckets)
        return st

    # ---------------------------------------------------------- queries

    def scopes(self) -> list:
        """Scope keys seen so far (``'fleet'`` + shard indices; ``None``
        for an unsharded gateway's events)."""
        return sorted(self._scopes, key=str)

    def in_flight(self) -> int:
        return len(self._live)

    def budget(self, qos) -> float:
        spec = self.specs.get(qos)
        return spec.miss_budget if spec else self.default_budget

    def counts(self, scope=FLEET) -> dict[str, dict]:
        """Exact cumulative per-class counters for one scope — the
        surface reconciliation gates compare (integer equality)."""
        out = {}
        for qos, st in sorted(self._scopes.get(scope, {}).items(),
                              key=lambda kv: str(kv[0])):
            out[qos] = dict(
                completions=st.completions,
                deadline_misses=st.deadline_misses,
                latency_misses=st.latency_misses,
                untracked=st.untracked,
                attribution=dict(st.attribution),
            )
        return out

    def miss_counts(self, scope=FLEET) -> dict[str, int]:
        """Per-class cumulative deadline misses (zero-count classes
        omitted — the same shape :func:`repro.obs.attrib.span_misses`
        derives offline)."""
        return {
            qos: st.deadline_misses
            for qos, st in self._scopes.get(scope, {}).items()
            if st.deadline_misses
        }

    def attribution(self, scope=FLEET) -> dict[str, dict[str, int]]:
        """Per-class miss-attribution histograms (classes with misses
        only — the shape :func:`repro.obs.attrib.attribute` derives)."""
        return {
            qos: dict(st.attribution)
            for qos, st in self._scopes.get(scope, {}).items()
            if st.deadline_misses
        }

    def burn_rates(self, qos, scope=FLEET) -> dict:
        """Cumulative + per-window burn rates for one class: miss rate
        over the budget (1.0 = burning exactly at budget)."""
        st = self._scopes.get(scope, {}).get(qos)
        budget = self.budget(qos)
        if st is None:
            return dict(cumulative=0.0,
                        windows={str(w): 0.0 for w in self.windows})
        cum = (st.deadline_misses / st.completions / budget
               if st.completions else 0.0)
        return dict(
            cumulative=cum,
            windows={str(w): st.windows[w].rate() / budget
                     for w in self.windows},
        )

    def summary(self, scope=FLEET) -> dict:
        """The full per-class SLO state for one scope, JSON-ready — what
        ``gateway.stats()`` / ``fabric.stats()`` surface as ``slo``."""
        per_class = {}
        for qos, st in sorted(self._scopes.get(scope, {}).items(),
                              key=lambda kv: str(kv[0])):
            spec = self.specs.get(qos)
            per_class[qos] = dict(
                completions=st.completions,
                deadline_misses=st.deadline_misses,
                latency_misses=st.latency_misses,
                untracked=st.untracked,
                miss_rate=(st.deadline_misses / st.completions
                           if st.completions else 0.0),
                budget=self.budget(qos),
                burn=self.burn_rates(qos, scope),
                attribution=dict(st.attribution),
                attribution_shares=attribution_shares(st.attribution),
                spec=spec.to_dict() if spec else None,
            )
        return dict(
            scope=scope,
            windows=list(self.windows),
            last_cycle=self.last_cycle,
            in_flight=len(self._live),
            per_class=per_class,
        )

    # ----------------------------------------------------- reconciliation

    def reconcile(self, spans) -> dict:
        """Integer-exact gate: the monitor's cumulative fleet miss counts
        and attribution histograms must equal the offline span-derived
        ones (:mod:`repro.obs.attrib` over the same event stream).
        ``holds`` tolerates nothing — equality to the integer."""
        from .attrib import attribute, span_misses

        online = self.miss_counts(FLEET)
        offline = span_misses(spans)
        online_att = self.attribution(FLEET)
        offline_att = attribute(spans)
        return dict(
            holds=bool(online == offline and online_att == offline_att),
            online=online,
            offline=offline,
            online_attribution=online_att,
            offline_attribution=offline_att,
        )


def find_monitor(sink, shard=None):
    """Locate an armed :class:`SloMonitor` inside a sink tree (through
    :class:`~repro.obs.events.TeeSink` fan-outs and
    :class:`~repro.obs.events.ShardSink` wrappers), returning
    ``(monitor, shard)`` — ``shard`` is the index the innermost wrapper
    tags events with (``None`` outside a fabric).  ``(None, shard)``
    when no monitor is armed."""
    if isinstance(sink, SloMonitor):
        return sink, shard
    if isinstance(sink, ShardSink):
        return find_monitor(sink.base, sink.shard)
    if isinstance(sink, TeeSink):
        for s in sink.sinks:
            mon, sh = find_monitor(s, shard)
            if mon is not None:
                return mon, sh
    return None, shard
