"""Cycle-exact telemetry over the serving stack.

Everything in this package rides the *modeled* cycle clock
(:mod:`repro.core.cycle_model` relation-(2) cycles) — no wall time
anywhere — so a telemetry stream is exactly reproducible from the same
seed and trace that produced the run.  Four pieces:

:mod:`repro.obs.events`
    The lossless structured event bus: scheduling-significant moments
    (queue-enter, admission, quantum grants, preemption yields, steals,
    forced escapes, swap holds, tile emissions, completions, per-request
    execution attribution) stamped in modeled cycles, emitted by the
    gateway, fabric, round clock and both engines behind a near-zero-cost
    null sink.

:mod:`repro.obs.spans`
    Per-request span assembly from the event stream — each completed
    request decomposed into queued / executing / preempted cycle
    segments (integer-exact: the three sum to its latency by
    construction) — plus exact-order-statistic latency breakdowns and
    ledger reconciliation against :class:`~repro.serve.clock.RoundClock`
    / :class:`~repro.serve.clock.FleetLedger` totals.

:mod:`repro.obs.capture`
    Record a live gateway/fabric's arrivals back into workload trace
    schema v1, so a production-shaped run replays bit-identically in CI.

:mod:`repro.obs.slo`
    Declarative per-class :class:`~repro.obs.slo.SloSpec` objectives and
    the online :class:`~repro.obs.slo.SloMonitor` sink: rolling
    multi-window burn rates on the modeled clock, per shard and
    fleet-aggregated, with cumulative miss counts reconciled
    integer-exactly against the offline span-derived ones.

:mod:`repro.obs.attrib`
    Deadline-miss attribution: classify each miss by its dominant span
    segment (queued / preempted / service / overdraft) — the *why*
    behind a burn rate, surfaced in ``stats()`` and the reports.

:mod:`repro.obs.energy`
    Joule-exact metering: the online :class:`~repro.obs.energy
    .EnergyMeter` sink prices the same event stream in integer
    picojoules (:mod:`repro.core.energy_model` plane-proportional
    rates), with per-request/class/shard/fleet attribution reconciled
    integer-exactly, rolling :class:`~repro.obs.energy.PowerSpec` watt
    caps on the burn-window machinery, and the speculative
    draft/verify op-class split closing like the cycle account.

:mod:`repro.obs.report`
    The ledger report generator: GOPS/W + p99 trend tables from
    ``BENCH_LEDGER.jsonl``, span-breakdown and SLO burn/attribution
    tables from committed ``BENCH_*.json`` artifacts — regenerated
    without re-running benches (``scripts/report.py`` is the CLI).
"""
from .attrib import (  # noqa: F401
    ATTRIB_CLASSES,
    attribute,
    attribution_shares,
    classify_segments,
    span_misses,
)
from .events import (  # noqa: F401
    NULL_SINK,
    Event,
    MetricsSink,
    NullSink,
    RecordingSink,
    ShardSink,
    TeeSink,
    payload_spec,
)
from .energy import (  # noqa: F401
    EnergyLedger,
    EnergyMeter,
    PowerSpec,
    attach_joules,
    find_meter,
)
from .slo import SloMonitor, SloSpec, find_monitor  # noqa: F401
from .spans import Span, assemble, breakdown, reconcile  # noqa: F401
