"""Cycle-exact telemetry over the serving stack.

Everything in this package rides the *modeled* cycle clock
(:mod:`repro.core.cycle_model` relation-(2) cycles) — no wall time
anywhere — so a telemetry stream is exactly reproducible from the same
seed and trace that produced the run.  Four pieces:

:mod:`repro.obs.events`
    The lossless structured event bus: scheduling-significant moments
    (queue-enter, admission, quantum grants, preemption yields, steals,
    forced escapes, swap holds, tile emissions, completions, per-request
    execution attribution) stamped in modeled cycles, emitted by the
    gateway, fabric, round clock and both engines behind a near-zero-cost
    null sink.

:mod:`repro.obs.spans`
    Per-request span assembly from the event stream — each completed
    request decomposed into queued / executing / preempted cycle
    segments (integer-exact: the three sum to its latency by
    construction) — plus exact-order-statistic latency breakdowns and
    ledger reconciliation against :class:`~repro.serve.clock.RoundClock`
    / :class:`~repro.serve.clock.FleetLedger` totals.

:mod:`repro.obs.capture`
    Record a live gateway/fabric's arrivals back into workload trace
    schema v1, so a production-shaped run replays bit-identically in CI.

:mod:`repro.obs.report`
    The ledger report generator: GOPS/W + p99 trend tables from
    ``BENCH_LEDGER.jsonl`` and span-breakdown tables from committed
    ``BENCH_*.json`` artifacts — regenerated without re-running benches
    (``scripts/report.py`` is the CLI).
"""
from .events import (  # noqa: F401
    NULL_SINK,
    Event,
    MetricsSink,
    NullSink,
    RecordingSink,
    ShardSink,
    TeeSink,
    payload_spec,
)
from .spans import Span, assemble, breakdown, reconcile  # noqa: F401
