"""Trace capture: record a live gateway/fabric's arrivals back into
workload trace schema v1.

The :class:`CaptureSink` listens to the event bus for ``submit`` events
(one per arrival, on whichever shard it routed to — fabric streams work
unchanged because shard routing happens *after* arrival, and the stamped
arrival cycle travels with the request).  Each record carries the raw
payload spec the gateway extracted *before* adapter preparation
(:func:`repro.obs.events.payload_spec`), so :meth:`CaptureSink.to_trace`
rebuilds a schema-v1 :class:`~repro.workload.trace.Trace` whose requests
reproduce the observed run:

- arrivals keep their exact modeled-cycle stamps;
- deadlines are stored relative (``deadline - arrival``), the schema's
  convention;
- the trace is marked ``meta['source'] = 'captured'`` so downstream
  tooling can tell captured traces from generated ones;
- replayed with the same seed, the trace's materializers regenerate
  bit-identical payloads: :class:`~repro.workload.trace.Trace` sorts
  requests by arrival (stable — ties keep emission order, which is
  submission order), so request *indices* match the original trace and
  the ``(seed, index)`` materializer keying reproduces the same bytes.

The capture→replay round-trip is property-tested in ``tests/test_obs.py``
and the schema-v1 version guard round-trip in ``tests/test_workload.py``.
"""
from __future__ import annotations

from .events import Event


class CaptureSink:
    """Record arrivals (``submit`` events) for trace reconstruction.

    Tee it with other sinks (:class:`~repro.obs.events.TeeSink`) to
    capture and record/aggregate in one pass.
    """

    enabled = True

    def __init__(self):
        self.records: list[Event] = []

    def emit(self, event: Event) -> None:
        if event.etype == "submit":
            self.records.append(event)

    def __len__(self) -> int:
        return len(self.records)

    def to_trace(self, name: str, *, seed: int, description: str = "",
                 meta: dict | None = None):
        """Build a schema-v1 trace from the captured arrivals.

        ``seed`` keys payload materialization at replay: pass the
        original trace's seed to reproduce the original payload bytes
        (see module docstring), or any seed for a statistically
        equivalent workload.
        """
        from repro.workload.trace import Trace, TraceRequest

        requests = []
        for e in self.records:
            d = e.data
            deadline = d.get("deadline")
            dc = None
            if deadline is not None:
                dc = int(deadline) - e.cycle
                if dc < 1:
                    dc = None  # schema requires >= 1; fall back to default
            requests.append(
                TraceRequest(
                    kind=d["kind"],
                    arrival_cycle=e.cycle,
                    payload=dict(d.get("spec") or {}),
                    qos=d.get("qos") or d["kind"],
                    deadline_cycles=dc,
                )
            )
        m = dict(meta or {})
        m["source"] = "captured"
        m.setdefault("captured_requests", len(requests))
        return Trace(
            name=name,
            seed=int(seed),
            description=description
            or f"captured from a live run ({len(requests)} arrivals)",
            requests=requests,
            meta=m,
        )
