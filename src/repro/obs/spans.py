"""Per-request span assembly + exact latency breakdowns.

A *span* is one request's life on the modeled clock, decomposed into
three integer cycle segments:

``queued``
    arrival → effective admission.  The gateway stamps admission at the
    round-start clock, which for a mid-round arrival can precede the
    arrival itself (admission happens at the next admission pass but is
    stamped at the round's start) — so the effective admission is
    ``max(admitted, arrival)`` and queueing is never negative.

``executing``
    the sum of the request's ``exec`` attribution events — the cycles
    its own micro-steps actually consumed.

``preempted``
    everything else between effective admission and completion: cycles
    the request sat admitted but not running (other classes' quanta,
    its own class's other requests, idle flow to segment boundaries).
    Defined as the residual ``total - queued - executing``, so the three
    segments sum to the request's latency *by construction* — exactness
    is an identity here; what the tests pin is that ``executing`` also
    reconciles with the :class:`~repro.serve.clock.RoundClock` /
    :class:`~repro.serve.clock.FleetLedger` worked totals
    (:func:`reconcile`).  The one case where the residual can go
    negative is a forced-progress overdraft (a single step bigger than
    the round budget clamps its completion stamp to the round end);
    such spans carry ``overdrafted=True``.

Spans are keyed ``(shard, rid)`` — rids are shard-local.  A stolen
request's donor-side ``submit`` is superseded by the thief-side
``import`` event (which carries the original arrival), so its span is
assembled where it completed, with latency measured from the true
arrival; the abandoned donor span is simply never completed and drops
out of the breakdowns.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core import cycle_model as cm


@dataclass
class Span:
    """One request's assembled life on the modeled clock (cycles)."""

    rid: int
    qos: str | None
    kind: str | None
    shard: int | None
    arrival: int | None = None
    admitted: int | None = None
    finished: int | None = None
    deadline: int | None = None
    exec_cycles: int = 0
    n_exec: int = 0
    #: attributed energy in integer picojoules, populated by
    #: :func:`repro.obs.energy.attach_joules` from an armed
    #: :class:`~repro.obs.energy.EnergyMeter` (None: no meter rode the
    #: run — latency-only span)
    pj: int | None = None

    @property
    def joules(self) -> float | None:
        return None if self.pj is None else self.pj * 1e-12

    @property
    def done(self) -> bool:
        return self.arrival is not None and self.finished is not None

    @property
    def missed_deadline(self) -> bool:
        """Completed past the absolute deadline its submit/import event
        carried (the offline truth :mod:`repro.obs.attrib` and the online
        :class:`~repro.obs.slo.SloMonitor` are reconciled on)."""
        return (
            self.done
            and self.deadline is not None
            and self.finished > self.deadline
        )

    @property
    def admitted_eff(self) -> int | None:
        """Effective admission: never before the arrival (see module
        docstring on round-start admission stamps)."""
        if self.arrival is None:
            return self.admitted
        if self.admitted is None:
            return None
        return max(self.admitted, self.arrival)

    @property
    def total(self) -> int | None:
        if not self.done:
            return None
        return self.finished - self.arrival

    @property
    def queued(self) -> int | None:
        if self.arrival is None or self.admitted_eff is None:
            return None
        return self.admitted_eff - self.arrival

    @property
    def executing(self) -> int:
        return self.exec_cycles

    @property
    def preempted(self) -> int | None:
        """Residual: total - queued - executing (may be negative only on
        forced overdrafts — see module docstring)."""
        if not self.done or self.queued is None:
            return None
        return self.total - self.queued - self.exec_cycles

    @property
    def overdrafted(self) -> bool:
        p = self.preempted
        return p is not None and p < 0


def _key(e) -> tuple:
    return (e.data.get("shard"), e.data["rid"])


def assemble(events) -> list[Span]:
    """Fold an event stream into per-request spans.

    Consumes ``submit`` / ``import`` / ``admit`` / ``exec`` / ``complete``
    events (others pass through untouched).  Returns every span seen —
    completed or not; breakdowns filter on :attr:`Span.done`.
    """
    spans: dict[tuple, Span] = {}
    for e in events:
        et = e.etype
        if et not in ("submit", "import", "admit", "exec", "complete"):
            continue
        d = e.data
        k = _key(e)
        sp = spans.get(k)
        if sp is None:
            sp = spans[k] = Span(
                rid=int(d["rid"]), qos=d.get("qos"), kind=d.get("kind"),
                shard=d.get("shard"),
            )
        if et in ("submit", "import"):
            # import re-keys a stolen request: its arrival travels with it
            sp.arrival = int(d.get("arrival", e.cycle))
            if d.get("deadline") is not None:
                sp.deadline = int(d["deadline"])
            sp.qos = d.get("qos", sp.qos)
            sp.kind = d.get("kind", sp.kind)
        elif et == "admit":
            sp.admitted = e.cycle
            sp.qos = d.get("qos", sp.qos)
            sp.kind = d.get("kind", sp.kind)
        elif et == "exec":
            sp.exec_cycles += int(d["cycles"])
            sp.n_exec += 1
            if sp.qos is None:
                sp.qos = d.get("qos")
        else:  # complete
            sp.finished = e.cycle
            sp.qos = d.get("qos", sp.qos)
            sp.kind = d.get("kind", sp.kind)
    return list(spans.values())


def _ms(cycles: int) -> float:
    return cycles / cm.FREQ_HZ * 1e3


def breakdown(spans, pcts=(50, 99)) -> dict:
    """Exact-order-statistic latency breakdowns, per class.

    For each class and percentile ``p``, the breakdown names the *actual
    request* at that order statistic (the same
    :func:`~repro.serve.clock.exact_percentile` semantics ``stats()``
    uses) and decomposes its latency into queued / executing / preempted
    cycles — so "the p99 is 11 ms" comes with "of which 7 ms was
    queueing behind the batch class's quantum".
    """
    from repro.serve.clock import exact_percentile

    done = [s for s in spans if s.done and s.queued is not None]
    per_class: dict[str, dict] = {}
    for s in done:
        per_class.setdefault(s.qos, []).append(s)
    out: dict[str, dict] = {}
    for qos in sorted(per_class, key=str):
        group = sorted(per_class[qos], key=lambda s: s.total)
        totals = [s.total for s in group]
        entry: dict = dict(
            n=len(group),
            queued_cycles=sum(s.queued for s in group),
            exec_cycles=sum(s.exec_cycles for s in group),
            preempted_cycles=sum(s.preempted for s in group),
            overdrafted=sum(1 for s in group if s.overdrafted),
        )
        for p in pcts:
            t = exact_percentile(totals, p)
            s = group[totals.index(t)]  # the order-statistic request
            entry[f"p{p}"] = dict(
                rid=s.rid,
                shard=s.shard,
                total_cycles=s.total,
                queued_cycles=s.queued,
                exec_cycles=s.exec_cycles,
                preempted_cycles=s.preempted,
                total_ms=_ms(s.total),
                queued_ms=_ms(s.queued),
                exec_ms=_ms(s.exec_cycles),
                preempted_ms=_ms(s.preempted),
            )
        out[qos] = entry
    return out


def reconcile(events, clocks, ledger=None) -> dict:
    """Integer-exact reconciliation of the event stream's execution
    attribution against the authoritative cycle ledgers.

    Sums every ``exec`` event's cycles (all requests, finished or not)
    per shard and compares with each shard's
    :attr:`~repro.serve.clock.RoundClock.worked_total`; with ``ledger``
    (a :class:`~repro.serve.clock.FleetLedger`) also against the
    incrementally-accumulated per-shard worked totals.  ``holds`` is the
    gate — equality to the integer, no tolerance.
    """
    clocks = list(clocks)
    per_shard = [0] * len(clocks)
    for e in events:
        if e.etype != "exec":
            continue
        s = e.data.get("shard")
        per_shard[0 if s is None else int(s)] += int(e.data["cycles"])
    worked = [c.worked_total for c in clocks]
    holds = per_shard == worked
    out = dict(
        holds=bool(holds),
        exec_cycles=per_shard,
        worked_total=worked,
        total_exec=sum(per_shard),
        total_worked=sum(worked),
    )
    if ledger is not None:
        out["ledger_worked"] = list(ledger.worked)
        out["holds"] = bool(holds and per_shard == list(ledger.worked))
    return out
