"""Joule-exact energy metering on the event bus.

:class:`EnergyMeter` is an event-bus sink (composable exactly like
:class:`~repro.obs.slo.SloMonitor`: arm it directly, teed, or wrapped in
:class:`~repro.obs.events.ShardSink`\\ s by a fabric) that streams the
``exec`` / ``round`` / ``draft`` / ``verify`` / ``accept`` / ``complete``
events a gateway or fabric already emits into **integer-picojoule**
energy attribution (:mod:`repro.core.energy_model` rates):

* every ``exec`` quantum is *active* energy — cycles x the per-kind
  pJ/cycle rate (static + plane-proportional dynamic switching for the
  kind's plane schedule) — attributed to its request, QoS class, shard
  and the fleet;
* every ``round`` boundary charges *idle* energy — static pJ for each
  elapsed-but-unworked cycle of that round (the round events carry
  ``worked``; elapsed is the distance between consecutive round stamps);
* speculative ``draft`` / ``verify`` / ``accept`` events feed a per-op-
  class account: draft cycles priced at the truncated draft-plane rate,
  verify cycles at the full-digit rate, with the wasted/useful split
  closing integer-exactly the way
  :func:`~repro.core.cycle_model.lm_spec_step_cycles` closes cycles
  (the per-slot ``accept`` cycle fields re-derive the round-level
  draft/verify totals — two independent event paths, gated equal).
  Each ``accept`` also *rebates* the request's exec charge from the
  full-digit rate down to the draft rate for its draft cycles, so the
  headline attribution prices op classes at their true plane widths.

The :class:`EnergyLedger` inside the meter carries the reconciliation
invariants, all in ``int`` pJ so ``reconcile()`` gates equality to the
picojoule, never within-epsilon:

1. per-shard ``active + idle`` sums equal the independently-accumulated
   fleet totals (the :class:`~repro.serve.clock.FleetLedger` discipline,
   applied to joules);
2. per-request attributed pJ (completed + in-flight) sum to ledger
   active energy, per shard and fleet;
3. per-class pJ sums equal active energy;
4. the speculative draft/verify account closes: slot-level cycles equal
   round-level cycles, and ``useful_pj + wasted_pj == draft_pj +
   verify_pj``.

:class:`PowerSpec` adds power-*cap* observability on the same bucketed-
ring machinery as the SLO burn windows: a per-shard watt budget over a
rolling cycle window; charges that push the rolling average above budget
count violations (edge-triggered), optionally emitting ``power-cap``
events into a side sink.

Arm the meter before traffic (``gateway.set_sink(meter)`` or tee it) —
rounds observed from an unseen prefix are counted ``untracked_rounds``
and charge idle only for their reported ``spent`` span, mirroring the
SloMonitor's untracked-completion discipline.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core import energy_model as em
from repro.core.cycle_model import FREQ_HZ

from .events import Event, ShardSink, TeeSink
from .slo import FLEET


@dataclass(frozen=True)
class PowerSpec:
    """A per-shard power cap: rolling average power over ``window``
    modeled cycles must stay within ``watts``.

    Args:
      watts: the budget in watts.
      window: rolling window length in modeled cycles.
      buckets: ring granularity (watts resolution <= 1 bucket).
    """

    watts: float
    window: int = 3_200_000
    buckets: int = 32

    def __post_init__(self):
        if self.watts <= 0:
            raise ValueError(f"watts {self.watts} <= 0")
        if self.window <= 0:
            raise ValueError(f"window {self.window} <= 0")
        if self.buckets < 1:
            raise ValueError(f"buckets {self.buckets} < 1")

    def to_dict(self) -> dict:
        return dict(watts=self.watts, window=self.window,
                    buckets=self.buckets)


class _PowerWindow:
    """Bucketed pJ ring over one rolling window of the modeled clock —
    the :class:`~repro.obs.slo._Window` burn-rate shape, accumulating
    picojoules instead of miss counts."""

    __slots__ = ("window", "buckets", "width", "pj", "_cur")

    def __init__(self, window: int, buckets: int):
        self.window = int(window)
        self.buckets = int(buckets)
        self.width = max(self.window // self.buckets, 1)
        self.pj = [0] * self.buckets
        self._cur = None

    def record(self, cycle: int, pj: int) -> None:
        b = cycle // self.width
        if self._cur is None:
            self._cur = b
        elif b > self._cur:
            for k in range(self._cur + 1,
                           min(b, self._cur + self.buckets) + 1):
                self.pj[k % self.buckets] = 0
            self._cur = b
        idx = (b if self._cur - b < self.buckets else
               self._cur - self.buckets + 1) % self.buckets
        self.pj[idx] += pj

    def watts(self) -> float:
        """Rolling average power over the window (0.0 when empty)."""
        return sum(self.pj) * FREQ_HZ / self.window * 1e-12


class _SpecAccount:
    """Per-scope speculative op-class energy account (module docstring
    invariant 4)."""

    __slots__ = ("draft_cycles", "verify_cycles", "draft_pj", "verify_pj",
                 "slot_draft_cycles", "slot_verify_cycles", "slot_pj",
                 "wasted_pj", "rounds", "drafted", "accepted")

    def __init__(self):
        self.draft_cycles = 0
        self.verify_cycles = 0
        self.draft_pj = 0
        self.verify_pj = 0
        # re-derived from per-slot accept events (independent path)
        self.slot_draft_cycles = 0
        self.slot_verify_cycles = 0
        self.slot_pj = 0
        self.wasted_pj = 0
        self.rounds = 0
        self.drafted = 0
        self.accepted = 0


class _ScopeState:
    """One scope's (shard / ``None`` / fleet) integer energy ledger
    entry plus the rolling power ring."""

    __slots__ = ("active_pj", "idle_pj", "worked_cycles", "idle_cycles",
                 "rounds", "untracked_rounds", "completions", "class_pj",
                 "class_cycles", "request_pj", "spec", "ring",
                 "peak_watts", "violations", "over_budget_charges",
                 "_over")

    def __init__(self, window: int, buckets: int):
        self.active_pj = 0
        self.idle_pj = 0
        self.worked_cycles = 0
        self.idle_cycles = 0
        self.rounds = 0
        self.untracked_rounds = 0
        self.completions = 0
        self.class_pj: dict = {}
        self.class_cycles: dict = {}
        # completed per-request energies per class (exact percentiles)
        self.request_pj: dict = {}
        self.spec = _SpecAccount()
        self.ring = _PowerWindow(window, buckets)
        self.peak_watts = 0.0
        self.violations = 0
        self.over_budget_charges = 0
        self._over = False


class EnergyLedger:
    """The meter's integer pJ ledger: per-scope states plus the fleet
    totals accumulated *independently* on every charge — additivity is a
    real two-path check, exactly like
    :meth:`~repro.serve.clock.FleetLedger.additivity`."""

    def __init__(self, window: int, buckets: int):
        self._window = int(window)
        self._buckets = int(buckets)
        self._scopes: dict = {}

    def state(self, scope) -> _ScopeState:
        st = self._scopes.get(scope)
        if st is None:
            st = self._scopes[scope] = _ScopeState(
                self._window, self._buckets
            )
        return st

    def scopes(self) -> list:
        return sorted(self._scopes, key=str)

    def shard_scopes(self) -> list:
        return [s for s in self.scopes() if s != FLEET]

    def additivity(self) -> dict:
        """Invariant 1: per-shard active/idle sums equal the fleet
        totals, to the picojoule."""
        fleet = self.state(FLEET)
        shard_active = sum(
            self.state(s).active_pj for s in self.shard_scopes()
        )
        shard_idle = sum(
            self.state(s).idle_pj for s in self.shard_scopes()
        )
        return dict(
            holds=bool(shard_active == fleet.active_pj
                       and shard_idle == fleet.idle_pj),
            fleet_active_pj=fleet.active_pj,
            shard_active_pj=shard_active,
            fleet_idle_pj=fleet.idle_pj,
            shard_idle_pj=shard_idle,
        )


class EnergyMeter:
    """Event-bus sink computing online joule attribution (module
    docstring).

    Args:
      rates: pJ per worked cycle by adapter kind (static + dynamic for
        the kind's plane schedule —
        :func:`repro.core.energy_model.active_rate_pj`).  Kinds not
        listed charge the full-8 rate: observation must not require
        declaration.
      draft_rates: pJ per cycle for speculative *draft* work by kind
        (the truncated draft-plane datapath); defaults to the kind's
        full rate, i.e. no draft discount unless the plan declares one.
      static_pj: static pJ per un-worked clock cycle.
      power: a :class:`PowerSpec` (or mapping shard -> PowerSpec) to
        gate rolling per-shard power against; ``None`` still tracks
        rolling watts over the default window, with no cap.
      sink: optional side sink receiving edge-triggered ``power-cap``
        events.
    """

    enabled = True

    def __init__(self, rates=None, *, draft_rates=None,
                 static_pj: int = em.PJ_STATIC_CYCLE,
                 power: PowerSpec | dict | None = None, sink=None):
        self.rates = {k: int(v) for k, v in (rates or {}).items()}
        self.draft_rates = {
            k: int(v) for k, v in (draft_rates or {}).items()
        }
        self.default_rate = em.active_rate_pj()
        self.static_pj = int(static_pj)
        if self.static_pj < 0:
            raise ValueError(f"static_pj {static_pj} < 0")
        if isinstance(power, PowerSpec) or power is None:
            self._power_default = power
            self._power_by_shard = {}
        else:
            self._power_default = None
            self._power_by_shard = dict(power)
        spec = self._power_default or next(
            iter(self._power_by_shard.values()), None
        )
        window = spec.window if spec else 3_200_000
        buckets = spec.buckets if spec else 32
        self.ledger = EnergyLedger(window, buckets)
        self._sink = sink
        self._live: dict[tuple, int] = {}
        self.completed_pj: dict = {}
        self._round_end: dict = {}
        self.last_cycle = 0
        # bounded log of cap-violation edges (newest kept)
        self.cap_events: list[dict] = []

    # ------------------------------------------------------------- rates

    def rate(self, kind) -> int:
        return self.rates.get(kind, self.default_rate)

    def draft_rate(self, kind) -> int:
        return self.draft_rates.get(kind, self.rate(kind))

    def power_spec(self, shard) -> PowerSpec | None:
        return self._power_by_shard.get(shard, self._power_default)

    # ------------------------------------------------------------- sink

    def emit(self, event) -> None:
        et = event.etype
        if et not in ("exec", "round", "complete", "draft", "verify",
                      "accept"):
            return
        if event.cycle > self.last_cycle:
            self.last_cycle = event.cycle
        d = event.data
        shard = d.get("shard")
        if et == "exec":
            self._exec(shard, event.cycle, d)
        elif et == "round":
            self._round(shard, event.cycle, d)
        elif et == "complete":
            self._complete(shard, d)
        elif et == "draft":
            self._draft(shard, d)
        elif et == "verify":
            self._verify(shard, d)
        else:  # accept
            self._accept(shard, event.cycle, d)

    def _exec(self, shard, cycle, d) -> None:
        cycles = int(d["cycles"])
        pj = cycles * self.rate(d.get("kind"))
        key = (shard, d["rid"])
        self._live[key] = self._live.get(key, 0) + pj
        qos = d.get("qos")
        for scope in (shard, FLEET):
            st = self.ledger.state(scope)
            st.active_pj += pj
            st.worked_cycles += cycles
            st.class_pj[qos] = st.class_pj.get(qos, 0) + pj
            st.class_cycles[qos] = st.class_cycles.get(qos, 0) + cycles
        self._charge_ring(shard, cycle, pj)

    def _round(self, shard, cycle, d) -> None:
        worked = int(d["worked"])
        prev = self._round_end.get(shard)
        untracked = False
        if prev is None:
            if int(d.get("round", 0)) == 0:
                # armed from the first round: the clock started at 0
                prev = 0
            else:
                # armed mid-run: the round's true span is unknown —
                # charge idle for the reported spent span only
                prev = cycle - int(d.get("spent", worked))
                untracked = True
        idle_c = max((cycle - prev) - worked, 0)
        pj = idle_c * self.static_pj
        for scope in (shard, FLEET):
            st = self.ledger.state(scope)
            st.idle_pj += pj
            st.idle_cycles += idle_c
            st.rounds += 1
            if untracked:
                st.untracked_rounds += 1
        self._round_end[shard] = cycle
        self._charge_ring(shard, cycle, pj)

    def _complete(self, shard, d) -> None:
        key = (shard, d["rid"])
        pj = self._live.pop(key, 0)
        qos = d.get("qos")
        for scope in (shard, FLEET):
            st = self.ledger.state(scope)
            st.completions += 1
            st.request_pj.setdefault(qos, []).append(pj)
        # keyed like spans: rids are only unique within a shard
        self.completed_pj[key] = pj

    def _draft(self, shard, d) -> None:
        cycles = int(d["cycles"])
        pj = cycles * self.draft_rate(d.get("kind"))
        for scope in (shard, FLEET):
            sp = self.ledger.state(scope).spec
            sp.draft_cycles += cycles
            sp.draft_pj += pj
            sp.rounds += 1

    def _verify(self, shard, d) -> None:
        cycles = int(d["cycles"])
        pj = cycles * self.rate(d.get("kind"))
        for scope in (shard, FLEET):
            sp = self.ledger.state(scope).spec
            sp.verify_cycles += cycles
            sp.verify_pj += pj

    def _accept(self, shard, cycle, d) -> None:
        dr = self.draft_rate(d.get("kind"))
        fr = self.rate(d.get("kind"))
        for scope in (shard, FLEET):
            sp = self.ledger.state(scope).spec
            sp.drafted += int(d.get("k", 0))
            sp.accepted += int(d.get("accepted", 0))
            # instrumented adapters carry the per-slot cycle split — the
            # independent path invariant 4 re-derives the round-level
            # totals from, with wasted work priced per op class
            if "draft_cycles" in d:
                dc, vc = int(d["draft_cycles"]), int(d["verify_cycles"])
                sp.slot_draft_cycles += dc
                sp.slot_verify_cycles += vc
                sp.slot_pj += dc * dr + vc * fr
                sp.wasted_pj += (int(d["wasted_draft_cycles"]) * dr
                                 + int(d["wasted_verify_cycles"]) * fr)
        # The slot's exec quantum was charged entirely at the full-digit
        # rate; its draft steps actually ran on the truncated draft-plane
        # datapath.  Rebate the difference against the request's live
        # charge (and every scope it flowed into), so the *headline*
        # attribution — not just the spec account — prices op classes at
        # their own plane widths.  The rebate lands only while the exec
        # charge is live, so every invariant keeps closing exactly.
        if dr < fr and "draft_cycles" in d and "rid" in d:
            key = (shard, d["rid"])
            if key in self._live:
                rebate = int(d["draft_cycles"]) * (fr - dr)
                self._live[key] -= rebate
                qos = d.get("qos")
                for scope in (shard, FLEET):
                    st = self.ledger.state(scope)
                    st.active_pj -= rebate
                    st.class_pj[qos] = st.class_pj.get(qos, 0) - rebate
                self._charge_ring(shard, cycle, -rebate)

    def _charge_ring(self, shard, cycle, pj: int) -> None:
        st = self.ledger.state(shard)
        st.ring.record(cycle, pj)
        watts = st.ring.watts()
        if watts > st.peak_watts:
            st.peak_watts = watts
        spec = self.power_spec(shard)
        if spec is None:
            st._over = False
            return
        over = watts > spec.watts
        if over:
            st.over_budget_charges += 1
            if not st._over:
                st.violations += 1
                rec = dict(cycle=cycle, shard=shard,
                           watts=watts, budget=spec.watts)
                self.cap_events.append(rec)
                del self.cap_events[:-64]
                if self._sink is not None:
                    self._sink.emit(Event(cycle, "power-cap", dict(rec)))
        st._over = over

    # ---------------------------------------------------------- queries

    def in_flight(self) -> int:
        return len(self._live)

    def spec_summary(self, scope=FLEET) -> dict | None:
        """The speculative op-class energy split for one scope, wasted /
        useful closed per invariant 4 (``None`` when no spec traffic)."""
        sp = self.ledger.state(scope).spec
        if not sp.rounds:
            return None
        total_pj = sp.draft_pj + sp.verify_pj
        return dict(
            rounds=sp.rounds,
            draft_cycles=sp.draft_cycles,
            verify_cycles=sp.verify_cycles,
            draft_pj=sp.draft_pj,
            verify_pj=sp.verify_pj,
            total_pj=total_pj,
            wasted_pj=sp.wasted_pj,
            useful_pj=total_pj - sp.wasted_pj,
            drafted=sp.drafted,
            accepted=sp.accepted,
            accept_rate=(sp.accepted / sp.drafted if sp.drafted
                         else None),
        )

    def summary(self, scope=FLEET) -> dict:
        """The full energy state for one scope, JSON-ready — what
        ``gateway.stats()`` / ``fabric.stats()`` surface as
        ``'energy'``."""
        from repro.serve.clock import exact_percentile

        st = self.ledger.state(scope)
        total_pj = st.active_pj + st.idle_pj
        per_class = {}
        for qos in sorted(set(st.class_pj) | set(st.request_pj),
                          key=str):
            reqs = st.request_pj.get(qos, [])
            n = len(reqs)
            p50 = exact_percentile(reqs, 50)
            p99 = exact_percentile(reqs, 99)
            per_class[qos] = dict(
                pj=st.class_pj.get(qos, 0),
                mj=em.pj_to_mj(st.class_pj.get(qos, 0)),
                cycles=st.class_cycles.get(qos, 0),
                requests=n,
                mean_request_pj=(sum(reqs) / n if n else None),
                p50_request_pj=p50,
                p99_request_pj=p99,
            )
        # the rolling power rings are charged per shard scope; the fleet
        # view aggregates them (watts add across lock-step shards)
        if scope == FLEET:
            shards = [self.ledger.state(s)
                      for s in self.ledger.shard_scopes()]
            spec = self._power_default
            power = dict(
                watts=sum(s.ring.watts() for s in shards),
                peak_watts=sum(s.peak_watts for s in shards),
                window=st.ring.window,
                budget_watts=(spec.watts * len(shards)
                              if spec and shards else None),
                violations=sum(s.violations for s in shards),
                over_budget_charges=sum(
                    s.over_budget_charges for s in shards
                ),
            )
        else:
            spec = self.power_spec(scope)
            power = dict(
                watts=st.ring.watts(),
                peak_watts=st.peak_watts,
                window=st.ring.window,
                budget_watts=spec.watts if spec else None,
                violations=st.violations,
                over_budget_charges=st.over_budget_charges,
            )
        return dict(
            scope=scope,
            last_cycle=self.last_cycle,
            static_pj_per_cycle=self.static_pj,
            rates={str(k): v for k, v in sorted(self.rates.items(),
                                                key=lambda kv: str(kv))},
            active_pj=st.active_pj,
            idle_pj=st.idle_pj,
            total_pj=total_pj,
            active_mj=em.pj_to_mj(st.active_pj),
            idle_mj=em.pj_to_mj(st.idle_pj),
            total_mj=em.pj_to_mj(total_pj),
            worked_cycles=st.worked_cycles,
            idle_cycles=st.idle_cycles,
            rounds=st.rounds,
            untracked_rounds=st.untracked_rounds,
            completions=st.completions,
            in_flight=len(self._live),
            per_class=per_class,
            spec=self.spec_summary(scope),
            power=power,
        )

    # ----------------------------------------------------- reconciliation

    def reconcile(self, spans=None) -> dict:
        """The integer-exact ledger gates (module docstring invariants).
        ``holds`` tolerates nothing — equality to the picojoule.  With
        ``spans`` (offline-assembled from an independent
        ``RecordingSink`` stream), additionally checks that the sum of
        per-request energies over completed spans equals the online
        completed total."""
        led = self.ledger
        additivity = led.additivity()
        checks = dict(additivity=additivity["holds"])

        # invariant 2+3: per-request and per-class sums == active, per
        # scope (live pJ keyed by shard folds into its scope's sum)
        live_by_scope: dict = {}
        for (shard, _rid), pj in self._live.items():
            live_by_scope[shard] = live_by_scope.get(shard, 0) + pj
        attribution = {}
        req_ok = cls_ok = True
        for scope in led.scopes():
            st = led.state(scope)
            live = (sum(live_by_scope.values()) if scope == FLEET
                    else live_by_scope.get(scope, 0))
            completed = sum(
                sum(v) for v in st.request_pj.values()
            )
            class_sum = sum(st.class_pj.values())
            ok_r = completed + live == st.active_pj
            ok_c = class_sum == st.active_pj
            req_ok &= ok_r
            cls_ok &= ok_c
            attribution[str(scope)] = dict(
                active_pj=st.active_pj,
                completed_pj=completed,
                live_pj=live,
                class_pj=class_sum,
                requests_hold=ok_r,
                classes_hold=ok_c,
            )
        checks["requests"] = req_ok
        checks["classes"] = cls_ok

        # invariant 4: the spec account closes — slot-level accept
        # fields re-derive the round-level draft/verify totals, and the
        # useful/wasted pJ split sums back exactly
        spec_ok = True
        spec_out = {}
        for scope in led.scopes():
            sp = led.state(scope).spec
            if not sp.rounds:
                continue
            s = self.spec_summary(scope)
            cycles_close = (
                sp.slot_draft_cycles == sp.draft_cycles
                and sp.slot_verify_cycles == sp.verify_cycles
            )
            pj_close = (
                sp.slot_pj == s["total_pj"]
                and s["useful_pj"] + s["wasted_pj"] == s["total_pj"]
                and 0 <= s["wasted_pj"] <= s["total_pj"]
            )
            spec_ok &= cycles_close and pj_close
            spec_out[str(scope)] = dict(
                cycles_close=cycles_close, pj_close=pj_close,
                slot_draft_cycles=sp.slot_draft_cycles,
                draft_cycles=sp.draft_cycles,
                slot_verify_cycles=sp.slot_verify_cycles,
                verify_cycles=sp.verify_cycles,
                slot_pj=sp.slot_pj,
                round_pj=s["total_pj"],
            )
        checks["spec"] = spec_ok

        out = dict(
            additivity=additivity,
            attribution=attribution,
            spec=spec_out,
        )
        if spans is not None:
            fleet = led.state(FLEET)
            online = sum(sum(v) for v in fleet.request_pj.values())
            offline = sum(
                self.completed_pj.get((sp.shard, sp.rid), 0)
                for sp in spans if sp.done
            )
            checks["spans"] = online == offline
            out["spans"] = dict(online_pj=online, offline_pj=offline)
        out["checks"] = checks
        out["holds"] = all(checks.values())
        return out


def attach_joules(spans, meter: EnergyMeter):
    """Grow assembled spans' ``pj`` field from the meter's per-request
    attribution (completed requests; in-flight spans get their partial
    charge).  Returns the same list."""
    for sp in spans:
        key = (sp.shard, sp.rid)
        sp.pj = (meter.completed_pj.get(key, 0) if sp.done
                 else meter._live.get(key, 0))
    return spans


def find_meter(sink, shard=None):
    """Locate an armed :class:`EnergyMeter` inside a sink tree (through
    :class:`~repro.obs.events.TeeSink` fan-outs and
    :class:`~repro.obs.events.ShardSink` wrappers), returning
    ``(meter, shard)`` — the :func:`~repro.obs.slo.find_monitor`
    contract."""
    if isinstance(sink, EnergyMeter):
        return sink, shard
    if isinstance(sink, ShardSink):
        return find_meter(sink.base, sink.shard)
    if isinstance(sink, TeeSink):
        for s in sink.sinks:
            m, sh = find_meter(s, shard)
            if m is not None:
                return m, sh
    return None, shard
