"""Deadline-miss attribution from span segments.

A deadline miss is a *symptom*; the span's cycle segments say *why*.
Every completed span decomposes into integer queued / executing /
preempted cycles (:mod:`repro.obs.spans` — the three sum to latency by
construction), so each miss classifies by its **dominant segment**:

``queued``
    The request mostly waited for an engine slot — admission capacity is
    short.  More shards (or a better router) is the fix.
``preempted``
    The request mostly sat admitted-but-not-running — other classes'
    quanta, its own class's backlog.  Shares/policy is the fix.
``service``
    The request's own execution dominates — the work itself is too slow
    for the deadline.  A cheaper plane schedule (tuned plan) is the fix.
``overdraft``
    A forced-progress overdraft clamped the completion stamp (negative
    residual; ``Span.overdrafted``) — the single-step cost exceeds the
    round budget, so no amount of fleet fixes it.

Ties resolve ``queued > preempted > service`` (deterministic: the
upstream cause wins), so the classification is a pure integer function
of the span — the online :class:`~repro.obs.slo.SloMonitor` applies the
same function to its streaming segments and the two histograms are
gated *equal*, not approximately equal.
"""
from __future__ import annotations

#: Attribution classes, fixed order (histograms serialize in this order).
ATTRIB_CLASSES = ("queued", "preempted", "service", "overdraft")


def classify_segments(queued: int, executing: int, preempted: int) -> str:
    """Dominant-segment class from integer cycle segments (docstring
    order; ties resolve queued > preempted > service)."""
    if preempted < 0:
        return "overdraft"
    if queued >= preempted and queued >= executing:
        return "queued"
    if preempted >= executing:
        return "preempted"
    return "service"


def classify(span) -> str:
    """Classify one completed :class:`~repro.obs.spans.Span`."""
    if not span.done or span.queued is None:
        raise ValueError(
            f"cannot classify an incomplete span (rid={span.rid}, "
            f"done={span.done})"
        )
    return classify_segments(span.queued, span.executing, span.preempted)


def _missed(span) -> bool:
    return (
        span.done
        and span.deadline is not None
        and span.finished > span.deadline
    )


def span_misses(spans) -> dict[str, int]:
    """Per-class deadline-miss counts from assembled spans — the offline
    truth the online :class:`~repro.obs.slo.SloMonitor` counts are gated
    integer-exactly against."""
    out: dict[str, int] = {}
    for s in spans:
        if _missed(s):
            out[s.qos] = out.get(s.qos, 0) + 1
    return out


def attribute(spans) -> dict[str, dict[str, int]]:
    """Per-class attribution histogram over the spans that missed their
    deadline: ``{qos: {queued: n, preempted: n, service: n,
    overdraft: n}}`` (every class key present, zero-filled)."""
    out: dict[str, dict[str, int]] = {}
    for s in spans:
        if not _missed(s):
            continue
        hist = out.setdefault(s.qos, {c: 0 for c in ATTRIB_CLASSES})
        if s.queued is None:
            # a miss with no admit event cannot be decomposed — impossible
            # for gateway-emitted streams (completion implies admission)
            raise ValueError(
                f"missed span rid={s.rid} has no admission record"
            )
        hist[classify(s)] += 1
    return out


def attribution_shares(hist: dict[str, int]) -> dict[str, float]:
    """One class's histogram as fractional shares (all zeros when the
    class has no misses — a share of nothing is zero, not NaN)."""
    total = sum(hist.get(c, 0) for c in ATTRIB_CLASSES)
    if total <= 0:
        return {c: 0.0 for c in ATTRIB_CLASSES}
    return {c: hist.get(c, 0) / total for c in ATTRIB_CLASSES}
