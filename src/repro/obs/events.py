"""Lossless structured event bus on the modeled cycle clock.

Every scheduling-significant moment in the serving stack emits one
:class:`Event` — a ``(cycle, etype, data)`` triple — into a *sink*.  The
default sink is :data:`NULL_SINK`, whose ``emit`` is a no-op and whose
``enabled`` flag lets hot paths skip even building the event record, so
an uninstrumented run pays one attribute check per potential emission
and nothing else (the "no behavior change from observing" property the
determinism tests pin).

Event taxonomy (the ``etype`` vocabulary; emitters in parentheses):

========== ==================================================== ==========
etype      meaning                                              emitter
========== ==================================================== ==========
submit     request enters the queue (arrival-stamped; carries   gateway
           ``rid/kind/qos/est/deadline`` and the raw payload
           ``spec`` the capture sink rebuilds traces from)
admit      request granted an engine slot                       gateway
grant      a class accrued quantum (round start or pro-rated    gateway
           mid-round)
preempt    a class yielded with work pending and budget left    gateway
           (the preemption point: next step unaffordable or a
           segment boundary)
forced     forced-progress overdraft step (liveness escape)     gateway
swap-hold  plan hot-swap queued; admission to the kind held     gateway
swap-inst  pending plan installed at a round boundary           gateway
exec       execution attribution: ``cycles`` of micro-step      gateway
           work charged to one request (offset-stamped —        (from
           summing ``exec`` cycles reconciles integer-exactly   adapter
           with ``RoundClock.worked_total``)                    exec logs)
tile       one tile emission passed through the gateway         gateway
draft      speculative round drafted ``k`` tokens per slot at   gateway
           the truncated-plane schedule (offset-stamped at the  (from
           end of the draft chain)                              obs logs)
verify     speculative round verified ``k+1`` known tokens      gateway
           through the full-digit schedule (layer-pipelined)
accept     one slot's acceptance outcome: ``accepted`` of       gateway
           ``k`` drafts survived, ``emitted`` tokens left the
           round (always >= 1 — the verifier's correction)
rollback   one slot rewound past its first draft mismatch       gateway
           (``rejected`` draft positions discarded; their
           cycles stay charged — wasted speculation is time)
complete   request finished (offset-exact stamp; ``latency``    gateway
           in cycles)
round      round closed (``spent``/``worked`` intra-round       RoundClock
           ledger)
route      fabric routed an arrival to a shard                  fabric
steal      work stealing moved queued requests                  fabric
export     donor side of a steal, per request                   gateway
import     thief side of a steal, per request (re-keyed rid;    gateway
           original ``arrival`` travels with it — span
           assembly treats it as the request's queue-enter)
lm-prefill / lm-step / seg-batch
           engine-local micro-step records.  Engines do not     engines
           know the absolute modeled clock, so these are
           **sequence-stamped** (a per-engine monotonic
           counter in the ``cycle`` field), kept out of span
           assembly.
========== ==================================================== ==========

Events from fabric shards pass through a :class:`ShardSink`, which adds
``shard`` to every record — per-shard streams interleave into one bus
without ambiguity (rids are shard-local).

Determinism: the whole stack is seeded and wall-time free, so the
canonical serialization (:meth:`Event.line` — sorted-key compact JSON)
of a run's stream is *byte-identical* across repeats.  Tests gate on
:meth:`RecordingSink.canonical_bytes`.
"""
from __future__ import annotations

import json


class Event:
    """One cycle-stamped telemetry record."""

    __slots__ = ("cycle", "etype", "data")

    def __init__(self, cycle: int, etype: str, data: dict | None = None):
        self.cycle = int(cycle)
        self.etype = str(etype)
        self.data = {} if data is None else data

    def to_obj(self):
        """JSON-ready ``[cycle, etype, data]`` triple."""
        return [self.cycle, self.etype, self.data]

    def line(self) -> str:
        """Canonical serialization: compact JSON, sorted keys — the unit
        of the byte-identical determinism guarantee."""
        return json.dumps(
            self.to_obj(), sort_keys=True, separators=(",", ":")
        )

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Event({self.cycle}, {self.etype!r}, {self.data!r})"

    def __eq__(self, other) -> bool:
        return (
            isinstance(other, Event)
            and self.cycle == other.cycle
            and self.etype == other.etype
            and self.data == other.data
        )


class NullSink:
    """The do-nothing sink. ``enabled`` is False so instrumented hot
    paths skip building event records entirely."""

    enabled = False

    def emit(self, event: Event) -> None:
        pass


#: Shared do-nothing sink — identity-compared by emitters, never mutated.
NULL_SINK = NullSink()


class RecordingSink:
    """Append-only in-memory sink (optionally filtered by etype)."""

    enabled = True

    def __init__(self, etypes=None):
        self.events: list[Event] = []
        self._etypes = None if etypes is None else frozenset(etypes)

    def emit(self, event: Event) -> None:
        if self._etypes is None or event.etype in self._etypes:
            self.events.append(event)

    def __len__(self) -> int:
        return len(self.events)

    def lines(self) -> list[str]:
        return [e.line() for e in self.events]

    def canonical_bytes(self) -> bytes:
        """The stream's canonical byte serialization (one JSON line per
        event, emission order) — equal across identically-seeded runs."""
        return ("\n".join(self.lines()) + "\n").encode() if self.events \
            else b""


class TeeSink:
    """Fan one emission out to several sinks."""

    enabled = True

    def __init__(self, sinks):
        self.sinks = [s for s in sinks if getattr(s, "enabled", True)]

    def emit(self, event: Event) -> None:
        for s in self.sinks:
            s.emit(event)


class ShardSink:
    """Wrap a base sink, tagging every event with its fabric shard index
    so per-shard streams interleave into one bus unambiguously."""

    enabled = True

    def __init__(self, base, shard: int):
        self.base = base
        self.shard = int(shard)

    def emit(self, event: Event) -> None:
        data = dict(event.data)
        data["shard"] = self.shard
        self.base.emit(Event(event.cycle, event.etype, data))


class MetricsSink:
    """Streaming metrics registry: per-etype counts and cycle sums,
    maintained incrementally so a long run never stores the stream."""

    enabled = True

    def __init__(self):
        self.counts: dict[str, int] = {}
        self.cycles: dict[str, int] = {}

    def emit(self, event: Event) -> None:
        et = event.etype
        self.counts[et] = self.counts.get(et, 0) + 1
        c = event.data.get("cycles")
        if c:
            self.cycles[et] = self.cycles.get(et, 0) + int(c)

    def summary(self) -> dict:
        return dict(
            counts=dict(sorted(self.counts.items())),
            cycles=dict(sorted(self.cycles.items())),
        )


def payload_spec(kind: str, payload, prepare_kw: dict | None = None) -> dict:
    """Extract the workload-schema-v1 payload spec from a raw submitted
    payload *before* the adapter prepares it (preparation is lossy — e.g.
    the modeled seg adapter collapses ``{h, w}`` to a tile count).

    Handles the shapes the stack actually submits: spec dicts (modeled
    adapters / replayed traces pass them through), LM prompt arrays or
    :class:`~repro.serve.engine.Request` objects (``prompt_len`` +
    ``max_new``), seg image arrays (``h`` + ``w``), and bare numeric
    costs (synthetic test adapters).  Unknown shapes degrade to ``{}``.
    """
    kw = prepare_kw or {}
    if isinstance(payload, dict):
        return {
            k: v for k, v in payload.items()
            if isinstance(v, (int, float, str, bool))
        }
    if kind == "lm":
        prompt = getattr(payload, "prompt", payload)
        try:
            n = int(len(prompt))
        except TypeError:
            return {}
        max_new = getattr(payload, "max_new", None)
        if max_new is None:
            max_new = kw.get("max_new", 16)
        return dict(prompt_len=n, max_new=int(max_new))
    shape = getattr(payload, "shape", None)
    if shape is not None and len(shape) >= 2:
        return dict(h=int(shape[0]), w=int(shape[1]))
    if isinstance(payload, (int, float)):
        return dict(cost=int(payload))
    return {}
