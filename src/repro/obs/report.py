"""Ledger report generator: trend + breakdown tables from committed
artifacts, no re-running of benches.

Inputs are the repo's own committed CI artifacts:

``BENCH_LEDGER.jsonl``
    one datapoint per revision (``scripts/bench_diff.py --ledger``):
    each bench's headline GOPS/W, certificate and extra headline metrics
    keyed by revision + committer date.  The report renders one trend
    table per bench — GOPS/W with per-revision deltas, and the latency
    headline (p99) where the bench carries one.

``BENCH_*.json``
    the per-bench payloads.  The gateway payload (and, when present,
    the fabric payload) carries a ``spans`` block — per-class
    exact-order-statistic latency breakdowns assembled from the event
    bus (:mod:`repro.obs.spans`) — rendered as "the p99 request spent X
    queued / Y executing / Z preempted" tables, plus the integer
    reconciliation verdict against the cycle ledgers.  The capacity
    payload (``BENCH_capacity.json``) additionally yields the
    cost-per-SLO frontier table and per-grid-point SLO burn +
    miss-attribution tables (:func:`frontier_table` /
    :func:`slo_tables`); the energy payload (``BENCH_energy.json``)
    yields the metered-joules frontier and per-class joule-breakdown
    tables (:func:`energy_tables`).

Output is markdown (the CI artifact) and a JSON twin for programmatic
consumers.  ``scripts/report.py`` is the CLI.
"""
from __future__ import annotations

import json
import os


def read_ledger(path) -> list[dict]:
    """Parse a ``BENCH_LEDGER.jsonl`` (newest entry last, as appended)."""
    entries: list[dict] = []
    if not os.path.exists(path):
        return entries
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                entries.append(json.loads(line))
    return entries


def read_benches(paths) -> dict[str, dict]:
    """Load BENCH payloads present on disk, keyed by their bench name."""
    out: dict[str, dict] = {}
    for p in paths:
        if not os.path.exists(p):
            continue
        with open(p) as fh:
            payload = json.load(fh)
        out[str(payload.get("bench", os.path.basename(p)))] = payload
    return out


def trend(entries) -> dict[str, list[dict]]:
    """Pivot ledger entries into per-bench revision series (entry order
    preserved — the ledger is append-ordered, oldest first)."""
    series: dict[str, list[dict]] = {}
    for e in entries:
        for bench, h in e.get("benches", {}).items():
            row = dict(
                revision=str(e.get("revision", "?"))[:12],
                date=str(e.get("date", ""))[:10],
            )
            row.update(h)
            series.setdefault(bench, []).append(row)
    return series


_LATENCY_KEYS = ("interactive_p99_ms", "seg_p99_ms", "min_shards",
                 "speedup", "accept_rate", "epr_pj")


def _fmt(v, nd=3) -> str:
    if v is None:
        return "—"
    if isinstance(v, float):
        return f"{v:.{nd}f}"
    return str(v)


def trend_tables(series: dict[str, list[dict]]) -> list[str]:
    """One markdown trend table per bench, GOPS/W deltas vs the previous
    ledger entry for the same bench."""
    out: list[str] = []
    for bench in sorted(series):
        rows = series[bench]
        lat_keys = [
            k for k in _LATENCY_KEYS if any(k in r for r in rows)
        ]
        head = ["revision", "date", "gops_w", "Δ%"]
        head += [k for k in lat_keys] + ["cert", "target"]
        lines = [
            f"### {bench}",
            "",
            "| " + " | ".join(head) + " |",
            "|" + "|".join("---" for _ in head) + "|",
        ]
        prev = None
        for r in rows:
            gw = r.get("gops_w")
            if prev not in (None, 0) and gw is not None:
                delta = f"{(gw - prev) / prev * 100:+.2f}"
            else:
                delta = "—"
            cells = [
                r["revision"], r["date"], _fmt(gw), delta,
                *[_fmt(r.get(k)) for k in lat_keys],
                _fmt(r.get("cert"), 4), _fmt(r.get("target")),
            ]
            lines.append("| " + " | ".join(cells) + " |")
            prev = gw if gw is not None else prev
        out.append("\n".join(lines))
    return out


def span_tables(payload: dict) -> str | None:
    """Render a BENCH payload's ``spans`` block (if any): per-class p50 /
    p99 queued-vs-executing-vs-preempted decompositions plus the ledger
    reconciliation verdict."""
    spans = payload.get("spans")
    if not spans:
        return None
    per_class = spans.get("per_class", {})
    head = ["class", "n", "pct", "total_ms",
            "queued_ms", "exec_ms", "preempted_ms", "rid"]
    lines = [
        "| " + " | ".join(head) + " |",
        "|" + "|".join("---" for _ in head) + "|",
    ]
    for qos in sorted(per_class):
        entry = per_class[qos]
        for key in sorted(k for k in entry if k.startswith("p")
                          and isinstance(entry[k], dict)):
            d = entry[key]
            lines.append(
                "| " + " | ".join([
                    qos, str(entry.get("n", "—")), key,
                    _fmt(d.get("total_ms")), _fmt(d.get("queued_ms")),
                    _fmt(d.get("exec_ms")), _fmt(d.get("preempted_ms")),
                    str(d.get("rid", "—")),
                ]) + " |"
            )
    rec = spans.get("reconcile")
    if rec is not None:
        verdict = "holds" if rec.get("holds") else "**VIOLATED**"
        lines.append("")
        lines.append(
            f"Ledger reconciliation: {verdict} — "
            f"Σ exec-attribution = {rec.get('total_exec')} cycles vs "
            f"worked_total = {rec.get('total_worked')} cycles."
        )
    return "\n".join(lines)


def frontier_table(payload: dict) -> str | None:
    """Render the capacity payload's cost-per-SLO frontier: per (plan,
    router, policy), the minimum shard count meeting every SLO and that
    fleet's GOPS/W."""
    if payload.get("bench") != "capacity":
        return None
    frontier = payload.get("frontier")
    if not frontier:
        return None
    head = ["plan", "router", "policy", "min shards", "gops_w",
            "miss attribution at frontier"]
    lines = [
        "| " + " | ".join(head) + " |",
        "|" + "|".join("---" for _ in head) + "|",
    ]
    for f in frontier:
        shares = f.get("attribution_shares") or {}
        # summarize per-class shares into the classes that actually
        # carry weight at this point ("clean" when nothing misses)
        parts = []
        for qos in sorted(shares):
            top = {k: v for k, v in shares[qos].items() if v}
            if top:
                parts.append(
                    qos + ": " + ", ".join(
                        f"{k} {v:.0%}" for k, v in sorted(
                            top.items(), key=lambda kv: -kv[1])
                    )
                )
        lines.append(
            "| " + " | ".join([
                str(f.get("plan")), str(f.get("router")),
                str(f.get("policy")),
                _fmt(f.get("min_shards")), _fmt(f.get("gops_w")),
                "; ".join(parts) or "clean",
            ]) + " |"
        )
    return "\n".join(lines)


def slo_tables(payload: dict) -> str | None:
    """Render per-grid-point SLO burn + miss-attribution rows from the
    capacity payload: one line per point — met verdict, fleet deadline
    misses, the interactive class's cumulative burn (miss rate over
    budget), and the queued / preempted / service / overdraft split of
    every miss."""
    if payload.get("bench") != "capacity":
        return None
    rows = payload.get("rows")
    if not rows:
        return None
    classes = payload.get("attrib_classes") or [
        "queued", "preempted", "service", "overdraft"
    ]
    head = ["point", "SLO", "misses", "interactive burn"] + list(classes)
    lines = [
        "| " + " | ".join(head) + " |",
        "|" + "|".join("---" for _ in head) + "|",
    ]
    for r in rows:
        per_class = r.get("slo", {}).get("per_class", {})
        burn = per_class.get("interactive", {}).get("burn", {})
        totals = {c: 0 for c in classes}
        for c in per_class.values():
            for k, v in (c.get("attribution") or {}).items():
                totals[k] = totals.get(k, 0) + v
        lines.append(
            "| " + " | ".join([
                str(r.get("label")),
                "met" if r.get("slo", {}).get("met") else "**miss**",
                str(r.get("deadline_misses")),
                _fmt(burn.get("cumulative"), 2),
                *[str(totals.get(c, 0)) for c in classes],
            ]) + " |"
        )
    return "\n".join(lines)


def specdecode_table(payload: dict) -> str | None:
    """Render the speculative-decode payload's headline: the tuned
    operating point, its modeled speedup over non-speculative decode,
    the measured acceptance, and the honest waste accounting."""
    if payload.get("bench") != "specdecode":
        return None
    gate = payload.get("gate")
    plan = payload.get("plan")
    if not gate or not plan:
        return None
    head = ["draft planes", "k", "speedup", "gate", "accept rate",
            "spec cycles", "baseline cycles", "wasted cycles"]
    sp = plan.get("spec_planes") or ["?"]
    lines = [
        "| " + " | ".join(head) + " |",
        "|" + "|".join("---" for _ in head) + "|",
        "| " + " | ".join([
            str(sp[0]), str(plan.get("spec_k")),
            _fmt(gate.get("speedup")) + "x",
            f">={_fmt(gate.get('min_speedup'), 1)}x "
            + ("holds" if gate.get("holds") else "**VIOLATED**"),
            _fmt(gate.get("accept_rate")),
            str(gate.get("spec_cycles")), str(gate.get("baseline_cycles")),
            str(gate.get("wasted_cycles")),
        ]) + " |",
    ]
    ev = payload.get("gateway", {}).get("spec_events")
    if ev:
        lines.append("")
        lines.append(
            "Gateway lifecycle events: " + ", ".join(
                f"{k}={ev.get(k)}" for k in
                ("draft", "verify", "accept", "rollback")
            ) + "."
        )
    return "\n".join(lines)


def energy_tables(payload: dict) -> tuple[str, str] | None:
    """Render the energy payload (``BENCH_energy.json``) as two tables:
    the metered frontier (metered vs analytic GOPS/W, total/idle
    millijoules, energy per request, power-cap violations per grid
    point) and the per-class joule breakdown (mean per-request
    microjoules per QoS class plus the speculative draft/verify energy
    split where the plan speculates)."""
    if payload.get("bench") != "energy":
        return None
    rows = payload.get("rows")
    if not rows:
        return None
    head = ["point", "metered gops_w", "analytic gops_w", "total mJ",
            "idle mJ", "uJ/request", "cap violations"]
    frontier = [
        "| " + " | ".join(head) + " |",
        "|" + "|".join("---" for _ in head) + "|",
    ]
    for r in rows:
        epr = r.get("energy_per_request_pj")
        frontier.append(
            "| " + " | ".join([
                str(r.get("label")),
                _fmt(r.get("metered_gops_w")),
                _fmt(r.get("analytic_gops_w")),
                _fmt(r.get("total_mj"), 1),
                _fmt(r.get("idle_mj"), 1),
                _fmt(None if epr is None else epr * 1e-6, 1),
                str((r.get("power") or {}).get("violations", "—")),
            ]) + " |"
        )
    classes = sorted({
        q for r in rows for q in (r.get("per_class") or {})
    })
    head2 = (["point"] + [f"{q} uJ/req" for q in classes]
             + ["draft mJ", "verify mJ", "wasted mJ", "accept rate"])
    breakdown = [
        "| " + " | ".join(head2) + " |",
        "|" + "|".join("---" for _ in head2) + "|",
    ]
    for r in rows:
        pc = r.get("per_class") or {}
        cells = [str(r.get("label"))]
        for q in classes:
            m = (pc.get(q) or {}).get("mean_request_pj")
            cells.append(_fmt(None if m is None else m * 1e-6, 1))
        sp = r.get("spec")
        if sp:
            cells += [
                _fmt(sp.get("draft_pj", 0) * 1e-9, 1),
                _fmt(sp.get("verify_pj", 0) * 1e-9, 1),
                _fmt(sp.get("wasted_pj", 0) * 1e-9, 1),
                _fmt(sp.get("accept_rate")),
            ]
        else:
            cells += ["—", "—", "—", "—"]
        breakdown.append("| " + " | ".join(cells) + " |")
    return "\n".join(frontier), "\n".join(breakdown)


def build_report(ledger_path, bench_paths) -> tuple[str, dict]:
    """Assemble the full report; returns ``(markdown, json_payload)``."""
    entries = read_ledger(ledger_path)
    series = trend(entries)
    benches = read_benches(bench_paths)

    md: list[str] = ["# Bench ledger report", ""]
    md.append(
        f"Regenerated from committed artifacts: {len(entries)} ledger "
        f"entries ({os.path.basename(str(ledger_path))}), "
        f"{len(benches)} bench payloads. No benches were re-run."
    )
    md.append("")
    if series:
        md.append("## Trends (GOPS/W + latency headlines per revision)")
        md.append("")
        for table in trend_tables(series):
            md.append(table)
            md.append("")
    else:
        md.append("_No ledger entries found — trend section empty._")
        md.append("")

    span_sections = {}
    for bench in sorted(benches):
        table = span_tables(benches[bench])
        if table is None:
            continue
        span_sections[bench] = benches[bench].get("spans")
        md.append(f"## Span breakdown — {bench}")
        md.append("")
        md.append(
            "Exact-order-statistic requests (the actual p50/p99 request, "
            "not an interpolation), decomposed into queued / executing / "
            "preempted modeled cycles:"
        )
        md.append("")
        md.append(table)
        md.append("")

    spec = benches.get("specdecode")
    spec_md = specdecode_table(spec) if spec else None
    if spec_md:
        md.append("## Speculative decode — precision drafts, "
                  "full-digit verify")
        md.append("")
        md.append(
            "Truncated-plane drafts verified by the certified full-digit "
            "schedule (`BENCH_specdecode.json`): modeled decode speedup "
            "at bit-identical token streams, with every wasted "
            "speculation cycle charged:"
        )
        md.append("")
        md.append(spec_md)
        md.append("")

    capacity = benches.get("capacity")
    frontier_md = frontier_table(capacity) if capacity else None
    if frontier_md:
        md.append("## Capacity frontier — cost per SLO")
        md.append("")
        md.append(
            "Minimum shard count meeting every declared SLO per (plan, "
            "router, policy), under the shared diurnal workload "
            "(`BENCH_capacity.json`); attribution shows where the "
            "frontier fleet's residual misses come from:"
        )
        md.append("")
        md.append(frontier_md)
        md.append("")
    energy = benches.get("energy")
    energy_md = energy_tables(energy) if energy else None
    if energy_md:
        frontier_t, breakdown_t = energy_md
        md.append("## Energy frontier — metered joules")
        md.append("")
        md.append(
            "Joule-exact metering (`BENCH_energy.json`): worked cycles "
            "priced at each plan's plane-proportional pJ/cycle rate, "
            "idle cycles at static power, speculative drafts at the "
            "truncated draft-plane rate — vs the analytic figure that "
            "prices every elapsed cycle at full chip power:"
        )
        md.append("")
        md.append(frontier_t)
        md.append("")
        md.append("### Per-class joule breakdown")
        md.append("")
        md.append(
            "Mean metered energy per completed request by QoS class, "
            "with the speculative draft/verify/wasted energy split "
            "(integer-pJ ledger, reconciled online == offline):"
        )
        md.append("")
        md.append(breakdown_t)
        md.append("")

    slo_md = slo_tables(capacity) if capacity else None
    if slo_md:
        md.append("## SLO burn + miss attribution per grid point")
        md.append("")
        md.append(
            "Online `SloMonitor` verdicts (reconciled integer-exactly "
            "with offline span-derived misses): cumulative burn is the "
            "miss rate over the class budget (>1 = objective blown); "
            "misses split by dominant span segment:"
        )
        md.append("")
        md.append(slo_md)
        md.append("")

    payload = dict(
        schema="repro.obs.report",
        version=1,
        ledger_entries=len(entries),
        trends=series,
        capacity=dict(
            frontier=capacity.get("frontier"),
            gate_holds=_gate_holds(capacity),
        ) if capacity else None,
        benches={
            b: dict(
                bench=b,
                gate_holds=_gate_holds(p),
                spans=span_sections.get(b),
            )
            for b, p in sorted(benches.items())
        },
    )
    return "\n".join(md) + "\n", payload


def _gate_holds(payload: dict):
    gate = payload.get("gate")
    if not isinstance(gate, dict):
        return None
    holds = gate.get("holds")
    return bool(holds) if holds is not None else None
