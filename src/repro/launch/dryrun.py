import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
#
# Multi-pod dry-run: lower + compile every (arch x shape) on the production
# meshes, record memory_analysis / cost_analysis / collective schedule.
#
#     PYTHONPATH=src python -m repro.launch.dryrun --arch yi_6b --shape train_4k
#     PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
#
# Outputs one JSON per cell under results/dryrun/ (read by benchmarks/roofline
# and EXPERIMENTS.md).  A cell FAILING to compile is a bug in the framework's
# sharding config — the point of this deliverable.

import argparse
import json
import time
import traceback
from pathlib import Path


from repro.configs import ARCH_IDS, get_config
from repro.configs.base import cells
from repro.launch import hlo_analysis, specs
from repro.launch.mesh import make_production_mesh

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _cell_costs(cfg, shape_name, mesh) -> dict:
    """Compile one config and return raw cost numbers (per-device module)."""
    cell = specs.build_cell(cfg, shape_name, mesh)
    with mesh:
        compiled = cell["fn"].lower(*cell["args"]).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    cost = cost or {}
    coll = hlo_analysis.collective_stats(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll["total_bytes"]),
        "coll_count": float(coll["total_count"]),
    }


def probe_costs(cfg, shape_name: str, mesh) -> dict:
    """True per-step costs via small UNROLLED probe compiles + linear
    extrapolation in depth (XLA cost_analysis counts a scan body once
    regardless of trip count — verified; see EXPERIMENTS.md §Dry-run).

    dense/moe/vlm/ssm:  v(L) = a + b*L, probes L=1,2 -> v(L_full)
    encdec:             enc_layers = n_layers = L probes (joint body)
    hybrid (zamba2):    probes at {g, 2g, g+tail}: v = v_g
                        + (v_2g - v_g)*(n_groups-1) + (v_{g+tail} - v_g)
    The microbatch loop is removed for probes (flops are mb-invariant; the
    grad sync happens once either way).
    """
    base = dict(microbatches=1, scan_unroll=True)
    fam = cfg.family
    if fam == "hybrid":
        g = cfg.attn_every or 6
        n_groups = cfg.n_layers // g
        tail = cfg.n_layers - n_groups * g
        v_g = _cell_costs(cfg.replace(n_layers=g, **base), shape_name, mesh)
        v_2g = _cell_costs(cfg.replace(n_layers=2 * g, **base), shape_name, mesh)
        out = {}
        if tail:
            v_gt = _cell_costs(cfg.replace(n_layers=g + tail, **base), shape_name, mesh)
        for k in v_g:
            full = v_g[k] + (v_2g[k] - v_g[k]) * (n_groups - 1)
            if tail:
                full += v_gt[k] - v_g[k]
            out[k] = full
        return out
    if fam == "encdec":
        v1 = _cell_costs(cfg.replace(n_layers=1, enc_layers=1, **base), shape_name, mesh)
        v2 = _cell_costs(cfg.replace(n_layers=2, enc_layers=2, **base), shape_name, mesh)
        return {k: v1[k] + (v2[k] - v1[k]) * (cfg.n_layers - 1) for k in v1}
    v1 = _cell_costs(cfg.replace(n_layers=1, **base), shape_name, mesh)
    v2 = _cell_costs(cfg.replace(n_layers=2, **base), shape_name, mesh)
    return {k: v1[k] + (v2[k] - v1[k]) * (cfg.n_layers - 1) for k in v1}


def apply_overrides(cfg, overrides: dict):
    """Apply dotted-key overrides, e.g. {'moe.ep': True, 'attn_chunk': 512}."""
    import dataclasses as dc

    plain = {k: v for k, v in overrides.items() if "." not in k}
    nested: dict[str, dict] = {}
    for k, v in overrides.items():
        if "." in k:
            outer, inner = k.split(".", 1)
            nested.setdefault(outer, {})[inner] = v
    if plain:
        cfg = cfg.replace(**plain)
    for outer, kv in nested.items():
        cfg = cfg.replace(**{outer: dc.replace(getattr(cfg, outer), **kv)})
    return cfg


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, quant: str = "none",
             overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if quant != "none":
        from repro.configs.base import QuantConfig

        # serving deploy mode: pre-quantized int8 weights + int8 KV cache,
        # int8 MXU dot as the compute model (the Pallas bit-plane kernel is
        # the TPU implementation; its MXU cost equals the int8 dot here).
        cfg = cfg.replace(quant=QuantConfig(
            mode=quant, impl="int8", weights_int8=True, kv_int8=True))
    if overrides:
        cfg = apply_overrides(cfg, overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = specs.build_cell(cfg, shape_name, mesh)
    with mesh:
        lowered = cell["fn"].lower(*cell["args"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_d = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            mem_d[k] = int(v)
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    cost_d = {k: float(v) for k, v in (cost or {}).items()
              if isinstance(v, (int, float)) and (
                  k in ("flops", "bytes accessed") or k.startswith("bytes accessed"))}

    hlo = compiled.as_text()
    coll = hlo_analysis.collective_stats(hlo)
    census = hlo_analysis.remat_census(hlo)

    # True per-step costs (scan bodies are cost-counted once; extrapolate
    # from small unrolled probes).
    t1 = time.time()
    corrected = probe_costs(cfg, shape_name, mesh)
    t_probe = time.time() - t1
    flops = corrected["flops"]
    coll_bytes = corrected["coll_bytes"]
    # Memory term: analytic HBM traffic model (cost_analysis bytes ignore
    # fusion — kept as "bytes_upper_bound"); see hlo_analysis docstring.
    mem_model = hlo_analysis.analytic_hbm_bytes(cell["kind"], **cell["meta"]["mem_in"])
    roof = hlo_analysis.roofline(flops, mem_model["total"], coll_bytes)

    n_chips = mesh.devices.size
    meta = cell["meta"]
    # MODEL_FLOPS: 6*N*D train (fwd+bwd), 2*N*D inference, per device.
    n_active = meta["active_params"]
    d_tokens = meta["tokens"]
    mult = 6 if cell["kind"] == "train" else 2
    model_flops_global = mult * n_active * d_tokens
    model_flops_per_chip = model_flops_global / n_chips
    useful = model_flops_per_chip / flops if flops else 0.0

    out = dict(
        arch=arch, shape=shape_name, kind=cell["kind"],
        mesh="2x16x16" if multi_pod else "16x16", chips=int(n_chips),
        quant=quant,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        probe_s=round(t_probe, 2),
        params=meta["params"], active_params=n_active,
        serve_mode=meta.get("serve_mode", "-"),
        memory=mem_d, cost_raw=cost_d, cost=corrected,
        hbm_traffic_model=mem_model,
        collectives=coll, census=census,
        roofline=roof,
        model_flops_per_chip=model_flops_per_chip,
        useful_flops_fraction=useful,
    )
    return out


def save(result: dict, tag: str = "") -> Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    name = f"{result['arch']}__{result['shape']}__{result['mesh'].replace('x','_')}"
    if result.get("quant", "none") != "none":
        name += f"__{result['quant']}"
    if tag:
        name += f"__{tag}"
    p = RESULTS / f"{name}.json"
    p.write_text(json.dumps(result, indent=1))
    return p


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--quant", default="none")
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (dotted keys ok), e.g. "
                         "--set moe.ep=True --set microbatches=8")
    args = ap.parse_args()

    import ast

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v

    todo: list[tuple[str, str]] = []
    if args.all:
        for a in ARCH_IDS:
            for s in cells(a):
                todo.append((a, s))
    else:
        assert args.arch and args.shape
        todo = [(args.arch, args.shape)]

    meshes = []
    if args.multi_pod or not args.single_pod:
        meshes.append(True)
    if args.single_pod or not args.multi_pod:
        meshes.insert(0, False)

    failures = 0
    for arch, shape in todo:
        for mp in meshes:
            mesh_tag = "2_16_16" if mp else "16_16"
            out_name = f"{arch}__{shape}__{mesh_tag}"
            if args.quant != "none":
                out_name += f"__{args.quant}"
            if args.tag:
                out_name += f"__{args.tag}"
            if args.skip_existing and (RESULTS / f"{out_name}.json").exists():
                print(f"[skip] {out_name}")
                continue
            try:
                r = run_cell(arch, shape, multi_pod=mp, quant=args.quant,
                             overrides=overrides or None)
                save(r, args.tag)
                roof = r["roofline"]
                print(
                    f"[ok] {out_name}: compile {r['compile_s']:.1f}s+{r['probe_s']:.1f}s "
                    f"flops/chip {r['cost']['flops']:.3e} "
                    f"coll {r['cost']['coll_bytes']:.3e}B "
                    f"dominant={roof['dominant']} "
                    f"bound={roof['step_time_lower_bound_s']*1e3:.2f}ms "
                    f"useful={r['useful_flops_fraction']:.2f}",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001 — record and continue
                failures += 1
                print(f"[FAIL] {out_name}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
