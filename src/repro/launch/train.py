"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch yi_6b --smoke \
        --steps 50 --ckpt-dir /tmp/ck [--resume] [--quant mma_int8]

On a real cluster this binary runs once per host (jax.distributed.initialize
picks up the coordinator from the environment) and the mesh comes from
launch.mesh.make_production_mesh; with --smoke it runs the reduced config on
local devices — the same code path the restart/elasticity tests exercise.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_smoke_config
from repro.configs.base import QuantConfig
from repro.data.pipeline import DataConfig
from repro.models import build
from repro.optim import adamw
from repro.train import train_step as ts
from repro.train import trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--quant", default="none")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.quant != "none":
        cfg = cfg.replace(quant=QuantConfig(mode=args.quant))
    mod = build(cfg)
    key = jax.random.PRNGKey(0)
    params = (mod.init_params(key, cfg, max_dec_pos=args.seq + 1)
              if cfg.family == "encdec" else mod.init_params(key, cfg))
    state = {"params": params, "opt": adamw.init(params)}

    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = (cfg.vlm_patches, cfg.d_model)
    if cfg.family == "encdec":
        extras["frames"] = (cfg.enc_seq, cfg.d_model)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
                      extras=extras or None)
    tcfg = trainer.TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                                 ckpt_dir=args.ckpt_dir)

    start = 0
    if args.resume:
        restored, start = trainer.resume(jax.eval_shape(lambda: state), tcfg)
        if restored is not None:
            state = restored
            print(f"resumed from step {start}")

    step_fn = jax.jit(lambda st, b: ts.train_step(st, b, cfg))
    state, metrics = trainer.train(state, step_fn, dcfg, tcfg, start_step=start)
    print(f"final loss {metrics['losses'][-1]:.4f}; "
          f"stragglers flagged: {metrics['stragglers']}")


if __name__ == "__main__":
    main()
