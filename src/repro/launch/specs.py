"""ShapeDtypeStruct input specs for every (arch x shape) cell — the
allocation-free stand-ins the dry-run lowers against.

``build_cell(cfg, shape_name, mesh)`` returns a dict with:
  kind: 'train' | 'prefill' | 'decode'
  fn:   the step function to jit
  args: tuple of abstract args (ShapeDtypeStructs)
  in_shardings / out_shardings
  meta: param counts etc. for the roofline
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import models
from repro.configs.base import SHAPES, ShapeConfig
from repro.parallel import param_specs as pspecs
from repro.parallel import sharding as shd
from repro.serve import serve_step as ss
from repro.train import train_step as ts


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def sharded_bytes(abstract_tree, shardings, mesh) -> int:
    """Exact per-chip bytes of a sharded pytree (leaf bytes / shard count)."""
    total = 0
    sh_leaves = jax.tree.leaves(
        shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
    )
    ab_leaves = jax.tree.leaves(abstract_tree)
    for leaf, sh in zip(ab_leaves, sh_leaves):
        factor = 1
        spec = getattr(sh, "spec", None)
        if spec is not None:
            for entry in spec:
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                for a in axes:
                    factor *= mesh.shape[a]
        total += math.prod(leaf.shape) * leaf.dtype.itemsize // factor
    return total


def param_count(abstract_params) -> int:
    return sum(math.prod(l.shape) for l in jax.tree.leaves(abstract_params))


def active_param_count(abstract_params, cfg) -> int:
    """MoE: count expert leaves at top_k/E utilization."""
    total = 0
    def is_expert(path):
        return "moe/" in path and any(s in path for s in ("w_gate", "w_up", "w_down"))

    def walk(path, leaf):
        nonlocal total
        n = math.prod(leaf.shape)
        if is_expert(path) and cfg.moe.n_experts:
            n = n * cfg.moe.top_k // cfg.moe.n_experts
        total += n

    import jax.tree_util as jtu
    for kp, leaf in jtu.tree_flatten_with_path(abstract_params)[0]:
        walk(pspecs._path_str(kp), leaf)
    return total


def _abstract_params(cfg, *, max_dec_pos: int = 4096):
    mod = models.build(cfg)
    key = jax.random.PRNGKey(0)

    def init():
        if cfg.family == "encdec":
            p = mod.init_params(key, cfg, max_dec_pos=max_dec_pos)
        else:
            p = mod.init_params(key, cfg)
        if cfg.quant.weights_int8:
            from repro.core.quant import quantize_params_int8

            p = quantize_params_int8(p)
        return p

    return jax.eval_shape(init)


def _train_batch_specs(cfg, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    mb = cfg.microbatches
    def with_mb(shp):
        if mb > 1:
            return (mb, shp[0] // mb) + shp[1:]
        return shp
    batch = {"tokens": sds(with_mb((b, s + 1)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = sds(with_mb((b, cfg.vlm_patches, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = sds(with_mb((b, cfg.enc_seq, cfg.d_model)), jnp.bfloat16)
    return batch


def _rules(cfg):
    return shd.RULE_SETS.get(getattr(cfg, "shard_rules", "default"),
                             shd.DEFAULT_RULES)


def build_cell(cfg, shape_name: str, mesh):
    shape = SHAPES[shape_name]
    with shd.use_mesh(mesh, _rules(cfg)):
        if shape.kind == "train":
            return _build_train(cfg, shape, mesh)
        if shape.kind == "prefill":
            return _build_prefill(cfg, shape, mesh)
        return _build_decode(cfg, shape, mesh)


def _mesh_sizes(mesh):
    dpsize = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dpsize *= mesh.shape[a]
    return dpsize, mesh.shape.get("model", 1)


def _build_train(cfg, shape, mesh):
    ab_state = ts.abstract_state(cfg)
    batch = _train_batch_specs(cfg, shape)
    st_sh = ts.state_shardings(ab_state, cfg, mesh)
    b_sh = ts.batch_shardings(batch, mesh, mb_leading=cfg.microbatches > 1)

    def step_fn(state, b):
        with shd.use_mesh(mesh, _rules(cfg)):
            return ts.train_step(state, b, cfg)

    jitted = jax.jit(
        step_fn, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None),
        donate_argnums=(0,),
    )
    n = param_count(ab_state["params"])
    # analytic-memory inputs (per chip)
    dp, ms = _mesh_sizes(mesh)
    mb = cfg.microbatches
    b_loc = shape.global_batch // dp // mb
    s_loc = shape.seq_len // ms if cfg.seq_shard else shape.seq_len
    v_sh = cfg.vocab // ms if cfg.vocab % ms == 0 else cfg.vocab
    n_layers_eff = cfg.n_layers + (cfg.enc_layers if cfg.family == "encdec" else 0)
    mem_in = dict(
        w_bytes=sharded_bytes(ab_state["params"], st_sh["params"], mesh),
        opt_bytes=(
            sharded_bytes(ab_state["opt"].master, st_sh["opt"].master, mesh)
            + sharded_bytes(ab_state["opt"].m, st_sh["opt"].m, mesh)
            + sharded_bytes(ab_state["opt"].v, st_sh["opt"].v, mesh)
        ),
        resid_bytes=b_loc * max(s_loc, 1) * cfg.d_model * 2,
        n_layers=n_layers_eff,
        logits_bytes=b_loc * shape.seq_len * v_sh * 4,
        microbatches=mb,
    )
    return dict(
        kind="train", fn=jitted, args=(ab_state, batch),
        meta=dict(
            params=n,
            active_params=active_param_count(ab_state["params"], cfg),
            tokens=shape.global_batch * shape.seq_len,
            mem_in=mem_in,
        ),
    )


def _serve_params(cfg, mesh, *, max_dec_pos=4096):
    """Abstract params + shardings for serving.  TP by default; auto-switch
    to 2-D (model x data, FSDP-style weight gathering) when the TP-sharded
    bf16 weights would not fit HBM (>10 GiB/chip) — logged in the cell."""
    ab = _abstract_params(cfg, max_dec_pos=max_dec_pos)
    n = param_count(ab)
    msize = mesh.shape.get("model", 1)
    per_chip = 2 * n / msize
    mode = "tp"
    if per_chip > 10 * (1 << 30):
        mode = "2d"
    p_sh = pspecs.named_shardings(ab, cfg, mesh)
    if mode == "2d":
        def widen(path, sh, leaf):
            spec = list(sh.spec) + [None] * (len(leaf.shape) - len(sh.spec))
            dpa = tuple(a for a in ("pod", "data") if a in mesh.shape)
            dsize = 1
            for a in dpa:
                dsize *= mesh.shape[a]
            for i, (sp, dim) in enumerate(zip(spec, leaf.shape)):
                if sp is None and dim % dsize == 0 and dim >= dsize:
                    spec[i] = dpa if len(dpa) > 1 else dpa[0]
                    break
            return NamedSharding(mesh, P(*spec))

        import jax.tree_util as jtu
        p_sh = jtu.tree_map_with_path(
            lambda kp, sh, leaf: widen(pspecs._path_str(kp), sh, leaf), p_sh, ab
        )
    return ab, p_sh, mode


def _build_prefill(cfg, shape, mesh):
    b, s = shape.global_batch, shape.seq_len
    ab_params, p_sh, mode = _serve_params(cfg, mesh, max_dec_pos=s + 1)
    prefill = ss.make_prefill(cfg)
    tokens = sds((b, s), jnp.int32)
    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = sds((b, cfg.vlm_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        extras["frames"] = sds((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    dpa = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = dpa if len(dpa) > 1 else dpa[0]
    tok_sh = NamedSharding(mesh, P(dp, None))
    ex_sh = {k: NamedSharding(mesh, P(dp, *([None] * (len(v.shape) - 1))))
             for k, v in extras.items()}

    def fn(params, tokens, extras):
        with shd.use_mesh(mesh, _rules(cfg)):
            return prefill(params, tokens, extras)

    jitted = jax.jit(fn, in_shardings=(p_sh, tok_sh, ex_sh))
    n = param_count(ab_params)
    dp, ms = _mesh_sizes(mesh)
    b_loc = b // dp if b % dp == 0 else b
    s_loc = s // ms if cfg.seq_shard else s
    v_sh = cfg.vocab // ms if cfg.vocab % ms == 0 else cfg.vocab
    n_layers_eff = cfg.n_layers + (cfg.enc_layers if cfg.family == "encdec" else 0)
    mem_in = dict(
        w_bytes=sharded_bytes(ab_params, p_sh, mesh),
        resid_bytes=b_loc * max(s_loc, 1) * cfg.d_model * 2,
        n_layers=n_layers_eff,
        logits_bytes=b_loc * s * v_sh * 4,
    )
    return dict(
        kind="prefill", fn=jitted, args=(ab_params, tokens, extras),
        meta=dict(params=n, active_params=active_param_count(ab_params, cfg),
                  tokens=b * s, serve_mode=mode, mem_in=mem_in),
    )


def _build_decode(cfg, shape, mesh):
    b, s = shape.global_batch, shape.seq_len
    ab_params, p_sh, mode = _serve_params(cfg, mesh, max_dec_pos=s + 1)
    decode, ab_cache = ss.make_decode(cfg, b, s)
    c_sh = ss.cache_shardings(ab_cache, cfg, mesh, b, max_seq=s)
    tokens = sds((b, 1), jnp.int32)
    extras = {}
    if cfg.family == "encdec":
        extras["memory"] = sds((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        # per-request precomputed cross-attention K/V (whisper serving: the
        # encoder memory is projected once at admission, not every token)
        xkv = (cfg.n_layers, b, cfg.enc_seq, cfg.n_kv_heads, cfg.hd)
        extras["cross_kv"] = {"k": sds(xkv, jnp.bfloat16),
                              "v": sds(xkv, jnp.bfloat16)}
    dpa = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = dpa if len(dpa) > 1 else dpa[0]
    dsize = 1
    for a in dpa:
        dsize *= mesh.shape[a]
    tok_sh = NamedSharding(mesh, P(dp, None) if b % dsize == 0 else P())

    def _ex_sharding(v):
        axes: list = [None] * len(v.shape)
        if b % dsize == 0:
            for i, d_ in enumerate(v.shape):
                if d_ == b:
                    axes[i] = dp
                    break
        return NamedSharding(mesh, P(*axes))

    ex_sh = jax.tree.map(_ex_sharding, extras)
    idx = sds((), jnp.int32)

    def fn(params, tokens, cache, index, extras):
        with shd.use_mesh(mesh, _rules(cfg)):
            return decode(params, tokens, cache, index, extras)

    jitted = jax.jit(
        fn,
        in_shardings=(p_sh, tok_sh, c_sh, NamedSharding(mesh, P()), ex_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(2,),
    )
    n = param_count(ab_params)
    dp, ms = _mesh_sizes(mesh)
    b_loc = b // dp if b % dp == 0 else b
    v_sh = cfg.vocab // ms if cfg.vocab % ms == 0 else cfg.vocab
    # per-token reads: KV cache + any per-request extras (encdec cross-KV /
    # encoder memory) — both cross HBM every step.
    extras_bytes = sharded_bytes(extras, ex_sh, mesh) if extras else 0
    mem_in = dict(
        w_bytes=sharded_bytes(ab_params, p_sh, mesh),
        cache_bytes=sharded_bytes(ab_cache, c_sh, mesh) + extras_bytes,
        logits_bytes=b_loc * v_sh * 4,
        n_layers=cfg.n_layers,
    )
    return dict(
        kind="decode", fn=jitted, args=(ab_params, tokens, ab_cache, idx, extras),
        meta=dict(params=n, active_params=active_param_count(ab_params, cfg),
                  tokens=b, serve_mode=mode, mem_in=mem_in),
    )
