"""Production meshes.  Functions (not module constants) so importing never
touches jax device state — required for the smoke tests to see 1 device.

Single pod: 16x16 = 256 chips (v5e pod), axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — the 'pod' axis
carries pure data parallelism across pods (DCN-connected in production;
gradient sync over 'pod' is the slice the grad-compression path targets).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 2):
    """Tiny mesh over however many (forced) host devices exist — used by
    sharding unit tests, not the dry-run."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
