"""Post-SPMD HLO analysis: collective bytes, op census, roofline terms.

``collective_bytes`` parses the compiled (per-device) HLO text and sums the
operand bytes of every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute (cost_analysis does not report collectives).

Roofline convention (documented in EXPERIMENTS.md): the compiled module is
the per-device SPMD program, so every term is *seconds per step per chip*:

    compute_s    = HLO_FLOPs(per-device)        / 197e12   (v5e bf16 peak)
    memory_s     = HLO_bytes(per-device)        / 819e9    (HBM bw)
    collective_s = collective_bytes(per-device) / 50e9     (per-link ICI)
"""
from __future__ import annotations

import re
from collections import defaultdict

PEAK_FLOPS = 197e12  # v5e bf16
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\(?([a-z0-9]+)\[([\d,]*)\]"
)
_COLL_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _bytes_of(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind from post-SPMD HLO text."""
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            sizes[m.group(1)] = _bytes_of(m.group(2), m.group(3))
    per_kind: dict[str, int] = defaultdict(int)
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _DEF_RE.match(line)
        for kind in _COLL_KINDS:
            # match the op invocation (e.g. "= bf16[...] all-gather("), not
            # "-done"/"-start" suffixes twice: count -start OR the sync form.
            if re.search(rf"\b{kind}(-start)?\(", stripped):
                if f"{kind}-done" in stripped:
                    continue
                args = stripped.split(f"{kind}(", 1)[-1] if f"{kind}(" in stripped \
                    else stripped.split(f"{kind}-start(", 1)[-1]
                args = args.split(")", 1)[0]
                ops = re.findall(r"%([\w.\-]+)", args)
                nbytes = sum(sizes.get(o, 0) for o in ops)
                if nbytes == 0 and m:
                    # fallback: result size (all-reduce result == operand)
                    nbytes = _bytes_of(m.group(2), m.group(3))
                per_kind[kind] += nbytes
                counts[kind] += 1
                break
    return {
        "bytes_by_kind": dict(per_kind),
        "counts_by_kind": dict(counts),
        "total_bytes": int(sum(per_kind.values())),
        "total_count": int(sum(counts.values())),
    }


def remat_census(hlo_text: str) -> dict:
    """Rough remat/redundancy signal: counts of dot/convolution ops."""
    dots = len(re.findall(r"\bdot\(", hlo_text))
    fusions = len(re.findall(r"\bfusion\(", hlo_text))
    return {"dot_ops": dots, "fusions": fusions}


def analytic_hbm_bytes(
    kind: str,
    *,
    w_bytes: float,          # sharded bf16 param bytes per chip
    opt_bytes: float = 0.0,  # sharded f32 master+m+v bytes per chip
    resid_bytes: float = 0.0,  # one layer's residual activation per chip
    n_layers: int = 0,
    logits_bytes: float = 0.0,  # per-chip logits tensor bytes (f32, sharded)
    cache_bytes: float = 0.0,  # per-chip KV-cache/state bytes
    microbatches: int = 1,
) -> dict:
    """Analytic per-chip HBM traffic per step (bytes).

    cost_analysis' "bytes accessed" ignores fusion (every HLO op's operands
    counted) — a >10x upper bound on real HBM traffic.  This model counts
    what actually crosses HBM on a fused TPU program:

    train:   weights read 3x per microbatch (fwd, remat-recompute, bwd)
             + grad accumulators rw per microbatch (f32, 2x param bytes each
               way) + optimizer update (read grads+master+m+v, write all)
             + saved residuals (write fwd, read bwd, write recompute)
             + logits (write fwd, read bwd, write dlogits)
    prefill: weights once, residual stream 2x, cache write, logits write
    decode:  weights once + full cache read (+ small vectors) — the classic
             bandwidth-bound regime
    """
    if kind == "train":
        grads = 2 * w_bytes  # f32 copy of every param
        weights_traffic = 3 * w_bytes * microbatches
        grad_traffic = 2 * grads * microbatches  # accumulate rw
        opt_traffic = grads + 2 * opt_bytes + w_bytes  # read g, rw opt, write w
        act_traffic = 3 * n_layers * resid_bytes
        logit_traffic = 3 * logits_bytes
        total = weights_traffic + grad_traffic + opt_traffic + act_traffic + logit_traffic
        parts = dict(weights=weights_traffic, grads=grad_traffic, opt=opt_traffic,
                     activations=act_traffic, logits=logit_traffic)
    elif kind == "prefill":
        act_traffic = 2 * n_layers * resid_bytes
        total = w_bytes + act_traffic + cache_bytes + logits_bytes
        parts = dict(weights=w_bytes, activations=act_traffic,
                     cache=cache_bytes, logits=logits_bytes)
    else:  # decode
        total = w_bytes + cache_bytes + logits_bytes
        parts = dict(weights=w_bytes, cache=cache_bytes, logits=logits_bytes)
    return {"total": total, "parts": parts}


def roofline(flops: float, bytes_accessed: float, coll_bytes: float) -> dict:
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll_bytes / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    total = max(bound, 1e-30)
    return {
        **terms,
        "dominant": dom.replace("_s", ""),
        "step_time_lower_bound_s": bound,
        "roofline_fraction_of_dominant": {
            k: (v / total) for k, v in terms.items()
        },
    }
