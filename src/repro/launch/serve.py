"""Serving launcher: continuous-batching engine on a (smoke) config.

    PYTHONPATH=src python -m repro.launch.serve --arch yi_6b --requests 8 \
        [--quant mma_int8 --planes 6]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import QuantConfig
from repro.models import build
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--quant", default="none")
    ap.add_argument("--planes", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if args.quant != "none":
        cfg = cfg.replace(quant=QuantConfig(mode=args.quant, planes=args.planes))
    mod = build(cfg)
    key = jax.random.PRNGKey(0)
    params = (mod.init_params(key, cfg, max_dec_pos=args.max_seq)
              if cfg.family == "encdec" else mod.init_params(key, cfg))

    eng = Engine(cfg, params, batch=args.batch, max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=int(rng.integers(2, 10))),
                    max_new=args.max_new) for i in range(args.requests)]
    done = eng.run(reqs)
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: {list(r.prompt)[:4]}... -> {r.out}")


if __name__ == "__main__":
    main()
