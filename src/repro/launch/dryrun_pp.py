import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
#
# Pipeline-parallel dry-run: PP=16 x DP=16 on the single-pod mesh for a
# dense arch (the PP alternative to the TP-collective-bound train cells).
#
#     PYTHONPATH=src python -m repro.launch.dryrun_pp --arch yi_6b

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import build
from repro.parallel import sharding as shd
from repro.parallel.pipeline import bubble_fraction, pipelined_loss_fn

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--n-micro", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).replace(seq_shard=False, microbatches=1)
    mesh = make_production_mesh()
    mod = build(cfg)
    key = jax.random.PRNGKey(0)
    ab_params = jax.eval_shape(lambda: mod.init_params(key, cfg))
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4097), jnp.int32)}

    from jax.sharding import NamedSharding, PartitionSpec as P

    # stage placement: layer-stacked leaves shard over 'model' (the stage
    # axis); embed/head/norms replicated across stages.
    def pspec(path_leaf):
        return P("model") if path_leaf else P()


    p_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), ab_params)
    p_sh["blocks"] = jax.tree.map(
        lambda _: NamedSharding(mesh, P("model")), ab_params["blocks"]
    )
    b_sh = {"tokens": NamedSharding(mesh, P("data", None))}

    def loss_and_grad(params, b):
        with shd.use_mesh(mesh):
            loss, _ = pipelined_loss_fn(params, b, cfg, n_micro=args.n_micro)
        return loss

    fn = jax.jit(jax.value_and_grad(loss_and_grad),
                 in_shardings=(p_sh, b_sh))
    t0 = time.time()
    lowered = fn.lower(ab_params, batch)
    compiled = lowered.compile()
    dt = time.time() - t0
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = hlo_analysis.collective_stats(compiled.as_text())
    mem = compiled.memory_analysis()
    out = dict(
        arch=args.arch, mode="pipeline", mesh="16x16",
        pp=mesh.shape["model"], dp=mesh.shape["data"],
        n_micro=args.n_micro,
        bubble=bubble_fraction(mesh.shape["model"], args.n_micro),
        compile_s=round(dt, 1),
        flops_raw=float((cost or {}).get("flops", 0.0)),
        collective_bytes_raw=coll["total_bytes"],
        collective_counts=coll["counts_by_kind"],
        temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0) or 0),
    )
    RESULTS.mkdir(parents=True, exist_ok=True)
    p = RESULTS / f"{args.arch}__train_4k__16_16__pp.json"
    p.write_text(json.dumps(out, indent=1))
    print(f"[ok] PP dry-run {args.arch}: compile {dt:.1f}s "
          f"bubble={out['bubble']:.2f} colls={coll['counts_by_kind']}")


if __name__ == "__main__":
    main()
