"""Distributed train step: grad accumulation (microbatches), AdamW update,
logical-axis sharding, donation.  One code path serves smoke tests (1 CPU
device, no mesh) and the 512-chip dry-run (mesh + NamedShardings).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro import models
from repro.optim import adamw, schedule
from repro.parallel import param_specs as pspecs
from repro.parallel import sharding as shd


def make_loss_fn(cfg) -> Callable:
    mod = models.build(cfg)
    return partial(mod.loss_fn, cfg=cfg)


def train_step(state: dict, batch: dict, cfg, *, peak_lr=3e-4, warmup=100, total=10_000):
    """state = {"params", "opt": AdamWState}; batch leaves have a leading
    microbatch dim (MB, ...) added by the data pipeline when
    cfg.microbatches > 1."""
    loss_fn = make_loss_fn(cfg)
    params = state["params"]

    def one_micro(carry, mb):
        grads_acc, loss_acc = carry
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
        return (grads_acc, loss_acc + loss), metrics

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if cfg.microbatches > 1:
        (grads, loss), _ = jax.lax.scan(one_micro, (zeros, 0.0), batch)
        grads = jax.tree.map(lambda g: g / cfg.microbatches, grads)
        loss = loss / cfg.microbatches
    else:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    lr = schedule.warmup_cosine(
        state["opt"].step + 1, peak_lr=peak_lr, warmup=warmup, total=total
    )
    new_params, new_opt, om = adamw.update(params, grads, state["opt"], lr=lr)
    new_state = {"params": new_params, "opt": new_opt}
    return new_state, {"loss": loss, **om}


def abstract_state(cfg, rng=None):
    """eval_shape the full train state — no allocation (dry-run path)."""
    mod = models.build(cfg)
    key = jax.random.PRNGKey(0)

    def init():
        if cfg.family == "encdec":
            p = mod.init_params(key, cfg, max_dec_pos=4096)
        else:
            p = mod.init_params(key, cfg)
        return {"params": p, "opt": adamw.init(p)}

    return jax.eval_shape(init)


def state_shardings(abstract, cfg, mesh):
    """NamedShardings for the whole train state (opt moments follow params)."""
    p_sh = pspecs.named_shardings(abstract["params"], cfg, mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P

    opt = abstract["opt"]
    m_sh = pspecs.named_shardings(opt.m, cfg, mesh)
    return {
        "params": p_sh,
        "opt": type(opt)(
            step=NamedSharding(mesh, P()),
            master=pspecs.named_shardings(opt.master, cfg, mesh),
            m=m_sh,
            v=pspecs.named_shardings(opt.v, cfg, mesh),
        ),
    }


def batch_shardings(abstract_batch, mesh, mb_leading: bool = False):
    """Batch dims shard per the ACTIVE rule set's 'batch' mapping (pure-DP
    ('pod','data') by default; all axes under 'ep_dp').  A leading microbatch
    dim stays unsharded.  Must be called inside ``sharding.use_mesh``."""
    from jax.sharding import NamedSharding

    def one(sds):
        nd = len(sds.shape)
        if nd == 0:
            return NamedSharding(mesh, shd.spec_for((), ()))
        bdim = 1 if (mb_leading and nd > 1) else 0
        names: list = [None] * nd
        names[bdim] = "batch"
        with shd.use_mesh(mesh, shd.active_rules()):
            return shd.named_sharding(*names, shape=sds.shape)

    return jax.tree.map(one, abstract_batch)


def build_jitted_train_step(cfg, mesh, abstract_st, abstract_batch):
    """jit with explicit in/out shardings + donation (dry-run + real run)."""
    st_sh = state_shardings(abstract_st, cfg, mesh)
    b_sh = batch_shardings(abstract_batch, mesh)

    def step_fn(state, batch):
        with shd.use_mesh(mesh):
            return train_step(state, batch, cfg)

    return jax.jit(
        step_fn,
        in_shardings=(st_sh, b_sh),
        out_shardings=(st_sh, None),
        donate_argnums=(0,),
    )
