"""Training loop with the fault-tolerance/straggler machinery wired in.

Responsibilities beyond calling train_step:
  * checkpoint/restart: resumes from the latest committed checkpoint; data
    is step-indexed so restart is bit-deterministic (no iterator state).
  * async checkpointing every ``ckpt_every`` steps (overlapped with compute).
  * straggler/hang watchdog: each step must complete within
    ``watchdog_factor`` x the trailing-median step time, else the step is
    flagged (on a real cluster this triggers requeue/replace of the slow
    host; here it logs — the detection logic is what we can test).
  * elastic restart: ``resume(mesh)`` re-shards the restored state onto
    whatever mesh the new incarnation has (see checkpoint/ckpt.py).
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field

import jax

from repro.checkpoint.ckpt import Checkpointer
from repro.data import pipeline as data_pipeline


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    watchdog_factor: float = 3.0
    log_every: int = 10


@dataclass
class StepTimer:
    history: list[float] = field(default_factory=list)
    flagged: list[int] = field(default_factory=list)

    def record(self, step: int, dt: float, factor: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if len(self.history) >= 5:
            med = statistics.median(self.history[-20:])
            if dt > factor * med:
                self.flagged.append(step)
                is_straggler = True
        self.history.append(dt)
        return is_straggler


def train(
    state,
    step_fn,
    data_cfg: data_pipeline.DataConfig,
    tcfg: TrainerConfig,
    *,
    start_step: int = 0,
    log=print,
):
    """Generic loop: state can be restored/elastic; returns (state, metrics)."""
    ckpt = Checkpointer(tcfg.ckpt_dir, keep=tcfg.keep)
    timer = StepTimer()
    losses = []
    step = start_step
    while step < tcfg.total_steps:
        batch = data_pipeline.get_batch(data_cfg, step)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        if timer.record(step, dt, tcfg.watchdog_factor):
            log(f"[straggler] step {step} took {dt:.3f}s (median "
                f"{statistics.median(timer.history[-20:]):.3f}s) — would requeue host")
        losses.append(float(metrics["loss"]))
        if step % tcfg.log_every == 0:
            log(f"step {step} loss {losses[-1]:.4f} ({dt*1e3:.0f} ms)")
        step += 1
        if step % tcfg.ckpt_every == 0 or step == tcfg.total_steps:
            ckpt.save_async(step, {"state": state})
    ckpt.wait()
    return state, {"losses": losses, "stragglers": timer.flagged}


def resume(like_state, tcfg: TrainerConfig, shardings=None):
    """Restore the latest checkpoint (None if fresh start)."""
    ckpt = Checkpointer(tcfg.ckpt_dir, keep=tcfg.keep)
    step = ckpt.latest_step()
    if step is None:
        return None, 0
    restored, step = ckpt.restore({"state": like_state}, shardings=shardings)
    return restored["state"], step
