"""Content-adaptive per-tile plane budgets (MINT, per region not per layer).

A medical image is mostly quiet background; the MSDF datapath's cost is
linear in digits consumed.  Dynamic activation quantization gives a flat
tile a scale proportional to its own amplitude, so — at the *same absolute
error budget the per-layer schedule already certified* — a tile at 1/2^k
of the image's amplitude can drop roughly k further LSB digits per layer
(:meth:`repro.core.PlaneSchedule.refine` holds the exact inequality).

Budgets are quantized into integer *classes* ``k = floor(-log2 r)`` (``r``
= tile amplitude / image amplitude, measured on the tile's input window)
rather than refined per tile continuously: the serving engine groups tiles
by class so each micro-batch runs one *static* refined schedule, and the
``kernels.mma_matmul.plane_variant`` specializations stay shared across
tiles, images and requests.  Class ``k`` refines with the ratio upper
bound ``2**-k >= r`` — conservative by construction.

Soundness note: the amplitude ratio is exact at the first conv; deeper
layers see it through ReLU convs, which track amplitude well but carry no
worst-case guarantee.  The certified statement (tested) is the refinement
inequality per layer at the measured ratio; the serving benchmark measures
the realized end-to-end error alongside the modeled cycle savings.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.plane_schedule import PlaneSchedule

from .tiling import TilePlan

# Flat-zero tiles have r = 0 (infinite class); cap so every class still
# streams at least the MSB digit and the class set stays small/jittable.
MAX_CLASS = 6


def amplitude_ratio(tile: np.ndarray, image_amax: float) -> float:
    """max|tile| / max|image|, clamped into [0, 1]."""
    if image_amax <= 0.0:
        return 1.0
    return min(1.0, float(np.max(np.abs(tile))) / float(image_amax))


def budget_class(ratio: float, *, max_class: int = MAX_CLASS) -> int:
    """Amplitude octaves below full scale: largest k <= max_class with
    ratio <= 2**-k (k = 0 for full-amplitude tiles)."""
    if not (0.0 <= ratio <= 1.0):
        raise ValueError(f"ratio {ratio} outside [0, 1]")
    if ratio == 0.0:
        return max_class
    return min(max_class, max(0, int(math.floor(-math.log2(ratio)))))


def budget_class_from_thresholds(
    ratio: float, thresholds: tuple[float, ...]
) -> int:
    """Budget class under *calibrated* thresholds (``repro.autotune``):
    the largest class ``c`` whose threshold still bounds the ratio
    (``ratio <= thresholds[c]``).  ``thresholds`` descend from 1.0, one per
    class — typically the amplitude octaves the calibration set actually
    occupies, so empty octaves cost no jit signatures.  A ratio calibration
    never saw lands in the nearest *louder* class — conservative (it drops
    no more digits than its measured-ratio bound allows)."""
    if not (0.0 <= ratio <= 1.0):
        raise ValueError(f"ratio {ratio} outside [0, 1]")
    if not thresholds or thresholds[0] != 1.0:
        raise ValueError(f"thresholds must start at 1.0, got {thresholds}")
    k = 0
    for c, t in enumerate(thresholds):
        if ratio <= t:
            k = c
        else:
            break
    return k


def class_schedule(base: PlaneSchedule, k: int) -> PlaneSchedule:
    """The static refined schedule micro-batches of class-``k`` tiles run:
    ``base`` refined at the class's conservative ratio bound 2**-k."""
    if k < 0:
        raise ValueError(f"class {k} < 0")
    if k == 0:
        return base
    return base.refine(2.0**-k)


def classify_tiles(
    canvas: np.ndarray,
    plan: TilePlan,
    *,
    max_class: int = MAX_CLASS,
    amax: float | None = None,
    thresholds: tuple[float, ...] | None = None,
) -> list[int]:
    """Budget class per tile of ``plan``, from each tile's *input window*
    (halo included — the window is what the forward actually consumes).
    Pass ``amax`` (the canvas abs-max) if already computed — admission
    also needs it for the amplitude-octave group key.  ``thresholds``
    switches from fixed octaves to a calibrated class table
    (:func:`budget_class_from_thresholds`)."""
    if amax is None:
        amax = float(np.max(np.abs(canvas)))
    out = []
    for t in plan.tiles:
        r = amplitude_ratio(canvas[t.y0 : t.y1, t.x0 : t.x1], amax)
        if thresholds is not None:
            out.append(budget_class_from_thresholds(r, thresholds))
        else:
            out.append(budget_class(r, max_class=max_class))
    return out
