"""Tiled-segmentation serving engine: request queue + slot table +
shape/class-grouped micro-batching, with per-image energy accounting.

The LM engine's loop, re-based on image tiles: requests (arbitrary-size
images) wait in a FIFO, a bounded slot table caps in-flight stitching
canvases, and the unit of batched work is a *micro-batch of tiles* instead
of one token per sequence.  Tiles are grouped by

    (input window shape, budget class, image amplitude octave)

and packed into fixed-size batches (padded with zero tiles), so the jit
cache holds one executable per group signature — a handful per image
geometry, reused across every request — and inside each executable the
static per-layer plane counts hit the same
``kernels.mma_matmul.plane_variant`` specializations.  Groups freely mix
tiles of different requests: micro-batching across the queue is the whole
point of the slot table.

Accounting mirrors the LM engine's energy story, per *image*: relation-(2)
cycles of every tile the image consumed (halo overhead included, priced
honestly) under its refined schedule, against the useful whole-canvas ops
— time, GOPS and GOPS/W at the paper's implied accelerator power.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cycle_model as cm
from repro.core import energy_model as em
from repro.core.plane_schedule import PlaneSchedule
from repro.models import unet
from repro.obs.events import NULL_SINK, Event
from repro.serve.queue import FifoQueue, SlotTable

from . import adaptive, tiling

_IMPLIED_POWER_W = (
    cm.PAPER_TABLE1["proposed"]["gops"] / cm.PAPER_TABLE1["proposed"]["gops_w"]
)


@functools.lru_cache(maxsize=2)
def _shared_forward(per_tile_quant: bool):
    """Process-wide jitted tile forward, shared by every engine instance so
    repeated engine construction (the autotuner's certify loop, the bench's
    row sweep) reuses one compile cache instead of re-tracing per engine.

    ``per_tile_quant=True`` vmaps the forward over the micro-batch, so the
    dynamic activation quantization inside sees one tile at a time: each
    tile gets its *own* int8 scale, numerics stop depending on which tiles
    happened to share a batch, and the per-tile certificate of a
    :class:`~repro.autotune.plan.TunedPlan` (computed on single windows)
    transfers to the batched serving path exactly."""
    if per_tile_quant:
        def fwd(params, x, cfg):
            return jax.vmap(
                lambda xi: unet.forward(params, xi[None], cfg)[0]
            )(x)

        return jax.jit(fwd, static_argnums=2)
    return jax.jit(unet.forward, static_argnums=2)


@dataclass
class SegResult:
    """One served image: stitched logits + the modeled energy account."""

    logits: np.ndarray  # (H, W, n_classes) f32
    cycles: int
    ops: int
    n_tiles: int
    class_counts: dict[int, int]  # budget class -> tile count
    pj: int = 0  # metered active energy: tile cycles at their plane rates

    @property
    def time_ms(self) -> float:
        return self.cycles / cm.FREQ_HZ * 1e3

    @property
    def gops(self) -> float:
        return self.ops / (self.time_ms * 1e-3) / 1e9

    @property
    def gops_per_w(self) -> float:
        return self.gops / _IMPLIED_POWER_W

    @property
    def energy_mj(self) -> float:
        return _IMPLIED_POWER_W * self.time_ms

    @property
    def metered_mj(self) -> float:
        return em.pj_to_mj(self.pj)

    @property
    def metered_gops_per_w(self) -> float | None:
        return em.metered_gops_per_w(self.ops, self.pj)


@dataclass(frozen=True)
class TileEvent:
    """One emitted tile: the progressive-display unit of the streaming API.

    Under priority scheduling the engine emits an image's structure-class
    tiles (low ``klass`` — full-amplitude, many-plane regions) before its
    background tiles, so a caller consuming events sees the clinically
    interesting content first; ``request.partial()`` is the stitch so far.
    ``cycles`` is the tile's relation-(2) price at its class schedule — the
    currency the serving gateway charges micro-batches against its round
    budget in.  ``pj`` is the same work priced in integer picojoules: each
    layer's cycles at that layer's plane-proportional rate, so narrower
    budget classes are cheaper per cycle, not just shorter.
    """

    rid: int
    tile: int  # index into request.plan.tiles
    klass: int  # budget class (0 = structure / full amplitude)
    cycles: int
    core: tuple[int, int, int, int]  # (y0, x0, y1, x1) canvas coords
    done: bool  # this emission completed the request
    request: "SegRequest"
    pj: int = 0


@dataclass
class SegRequest:
    rid: int
    image: np.ndarray  # (H, W, C)
    # scheduling label: tiles of different groups never share a micro-batch,
    # so a caller (the gateway) can step one group's work under its own
    # cycle quantum without charging it for another group's tiles
    group: str | None = None
    # filled at admission
    plan: tiling.TilePlan | None = None
    slot: int = -1
    canvas_in: np.ndarray | None = None
    canvas_out: np.ndarray | None = None
    remaining: int = 0
    cycles: int = 0
    pj: int = 0
    ops: int = 0
    class_counts: dict[int, int] = field(default_factory=dict)
    emitted: list[int] = field(default_factory=list)  # tile emission order
    result: SegResult | None = None

    @property
    def done(self) -> bool:
        return self.result is not None

    def partial(self) -> np.ndarray:
        """The progressive stitch so far: emitted cores hold their final
        logits (stitching is a disjoint scatter, so early tiles are exact),
        unemitted cores are zero.  After completion this is the final
        result's logits."""
        if self.result is not None:
            return self.result.logits
        if self.canvas_out is None:
            raise ValueError(f"request {self.rid} not yet admitted")
        return self.canvas_out[: self.plan.h, : self.plan.w].copy()


class SegEngine:
    """Micro-batching executor for U-Net segmentation requests.

    Args:
      cfg: the :class:`~repro.models.unet.UNetConfig` to serve (its
        ``plane_schedule`` / ``planes`` is the certified layer-level
        policy; ``quant_mode='none'`` serves the float datapath and makes
        tiling bit-comparable to the whole-image forward).
      params: U-Net params for ``cfg``.
      tile: core stride (multiple of ``2**depth``).
      halo: exact by default (:func:`~repro.segserve.tiling.halo_for`);
        0 + ``cfg.pad_mode='edge'`` is the cheap seam-tolerant mode.
      batch: fixed tile micro-batch size (short groups are zero-padded).
      max_active: slot-table capacity — concurrent stitching canvases.
      adaptive: refine the layer schedule per budget class (quantized
        datapath only).
      max_class: amplitude-octave cap for flat/empty tiles.
      plan: a :class:`~repro.autotune.plan.TunedPlan` — overrides ``tile``
        and ``halo`` with the tuned geometry (validated through
        ``cfg.validate_tile``), classifies tiles by the *calibrated*
        thresholds instead of fixed octaves, runs each class at the plan's
        measured-ratio refined schedule, and switches the quantized
        datapath to per-tile activation scales so the plan's certificate
        transfers to the batched path exactly.
      priority: prefill-style tile prioritization — pick the pending
        micro-batch group with the *lowest* budget class first (structure
        before background), so progressive consumers (:class:`TileEvent`
        stream, ``SegRequest.partial``) see the high-information regions
        early.  Scheduling order only: group membership and within-group
        packing are fixed at admission, so the final stitch is
        bit-identical to the ``priority=False`` (admission-order) path
        whenever numerics are batch-composition independent — always under
        a tuned ``plan`` (per-tile quantization) or the float datapath,
        and on the batch-shared-scale quantized path whenever the
        admission sequence itself is unchanged (e.g. requests <=
        ``max_active``).  With shared scales *and* slot churn, reordering
        can shift which requests' same-key tiles share a batch, which
        legitimately moves low-bit rounding.
    """

    def __init__(
        self,
        cfg: unet.UNetConfig,
        params,
        *,
        tile: int = 32,
        halo: int | None = None,
        batch: int = 4,
        max_active: int = 4,
        adaptive: bool = True,
        max_class: int = adaptive.MAX_CLASS,
        plan=None,
        priority: bool = True,
    ):
        self.cfg = cfg
        self.params = params
        self.plan = plan
        if plan is not None:
            if getattr(plan, "workload", "unet") != "unet":
                raise ValueError(
                    f"cannot serve a {plan.workload!r} plan through the "
                    f"segmentation engine"
                )
            if len(plan.planes) != len(cfg.conv_layers()):
                raise ValueError(
                    f"plan covers {len(plan.planes)} convs but this "
                    f"geometry has {len(cfg.conv_layers())}"
                )
            # the halo walk's geometry guard, through UNetConfig validation
            tile = cfg.validate_tile(int(plan.tile), halo=int(plan.halo))
            halo = int(plan.halo)
        mult = 2**cfg.depth
        if tile < mult or tile % mult:
            raise ValueError(
                f"tile {tile} must be a positive multiple of 2**depth = {mult}"
            )
        if halo is not None and halo < 0:
            raise ValueError(f"halo {halo} < 0")
        if batch < 1:
            raise ValueError(f"batch {batch} < 1")
        self.tile = tile
        self.halo = halo
        self.batch = batch
        self.priority = priority
        quantized = cfg.quant_mode == "mma_int8"
        self.adaptive = adaptive and quantized and (
            plan is None or plan.class_thresholds is not None
        )
        self.max_class = max_class
        if plan is not None and quantized:
            self.base_schedule = plan.schedule()
        elif quantized:
            self.base_schedule = cfg.schedule()
        else:
            self.base_schedule = PlaneSchedule.uniform(
                8, len(cfg.conv_layers())
            )
        self.queue: FifoQueue[SegRequest] = FifoQueue()
        self.slots: SlotTable[SegRequest] = SlotTable(max_active)
        # (in_h, in_w, class, amax_octave) -> [(request, tile_index), ...]
        self._tasks: dict[tuple[int, int, int, int], list] = {}
        self._fwd = _shared_forward(plan is not None and quantized)
        self._cfg_for_class: dict[int, unet.UNetConfig] = {}
        self._pj_cache: dict[tuple[int, int, int], int] = {}
        self._next_rid = 0
        # telemetry (repro.obs.events): engine-local micro-batch records,
        # sequence-stamped — the gateway owns the cycle-exact account
        self.obs = NULL_SINK
        self._obs_seq = 0

    # ----------------------------------------------------------- schedules

    def _class_planes(self, k: int) -> tuple[int, ...]:
        """Per-layer budgets class-``k`` micro-batches run: the plan's
        calibrated table, else the octave-heuristic refinement."""
        if self.plan is not None:
            return tuple(self.plan.class_schedule(k))
        return adaptive.class_schedule(self.base_schedule, k).planes

    def class_cfg(self, k: int) -> unet.UNetConfig:
        """The (static, jit-cache-keyed) config class-``k`` batches run."""
        if k not in self._cfg_for_class:
            cfg = self.cfg
            if cfg.quant_mode == "mma_int8":
                cfg = dataclasses.replace(
                    cfg, plane_schedule=self._class_planes(k)
                )
            self._cfg_for_class[k] = cfg
        return self._cfg_for_class[k]

    def _tile_cycles(self, in_h: int, in_w: int, k: int) -> int:
        """Relation-(2) cycles of one (in_h, in_w) tile at class ``k``."""
        return cm.unet_window_cycles(
            (in_h, in_w), self.cfg.in_ch, self.cfg.base, self.cfg.depth,
            self.cfg.convs_per_stage, self._class_planes(k),
        )

    def _tile_pj(self, in_h: int, in_w: int, k: int) -> int:
        """Metered active energy of one (in_h, in_w) tile at class ``k``:
        the same relation-(2) layer cycles as :meth:`_tile_cycles`, each
        priced at its layer's plane rate (integer pJ).  Memoized like the
        cycle price — thousands of tiles share a handful of signatures."""
        key = (in_h, in_w, k)
        pj = self._pj_cache.get(key)
        if pj is None:
            layers = cm.unet_conv_layers(
                (in_h, in_w), self.cfg.in_ch, self.cfg.base, self.cfg.depth,
                self.cfg.convs_per_stage,
            )
            pj = em.schedule_pj(layers, self._class_planes(k))
            self._pj_cache[key] = pj
        return pj

    # ------------------------------------------------------------ admission

    def submit(self, image: np.ndarray, *, group: str | None = None
               ) -> SegRequest:
        """Enqueue one (H, W, C) image; returns its request handle.
        ``group`` labels the request's tiles for group-scoped stepping
        (QoS classes at the gateway); ``None`` joins the unlabeled pool."""
        image = np.asarray(image)
        if (image.ndim != 3 or image.shape[-1] != self.cfg.in_ch
                or image.shape[0] < 1 or image.shape[1] < 1):
            raise ValueError(
                f"expected (H, W, {self.cfg.in_ch}) image with H, W >= 1, "
                f"got {image.shape}"
            )
        req = SegRequest(rid=self._next_rid, image=image, group=group)
        self._next_rid += 1
        self.queue.push(req)
        return req

    def _admit(self, req: SegRequest) -> bool:
        # Plan before occupying: a planning error must not leak the slot.
        req.plan = tiling.plan_tiles(
            req.image.shape[0], req.image.shape[1], depth=self.cfg.depth,
            convs_per_stage=self.cfg.convs_per_stage, tile=self.tile,
            halo=self.halo,
        )
        slot = self.slots.occupy(req)
        if slot is None:
            return False
        req.slot = slot
        canvas = tiling.pad_canvas(req.image.astype(np.float32), req.plan)
        req.canvas_in = canvas
        req.canvas_out = np.zeros(
            (req.plan.pad_h, req.plan.pad_w, self.cfg.n_classes), np.float32
        )
        req.remaining = req.plan.n_tiles
        req.ops = cm.model_ops(
            cm.unet_conv_layers(
                (req.plan.pad_h, req.plan.pad_w), self.cfg.in_ch,
                self.cfg.base, self.cfg.depth, self.cfg.convs_per_stage,
            )
        )
        amax = float(np.max(np.abs(canvas)))
        if self.adaptive:
            classes = adaptive.classify_tiles(
                canvas, req.plan, max_class=self.max_class, amax=amax,
                thresholds=(
                    None if self.plan is None else self.plan.class_thresholds
                ),
            )
        else:
            classes = [0] * req.plan.n_tiles
        # The octave key component keeps batch-shared dynamic scales
        # compatible; under a plan the forward quantizes per tile, numerics
        # are batch-composition independent, and splitting groups by octave
        # would only fragment the packing — so collapse it.
        if self.plan is not None:
            octave = 0
        else:
            octave = int(math.floor(math.log2(amax))) if amax > 0 else 0
        for ti, (spec, k) in enumerate(zip(req.plan.tiles, classes)):
            key = (spec.in_h, spec.in_w, k, octave, req.group)
            self._tasks.setdefault(key, []).append((req, ti))
            req.class_counts[k] = req.class_counts.get(k, 0) + 1
        return True

    # ------------------------------------------------------------- stepping

    def has_work(self, group: str | None = ...) -> bool:
        """Admitted tiles are waiting to run (the public surface callers —
        the gateway's adapter — poll instead of reaching into the task
        table).  Pass ``group`` to ask about one scheduling group only
        (``...``, the default, means *any* group)."""
        if group is ...:
            return bool(self._tasks)
        return any(key[4] == group for key in self._tasks)

    def pending(self, group: str | None = ...) -> int:
        """How many admitted tiles are waiting to run."""
        return sum(
            len(g) for key, g in self._tasks.items()
            if group is ... or key[4] == group
        )

    def _next_key(self, group=...):
        keys = (
            list(self._tasks) if group is ...
            else [k for k in self._tasks if k[4] == group]
        )
        if not keys:
            return None
        if self.priority:
            return min(keys, key=lambda g: g[2])
        return keys[0]

    def next_cost(self, group: str | None = ...) -> int:
        """Relation-(2) price of the micro-batch :meth:`step` would run
        next (0 when idle).  The preemption point of the serving gateway:
        a step whose price exceeds the class's remaining quantum is not
        started — the quantum carries to the next round instead of the
        step overdrafting it."""
        key = self._next_key(group)
        if key is None:
            return 0
        in_h, in_w, k = key[0], key[1], key[2]
        n = min(len(self._tasks[key]), self.batch)
        return n * self._tile_cycles(in_h, in_w, k)

    def step(self, group: str | None = ...) -> list[TileEvent]:
        """Run one micro-batch and return its tile emissions (empty when
        idle — falsy, so boolean call sites keep working).  ``group``
        restricts the step to one scheduling group's tiles (the gateway's
        class-quantum accounting); the default serves any group.

        Group choice is the prioritization point: structure-first (lowest
        budget class; FIFO among equals via dict insertion order) under
        ``priority=True``, plain admission order otherwise.  Only *which*
        group runs next changes — group membership and within-group batch
        packing are fixed at admission — so emission order is scheduling
        policy, not numerics (see the ``priority`` docstring for the one
        shared-scale caveat under slot churn).
        """
        key = self._next_key(group)
        if key is None:
            return []
        task_group = self._tasks[key]
        taken, self._tasks[key] = task_group[: self.batch], task_group[self.batch :]
        if not self._tasks[key]:
            del self._tasks[key]
        in_h, in_w, k = key[0], key[1], key[2]
        x = np.zeros((self.batch, in_h, in_w, self.cfg.in_ch), np.float32)
        for b, (req, ti) in enumerate(taken):
            spec = req.plan.tiles[ti]
            x[b] = req.canvas_in[spec.y0 : spec.y1, spec.x0 : spec.x1]
        out = np.asarray(self._fwd(self.params, jnp.asarray(x), self.class_cfg(k)))
        events: list[TileEvent] = []
        cyc = self._tile_cycles(in_h, in_w, k)  # one price, both accounts
        pj = self._tile_pj(in_h, in_w, k)
        for b, (req, ti) in enumerate(taken):
            spec = req.plan.tiles[ti]
            cy, cx = spec.crop
            req.canvas_out[
                spec.core_y0 : spec.core_y1, spec.core_x0 : spec.core_x1
            ] = out[b][cy, cx]
            req.cycles += cyc
            req.pj += pj
            req.remaining -= 1
            req.emitted.append(ti)
            if req.remaining == 0:
                self._finish(req)
            events.append(
                TileEvent(
                    rid=req.rid, tile=ti, klass=k, cycles=cyc,
                    core=(
                        spec.core_y0, spec.core_x0, spec.core_y1, spec.core_x1
                    ),
                    done=req.done, request=req, pj=pj,
                )
            )
        if self.obs.enabled:
            self._obs_seq += 1
            self.obs.emit(Event(self._obs_seq, "seg-batch", dict(
                klass=int(k), tiles=len(taken), cycles=int(cyc * len(taken)),
                pj=int(pj * len(taken)),
            )))
        return events

    def _finish(self, req: SegRequest) -> None:
        req.result = SegResult(
            logits=req.canvas_out[: req.plan.h, : req.plan.w].copy(),
            cycles=req.cycles,
            ops=req.ops,
            n_tiles=req.plan.n_tiles,
            class_counts=dict(sorted(req.class_counts.items())),
            pj=req.pj,
        )
        self.slots.release(req.slot)
        req.canvas_in = None
        req.canvas_out = None

    # ------------------------------------------------------------ the loop

    def run(self, images: list[np.ndarray]) -> list[SegResult]:
        """Serve a batch of images to completion, in submission order."""
        reqs = [self.submit(im) for im in images]
        self.flush()
        return [r.result for r in reqs]

    def flush(self) -> None:
        """Drain the queue and every in-flight request (the event-less
        view of :meth:`serve_stream` — one loop, two surfaces)."""
        for _ in self.serve_stream([]):
            pass

    def serve_stream(self, images: list[np.ndarray]):
        """Progressive serving: yield :class:`TileEvent` s as tiles finish.

        Under ``priority=True`` each image's structure-class tiles stream
        out before its background tiles; consume ``event.request.partial()``
        for the stitch so far and ``event.request.result`` once
        ``event.done``.  Equivalent to :meth:`run` in final outputs."""
        for im in images:
            self.submit(im)
        while self.queue or self.slots.any_active() or self._tasks:
            self.queue.pump(self.slots, self._admit)
            events = self.step()
            if not events and not self.queue:
                break
            yield from events
