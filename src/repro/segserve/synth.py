"""Synthetic medical-style test images for the segmentation bench/examples.

One generator shared by ``benchmarks/segserve.py`` and
``examples/segment_image.py`` so the image the bench prices and the image
the example demonstrates never drift apart.
"""
from __future__ import annotations

import numpy as np


def phantom_image(h: int, w: int, c: int, seed: int = 0) -> np.ndarray:
    """Quiet background with one bright structure near the top-left — the
    content-adaptive case: tiles whose halo window clears the structure sit
    orders of magnitude below the image amplitude."""
    rng = np.random.default_rng(seed)
    img = rng.normal(0.0, 0.01, (h, w, c))
    sh, sw = max(1, h // 5), max(1, w // 4)
    img[sh : 2 * sh, sw : 2 * sw] += rng.normal(0.0, 1.0, (sh, sw, c))
    return img.astype(np.float32)
