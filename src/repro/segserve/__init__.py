"""Streaming tiled-segmentation serving: the paper's target application
(U-Net medical-image segmentation) as a served workload.

``tiling``   — receptive-field-exact halo decomposition + stitching of
               arbitrary (H, W) images, numerically equivalent to the
               whole-image forward;
``adaptive`` — content-adaptive per-tile plane budgets (flat background
               tiles consume fewer MSB digits), layered on the certified
               per-layer :class:`~repro.core.PlaneSchedule` — with budget
               classes from fixed octaves or from a
               :class:`~repro.autotune.TunedPlan`'s calibrated thresholds;
``engine``   — request-queue + slot-table micro-batching executor with
               per-image relation-(2) cycle / GOPS/W accounting; pass a
               tuned ``plan=`` to serve a certified operating point
               (tuned tile/halo, calibrated classes, per-tile quant).
"""
from . import adaptive, engine, synth, tiling  # noqa: F401
from .adaptive import budget_class_from_thresholds  # noqa: F401
from .engine import SegEngine, SegRequest, SegResult, TileEvent  # noqa: F401
from .tiling import halo_for, plan_tiles, stitch, tiled_forward  # noqa: F401
