"""Receptive-field-exact halo tiling of arbitrary images for the U-Net.

The paper's target deployment segments medical images whose sizes have
nothing to do with the calibrated 80x80 geometry.  DSLR-CNN streams CNN
compute over spatial tiles; the original U-Net paper's "overlap-tile"
strategy makes tiling *exact* by giving each tile enough surrounding
context that its core region is unaffected by the artificial cut.  This
module is that strategy for the SAME-padded U-Net in ``models.unet``:

  * :func:`halo_for` — the exact invalid-margin width of an artificial
    tile boundary, from a worst-case walk of the forward graph;
  * :func:`plan_tiles` — a core grid over the (2**depth-aligned, padded)
    canvas, each core dilated by the halo and *clipped to the canvas*, so
    a tile edge that coincides with a real image edge keeps SAME-padding
    semantics and stays bit-comparable to the whole-image forward;
  * :func:`stitch` — writes each tile's valid core back into one canvas;
  * :func:`tiled_forward` — the single-shot reference path the serving
    engine (and the equivalence tests) are built on.

Alignment is the load-bearing invariant: core stride, halo, clip edges and
canvas dims are all multiples of ``2**depth``, so every tile start is
pool-aligned at every level of the ladder and maxpool windows, nearest-
upsample sources and skip concats coincide with the whole-image run.

Invalid-margin recurrence (per artificial side, in pixels at the current
resolution; ``c`` convs per stage): a SAME conv widens the wrong border by
one row (``m += 1`` per conv), a 2x2/2 maxpool keeps a pooled row wrong if
its window touches a wrong row (``m = ceil(m/2)``), nearest upsample
doubles it (``m = 2m``), and skip concat takes the worse branch
(``m = max(m, skip)``).  The input halo must cover the final margin.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def _ceil_to(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def halo_for(depth: int, convs_per_stage: int = 1) -> int:
    """Exact halo width (input pixels per side) that makes an artificial
    tile boundary invisible to the core region, rounded up to a multiple of
    ``2**depth`` so clipped tiles stay pool-aligned.

    E.g. depth=3, one conv per stage (the calibrated geometry): the margin
    walk gives 23 wrong border pixels, so the halo is 24.
    """
    if depth < 0:
        raise ValueError(f"depth {depth} < 0")
    if convs_per_stage < 1:
        raise ValueError(f"convs_per_stage {convs_per_stage} < 1")
    m = 0
    skip_margins = []
    for _ in range(depth):
        m += convs_per_stage  # encoder convs
        skip_margins.append(m)
        m = -(-m // 2)  # 2x2/2 maxpool: ceil
    m += convs_per_stage  # bottleneck convs
    for level in reversed(range(depth)):
        m = 2 * m  # nearest upsample
        m = max(m, skip_margins[level])  # skip concat
        m += convs_per_stage  # decoder convs
    return _ceil_to(max(m, 1), 2**depth)


@dataclass(frozen=True)
class TileSpec:
    """One tile: its input window and its valid core, in canvas coords.

    The input window is the core dilated by the halo and clipped to the
    canvas — where clipping bites, the tile edge *is* an image edge and
    SAME padding there is the real thing, not an artifact.
    """

    y0: int
    x0: int
    y1: int
    x1: int
    core_y0: int
    core_x0: int
    core_y1: int
    core_x1: int

    @property
    def in_h(self) -> int:
        return self.y1 - self.y0

    @property
    def in_w(self) -> int:
        return self.x1 - self.x0

    @property
    def in_shape(self) -> tuple[int, int]:
        return (self.in_h, self.in_w)

    @property
    def crop(self) -> tuple[slice, slice]:
        """Slices selecting the valid core inside this tile's output."""
        return (
            slice(self.core_y0 - self.y0, self.core_y1 - self.y0),
            slice(self.core_x0 - self.x0, self.core_x1 - self.x0),
        )


@dataclass(frozen=True)
class TilePlan:
    """Tiling of one image: padded canvas geometry + the tile set."""

    h: int  # original image dims
    w: int
    pad_h: int  # canvas dims (multiples of 2**depth)
    pad_w: int
    depth: int
    tile: int
    halo: int
    tiles: tuple[TileSpec, ...]

    @property
    def n_tiles(self) -> int:
        return len(self.tiles)

    def halo_overhead(self) -> float:
        """Input pixels computed / canvas pixels — the price of exactness."""
        total = sum(t.in_h * t.in_w for t in self.tiles)
        return total / (self.pad_h * self.pad_w)


def plan_tiles(
    h: int,
    w: int,
    *,
    depth: int,
    convs_per_stage: int = 1,
    tile: int = 32,
    halo: int | None = None,
) -> TilePlan:
    """Plan an exact tiling of an ``h x w`` image.

    The canvas pads ``h, w`` up to multiples of ``2**depth`` (the forward
    needs it; the pad strip rides the bottom/right tiles and is cropped off
    after stitching).  Cores of ``tile x tile`` (smaller at the far edges)
    stride the canvas; ``halo`` defaults to the exact :func:`halo_for`
    width and may be overridden — smaller halos (down to 0, typically with
    ``pad_mode='edge'``) buy cycles at the price of seam error.
    """
    if h < 1 or w < 1:
        raise ValueError(f"image dims {h}x{w} must be positive")
    mult = 2**depth
    if tile < mult or tile % mult:
        raise ValueError(
            f"tile {tile} must be a positive multiple of 2**depth = {mult}"
        )
    if halo is None:
        halo = halo_for(depth, convs_per_stage)
    elif halo < 0:
        raise ValueError(f"halo {halo} < 0")
    else:
        halo = _ceil_to(halo, mult) if halo else 0
    pad_h, pad_w = _ceil_to(h, mult), _ceil_to(w, mult)
    tiles = []
    for cy in range(0, pad_h, tile):
        core_h = min(tile, pad_h - cy)
        for cx in range(0, pad_w, tile):
            core_w = min(tile, pad_w - cx)
            tiles.append(
                TileSpec(
                    y0=max(0, cy - halo),
                    x0=max(0, cx - halo),
                    y1=min(pad_h, cy + core_h + halo),
                    x1=min(pad_w, cx + core_w + halo),
                    core_y0=cy,
                    core_x0=cx,
                    core_y1=cy + core_h,
                    core_x1=cx + core_w,
                )
            )
    return TilePlan(
        h=h, w=w, pad_h=pad_h, pad_w=pad_w, depth=depth, tile=tile,
        halo=halo, tiles=tuple(tiles),
    )


def pad_canvas(image: np.ndarray, plan: TilePlan) -> np.ndarray:
    """(H, W, C) image -> (pad_h, pad_w, C) canvas (zero pad bottom/right)."""
    if image.shape[:2] != (plan.h, plan.w):
        raise ValueError(
            f"image {image.shape[:2]} does not match plan {(plan.h, plan.w)}"
        )
    return np.pad(
        image,
        ((0, plan.pad_h - plan.h), (0, plan.pad_w - plan.w), (0, 0)),
    )


def stitch(plan: TilePlan, outputs: list[np.ndarray]) -> np.ndarray:
    """Assemble per-tile outputs into the (h, w, C) result.

    ``outputs[i]`` is the full forward output of ``plan.tiles[i]``'s input
    window; only its valid core is kept.  Cores partition the canvas, so
    stitching is a plain scatter — no blending, no seams.
    """
    if len(outputs) != plan.n_tiles:
        raise ValueError(f"{len(outputs)} outputs for {plan.n_tiles} tiles")
    c = outputs[0].shape[-1]
    canvas = np.zeros((plan.pad_h, plan.pad_w, c), outputs[0].dtype)
    for spec, out in zip(plan.tiles, outputs):
        if out.shape[:2] != spec.in_shape:
            raise ValueError(
                f"tile output {out.shape[:2]} does not match input window "
                f"{spec.in_shape}"
            )
        cy, cx = spec.crop
        canvas[spec.core_y0 : spec.core_y1, spec.core_x0 : spec.core_x1] = (
            out[cy, cx]
        )
    return canvas[: plan.h, : plan.w]


def tiled_forward(params, image: np.ndarray, cfg, *, tile: int = 32,
                  halo: int | None = None):
    """Whole-image-equivalent segmentation of one (H, W, C) image, tile by
    tile — the single-shot reference the serving engine micro-batches.

    With the default exact halo and ``cfg.quant_mode='none'`` this matches
    ``unet.forward`` on the padded canvas to float tolerance (the
    equivalence the tests lock).  Quantized runs differ slightly by design:
    activation scales are dynamic per tile batch, not per image.
    """
    import jax.numpy as jnp

    from repro.models import unet

    plan = plan_tiles(
        image.shape[0], image.shape[1], depth=cfg.depth,
        convs_per_stage=cfg.convs_per_stage, tile=tile, halo=halo,
    )
    canvas = pad_canvas(np.asarray(image), plan)
    outs = []
    for spec in plan.tiles:
        xin = jnp.asarray(
            canvas[spec.y0 : spec.y1, spec.x0 : spec.x1][None]
        )
        outs.append(np.asarray(unet.forward(params, xin, cfg)[0]))
    return stitch(plan, outs), plan
