"""Granite-20B (code) — llama-arch with MQA (kv=1). [arXiv:2405.04324; hf]

kv_heads=1 cannot shard over the 16-way model axis; the sharding rules fall
back automatically (head_dim sharding for the cache) — see parallel/sharding.
"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="granite_20b",
        family="dense",
        n_layers=52,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        d_ff=24576,
        vocab=49_152,
        rope_theta=10_000.0,
        act="gelu",  # GPT-BigCode-style MLP
        microbatches=8,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=1, d_ff=256, vocab=512,
        microbatches=1, attn_chunk=64,
    )
