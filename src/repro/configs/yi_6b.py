"""Yi-6B — llama-architecture dense GQA. [arXiv:2403.04652; hf]"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="yi_6b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        d_ff=11008,
        vocab=64_000,
        rope_theta=5_000_000.0,
        act="swiglu",
        microbatches=4,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
        microbatches=1, attn_chunk=64,
    )
