"""H2O-Danube3-4B — llama/mistral mix with sliding-window attention.
[arXiv:2401.16818 (danube series); unverified]

SWA window 4096 keeps attention sub-quadratic, so this arch RUNS the
long_500k decode cell (the KV cache is bounded by the window).
"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="h2o_danube_3_4b",
        family="dense",
        n_layers=24,
        d_model=3840,
        n_heads=32,
        n_kv_heads=8,
        d_ff=10240,
        vocab=32_000,
        rope_theta=10_000.0,
        swa_window=4096,
        act="swiglu",
        microbatches=4,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
        swa_window=32, microbatches=1, attn_chunk=64,
    )
