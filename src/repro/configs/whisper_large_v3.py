"""Whisper-large-v3 — enc-dec audio; conv/mel frontend is a stub
(precomputed frame embeddings). [arXiv:2212.04356; unverified]

32 encoder + 32 decoder layers, d_model 1280, 20 heads (MHA), GELU MLP.
Assigned seq shapes apply to the decoder stream (DESIGN.md).
"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper_large_v3",
        family="encdec",
        n_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab=51_866,
        act="gelu",
        enc_layers=32,
        enc_seq=1500,
        microbatches=2,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab=512,
        enc_layers=2, enc_seq=32, microbatches=1, attn_chunk=64,
    )
