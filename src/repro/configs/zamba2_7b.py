"""Zamba2-7B — hybrid: 81 Mamba2 layers + one weight-shared attention block
interleaved every 6 layers. [arXiv:2411.15242; unverified]

ssm_state=64; the shared attention block runs on [hidden ; embedding]
(2*d_model wide).  SSM state is O(1) in sequence length -> long_500k RUNS.
"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2_7b",
        family="hybrid",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        d_ff=14336,  # unused by mamba blocks; shared block is attention-only
        vocab=32_000,
        rope_theta=10_000.0,
        ssm_state=64,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv=4,
        attn_every=6,
        microbatches=4,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        n_layers=5, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab=512,
        ssm_state=16, ssm_head_dim=32, attn_every=2, microbatches=1,
        attn_chunk=64,
    )
