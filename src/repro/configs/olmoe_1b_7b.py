"""OLMoE-1B-7B — MoE, 64 experts top-8, d_ff=1024 per expert.
[arXiv:2409.02060; hf]
"""
from .base import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="olmoe_1b_7b",
        family="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1024,
        vocab=50_304,
        rope_theta=10_000.0,
        act="swiglu",
        moe=MoEConfig(n_experts=64, top_k=8, expert_ff=1024, capacity_factor=1.25,
                      ep=True),
        microbatches=2,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
        moe=MoEConfig(n_experts=8, top_k=2, expert_ff=128, capacity_factor=1.25),
        microbatches=1, attn_chunk=64,
    )
