"""DBRX-132B — fine-grained MoE, 16 experts top-4.
[hf:databricks/dbrx-base; unverified]
"""
from .base import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="dbrx_132b",
        family="moe",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=10752,
        vocab=100_352,
        rope_theta=500_000.0,
        act="swiglu",
        moe=MoEConfig(n_experts=16, top_k=4, expert_ff=10752, capacity_factor=1.25,
                      ep=True),
        microbatches=8,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
        moe=MoEConfig(n_experts=4, top_k=2, expert_ff=256, capacity_factor=1.25),
        microbatches=1, attn_chunk=64,
    )
