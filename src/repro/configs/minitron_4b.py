"""Minitron-4B — width-pruned Nemotron, dense GQA. [arXiv:2407.14679; hf]"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="minitron_4b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        head_dim=128,  # minitron keeps 128-dim heads after width pruning
        d_ff=9216,
        vocab=256_000,
        rope_theta=10_000.0,
        act="swiglu",
        microbatches=4,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, microbatches=1, attn_chunk=64,
    )
