"""InternVL2-76B — VLM: stub InternViT frontend + InternLM2-like 76B LM
backbone. [arXiv:2404.16821; unverified]

Per the assignment, only the transformer backbone is modeled; the vision
frontend is a stub (``input_specs`` provides 256 precomputed patch
embeddings prepended to the token stream).
"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="internvl2_76b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab=128_256,
        rope_theta=1_000_000.0,
        act="swiglu",
        vlm_patches=256,
        microbatches=8,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
        vlm_patches=8, microbatches=1, attn_chunk=64,
    )
