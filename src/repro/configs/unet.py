"""The paper's U-Net (Table-1-calibrated geometry) — see models/unet.py."""
from repro.models.unet import UNetConfig


def config() -> UNetConfig:
    return UNetConfig()


def smoke_config() -> UNetConfig:
    return UNetConfig(hw=16, in_ch=4, base=8, depth=2, n_classes=3)
