"""RWKV6-3B ("Finch") — attention-free, data-dependent decay.
[arXiv:2404.05892; hf]

State is O(1) in sequence length -> long_500k RUNS.
"""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6_3b",
        family="ssm",
        n_layers=32,
        d_model=2560,
        n_heads=40,  # d_model / 64
        n_kv_heads=40,
        d_ff=8960,
        vocab=65_536,
        ssm_head_dim=64,
        microbatches=2,
    )


def smoke_config() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=2, n_kv_heads=2, d_ff=256, vocab=512,
        ssm_head_dim=64, microbatches=1, attn_chunk=64,
    )
