"""Architecture configs: the 10 assigned archs + the paper's U-Net.

``get_config(name)`` returns the full-size config; ``get_smoke_config(name)``
a reduced same-family config for CPU smoke tests.
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "minitron_4b",
    "yi_6b",
    "h2o_danube_3_4b",
    "granite_20b",
    "internvl2_76b",
    "olmoe_1b_7b",
    "dbrx_132b",
    "zamba2_7b",
    "whisper_large_v3",
    "rwkv6_3b",
]


def _norm(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str):
    mod = importlib.import_module(f"repro.configs.{_norm(name)}")
    return mod.config()


def get_smoke_config(name: str):
    mod = importlib.import_module(f"repro.configs.{_norm(name)}")
    return mod.smoke_config()
