"""Config schema shared by every architecture, plus the input-shape sets.

Families: 'dense' (decoder-only transformer, optionally GQA/MQA/SWA),
'moe' (dense + mixture-of-experts FFN), 'hybrid' (Mamba2 backbone with a
shared attention block — Zamba2), 'ssm' (attention-free RWKV6), 'encdec'
(Whisper), 'vlm' (dense LM + stub patch-embedding prefix), 'unet' (the
paper's target application).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class QuantConfig:
    """The paper's technique as a first-class feature: any linear can run
    int8 through the MMA datapath with MSDF-style plane truncation."""

    mode: str = "none"  # 'none' | 'mma_int8'
    planes: int = 8  # MSB planes consumed (global early-termination knob)
    # Per-layer plane budgets (dynamic precision, MINT-style).  Consumed by
    # the transformer families (dense/moe/vlm) — models.build rejects it
    # elsewhere.  When set, it overrides ``planes`` for the scan-rolled
    # block stack: entry l is layer l's budget (clamped to the last entry
    # for deeper stacks) and rides the
    # layer scan as data via the exact bit-mask truncation identity
    # (core.bitplane.truncate_to_planes).  Non-block linears (the lm head)
    # keep the global ``planes``.  Build with
    # core.PlaneSchedule.from_weights / serve.engine.lm_schedule_from_params.
    plane_schedule: tuple[int, ...] | None = None
    impl: str = "xla"  # 'xla' | 'pallas' | 'cascade' | 'int8'
    # Serving extensions (beyond-paper, §Perf iteration 3): store weights as
    # int8 (+per-channel scale) instead of quantizing bf16 on the fly, and
    # keep the KV cache in int8 with a calibrated static scale.
    weights_int8: bool = False
    kv_int8: bool = False


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 1
    expert_ff: int = 0  # per-expert FFN width
    capacity_factor: float = 1.25
    # Expert parallelism via shard_map + explicit all-to-all over 'model'
    # (GSPMD cannot shard the data-dependent scatter dispatch — §Perf iter 1).
    ep: bool = False


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm | unet
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    swa_window: int = 0  # 0 = full attention; >0 = sliding window
    norm_eps: float = 1e-5
    act: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False
    # MoE
    moe: MoEConfig = field(default_factory=MoEConfig)
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_every: int = 0  # hybrid: shared attn block after every N ssm layers
    # enc-dec
    enc_layers: int = 0
    enc_seq: int = 1500  # stub audio frontend frames
    # vlm
    vlm_patches: int = 0
    # quantized MMA datapath
    quant: QuantConfig = field(default_factory=QuantConfig)
    # training knobs
    remat: str = "full"  # none | full
    microbatches: int = 1
    seq_shard: bool = True  # sequence-parallel residual stream
    attn_chunk: int = 1024  # flash-attention kv chunk
    scan_unroll: bool = False  # unroll layer scans (dry-run cost probes)
    shard_rules: str = "default"  # logical->mesh rule set (see parallel.sharding)
    dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


# The assigned LM shape set (identical across the 10 archs).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# Archs allowed to run long_500k (sub-quadratic attention); all others SKIP
# per the assignment (full attention at 500k), noted in DESIGN.md.
LONG_CONTEXT_OK = {"h2o_danube_3_4b", "zamba2_7b", "rwkv6_3b"}


def cells(arch_name: str) -> list[str]:
    """The shape cells that are runnable for this arch."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch_name.replace("-", "_") in LONG_CONTEXT_OK:
        out.append("long_500k")
    return out
