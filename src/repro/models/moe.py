"""Mixture-of-experts FFN: top-k routing, capacity-bounded sort-based
dispatch (no dense (T, E, C) dispatch tensors — scales to 32k sequences),
expert-parallel over the 'model' mesh axis.

Dispatch: flatten (token, k) assignments, sort by expert id, take the first
C = ceil(T*k/E * capacity_factor) slots per expert (tokens beyond capacity
are dropped — standard Switch/Mixtral-style), run the per-expert FFN as one
batched einsum over stacked expert weights, and scatter-add weighted outputs
back.  Sorting gives O(Tk log Tk) routing and O(E*C*D) activation memory,
and the E dimension shards cleanly over 'model' (GSPMD inserts the
all-to-all at the dispatch boundary).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain
from . import layers


def init_moe(key, cfg) -> dict:
    m = cfg.moe
    ks = jax.random.split(key, 4)
    e, d, f = m.n_experts, cfg.d_model, m.expert_ff

    def ex(k, shape, fan_in):
        return (jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(
            jnp.bfloat16
        )

    return {
        "router": layers.init_linear(ks[0], d, e),
        "w_gate": ex(ks[1], (e, d, f), d),
        "w_up": ex(ks[2], (e, d, f), d),
        "w_down": ex(ks[3], (e, f, d), f),
    }


def moe_ffn(p: dict, x: jax.Array, cfg) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)

    logits = layers.linear(p["router"], xf).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, m.top_k)  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Flatten (token, k) assignments and sort by expert.
    tk = t * m.top_k
    eid = idx.reshape(tk)
    tok = jnp.repeat(jnp.arange(t), m.top_k)
    gw = gate.reshape(tk)
    order = jnp.argsort(eid)
    eid_s, tok_s, gw_s = eid[order], tok[order], gw[order]
    # Position within the expert's segment (first-occurrence trick).
    first = jnp.searchsorted(eid_s, eid_s, side="left")
    pos = jnp.arange(tk) - first
    # capacity floor of 4 keeps tiny decode batches effectively dropless
    cap = min(tk, max(int(t * m.top_k / m.n_experts * m.capacity_factor), 4))
    keep = pos < cap

    # Dispatch: (E, C, D) buffer — E shards over 'model' (EP), C over the DP
    # axes (each data shard's tokens land in its capacity slice after the
    # GSPMD all-to-all), D unsharded.  Without the C sharding every data
    # shard would replicate all expert FLOPs (16x waste — caught by the
    # dry-run roofline, see EXPERIMENTS.md §Perf).
    pos_c = jnp.where(keep, pos, cap)  # dropped -> OOB row (scatter-drop)
    buf = jnp.zeros((m.n_experts, cap, d), x.dtype)
    xe = buf.at[eid_s, pos_c].set(xf[tok_s], mode="drop")
    xe = constrain(xe, "experts", "expert_capacity", None)

    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = constrain(h, "experts", "expert_capacity", "ffn")
    oe = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    oe = constrain(oe, "experts", "expert_capacity", None)

    # Combine: gather each kept assignment's output, weight, scatter-add.
    contrib = oe[eid_s, jnp.minimum(pos, cap - 1)]
    contrib = contrib * (gw_s * keep)[:, None].astype(x.dtype)
    out = jnp.zeros((t, d), x.dtype).at[tok_s].add(contrib)
    return out.reshape(b, s, d)


def _local_dispatch(xf, logits, n_experts, top_k, cap, dtype):
    """Shared routing math on a (local) token slab: returns the dispatch
    buffer (E, cap, D) plus the combine metadata."""
    t, d = xf.shape
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    tk = t * top_k
    eid = idx.reshape(tk)
    tok = jnp.repeat(jnp.arange(t), top_k)
    gw = gate.reshape(tk)
    order = jnp.argsort(eid)
    eid_s, tok_s, gw_s = eid[order], tok[order], gw[order]
    first = jnp.searchsorted(eid_s, eid_s, side="left")
    pos = jnp.arange(tk) - first
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap)
    buf = jnp.zeros((n_experts, cap, d), dtype)
    xe = buf.at[eid_s, pos_c].set(xf[tok_s], mode="drop")
    return xe, (eid_s, pos, tok_s, gw_s, keep)


def _local_combine(oe, meta, t, cap, dtype):
    eid_s, pos, tok_s, gw_s, keep = meta
    d = oe.shape[-1]
    contrib = oe[eid_s, jnp.minimum(pos, cap - 1)]
    contrib = contrib * (gw_s * keep)[:, None].astype(dtype)
    return jnp.zeros((t, d), dtype).at[tok_s].add(contrib)


def moe_ffn_ep(p: dict, x: jax.Array, cfg) -> jax.Array:
    """Expert parallelism with an EXPLICIT all-to-all (shard_map over the
    full mesh).  Each (data, seq) shard routes its local tokens, packs an
    (M, E_loc, C, D) send buffer (M = |model| expert shards), all-to-alls
    over 'model', runs its local experts, and all-to-alls back.

    Replaces the GSPMD-partitioned scatter dispatch, whose data-dependent
    indices force token replication (olmoe train_4k baseline: 243 s
    collective term vs 0.4 s compute — EXPERIMENTS.md §Perf iteration 1).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import current_mesh, spec_for

    mesh = current_mesh()
    m = cfg.moe
    b, s, d = x.shape
    if mesh is None:
        return moe_ffn(p, x, cfg)
    msize = mesh.shape.get("model", 1)

    # token layout from the ACTIVE rule set: default = (batch->dp, seq->model)
    # [SP], ep_dp = (batch->all axes, seq unsharded) [DeepSpeed-MoE style].
    x_spec = spec_for(("batch", "seq", None), x.shape)

    def _size(entry):
        if entry is None:
            return 1
        axes = entry if isinstance(entry, tuple) else (entry,)
        out = 1
        for a in axes:
            out *= mesh.shape[a]
        return out

    b_fac, s_fac = _size(x_spec[0]), _size(x_spec[1])
    if (msize == 1 or m.n_experts % msize or b % b_fac or s % s_fac
            or cfg.quant.mode != "none"):
        return moe_ffn(p, x, cfg)  # fall back to the GSPMD path

    e_loc = m.n_experts // msize
    t_loc = (b // b_fac) * (s // s_fac)
    cap = min(t_loc * m.top_k,
              max(int(t_loc * m.top_k / m.n_experts * m.capacity_factor), 4))

    w_spec = P("model", None, None)

    def body(xs, router_w, wg, wu, wd):
        bl, sl, _ = xs.shape
        xf = xs.reshape(bl * sl, d)
        logits = (xf @ router_w.astype(jnp.float32))
        xe, meta = _local_dispatch(xf, logits, m.n_experts, m.top_k, cap, xs.dtype)
        # (E, C, D) -> (M, E_loc, C, D): expert e = m'*E_loc + j lives on m'
        send = xe.reshape(msize, e_loc, cap, d)
        recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0,
                                  tiled=False)
        # recv: (M, E_loc, C, D) — slabs from every source shard
        xcat = recv.transpose(1, 0, 2, 3).reshape(e_loc, msize * cap, d)
        g = jnp.einsum("ecd,edf->ecf", xcat, wg.astype(xs.dtype))
        u = jnp.einsum("ecd,edf->ecf", xcat, wu.astype(xs.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xs.dtype) * u
        oe = jnp.einsum("ecf,efd->ecd", h, wd.astype(xs.dtype))
        back = oe.reshape(e_loc, msize, cap, d).transpose(1, 0, 2, 3)
        ret = jax.lax.all_to_all(back, "model", split_axis=0, concat_axis=0,
                                 tiled=False)
        oe_local = ret.reshape(m.n_experts, cap, d)
        y = _local_combine(oe_local, meta, bl * sl, cap, xs.dtype)
        return y.reshape(bl, sl, d)

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec, w_spec),
        out_specs=x_spec,
        check_rep=False,
    )
    return fn(x, p["router"]["w"], p["w_gate"], p["w_up"], p["w_down"])


def load_balance_loss(p: dict, x: jax.Array, cfg) -> jax.Array:
    """Auxiliary load-balancing loss (Switch-style: E * sum(f_e * P_e))."""
    m = cfg.moe
    xf = x.reshape(-1, x.shape[-1])
    logits = layers.linear(p["router"], xf).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    f = jnp.mean(jax.nn.one_hot(top1, m.n_experts), axis=0)
    pmean = jnp.mean(probs, axis=0)
    return m.n_experts * jnp.sum(f * pmean)
