"""Model zoo: every assigned architecture family, built from shared layers.

All stacks are ``lax.scan``-rolled over layers (O(1) HLO size in depth) and
annotated with logical-axis sharding constraints (repro.parallel.sharding).
"""
from . import layers  # noqa: F401


# Families whose forward consumes cfg.quant.plane_schedule (the per-layer
# dynamic-precision policy rides the transformer layer scan).  Elsewhere a
# schedule would be silently ignored — reject it instead.
PLANE_SCHEDULE_FAMILIES = ("dense", "moe", "vlm")


def build(cfg):
    """Return the model module for a config (forward/init/decode API)."""
    from . import rwkv6, transformer, unet, whisper, zamba2

    quant = getattr(cfg, "quant", None)
    if (quant is not None and getattr(quant, "plane_schedule", None) is not None
            and cfg.family not in PLANE_SCHEDULE_FAMILIES):
        raise NotImplementedError(
            f"quant.plane_schedule is only consumed by the transformer "
            f"families {PLANE_SCHEDULE_FAMILIES}, not {cfg.family!r}; use the "
            f"global quant.planes knob there (U-Net has its own "
            f"UNetConfig.plane_schedule)"
        )
    return {
        "dense": transformer,
        "moe": transformer,
        "vlm": transformer,
        "hybrid": zamba2,
        "ssm": rwkv6,
        "encdec": whisper,
        "unet": unet,
    }[cfg.family]
