"""Model zoo: every assigned architecture family, built from shared layers.

All stacks are ``lax.scan``-rolled over layers (O(1) HLO size in depth) and
annotated with logical-axis sharding constraints (repro.parallel.sharding).
"""
from . import layers  # noqa: F401


def build(cfg):
    """Return the model module for a config (forward/init/decode API)."""
    from . import rwkv6, transformer, unet, whisper, zamba2

    return {
        "dense": transformer,
        "moe": transformer,
        "vlm": transformer,
        "hybrid": zamba2,
        "ssm": rwkv6,
        "encdec": whisper,
        "unet": unet,
    }[cfg.family]
