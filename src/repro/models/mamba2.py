"""Mamba2 (SSD) block — chunked state-space scan, plus O(1)-state decode.

Training/prefill uses the chunk-parallel SSD form (intra-chunk quadratic +
inter-chunk state carry via lax.scan), so the sequence dim never appears
squared at full length.  Decode carries the (H, N, P) state — this is what
makes ``long_500k`` runnable for the hybrid/ssm archs.

Shapes follow the Mamba2 paper: d_inner = expand*d_model, P = head_dim,
H = d_inner/P heads, N = ssm_state, single B/C group (G=1, like Zamba2).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain
from . import layers

CHUNK = 256


def dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    p = cfg.ssm_head_dim
    h = d_inner // p
    n = cfg.ssm_state
    return d_inner, h, p, n


def init_mamba_block(key, cfg) -> dict:
    d_inner, h, p, n = dims(cfg)
    ks = jax.random.split(key, 6)
    conv_dim = d_inner + 2 * n  # x plus B and C streams get the short conv
    # Three separate projections instead of one packed in_proj: identical
    # math/params, but each output is independently shardable — the packed
    # layout's split offsets (d_inner, 2*d_inner+2n) don't align with 16-way
    # shard boundaries and forced an all-to-all + permutes per layer
    # (zamba2 prefill baseline — EXPERIMENTS.md §Perf iteration 4).
    return {
        "z_proj": layers.init_linear(ks[0], cfg.d_model, d_inner),
        "xbc_proj": layers.init_linear(ks[3], cfg.d_model, conv_dim),
        "dt_proj": layers.init_linear(ks[4], cfg.d_model, h),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), jnp.float32)
                   / math.sqrt(cfg.ssm_conv)).astype(jnp.bfloat16),
        "conv_b": jnp.zeros((conv_dim,), jnp.bfloat16),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": layers.init_norm(d_inner),
        "out_proj": layers.init_linear(ks[2], d_inner, cfg.d_model),
    }


def _short_conv(p, xbc, conv_state=None):
    """Depthwise causal conv, window cfg.ssm_conv.  conv_state: (B, W-1, C)
    for decode; returns (out, new_state)."""
    w = p["conv_w"].astype(xbc.dtype)  # (W, C)
    win = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros(xbc.shape[:1] + (win - 1,) + xbc.shape[2:], xbc.dtype)
        xp = jnp.concatenate([pad, xbc], axis=1)
        new_state = xp[:, -(win - 1) :, :]
    else:
        xp = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
        new_state = xp[:, -(win - 1) :, :]
    out = sum(
        xp[:, i : i + xbc.shape[1], :] * w[i] for i in range(win)
    ) + p["conv_b"].astype(xbc.dtype)
    return jax.nn.silu(out.astype(jnp.float32)).astype(xbc.dtype), new_state


def _ssd_chunked(x, dt, a, bmat, cmat):
    """Chunk-parallel SSD.  x: (B, S, H, P); dt: (B, S, H); a: (H,) (>0 decay
    rates); bmat/cmat: (B, S, N).  Returns y: (B, S, H, P)."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    q = CHUNK
    nc = s // q
    assert s % q == 0, f"seq {s} not divisible by chunk {q}"

    xs = x.reshape(b, nc, q, h, p)
    dts = dt.reshape(b, nc, q, h)
    bs = bmat.reshape(b, nc, q, n)
    cs = cmat.reshape(b, nc, q, n)

    # log-decay per step: s_t = -dt_t * a  (a > 0)
    ls = -dts * a[None, None, None, :]  # (B, NC, Q, H)
    cum = jnp.cumsum(ls, axis=2)  # within-chunk cumulative log decay

    # Intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j  (else 0)
    li = cum[:, :, :, None, :]  # (B,NC,Q,1,H)
    lj = cum[:, :, None, :, :]  # (B,NC,1,Q,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.exp(jnp.where(mask[None, None, :, :, None], li - lj, -jnp.inf))
    cb = jnp.einsum("bcin,bcjn->bcij", cs, bs)  # (B,NC,Q,Q)
    att = cb[..., None] * decay * dts[:, :, None, :, :]  # (B,NC,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, xs)

    # Chunk-final states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j x_j^T
    w_end = jnp.exp(cum[:, :, -1:, :] - cum) * dts  # (B,NC,Q,H)
    sc = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bs, w_end, xs)  # (B,NC,H,N,P)

    # Inter-chunk scan: state_{c} = exp(sum ls_c) state_{c-1} + S_c
    total = jnp.exp(cum[:, :, -1, :])  # (B,NC,H)

    def scan_fn(carry, inp):
        tot, s_c = inp
        new = tot[..., None, None] * carry + s_c
        return new, carry  # emit the *incoming* state for chunk c

    init = jnp.zeros((b, h, n, p), jnp.float32)
    _, prev_states = jax.lax.scan(
        scan_fn,
        init,
        (total.transpose(1, 0, 2), sc.transpose(1, 0, 2, 3, 4).astype(jnp.float32)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,NC,H,N,P)

    # Inter-chunk contribution: y_i += C_i . (exp(cum_i) * state_prev)
    w_in = jnp.exp(cum)  # (B,NC,Q,H)
    y_inter = jnp.einsum(
        "bcin,bcih,bchnp->bcihp", cs, w_in, prev_states.astype(cs.dtype)
    )
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y


def mamba_forward(p, x, cfg, *, state=None):
    """x: (B, S, D).  state (decode): dict(conv=(B,W-1,C), ssm=(B,H,N,P)).
    Returns (out, new_state)."""
    d_inner, h, pd, n = dims(cfg)
    bsz, s, _ = x.shape
    z = layers.linear(p["z_proj"], x, cfg.quant)
    xbc = layers.linear(p["xbc_proj"], x, cfg.quant)
    dt = layers.linear(p["dt_proj"], x, cfg.quant)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = jnp.exp(p["a_log"])  # (H,) positive decay rates

    conv_state = None if state is None else state["conv"]
    xbc, new_conv = _short_conv(p, xbc, conv_state)
    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    xs = xs.reshape(bsz, s, h, pd)
    xs = constrain(xs, "batch", None, "heads", None)

    if state is None:
        y = _ssd_chunked(xs, dt, a, bmat, cmat)
        new_ssm = None  # training path does not emit state
    else:
        # Single-step recurrence: state' = exp(-dt a) state + dt B x^T
        assert s == 1
        ssm = state["ssm"]  # (B,H,N,P) f32
        dt1 = dt[:, 0, :]  # (B,H)
        decay = jnp.exp(-dt1 * a[None, :])  # (B,H)
        bx = jnp.einsum(
            "bn,bh,bhp->bhnp", bmat[:, 0].astype(jnp.float32), dt1,
            xs[:, 0].astype(jnp.float32),
        )
        new_ssm = decay[..., None, None] * ssm + bx
        y = jnp.einsum("bn,bhnp->bhp", cmat[:, 0].astype(jnp.float32), new_ssm)
        y = y[:, None]  # (B,1,H,P)

    y = y.astype(x.dtype) + xs * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, d_inner)
    y = layers.rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), cfg.norm_eps)
    out = layers.linear(p["out_proj"], y, cfg.quant)
    new_state = None if state is None else {"conv": new_conv, "ssm": new_ssm}
    return out, new_state


def init_state(cfg, batch: int) -> dict:
    d_inner, h, pd, n = dims(cfg)
    conv_dim = d_inner + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), jnp.bfloat16),
        "ssm": jnp.zeros((batch, h, n, pd), jnp.float32),
    }
