"""Shared building blocks: linear (with the MMA quantized path), norms, RoPE,
flash attention (chunked online-softmax, SWA-capable), MLPs.

Params are plain pytrees (nested dicts of jnp arrays); init_* functions
build them.  Everything is functional — no module framework — so stacks can
be vmapped/scanned and sharded freely.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import mma
from repro.parallel.sharding import constrain

# Static scale for the int8 KV cache (post-RMSNorm K/V magnitudes are ~O(1);
# 0.05 gives +-6.35 dynamic range with <0.4% saturation on our smoke nets —
# a production deployment calibrates this per layer from a few batches).
KV_CACHE_SCALE = 0.05

# ---------------------------------------------------------------- init utils


def _dense_init(key, shape, in_axis=0):
    fan_in = shape[in_axis] if isinstance(in_axis, int) else math.prod(
        shape[a] for a in in_axis
    )
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32) * std).astype(
        jnp.bfloat16
    )


def init_linear(key, d_in: int, d_out: int, *, bias: bool = False) -> dict:
    p = {"w": _dense_init(key, (d_in, d_out))}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.bfloat16)
    return p


def init_norm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.bfloat16)}


# ------------------------------------------------------------------- kernels


def linear(p: dict, x: jax.Array, quant=None) -> jax.Array:
    """Dense layer; routes through the MMA int8 bit-serial datapath when the
    config enables the paper's technique (weights per-channel int8, dynamic
    activation scale, ``planes`` MSB planes — see core/mma.py).

    ``w_q``/``w_scale`` leaves (from quant.quantize_params_int8 — serving
    mode) carry pre-quantized int8 weights: half the HBM bytes of bf16 and
    no requantization per step.
    """
    from repro.core import quant as quant_lib

    # Per-row activation scales for batched inputs: a tensor-wide amax lets
    # one batch row's magnitudes shift every other row's quantization grid,
    # breaking the slot-isolation invariant the serving engines document
    # (the fused pallas epilogue takes one scale, so that path keeps the
    # per-tensor grid).
    batch_axis = 0 if x.ndim >= 3 else None
    if "w_q" in p:
        planes = quant.planes if quant is not None else 8
        impl = quant.impl if quant is not None else "xla"
        xq = quant_lib.quantize_acts(
            x.astype(jnp.float32),
            batch_axis=None if impl == "pallas" else batch_axis,
        )
        w_scale = jnp.squeeze(p["w_scale"], axis=-2)
        if impl == "pallas":
            from repro.kernels import ops as kops

            out = kops.mma_matmul_scaled(
                xq.values, p["w_q"], xq.scale, w_scale, planes=planes
            ).astype(x.dtype)
        else:
            out_i32 = mma.mma_dot(xq.values, p["w_q"], planes=planes, impl=impl)
            out = (out_i32.astype(jnp.float32)
                   * (xq.scale * w_scale)).astype(x.dtype)
    else:
        w = p["w"]
        if quant is not None and quant.mode == "mma_int8":
            out = mma.mma_linear(
                x.astype(jnp.float32), w.astype(jnp.float32), planes=quant.planes,
                impl=quant.impl, batch_axis=batch_axis,
            ).astype(x.dtype)
        else:
            out = jax.lax.dot_general(
                x, w.astype(x.dtype), (((x.ndim - 1,), (0,)), ((), ())),
            )
    if "b" in p:
        out = out + p["b"].astype(out.dtype)
    return out


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (math.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def constrain_qkv(q, k, v, cfg, s):
    """Attention sharding: head-sharded (TP) when n_heads divides |model|;
    otherwise CONTEXT PARALLELISM (q seq-sharded over 'model', kv
    replicated).  Without the fallback, archs whose head counts don't divide
    the model axis (minitron 24H, whisper 20H on a 16-way axis) replicate
    all attention FLOPs |model|x — caught by the dry-run roofline
    (EXPERIMENTS.md §Perf iteration 2)."""
    from repro.parallel.sharding import current_mesh

    mesh = current_mesh()
    msize = mesh.shape.get("model", 1) if mesh is not None else 1
    heads_ok = cfg.n_heads % msize == 0
    if s <= 8:
        # Decode: k/v must match the (sequence-sharded) cache layout BEFORE
        # the dynamic-update-slice — head-sharding them forces GSPMD to
        # all-to-all the entire cache between layouts every token (zamba2
        # decode baseline: 12 GB/step of resharding a2a — §Perf).
        q = constrain(q, "batch", None, None, None)
        k = constrain(k, "batch", None, None, None)
        v = constrain(v, "batch", None, None, None)
    elif heads_ok:
        q = constrain(q, "batch", None, "heads", None)
        k = constrain(k, "batch", None, "kv_heads", None)
        v = constrain(v, "batch", None, "kv_heads", None)
    else:
        q = constrain(q, "batch", "seq", None, None)
        k = constrain(k, "batch", None, None, None)
        v = constrain(v, "batch", None, None, None)
    return q, k, v


# ----------------------------------------------------------- flash attention


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    chunk: int = 1024,
    q_offset: jax.Array | int = 0,
) -> jax.Array:
    """Chunked online-softmax attention (pure JAX, O(S*chunk) memory).

    q: (B, S, H, D); k, v: (B, T, KV, D) with H % KV == 0 (GQA).
    ``window``>0 limits attention to the last ``window`` keys (SWA).
    ``q_offset``: absolute position of q[0] (decode: T_cache).
    """
    b, s, h, d = q.shape
    _, t, kv, _ = k.shape
    groups = h // kv
    scale = 1.0 / math.sqrt(d)
    if jnp.ndim(q_offset) > 0:  # per-row offsets (slot-isolated decode)
        q_pos = jnp.reshape(q_offset, (-1, 1)) + jnp.arange(s)[None, :]
    else:
        q_pos = (jnp.arange(s) + q_offset)[None, :]  # (1, S)
    qg = q.reshape(b, s, kv, groups, d)

    # Short-query (decode) fast path: one unchunked pass — no loop, full
    # flops visible to cost_analysis, scores stay small ((B,KV,G,s,T)).
    if s <= 8:
        k_pos = jnp.arange(t)[None, :]
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
        ok = (k_pos[None, :, :] <= q_pos[..., None]) if causal else jnp.ones((1, s, t), bool)
        if window:
            ok = ok & (k_pos[None, :, :] > q_pos[..., None] - window)
        scores = jnp.where(ok[:, None, None, :, :], scores, -jnp.inf)
        m = scores.max(axis=-1, keepdims=True)
        p = jnp.exp(scores - jax.lax.stop_gradient(m))
        out = jnp.einsum("bkgst,btkd->bskgd", p.astype(q.dtype), v).astype(jnp.float32)
        out = out / jnp.maximum(p.sum(-1), 1e-20).transpose(0, 3, 1, 2)[..., None]
        return out.reshape(b, s, h, d).astype(q.dtype)

    n_chunks = (t + chunk - 1) // chunk
    tc = n_chunks * chunk
    k = jnp.pad(k, ((0, 0), (0, tc - t), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, tc - t), (0, 0), (0, 0)))

    # Online-softmax over kv chunks, UNROLLED python loop: flops fully
    # visible to the roofline (a lax.scan body is cost-counted once), and XLA
    # still schedules the chain with O(S*chunk) liveness.
    m_prev = jnp.full((b, kv, groups, s), -jnp.inf, jnp.float32)
    l_prev = jnp.zeros((b, kv, groups, s), jnp.float32)
    acc = jnp.zeros((b, s, kv, groups, d), jnp.float32)
    for j in range(n_chunks):
        kj = jax.lax.dynamic_slice_in_dim(k, j * chunk, chunk, 1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * chunk, chunk, 1)
        k_pos = (j * chunk + jnp.arange(chunk))[:, None]  # (chunk, 1)
        scores = jnp.einsum("bskgd,bckd->bkgsc", qg, kj).astype(jnp.float32) * scale
        ok = (k_pos.T <= q_pos[..., None]) if causal else jnp.ones((1, s, chunk), bool)
        if window:
            ok = ok & (k_pos.T > q_pos[..., None] - window)
        ok = ok & (k_pos[:, 0] < t)[None, None, :]
        scores = jnp.where(ok[:, None, None, :, :], scores, -jnp.inf)
        m_new = jnp.maximum(m_prev, scores.max(axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)  # all-masked rows
        p = jnp.exp(scores - m_safe[..., None])
        p = jnp.where(jnp.isfinite(scores), p, 0.0)
        corr = jnp.exp(jnp.where(jnp.isfinite(m_prev), m_prev - m_safe, -jnp.inf))
        corr = jnp.where(jnp.isfinite(corr), corr, 0.0)
        l_prev = l_prev * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgsc,bckd->bskgd", p.astype(q.dtype), vj).astype(jnp.float32)
        acc = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        m_prev = m_new
    l = jnp.maximum(l_prev, 1e-20)
    out = acc / l.transpose(0, 3, 1, 2)[..., None]
    return out.reshape(b, s, h, d).astype(q.dtype)


# -------------------------------------------------------------------- blocks


def init_attention(key, cfg) -> dict:
    ks = jax.random.split(key, 4)
    hd = cfg.hd
    return {
        "wq": init_linear(ks[0], cfg.d_model, cfg.n_heads * hd),
        "wk": init_linear(ks[1], cfg.d_model, cfg.n_kv_heads * hd),
        "wv": init_linear(ks[2], cfg.d_model, cfg.n_kv_heads * hd),
        "wo": init_linear(ks[3], cfg.n_heads * hd, cfg.d_model),
    }


def attention(
    p: dict,
    x: jax.Array,
    cfg,
    *,
    positions: jax.Array,
    cache: tuple[jax.Array, jax.Array] | None = None,
    cache_index: jax.Array | None = None,
    causal: bool = True,
):
    """Multi-head attention with GQA/MQA, RoPE, SWA and optional KV cache.

    x: (B, S, D) — seq-sharded on entry (SP); internals are head-sharded.
    cache: (k, v) each (B, S_max, KV, hd); cache_index: write offset —
    a scalar (every row appends at the same position, the batched-serving
    approximation), or a (B,) vector of per-row positions (slot-isolated
    decode: each row writes at its own length, so a row's cache history
    depends only on its own tokens and serving order cannot perturb
    numerics — what the engine's chunked-prefill path relies on).
    Returns (out, new_cache).
    """
    b, s, _ = x.shape
    hd = cfg.hd
    q = linear(p["wq"], x, cfg.quant).reshape(b, s, cfg.n_heads, hd)
    k = linear(p["wk"], x, cfg.quant).reshape(b, s, cfg.n_kv_heads, hd)
    v = linear(p["wv"], x, cfg.quant).reshape(b, s, cfg.n_kv_heads, hd)
    q, k, v = constrain_qkv(q, k, v, cfg, s)
    if positions is not None:  # rope (None for whisper learned-pos)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        ck, cv = cache
        if jnp.ndim(cache_index) > 0:
            # per-row write positions: row b's update lands at its own
            # index, so rows never stomp each other's cache history
            def _write(c, u):
                return jax.vmap(
                    lambda cr, ur, i: jax.lax.dynamic_update_slice(
                        cr, ur, (i, 0, 0)
                    )
                )(c, u, cache_index)
        else:
            def _write(c, u):
                return jax.lax.dynamic_update_slice(
                    c, u, (0, cache_index, 0, 0)
                )
        if ck.dtype == jnp.int8:
            # int8 KV cache with a calibrated static scale (TRT-LLM-style;
            # halves decode cache traffic — §Perf iteration 3).
            kq = jnp.clip(jnp.round(k.astype(jnp.float32) / KV_CACHE_SCALE),
                          -127, 127).astype(jnp.int8)
            vq = jnp.clip(jnp.round(v.astype(jnp.float32) / KV_CACHE_SCALE),
                          -127, 127).astype(jnp.int8)
            ck = _write(ck, kq)
            cv = _write(cv, vq)
            new_cache = (ck, cv)
            k = (ck.astype(jnp.float32) * KV_CACHE_SCALE).astype(q.dtype)
            v = (cv.astype(jnp.float32) * KV_CACHE_SCALE).astype(q.dtype)
        else:
            ck = _write(ck, k.astype(ck.dtype))
            cv = _write(cv, v.astype(cv.dtype))
            new_cache = (ck, cv)
            k, v = ck, cv
        q_offset = cache_index
        if s <= 8:
            # Decode: keep the cache sequence-sharded ('kv_seq' -> model) and
            # replicate the tiny q heads — attention becomes a partial
            # softmax per seq shard + an O(B*H*d) psum instead of an
            # all-gather of the cache (see EXPERIMENTS.md SPerf).
            q = constrain(q, "batch", None, None, None)
            k = constrain(k, "batch", "kv_seq", None, None)
            v = constrain(v, "batch", "kv_seq", None, None)
    else:
        q_offset = 0

    out = flash_attention(
        q, k, v, causal=causal, window=cfg.swa_window, chunk=cfg.attn_chunk,
        q_offset=q_offset,
    )
    out = constrain(out, "batch", None, "heads", None)
    out = linear(p["wo"], out.reshape(b, s, cfg.n_heads * hd), cfg.quant)
    return out, new_cache


def init_mlp(key, cfg, d_ff: int | None = None) -> dict:
    ks = jax.random.split(key, 3)
    ff = d_ff or cfg.d_ff
    if cfg.act == "swiglu":
        return {
            "w_gate": init_linear(ks[0], cfg.d_model, ff),
            "w_up": init_linear(ks[1], cfg.d_model, ff),
            "w_down": init_linear(ks[2], ff, cfg.d_model),
        }
    return {
        "w_up": init_linear(ks[0], cfg.d_model, ff, bias=True),
        "w_down": init_linear(ks[1], ff, cfg.d_model, bias=True),
    }


def mlp(p: dict, x: jax.Array, cfg) -> jax.Array:
    if "w_gate" in p:
        g = linear(p["w_gate"], x, cfg.quant)
        u = linear(p["w_up"], x, cfg.quant)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jax.nn.gelu(linear(p["w_up"], x, cfg.quant).astype(jnp.float32)).astype(
            x.dtype
        )
    h = constrain(h, "batch", None, "ffn")
    return linear(p["w_down"], h, cfg.quant)


def init_embedding(key, vocab: int, d: int) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(jnp.bfloat16)}


def embed(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p: dict, x: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        x, p["table"].astype(x.dtype), (((x.ndim - 1,), (1,)), ((), ()))
    )
