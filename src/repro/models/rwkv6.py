"""RWKV6 ("Finch") — attention-free LM with data-dependent decay.

Time-mix: per-head state S (P x P) updated as
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
with w_t data-dependent (LoRA on the shifted-token mix), per RWKV6.  The
sequence recurrence is a lax.scan (O(1) HLO, O(S) wall time); decode carries
(S, last-token) state — attention-free, so ``long_500k`` is in-family.

Channel-mix: token-shift + squared-ReLU MLP (d_ff = 3.5 * d_model for the
3B Finch config).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain
from . import layers

LORA_R = 64


def dims(cfg):
    p = cfg.ssm_head_dim or 64
    h = cfg.d_model // p
    return h, p


def init_time_mix(key, cfg) -> dict:
    h, p = dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 10)
    return {
        "mix_base": jnp.zeros((5, d), jnp.bfloat16),  # r,k,v,w,g interpolants
        "mix_lora_a": layers.init_linear(ks[0], d, LORA_R * 5),
        "mix_lora_b": (jax.random.normal(ks[1], (5, LORA_R, d), jnp.float32) * 0.01
                       ).astype(jnp.bfloat16),
        "wr": layers.init_linear(ks[2], d, d),
        "wk": layers.init_linear(ks[3], d, d),
        "wv": layers.init_linear(ks[4], d, d),
        "wg": layers.init_linear(ks[5], d, d),
        "wo": layers.init_linear(ks[6], d, d),
        "w_base": jnp.full((d,), -6.0, jnp.float32),  # decay bias (pre -exp)
        "w_lora_a": layers.init_linear(ks[7], d, LORA_R),
        "w_lora_b": (jax.random.normal(ks[8], (LORA_R, d), jnp.float32) * 0.01
                     ).astype(jnp.bfloat16),
        "u": jnp.zeros((h, p), jnp.float32),  # bonus for current token
        "ln_x": layers.init_norm(d),
    }


def init_channel_mix(key, cfg) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "mix_k": jnp.zeros((d,), jnp.bfloat16),
        "mix_r": jnp.zeros((d,), jnp.bfloat16),
        "wk": layers.init_linear(ks[0], d, cfg.d_ff),
        "wv": layers.init_linear(ks[1], cfg.d_ff, d),
        "wr": layers.init_linear(ks[2], d, d),
    }


def init_block(key, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": layers.init_norm(cfg.d_model),
        "time_mix": init_time_mix(k1, cfg),
        "ln2": layers.init_norm(cfg.d_model),
        "channel_mix": init_channel_mix(k2, cfg),
    }


def init_params(key, cfg) -> dict:
    ke, kb, kh = jax.random.split(key, 3)
    bkeys = jax.random.split(kb, cfg.n_layers)
    return {
        "embed": layers.init_embedding(ke, cfg.vocab, cfg.d_model),
        "blocks": jax.vmap(lambda k: init_block(k, cfg))(bkeys),
        "ln_f": layers.init_norm(cfg.d_model),
        "head": layers.init_linear(kh, cfg.d_model, cfg.vocab),
    }


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros / carried last token at t=0)."""
    pad = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def time_mix(p, x, cfg, *, state=None):
    """x: (B,S,D) -> (out, new_state); state = {"s": (B,H,P,P), "x": (B,D)}."""
    h, pd = dims(cfg)
    b, s, d = x.shape
    xprev = _shift(x, None if state is None else state["x"])
    # data-dependent interpolation (the RWKV6 "ddlerp")
    delta = xprev - x
    lora = jnp.tanh(layers.linear(p["mix_lora_a"], x).reshape(b, s, 5, LORA_R))
    dyn = jnp.einsum("bsfr,frd->bsfd", lora, p["mix_lora_b"].astype(x.dtype))
    mix = p["mix_base"].astype(x.dtype)[None, None] + dyn  # (B,S,5,D)
    xr, xk, xv, xw, xg = [
        x + delta * mix[:, :, i, :] for i in range(5)
    ]
    r = layers.linear(p["wr"], xr, cfg.quant).reshape(b, s, h, pd)
    k = layers.linear(p["wk"], xk, cfg.quant).reshape(b, s, h, pd)
    v = layers.linear(p["wv"], xv, cfg.quant).reshape(b, s, h, pd)
    g = jax.nn.silu(layers.linear(p["wg"], xg, cfg.quant).astype(jnp.float32))
    # data-dependent decay  w_t = exp(-exp(base + lora_w(xw)))
    wl = jnp.tanh(layers.linear(p["w_lora_a"], xw))
    wd = layers.linear({"w": p["w_lora_b"]}, wl)
    logw = p["w_base"][None, None, :] + wd.astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logw)).reshape(b, s, h, pd)  # in (0,1)

    r = constrain(r, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "heads", None)
    v = constrain(v, "batch", None, "heads", None)

    u = p["u"].astype(jnp.float32)

    def step(carry, inp):
        s_state = carry  # (B,H,P,P) f32
        rt, kt, vt, wt = inp  # each (B,H,P)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,P,P)
        y = jnp.einsum("bhp,bhpq->bhq", rt, s_state + u[None, :, :, None] * kv)
        new = wt[..., :, None] * s_state + kv
        return new, y

    s0 = (
        jnp.zeros((b, h, pd, pd), jnp.float32)
        if state is None
        else state["s"]
    )
    seq = (
        r.transpose(1, 0, 2, 3).astype(jnp.float32),
        k.transpose(1, 0, 2, 3).astype(jnp.float32),
        v.transpose(1, 0, 2, 3).astype(jnp.float32),
        w.transpose(1, 0, 2, 3).astype(jnp.float32),
    )
    s_final, ys = jax.lax.scan(step, s0, seq)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d)  # (B,S,H,P)->(B,S,D)
    y = layers.rmsnorm(p["ln_x"], y.astype(x.dtype), cfg.norm_eps)
    out = layers.linear(p["wo"], (y.astype(jnp.float32) * g).astype(x.dtype), cfg.quant)
    new_state = None if state is None else {"s": s_final, "x": x[:, -1, :]}
    return out, new_state


def channel_mix(p, x, cfg, *, last=None):
    xprev = _shift(x, last)
    xk = x + (xprev - x) * p["mix_k"].astype(x.dtype)
    xr = x + (xprev - x) * p["mix_r"].astype(x.dtype)
    k = layers.linear(p["wk"], xk, cfg.quant)
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    k = constrain(k, "batch", None, "ffn")
    kv = layers.linear(p["wv"], k, cfg.quant)
    r = jax.nn.sigmoid(layers.linear(p["wr"], xr, cfg.quant).astype(jnp.float32))
    out = (r * kv.astype(jnp.float32)).astype(x.dtype)
    new_last = None if last is None else x[:, -1, :]
    return out, new_last


def forward(params, tokens, cfg, *, state=None, **_):
    """state (decode): {"tm": {"s","x"} stacked (L,...), "cm_x": (L,B,D)}."""
    x = layers.embed(params["embed"], tokens)
    x = constrain(x, "batch", "seq" if cfg.seq_shard else None, None)

    def body(carry, xs):
        h = carry
        if state is None:
            blk = xs
            tm, _ = time_mix(blk["time_mix"], layers.rmsnorm(blk["ln1"], h, cfg.norm_eps), cfg)
            h = h + tm
            cm, _ = channel_mix(blk["channel_mix"], layers.rmsnorm(blk["ln2"], h, cfg.norm_eps), cfg)
            h = h + cm
            return constrain(h, "batch", "seq" if cfg.seq_shard else None, None), None
        blk, tm_s, tm_x, cm_x = xs
        tm, new_tm = time_mix(
            blk["time_mix"], layers.rmsnorm(blk["ln1"], h, cfg.norm_eps), cfg,
            state={"s": tm_s, "x": tm_x},
        )
        h = h + tm
        cm, new_cm = channel_mix(
            blk["channel_mix"], layers.rmsnorm(blk["ln2"], h, cfg.norm_eps), cfg,
            last=cm_x,
        )
        h = h + cm
        return h, (new_tm["s"], new_tm["x"], new_cm)

    fn = body
    if cfg.remat == "full" and state is None:
        fn = jax.checkpoint(body, prevent_cse=False)

    if state is None:
        x, _ = jax.lax.scan(fn, x, params["blocks"], unroll=cfg.scan_unroll)
        new_state = None
    else:
        x, ys = jax.lax.scan(
            fn, x, (params["blocks"], state["tm_s"], state["tm_x"], state["cm_x"]),
            unroll=cfg.scan_unroll,
        )
        new_state = {"tm_s": ys[0], "tm_x": ys[1], "cm_x": ys[2]}

    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = layers.linear(params["head"], x, cfg.quant)
    logits = constrain(logits, "batch", None, "vocab")
    return (logits, new_state) if state is not None else logits


def init_state(cfg, batch: int) -> dict:
    h, pd = dims(cfg)
    return {
        "tm_s": jnp.zeros((cfg.n_layers, batch, h, pd, pd), jnp.float32),
        "tm_x": jnp.zeros((cfg.n_layers, batch, cfg.d_model), jnp.bfloat16),
        "cm_x": jnp.zeros((cfg.n_layers, batch, cfg.d_model), jnp.bfloat16),
    }


def loss_fn(params, batch, cfg):
    tokens = batch["tokens"][:, :-1]
    targets = batch["tokens"][:, 1:]
    logits = forward(params, tokens, cfg).astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    return nll, {"nll": nll}


def decode_step(params, tokens, state, cache_index, cfg, **_):
    del cache_index
    return forward(params, tokens, cfg, state=state)
