"""U-Net (the paper's target application) with MMA-quantized 3x3 convs.

Faithful to the paper's deployment: the network is trained in float (or
QAT), quantized FBGEMM-style to int8, and its 3x3 convolutions execute on
the MSDF merged multiply-add datapath (``core.mma`` / ``kernels.mma_conv2d``
— the KPB maps the k*k taps into the contraction dim).  2x2 pool/upsample
and the final 1x1 conv run off the accelerator, as in the paper (Sec. 3.1).

The default geometry is the Table-1-calibrated config
(``core.cycle_model.CALIBRATED_UNET``): 80x80x4 input, base 48, depth 3.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import mma
from repro.core.cycle_model import CALIBRATED_UNET, ConvLayerSpec, unet_conv_layers


@dataclass(frozen=True)
class UNetConfig:
    hw: int = CALIBRATED_UNET["hw"]
    in_ch: int = CALIBRATED_UNET["in_ch"]
    base: int = CALIBRATED_UNET["base"]
    depth: int = CALIBRATED_UNET["depth"]
    convs_per_stage: int = CALIBRATED_UNET["convs_per_stage"]
    n_classes: int = 4
    quant_mode: str = "none"  # 'none' | 'mma_int8'
    planes: int = 8
    impl: str = "xla"  # mma impl: xla | pallas | cascade | int8
    family: str = "unet"

    def conv_layers(self) -> list[ConvLayerSpec]:
        return unet_conv_layers(self.hw, self.in_ch, self.base, self.depth,
                                self.convs_per_stage)


def _conv_init(key, kh, kw, cin, cout):
    std = 1.0 / jnp.sqrt(kh * kw * cin)
    return {
        "w": (jax.random.truncated_normal(key, -2, 2, (kh, kw, cin, cout), jnp.float32) * std),
        "b": jnp.zeros((cout,), jnp.float32),
    }


def init_params(key, cfg: UNetConfig) -> dict:
    keys = iter(jax.random.split(key, 64))
    p: dict = {"enc": [], "dec": []}
    ch = cfg.in_ch
    enc_ch = []
    for d in range(cfg.depth):
        c = cfg.base * (2**d)
        stage = [_conv_init(next(keys), 3, 3, ch, c)]
        for _ in range(cfg.convs_per_stage - 1):
            stage.append(_conv_init(next(keys), 3, 3, c, c))
        p["enc"].append(stage)
        enc_ch.append(c)
        ch = c
    c = cfg.base * (2**cfg.depth)
    p["bottleneck"] = [_conv_init(next(keys), 3, 3, ch, c)]
    for _ in range(cfg.convs_per_stage - 1):
        p["bottleneck"].append(_conv_init(next(keys), 3, 3, c, c))
    ch = c
    for d in reversed(range(cfg.depth)):
        c = enc_ch[d]
        stage = [_conv_init(next(keys), 3, 3, c + ch, c)]
        for _ in range(cfg.convs_per_stage - 1):
            stage.append(_conv_init(next(keys), 3, 3, c, c))
        p["dec"].append(stage)
        ch = c
    p["head"] = _conv_init(next(keys), 1, 1, ch, cfg.n_classes)
    return p


def conv3x3(p, x, cfg: UNetConfig):
    """3x3 conv through the selected datapath (float or MMA int8)."""
    if cfg.quant_mode == "mma_int8":
        from repro.core import quant
        from repro.kernels import ops

        xq = quant.quantize_acts(x)
        wq = quant.quantize_weights(p["w"], channel_axis=-1)
        if cfg.impl == "pallas":
            out = ops.mma_conv2d(xq.values, wq.values, planes=cfg.planes)
        else:
            # im2col + the selected matmul path (xla horner / cascade / int8)
            kh, kw, cin, cout = p["w"].shape
            xp = jnp.pad(xq.values, ((0, 0), (1, 1), (1, 1), (0, 0)))
            n, h, w_, _ = x.shape
            patches = jnp.concatenate(
                [xp[:, i : i + h, j : j + w_, :] for i in range(kh) for j in range(kw)],
                axis=-1,
            )
            out = mma.mma_dot(
                patches.reshape(-1, kh * kw * cin),
                wq.values.reshape(kh * kw * cin, cout),
                planes=cfg.planes,
                impl=cfg.impl,
            ).reshape(n, h, w_, cout)
        out = out.astype(jnp.float32) * quant.quantized_matmul_scale(xq.scale, wq.scale)
    else:
        out = jax.lax.conv_general_dilated(
            x, p["w"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
    return out + p["b"]


def forward(params, x, cfg: UNetConfig):
    """x: (N, H, W, Cin) -> logits (N, H, W, n_classes)."""
    skips = []
    h = x
    for stage in params["enc"]:
        for conv in stage:
            h = jax.nn.relu(conv3x3(conv, h, cfg))
        skips.append(h)
        h = jax.lax.reduce_window(
            h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )
    for conv in params["bottleneck"]:
        h = jax.nn.relu(conv3x3(conv, h, cfg))
    for d, stage in enumerate(params["dec"]):
        # 2x nearest upsample (off-accelerator op, like the paper's 2x2 path)
        n, hh, ww, c = h.shape
        h = jnp.broadcast_to(h[:, :, None, :, None, :], (n, hh, 2, ww, 2, c)).reshape(
            n, hh * 2, ww * 2, c
        )
        h = jnp.concatenate([skips[-(d + 1)], h], axis=-1)
        for conv in stage:
            h = jax.nn.relu(conv3x3(conv, h, cfg))
    out = jax.lax.conv_general_dilated(
        h, params["head"]["w"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + params["head"]["b"]


def loss_fn(params, batch, cfg: UNetConfig):
    """Segmentation cross-entropy; batch = {"image": (N,H,W,C), "mask": (N,H,W)}."""
    logits = forward(params, batch["image"], cfg).astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["mask"][..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    return nll, {"nll": nll}
