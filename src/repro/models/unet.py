"""U-Net (the paper's target application) with MMA-quantized 3x3 convs.

Faithful to the paper's deployment: the network is trained in float (or
QAT), quantized FBGEMM-style to int8, and its 3x3 convolutions execute on
the MSDF merged multiply-add datapath (``core.mma`` / ``kernels.mma_conv2d``
— the KPB maps the k*k taps into the contraction dim).  2x2 pool/upsample
and the final 1x1 conv run off the accelerator, as in the paper (Sec. 3.1).

The default geometry is the Table-1-calibrated config
(``core.cycle_model.CALIBRATED_UNET``): 80x80x4 input, base 48, depth 3.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.cycle_model import CALIBRATED_UNET, ConvLayerSpec, unet_conv_layers
from repro.core.plane_schedule import PlaneSchedule


@dataclass(frozen=True)
class UNetConfig:
    hw: int = CALIBRATED_UNET["hw"]
    in_ch: int = CALIBRATED_UNET["in_ch"]
    base: int = CALIBRATED_UNET["base"]
    depth: int = CALIBRATED_UNET["depth"]
    convs_per_stage: int = CALIBRATED_UNET["convs_per_stage"]
    n_classes: int = 4
    quant_mode: str = "none"  # 'none' | 'mma_int8'
    planes: int = 8
    # Per-3x3-conv plane budgets, in forward order (enc, bottleneck, dec) —
    # same order as ``conv_layers()``.  None -> uniform ``planes``.
    plane_schedule: tuple[int, ...] | None = None
    impl: str = "xla"  # mma impl: xla | pallas | cascade | int8
    # Border fill of every 3x3 conv: 'zero' (the SAME convention) or
    # 'edge' / 'reflect' — the external padding control halo-free image
    # tiles use (see kernels.ops.mma_conv2d and repro.segserve).
    pad_mode: str = "zero"
    family: str = "unet"

    def conv_layers(self) -> list[ConvLayerSpec]:
        return unet_conv_layers(self.hw, self.in_ch, self.base, self.depth,
                                self.convs_per_stage)

    def schedule(self) -> PlaneSchedule:
        """The active per-layer precision policy (explicit or uniform)."""
        n = len(self.conv_layers())
        if self.plane_schedule is not None:
            if len(self.plane_schedule) != n:
                raise ValueError(
                    f"plane_schedule has {len(self.plane_schedule)} entries "
                    f"but this geometry (depth={self.depth}, "
                    f"convs_per_stage={self.convs_per_stage}) has {n} 3x3 "
                    f"convs — one budget per conv, in forward order"
                )
            return PlaneSchedule.from_list(self.plane_schedule)
        return PlaneSchedule.uniform(self.planes, n)

    # ------------------------------------------------------- tile geometry

    def min_viable_tile(self) -> int:
        """Smallest core stride worth tiling at: the first multiple of
        ``2**depth`` strictly larger than twice the receptive-field halo, so
        a tile's valid core is at least as large as the redundant context it
        pays for on each axis."""
        from repro.segserve.tiling import halo_for  # lazy: segserve imports us

        mult = 2**self.depth
        halo = halo_for(self.depth, self.convs_per_stage)
        return (2 * halo // mult + 1) * mult

    def validate_tile(self, tile: int, *, halo: int | None = None) -> int:
        """Geometry check for a tiled deployment of this net: rejects core
        strides the halo walk proves degenerate (``tile <= 2*halo`` means
        every interior window is mostly halo, so the tiling computes more
        redundant context than useful core) with the minimum viable tile
        named.  ``halo=None`` checks against the exact receptive-field halo;
        an explicit smaller halo (seam-tolerant modes) relaxes the check.
        Returns ``tile`` so call sites can validate inline."""
        from repro.segserve.tiling import halo_for  # lazy: segserve imports us

        mult = 2**self.depth
        if tile < mult or tile % mult:
            raise ValueError(
                f"tile {tile} must be a positive multiple of 2**depth = {mult}"
            )
        h = halo_for(self.depth, self.convs_per_stage) if halo is None else halo
        if h > 0 and tile <= 2 * h:
            min_viable = (2 * h // mult + 1) * mult
            raise ValueError(
                f"tile {tile} <= 2*halo = {2 * h} at depth {self.depth} "
                f"(convs_per_stage={self.convs_per_stage}): every interior "
                f"window would be mostly redundant halo context; the minimum "
                f"viable tile for this geometry is {min_viable}"
            )
        return tile


def _conv_init(key, kh, kw, cin, cout):
    std = 1.0 / jnp.sqrt(kh * kw * cin)
    return {
        "w": (jax.random.truncated_normal(key, -2, 2, (kh, kw, cin, cout), jnp.float32) * std),
        "b": jnp.zeros((cout,), jnp.float32),
    }


def init_params(key, cfg: UNetConfig) -> dict:
    keys = iter(jax.random.split(key, 64))
    p: dict = {"enc": [], "dec": []}
    ch = cfg.in_ch
    enc_ch = []
    for d in range(cfg.depth):
        c = cfg.base * (2**d)
        stage = [_conv_init(next(keys), 3, 3, ch, c)]
        for _ in range(cfg.convs_per_stage - 1):
            stage.append(_conv_init(next(keys), 3, 3, c, c))
        p["enc"].append(stage)
        enc_ch.append(c)
        ch = c
    c = cfg.base * (2**cfg.depth)
    p["bottleneck"] = [_conv_init(next(keys), 3, 3, ch, c)]
    for _ in range(cfg.convs_per_stage - 1):
        p["bottleneck"].append(_conv_init(next(keys), 3, 3, c, c))
    ch = c
    for d in reversed(range(cfg.depth)):
        c = enc_ch[d]
        stage = [_conv_init(next(keys), 3, 3, c + ch, c)]
        for _ in range(cfg.convs_per_stage - 1):
            stage.append(_conv_init(next(keys), 3, 3, c, c))
        p["dec"].append(stage)
        ch = c
    p["head"] = _conv_init(next(keys), 1, 1, ch, cfg.n_classes)
    return p


def conv3x3(p, x, cfg: UNetConfig, *, planes: int | None = None):
    """3x3 conv through the selected datapath (float or MMA int8).

    ``planes`` overrides the global ``cfg.planes`` for this layer — the hook
    the per-layer :class:`PlaneSchedule` drives.  Static per-layer budgets
    compile one specialized kernel variant per distinct count (shared across
    layers), so a 4-plane layer runs half the MXU work of an 8-plane one.
    """
    if planes is None:
        planes = cfg.planes
    if cfg.quant_mode == "mma_int8":
        from repro.core import quant
        from repro.kernels import ops

        xq = quant.quantize_acts(x)
        wq = quant.quantize_weights(p["w"], channel_axis=-1)
        out = ops.mma_conv2d(
            xq.values, wq.values, planes=planes, impl=cfg.impl,
            pad_mode=cfg.pad_mode,
        )
        out = out.astype(jnp.float32) * quant.quantized_matmul_scale(xq.scale, wq.scale)
    elif cfg.pad_mode == "zero":
        out = jax.lax.conv_general_dilated(
            x, p["w"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
    else:
        xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)), mode=cfg.pad_mode)
        out = jax.lax.conv_general_dilated(
            xp, p["w"], (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
    return out + p["b"]


def forward(params, x, cfg: UNetConfig, *, planes_arr=None, taps=None):
    """x: (N, H, W, Cin) -> logits (N, H, W, n_classes).

    3x3 convs are visited in the same order as ``cfg.conv_layers()`` /
    ``unet_conv_layers`` (encoder, bottleneck, decoder), so schedule entry
    ``l`` lines up with cycle-model layer ``l``.

    Spatial dims need not equal ``cfg.hw`` (halo tiles of the segmentation
    server run rectangular crops through this same function), but both must
    divide by ``2**depth`` so the pool/upsample ladder round-trips; anything
    else used to die deep in the decoder concat, so reject it up front.

    Two calibration hooks (``repro.autotune``), both off by default:

    ``planes_arr``
        an (L,) int32 array of per-conv plane budgets that *overrides*
        ``cfg``'s schedule.  Because it may be a traced value (the budgets
        ride in as data via the exact bit-mask identity,
        ``bitplane.truncate_to_planes``), one compilation serves every
        candidate schedule — the search loop sweeps hundreds of schedules
        without retracing.  Quantized datapath only; ignored for float.
    ``taps``
        a list to append each post-ReLU conv activation to, in schedule
        order — the instrumented forward activation statistics are read
        from.  Appends traced arrays under ``jit``; have the jitted wrapper
        return them.
    """
    mult = 2**cfg.depth
    if x.shape[1] % mult or x.shape[2] % mult:
        raise ValueError(
            f"spatial dims {x.shape[1]}x{x.shape[2]} not divisible by "
            f"2**depth = {mult}; pad the input (segserve.tiling.plan_tiles "
            f"does this for arbitrary images)"
        )
    sched = cfg.schedule() if cfg.quant_mode == "mma_int8" else None
    li = 0

    def qconv(conv, h):
        nonlocal li
        if planes_arr is not None and cfg.quant_mode == "mma_int8":
            pl = planes_arr[li]
        else:
            pl = sched.planes_for(li) if sched is not None else None
        li += 1
        out = jax.nn.relu(conv3x3(conv, h, cfg, planes=pl))
        if taps is not None:
            taps.append(out)
        return out

    skips = []
    h = x
    for stage in params["enc"]:
        for conv in stage:
            h = qconv(conv, h)
        skips.append(h)
        h = jax.lax.reduce_window(
            h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )
    for conv in params["bottleneck"]:
        h = qconv(conv, h)
    for d, stage in enumerate(params["dec"]):
        # 2x nearest upsample (off-accelerator op, like the paper's 2x2 path)
        n, hh, ww, c = h.shape
        h = jnp.broadcast_to(h[:, :, None, :, None, :], (n, hh, 2, ww, 2, c)).reshape(
            n, hh * 2, ww * 2, c
        )
        h = jnp.concatenate([skips[-(d + 1)], h], axis=-1)
        for conv in stage:
            h = qconv(conv, h)
    out = jax.lax.conv_general_dilated(
        h, params["head"]["w"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + params["head"]["b"]


def forward_with_error_bound(params, x, cfg: UNetConfig):
    """Scheduled forward plus a *sound* end-to-end error certificate.

    Returns ``(out_sched, out_full, advertised_rel_bound)`` where
    ``out_sched`` is the forward under ``cfg``'s plane schedule, ``out_full``
    the same datapath at full 8-plane precision, and the bound satisfies

        max|out_sched - out_full|  <=  advertised_rel_bound * max|out_full|

    by construction.  The certificate is interval propagation through the
    exact forward graph: each truncated conv contributes its analytic
    worst-case truncation error ((2^d - 1) * colsum|w_q|, ``early_term``)
    plus the activation-requantization jitter of both paths, and upstream
    error is amplified by the layer's L-inf operator norm (max column L1 of
    the dequantized weight).  ReLU / maxpool / 2x-upsample are 1-Lipschitz
    and concat takes the max of branch errors, so the composition is
    worst-case sound — unlike the first-order per-layer sum
    (``PlaneSchedule.rel_err_bound``), which ignores inter-layer gain.
    """
    from repro.core import quant
    from repro.core.bitplane import N_BITS

    sched = cfg.schedule()
    full_cfg = dataclasses.replace(cfg, plane_schedule=None, planes=8)
    out_full = forward(params, x, full_cfg)
    out_sched = forward(params, x, cfg)

    # --- interval propagation along the same graph -------------------------
    li = 0
    err = 0.0  # abs L-inf bound on (sched activation - full activation)

    def conv_err(p, h_ref, err_in):
        nonlocal li
        planes = sched.planes_for(li)
        li += 1
        wq = quant.quantize_weights(p["w"], channel_axis=-1)
        w2 = wq.values.reshape(-1, wq.values.shape[-1]).astype(jnp.int32)
        ws = jnp.squeeze(wq.scale)  # (cout,)
        # dequantized per-column L1 — the L-inf operator norm of the conv
        col_l1 = jnp.sum(jnp.abs(w2), axis=0).astype(jnp.float32) * ws
        opnorm = float(jnp.max(col_l1))
        amax_ref = float(jnp.max(jnp.abs(h_ref)))
        s_ref = max(amax_ref, 1e-8) / 127.0
        s_sched = max(amax_ref + err_in, 1e-8) / 127.0
        dropped = N_BITS - planes
        if err_in == 0.0 and dropped == 0:
            return 0.0  # identical datapaths
        # input divergence + the two paths' requantization jitter
        din = err_in + 0.5 * (s_ref + s_sched)
        e = opnorm * din
        if dropped:
            # truncation of the scheduled path's planes, in float units:
            # (2^d - 1) * max col-L1 of the dequantized weight * act scale
            e += (2**dropped - 1) * opnorm * s_sched
        return e

    # replay the forward structure on the *reference* activations
    h = x
    skips = []
    skip_errs = []
    for stage in params["enc"]:
        for conv in stage:
            err = conv_err(conv, h, err)
            h = jax.nn.relu(conv3x3(conv, h, full_cfg))
        skips.append(h)
        skip_errs.append(err)
        h = jax.lax.reduce_window(
            h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )
    for conv in params["bottleneck"]:
        err = conv_err(conv, h, err)
        h = jax.nn.relu(conv3x3(conv, h, full_cfg))
    for d, stage in enumerate(params["dec"]):
        n, hh, ww, c = h.shape
        h = jnp.broadcast_to(h[:, :, None, :, None, :], (n, hh, 2, ww, 2, c)).reshape(
            n, hh * 2, ww * 2, c
        )
        h = jnp.concatenate([skips[-(d + 1)], h], axis=-1)
        err = max(err, skip_errs[-(d + 1)])
        for conv in stage:
            err = conv_err(conv, h, err)
            h = jax.nn.relu(conv3x3(conv, h, full_cfg))
    # float 1x1 head, shared by both paths: pure propagation
    w_head = params["head"]["w"].reshape(-1, params["head"]["w"].shape[-1])
    err = err * float(jnp.max(jnp.sum(jnp.abs(w_head), axis=0)))

    denom = max(float(jnp.max(jnp.abs(out_full))), 1e-8)
    return out_sched, out_full, err / denom


def conv_weights_in_order(params) -> list[jax.Array]:
    """Float 3x3-conv weights in forward order (enc, bottleneck, dec)."""
    ws = []
    for stage in params["enc"]:
        ws += [conv["w"] for conv in stage]
    ws += [conv["w"] for conv in params["bottleneck"]]
    for stage in params["dec"]:
        ws += [conv["w"] for conv in stage]
    return ws


def schedule_from_params(
    params, target_rel_err: float
) -> PlaneSchedule:
    """Build the per-layer precision policy from this net's actual weights:
    quantize each 3x3 conv FBGEMM-style and pick the fewest planes whose
    analytic worst-case relative error meets ``target_rel_err``."""
    from repro.core import quant

    wq = [
        quant.quantize_weights(w, channel_axis=-1).values.reshape(-1, w.shape[-1])
        for w in conv_weights_in_order(params)
    ]
    return PlaneSchedule.from_weights(wq, target_rel_err)


def loss_fn(params, batch, cfg: UNetConfig):
    """Segmentation cross-entropy; batch = {"image": (N,H,W,C), "mask": (N,H,W)}."""
    logits = forward(params, batch["image"], cfg).astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["mask"][..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    return nll, {"nll": nll}
