"""Zamba2 — hybrid: Mamba2 backbone with a *shared* attention block.

Zamba2 interleaves a single weight-shared attention+MLP block into the
Mamba2 stack (applied every ``attn_every`` layers, with the original
embedding concatenated to the block input).  We scan over groups of
``attn_every`` mamba layers and apply the shared block between groups —
one copy of attention weights, exactly the paper's parameter-sharing trick.

81 assigned layers = 13 groups of 6 + 3 tail mamba layers (scanned
separately); the shared block fires after each full group.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain
from . import layers, mamba2


def _group_split(cfg):
    g = cfg.attn_every or 6
    n_groups = cfg.n_layers // g
    tail = cfg.n_layers - n_groups * g
    return g, n_groups, tail


def init_params(key, cfg) -> dict:
    ke, km, ks, kt, kh = jax.random.split(key, 5)
    g, n_groups, tail = _group_split(cfg)
    mkeys = jax.random.split(km, n_groups * g).reshape(n_groups, g, 2)
    grouped = jax.vmap(
        jax.vmap(lambda k: {"ln": layers.init_norm(cfg.d_model),
                            "mamba": mamba2.init_mamba_block(k, cfg)})
    )(mkeys)
    # Shared attention block input is [hidden ; embedding] (Zamba concat).
    shared_cfg = cfg.replace(d_model=2 * cfg.d_model)
    shared = {
        "ln": layers.init_norm(2 * cfg.d_model),
        "attn": layers.init_attention(ks, shared_cfg),
        "proj": layers.init_linear(kt, 2 * cfg.d_model, cfg.d_model),
    }
    p = {
        "embed": layers.init_embedding(ke, cfg.vocab, cfg.d_model),
        "groups": grouped,
        "shared": shared,
        "ln_f": layers.init_norm(cfg.d_model),
        "head": layers.init_linear(kh, cfg.d_model, cfg.vocab),
    }
    if tail:
        tkeys = jax.random.split(kh, tail).reshape(tail, 2)
        p["tail"] = jax.vmap(
            lambda k: {"ln": layers.init_norm(cfg.d_model),
                       "mamba": mamba2.init_mamba_block(k, cfg)}
        )(tkeys)
    return p


def _shared_attn(p, x, emb, cfg, *, positions, cache=None, cache_index=None):
    """The weight-shared attention block on [x ; emb] (2*d_model wide)."""
    cat = jnp.concatenate([x, emb], axis=-1)
    shared_cfg = cfg.replace(d_model=2 * cfg.d_model)
    h, new_cache = layers.attention(
        p["attn"], layers.rmsnorm(p["ln"], cat, cfg.norm_eps), shared_cfg,
        positions=positions, cache=cache, cache_index=cache_index,
    )
    return x + layers.linear(p["proj"], h, cfg.quant), new_cache


def forward(params, tokens, cfg, *, state=None, cache_index=None, **_):
    """state (decode): {"mamba": stacked group states, "tail": ...,
    "attn_k"/"attn_v": (G, B, S_max, KV, hd), "emb": None}."""
    g, n_groups, tail = _group_split(cfg)
    emb = layers.embed(params["embed"], tokens)
    x = constrain(emb, "batch", "seq" if cfg.seq_shard else None, None)
    base = 0 if cache_index is None else cache_index
    positions = base + jnp.arange(x.shape[1])[None, :]

    def mamba_group(h, gp, gstate):
        """Scan over the g mamba layers inside one group."""

        def inner(c, xs):
            hh = c
            if gstate is None:
                blk = xs
                out, _ = mamba2.mamba_forward(
                    blk["mamba"], layers.rmsnorm(blk["ln"], hh, cfg.norm_eps), cfg
                )
                return hh + out, None
            blk, conv_s, ssm_s = xs
            out, new_s = mamba2.mamba_forward(
                blk["mamba"], layers.rmsnorm(blk["ln"], hh, cfg.norm_eps), cfg,
                state={"conv": conv_s, "ssm": ssm_s},
            )
            return hh + out, (new_s["conv"], new_s["ssm"])

        fn = inner
        if cfg.remat == "full" and gstate is None:
            fn = jax.checkpoint(inner, prevent_cse=False)
        if gstate is None:
            h, _ = jax.lax.scan(fn, h, gp, unroll=cfg.scan_unroll)
            return h, None
        h, new = jax.lax.scan(fn, h, (gp, gstate["conv"], gstate["ssm"]),
                              unroll=cfg.scan_unroll)
        return h, {"conv": new[0], "ssm": new[1]}

    # Groups are iterated in Python (13 iterations — the shared attention
    # block between groups has *one* weight copy, so it cannot live in the
    # same scan as the stacked mamba params).
    new_state = {"groups": [], "attn": []} if state is not None else None
    for gi in range(n_groups):
        gp = jax.tree.map(lambda a, gi=gi: a[gi], params["groups"])
        gstate = None if state is None else jax.tree.map(
            lambda a, gi=gi: a[gi], state["groups"]
        )
        x, gnew = mamba_group(x, gp, gstate)
        if state is None:
            x, _ = _shared_attn(params["shared"], x, emb, cfg, positions=positions)
        else:
            ck = state["attn_k"][gi]
            cv = state["attn_v"][gi]
            x, (nk, nv) = _shared_attn(
                params["shared"], x, emb, cfg, positions=positions,
                cache=(ck, cv), cache_index=base,
            )
            new_state["attn"].append((nk, nv))
            new_state["groups"].append(gnew)

    if tail:
        tstate = None if state is None else state["tail"]
        x, tnew = mamba_group(x, params["tail"], tstate)
        if state is not None:
            new_state["tail"] = tnew

    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = layers.linear(params["head"], x, cfg.quant)
    logits = constrain(logits, "batch", None, "vocab")
    if state is None:
        return logits
    out_state = {
        "groups": jax.tree.map(lambda *xs: jnp.stack(xs), *new_state["groups"]),
        "attn_k": jnp.stack([kv[0] for kv in new_state["attn"]]),
        "attn_v": jnp.stack([kv[1] for kv in new_state["attn"]]),
    }
    if tail:
        out_state["tail"] = new_state["tail"]
    return logits, out_state


def init_state(cfg, batch: int, max_seq: int) -> dict:
    g, n_groups, tail = _group_split(cfg)
    one = mamba2.init_state(cfg, batch)
    groups = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_groups, g) + a.shape), one
    )
    # The shared block attends over [x ; emb] (2*d_model wide), so its head
    # dim doubles relative to cfg.hd.
    shared_hd = 2 * cfg.d_model // cfg.n_heads
    kv_shape = (n_groups, batch, max_seq, cfg.n_kv_heads, shared_hd)
    st = {
        "groups": groups,
        "attn_k": jnp.zeros(kv_shape, jnp.bfloat16),
        "attn_v": jnp.zeros(kv_shape, jnp.bfloat16),
    }
    if tail:
        st["tail"] = jax.tree.map(lambda a: jnp.broadcast_to(a, (tail,) + a.shape), one)
    return st


def loss_fn(params, batch, cfg):
    tokens = batch["tokens"][:, :-1]
    targets = batch["tokens"][:, 1:]
    logits = forward(params, tokens, cfg).astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    return nll, {"nll": nll}


def decode_step(params, tokens, state, cache_index, cfg, **_):
    return forward(params, tokens, cfg, state=state, cache_index=cache_index)
