"""Whisper (enc-dec audio) — transformer backbone only, per the assignment:
the conv/mel frontend is a STUB (``input_specs`` provides precomputed frame
embeddings).  32 encoder + 32 decoder layers, learned positions, GELU MLPs.

Shape convention (DESIGN.md): the assigned seq shapes apply to the *decoder*
token stream; the encoder memory is the stub's ``enc_seq`` frames.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain
from . import layers


def init_cross_attention(key, cfg) -> dict:
    return layers.init_attention(key, cfg)


def init_enc_block(key, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": layers.init_norm(cfg.d_model),
        "attn": layers.init_attention(k1, cfg),
        "ln2": layers.init_norm(cfg.d_model),
        "mlp": layers.init_mlp(k2, cfg),
    }


def init_dec_block(key, cfg) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": layers.init_norm(cfg.d_model),
        "self_attn": layers.init_attention(k1, cfg),
        "ln_x": layers.init_norm(cfg.d_model),
        "cross_attn": init_cross_attention(k2, cfg),
        "ln2": layers.init_norm(cfg.d_model),
        "mlp": layers.init_mlp(k3, cfg),
    }


def init_params(key, cfg, *, max_dec_pos: int = 4096) -> dict:
    ke, kd, kpe, kpd, kemb = jax.random.split(key, 5)
    ekeys = jax.random.split(ke, cfg.enc_layers or cfg.n_layers)
    dkeys = jax.random.split(kd, cfg.n_layers)
    return {
        "enc_pos": (jax.random.normal(kpe, (cfg.enc_seq, cfg.d_model), jnp.float32)
                    * 0.01).astype(jnp.bfloat16),
        "enc_blocks": jax.vmap(lambda k: init_enc_block(k, cfg))(ekeys),
        "enc_ln": layers.init_norm(cfg.d_model),
        "embed": layers.init_embedding(kemb, cfg.vocab, cfg.d_model),
        "dec_pos": (jax.random.normal(kpd, (max_dec_pos, cfg.d_model), jnp.float32)
                    * 0.01).astype(jnp.bfloat16),
        "dec_blocks": jax.vmap(lambda k: init_dec_block(k, cfg))(dkeys),
        "dec_ln": layers.init_norm(cfg.d_model),
    }


def encode(params, frames, cfg):
    """frames: (B, T_enc, D) stub embeddings -> encoder memory (B, T_enc, D)."""
    x = frames.astype(jnp.bfloat16) + params["enc_pos"][None, : frames.shape[1]]
    x = constrain(x, "batch", "seq" if cfg.seq_shard else None, None)

    def body(h, blk):
        a, _ = layers.attention(
            blk["attn"], layers.rmsnorm(blk["ln1"], h, cfg.norm_eps), cfg,
            positions=None, causal=False,
        )
        h = h + a
        h = h + layers.mlp(blk["mlp"], layers.rmsnorm(blk["ln2"], h, cfg.norm_eps), cfg)
        return constrain(h, "batch", "seq" if cfg.seq_shard else None, None), None

    fn = body
    if cfg.remat == "full":
        fn = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(fn, x, params["enc_blocks"], unroll=cfg.scan_unroll)
    return layers.rmsnorm(params["enc_ln"], x, cfg.norm_eps)


def _cross_attend(p, x, memory, cfg, *, cross_kv=None):
    """Cross attention: queries from decoder x, keys/values from memory.

    ``cross_kv`` = (k, v) precomputed once per request (decode fast path —
    re-projecting the encoder memory every token costs 2*T_enc*d^2 FLOPs
    per layer per step; see EXPERIMENTS.md §Perf whisper-decode note).
    """
    b, s, _ = x.shape
    hd = cfg.hd
    q = layers.linear(p["wq"], x, cfg.quant).reshape(b, s, cfg.n_heads, hd)
    if cross_kv is not None:
        k, v = cross_kv
    else:
        k = layers.linear(p["wk"], memory, cfg.quant).reshape(
            b, memory.shape[1], cfg.n_kv_heads, hd
        )
        v = layers.linear(p["wv"], memory, cfg.quant).reshape(
            b, memory.shape[1], cfg.n_kv_heads, hd
        )
    q, k, v = layers.constrain_qkv(q, k, v, cfg, s)
    out = layers.flash_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    return layers.linear(p["wo"], out.reshape(b, s, cfg.n_heads * hd), cfg.quant)


def precompute_cross_kv(params, memory, cfg):
    """Project the encoder memory through every decoder layer's cross-attn
    k/v once per request: returns {"k","v"}: (L, B, T_enc, KV, hd)."""
    b, t, _ = memory.shape
    hd = cfg.hd

    def one(_, blk):
        p = blk["cross_attn"]
        k = layers.linear(p["wk"], memory, cfg.quant).reshape(b, t, cfg.n_kv_heads, hd)
        v = layers.linear(p["wv"], memory, cfg.quant).reshape(b, t, cfg.n_kv_heads, hd)
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(one, None, params["dec_blocks"],
                               unroll=cfg.scan_unroll)
    return {"k": ks, "v": vs}


def decode(params, tokens, memory, cfg, *, cache=None, cache_index=None,
           cross_kv=None):
    x = layers.embed(params["embed"], tokens)
    base = 0 if cache_index is None else cache_index
    # Whisper uses learned absolute decoder positions (not RoPE).
    pos = jax.lax.dynamic_slice_in_dim(params["dec_pos"], base, x.shape[1], 0)
    x = x + pos[None]
    positions = None
    x = constrain(x, "batch", "seq" if cfg.seq_shard else None, None)

    def body(carry, xs):
        h = carry
        ckv = None
        if cache is None:
            blk = xs
            a, _ = layers.attention(
                blk["self_attn"], layers.rmsnorm(blk["ln1"], h, cfg.norm_eps), cfg,
                positions=positions,
            )
            new_kv = None
        else:
            if cross_kv is not None:
                blk, ck, cv, xk, xv = xs
                ckv = (xk, xv)
            else:
                blk, ck, cv = xs
            a, new_kv = layers.attention(
                blk["self_attn"], layers.rmsnorm(blk["ln1"], h, cfg.norm_eps), cfg,
                positions=positions, cache=(ck, cv), cache_index=base,
            )
        h = h + a
        h = h + _cross_attend(
            blk["cross_attn"], layers.rmsnorm(blk["ln_x"], h, cfg.norm_eps), memory,
            cfg, cross_kv=ckv,
        )
        h = h + layers.mlp(blk["mlp"], layers.rmsnorm(blk["ln2"], h, cfg.norm_eps), cfg)
        h = constrain(h, "batch", "seq" if cfg.seq_shard else None, None)
        return h, new_kv

    fn = body
    if cfg.remat == "full" and cache is None:
        fn = jax.checkpoint(body, prevent_cse=False)
    if cache is None:
        x, _ = jax.lax.scan(fn, x, params["dec_blocks"], unroll=cfg.scan_unroll)
        new_cache = None
    else:
        xs_in = (params["dec_blocks"], cache["k"], cache["v"])
        if cross_kv is not None:
            xs_in = xs_in + (cross_kv["k"], cross_kv["v"])
        x, kv = jax.lax.scan(fn, x, xs_in, unroll=cfg.scan_unroll)
        new_cache = {"k": kv[0], "v": kv[1]}

    x = layers.rmsnorm(params["dec_ln"], x, cfg.norm_eps)
    logits = layers.unembed(params["embed"], x)  # whisper ties output proj
    logits = constrain(logits, "batch", None, "vocab")
    return (logits, new_cache) if cache is not None else logits


def forward(params, batch_or_tokens, cfg, **kw):
    """Training forward: batch = {"frames": (B,T,D), "tokens": (B,S)}."""
    if isinstance(batch_or_tokens, dict):
        frames = batch_or_tokens["frames"]
        tokens = batch_or_tokens["tokens"]
    else:
        raise ValueError("whisper.forward expects a batch dict")
    memory = encode(params, frames, cfg)
    return decode(params, tokens, memory, cfg)


def loss_fn(params, batch, cfg):
    tokens = batch["tokens"][:, :-1]
    targets = batch["tokens"][:, 1:]
    memory = encode(params, batch["frames"], cfg)
    logits = decode(params, tokens, memory, cfg).astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    return nll, {"nll": nll}


def init_cache(cfg, batch: int, max_seq: int, *, dtype=jnp.bfloat16) -> dict:
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(params, tokens, cache, cache_index, cfg, *, memory=None,
                cross_kv=None, **_):
    """Serving step: memory (and optionally the per-layer cross K/V — see
    ``precompute_cross_kv``) computed once at request admission."""
    assert memory is not None
    return decode(params, tokens, memory, cfg, cache=cache,
                  cache_index=cache_index, cross_kv=cross_kv)
