"""Decoder-only transformer LM — families 'dense', 'moe', 'vlm'.

Layer stacks are lax.scan-rolled (stacked params, O(1) HLO in depth — keeps
512-device SPMD compiles tractable and real-cluster compile times sane).
Sequence parallelism on the residual stream, TP inside blocks, EP for MoE.
VLM ('vlm'): a prefix of precomputed patch embeddings (the stub modality
frontend per the assignment) is concatenated before the token embeddings.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain
from . import layers, moe as moe_lib


# ------------------------------------------------------------------ params


def init_block(key, cfg) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "ln1": layers.init_norm(cfg.d_model),
        "attn": layers.init_attention(ks[0], cfg),
        "ln2": layers.init_norm(cfg.d_model),
    }
    if cfg.moe.n_experts:
        p["moe"] = moe_lib.init_moe(ks[1], cfg)
    else:
        p["mlp"] = layers.init_mlp(ks[2], cfg)
    return p


def init_params(key, cfg) -> dict:
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    block_keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(block_keys)
    p = {
        "embed": layers.init_embedding(k_emb, cfg.vocab, cfg.d_model),
        "blocks": blocks,  # every leaf stacked (L, ...)
        "ln_f": layers.init_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = layers.init_linear(k_head, cfg.d_model, cfg.vocab)
    return p


# ----------------------------------------------------------------- forward


def _block(p, x, cfg, *, positions, cache=None, cache_index=None):
    h, new_cache = layers.attention(
        p["attn"], layers.rmsnorm(p["ln1"], x, cfg.norm_eps), cfg,
        positions=positions, cache=cache, cache_index=cache_index,
    )
    x = x + h
    x = constrain(x, "batch", "seq" if cfg.seq_shard else None, None)
    h2 = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.moe.n_experts:
        ffn = moe_lib.moe_ffn_ep if cfg.moe.ep else moe_lib.moe_ffn
        h2 = ffn(p["moe"], h2, cfg)
    else:
        h2 = layers.mlp(p["mlp"], h2, cfg)
    x = x + h2
    return constrain(x, "batch", "seq" if cfg.seq_shard else None, None), new_cache


def forward(
    params: dict,
    tokens: jax.Array,
    cfg,
    *,
    prefix_embeds: jax.Array | None = None,
    cache: dict | None = None,
    cache_index: jax.Array | None = None,
    return_aux: bool = False,
):
    """tokens: (B, S) int32 -> logits (B, S[+P], vocab).

    With ``cache`` (decode/prefill-into-cache): returns (logits, new_cache);
    cache = {"k": (L, B, S_max, KV, hd), "v": ...}.
    """
    x = layers.embed(params["embed"], tokens)
    if prefix_embeds is not None:  # vlm stub frontend
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    base = 0 if cache_index is None else cache_index
    if jnp.ndim(base) > 0:  # per-row cache positions (slot-isolated decode)
        positions = jnp.reshape(base, (-1, 1)) + jnp.arange(s)[None, :]
    else:
        positions = base + jnp.arange(s)[None, :]
    x = constrain(x, "batch", "seq" if cfg.seq_shard else None, None)

    aux0 = jnp.zeros((), jnp.float32)

    # Per-layer dynamic precision: the schedule rides the layer scan as data
    # (an (L,) int32 vector of plane budgets).  The scan body folds layer l's
    # budget into a per-layer QuantConfig; downstream the budget is a traced
    # scalar, which core.mma resolves via the exact bit-mask truncation
    # identity — same numerics as static plane truncation, one fused matmul.
    sched = None
    if cfg.quant.mode == "mma_int8" and cfg.quant.plane_schedule is not None:
        from repro.core.plane_schedule import PlaneSchedule

        ps = PlaneSchedule.from_list(cfg.quant.plane_schedule)
        sched = jnp.asarray(
            [ps.planes_for(i) for i in range(cfg.n_layers)], jnp.int32
        )

    def body(carry, xs):
        h, aux = carry
        if sched is not None:
            xs, planes_l = xs
            lcfg = cfg.replace(
                quant=dataclasses.replace(
                    cfg.quant, planes=planes_l, plane_schedule=None
                )
            )
        else:
            lcfg = cfg
        if cache is None:
            blk = xs
            if cfg.moe.n_experts:
                aux = aux + moe_lib.load_balance_loss(
                    blk["moe"], layers.rmsnorm(blk["ln2"], h, cfg.norm_eps), lcfg
                )
            h, _ = _block(blk, h, lcfg, positions=positions)
            return (h, aux), None
        blk, ck, cv = xs
        h, new_kv = _block(
            blk, h, lcfg, positions=positions, cache=(ck, cv), cache_index=base
        )
        return (h, aux), new_kv

    block_fn = body
    if cfg.remat == "full" and cache is None:
        block_fn = jax.checkpoint(body, prevent_cse=False)

    if cache is None:
        blocks_xs = params["blocks"] if sched is None else (params["blocks"], sched)
        (x, aux), _ = jax.lax.scan(block_fn, (x, aux0), blocks_xs, unroll=cfg.scan_unroll)
        new_cache = None
    else:
        blocks_xs = (params["blocks"], cache["k"], cache["v"])
        if sched is not None:
            blocks_xs = (blocks_xs, sched)
        (x, aux), kv = jax.lax.scan(
            block_fn, (x, aux0), blocks_xs,
            unroll=cfg.scan_unroll,
        )
        new_cache = {"k": kv[0], "v": kv[1]}

    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x)
    else:
        logits = layers.linear(params["head"], x, cfg.quant)
    logits = constrain(logits, "batch", None, "vocab")
    if cache is not None:
        return logits, new_cache
    if return_aux:
        return logits, aux
    return logits


# --------------------------------------------------------------------- loss


def loss_fn(params, batch, cfg):
    """Next-token cross-entropy; batch = {"tokens": (B, S+1)} (+ optional
    "patches" for vlm).  Returns (loss, metrics)."""
    tokens = batch["tokens"][:, :-1]
    targets = batch["tokens"][:, 1:]
    prefix = batch.get("patches")
    logits, aux = forward(params, tokens, cfg, prefix_embeds=prefix, return_aux=True)
    if prefix is not None:
        logits = logits[:, prefix.shape[1] :, :]
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    loss = nll + 0.01 * aux
    return loss, {"nll": nll, "aux": aux}


# ------------------------------------------------------------------- decode


def init_cache(cfg, batch: int, max_seq: int, *, dtype=jnp.bfloat16) -> dict:
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_specs(cfg):
    """Logical axes of the KV cache (for sharding: batch over data, kv-heads
    over model when divisible, else head_dim)."""
    return (None, "batch", None, "kv_heads", "kv_head_dim")


def decode_step(params, tokens, cache, cache_index, cfg, *, prefix_embeds=None):
    """One serving step: tokens (B, S_new) appended at cache_index.

    prefill: S_new = prompt length; decode: S_new = 1.
    Returns (logits for the new positions, updated cache).
    """
    logits, new_cache = forward(
        params, tokens, cfg, prefix_embeds=prefix_embeds,
        cache=cache, cache_index=cache_index,
    )
    return logits, new_cache
