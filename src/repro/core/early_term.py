"""Early termination / progressive precision — the MSDF property on TPU.

MSDF arithmetic emits the most significant digits first, so a consumer can
stop once it has enough precision (paper Sec. 2, and "future work": early
termination).  In the bit-plane formulation the analogue is *plane
truncation*: stop after the ``b`` most significant activation planes.

Exact worst-case bound (planes are 0/1):

    |S_full - S_b| = | sum_{j < 8-b} 2^j * (plane_j @ w) |
                   <= (2**(8-b) - 1) * sum_k |w[k, n]|        per output n

and with the midpoint correction (add E[dropped] = (2^(8-b)-1)/2 * colsum(w))
the bound halves.  These bounds drive :func:`choose_planes`, which picks the
fewest planes meeting a target relative error per layer — the serving-time
knob (`quant.planes`) that gives LM decode the paper's progressive-precision
property.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .bitplane import N_BITS


def truncation_bound(w_int8: jax.Array, planes: int, *, midpoint: bool = True) -> jax.Array:
    """Worst-case |error| per output column of an int8 matmul truncated to
    ``planes`` MSB activation planes.  w_int8: (K, N)."""
    dropped = N_BITS - planes
    l1 = jnp.sum(jnp.abs(w_int8.astype(jnp.int32)), axis=0)
    bound = (2**dropped - 1) * l1
    if midpoint:
        bound = (bound + 1) // 2
    return bound


def output_scale_bound(w_int8: jax.Array) -> jax.Array:
    """Scale of the full-precision output: 255 * colsum(|w|) (worst case for
    uint8-offset activations) — used to turn absolute bounds relative."""
    return 255 * jnp.sum(jnp.abs(w_int8.astype(jnp.int32)), axis=0)


def choose_planes(
    w_int8: jax.Array, target_rel_err: float, *, midpoint: bool = True
) -> int:
    """Fewest planes such that worst-case relative error <= target.

    ``midpoint=False`` bounds *uncorrected* truncation — what the deployed
    datapaths (``bitplane_matmul`` with correction='none', the Pallas kernel,
    ``truncate_to_planes``) actually apply; the midpoint bound is half-sized
    and only valid when the consumer adds the expected-value correction.
    """
    denom = jnp.maximum(output_scale_bound(w_int8).astype(jnp.float32), 1.0)
    for b in range(1, N_BITS + 1):
        bound = truncation_bound(w_int8, b, midpoint=midpoint)
        rel = jnp.max(bound.astype(jnp.float32) / denom)
        if float(rel) <= target_rel_err:
            return b
    return N_BITS


def empirical_rel_err(exact: jax.Array, approx: jax.Array) -> jax.Array:
    """Measured relative error, for validating the bound in tests/examples."""
    denom = jnp.maximum(jnp.max(jnp.abs(exact.astype(jnp.float32))), 1.0)
    return jnp.max(jnp.abs(exact - approx).astype(jnp.float32)) / denom
