"""Integer fixed-point (pJ-scale) energy costing for the modeled datapath.

:mod:`repro.core.cycle_model` prices *time* — relation-(2) cycles on the
100 MHz modeled clock — and until now energy appeared only as
``PlatformRow.energy_mj = power x time`` with power held at the paper's
implied constant (Table 1 proposed: GOPS / (GOPS/W) = 52.95 / 15.14
= 3.497 W).  That constant hides the two effects the paper (and MINT's
dynamic-precision MSDF inference) actually exploit:

* **Plane-proportional dynamic energy.**  A layer truncated to ``b``
  MSB planes streams ``b`` activation digits, so its AND-array/digit
  path both runs for fewer cycles (``schedule_tile_cycles``: the 2b
  steady-state initiation interval) *and* switches a ``b``-plane-deep
  digit pipeline each of those cycles.  Dynamic energy therefore scales
  ~quadratically with the plane budget (cycles x per-cycle switching),
  which is exactly the "energy win beyond finishing earlier" the
  cycle-model comment conservatively declined to claim.
* **Static energy charged per clock cycle.**  Leakage + clock tree burn
  every cycle, worked or idle — an idle shard is cheap but not free, so
  fleet sizing trades static floor against dynamic work.

Everything here is **integer picojoules** so the observability layer
(:mod:`repro.obs.energy`) can gate ledger reconciliation exactly the
way cycle accounting already is (``spans`` <-> ``RoundClock`` <->
``FleetLedger``): joule totals are sums of ``int`` pJ, never floats.

Calibration anchor (golden-locked in ``tests/test_energy.py``): one
active cycle at the full n=8 plane budget costs

    ``PJ_STATIC_CYCLE + 8 * PJ_PLANE_CYCLE = 34_973 pJ``

i.e. 3.4973 W sustained at 100 MHz — the paper's implied chip power to
<0.01% — so the modeled full-8 calibrated U-Net reproduces Table 1's
proposed-row GOPS/W (15.14) and energy (186.20 mJ) within the same
~1% family of residuals the cycle calibration already carries.  The
static share (~25% of full-width active power) follows the usual
FPGA split for an AND-array-dominated datapath.
"""
from __future__ import annotations

from repro.core import cycle_model as cm
from repro.core.cycle_model import FREQ_HZ, N_BITS, PAPER_TABLE1

#: Dynamic switching energy of one digit plane for one active cycle
#: (AND-array column + online-adder slice + plane mux), integer pJ.
PJ_PLANE_CYCLE = 3_280

#: Static energy (leakage + clock distribution) of one clock cycle,
#: charged whether or not the datapath worked, integer pJ.
PJ_STATIC_CYCLE = 8_733

#: Energy of one active cycle at the full n=8 digit budget — the
#: calibration anchor (== paper-implied 3.497 W at 100 MHz).
PJ_FULL_CYCLE = PJ_STATIC_CYCLE + N_BITS * PJ_PLANE_CYCLE


def active_rate_pj(planes: int = N_BITS) -> int:
    """pJ per *worked* cycle on a datapath switching ``planes`` digit
    planes (static share included — a worked cycle is also a clock
    cycle)."""
    if not 1 <= planes <= N_BITS:
        raise ValueError(f"planes {planes} outside 1..{N_BITS}")
    return PJ_STATIC_CYCLE + planes * PJ_PLANE_CYCLE


def active_pj(cycles: int, planes: int = N_BITS) -> int:
    """Energy of ``cycles`` worked cycles at a ``planes`` digit budget."""
    return int(cycles) * active_rate_pj(planes)


def idle_pj(cycles: int) -> int:
    """Static burn of ``cycles`` un-worked clock cycles."""
    return int(cycles) * PJ_STATIC_CYCLE


def pj_to_j(pj: int) -> float:
    return pj * 1e-12


def pj_to_mj(pj: int) -> float:
    return pj * 1e-9


def modeled_power_w(planes: int = N_BITS) -> float:
    """Sustained power of a fully-active datapath at ``planes`` digits."""
    return active_rate_pj(planes) * FREQ_HZ * 1e-12


def implied_chip_power_w() -> float:
    """The paper's implied constant (Table 1 proposed GOPS / (GOPS/W))
    — what :func:`cm.proposed_row` charges every cycle regardless of
    activity.  The meter's static/dynamic split refines this."""
    row = PAPER_TABLE1["proposed"]
    return row["gops"] / row["gops_w"]


def metered_gops_per_w(ops: int, pj: int) -> float | None:
    """GOPS/W from an ops count and a metered energy: time cancels —
    (ops/t/1e9) / (E/t) = ops / (E_J * 1e9) = 1000 * ops / pJ."""
    if pj <= 0:
        return None
    return 1000.0 * ops / pj


# ---- per-layer / per-schedule costing --------------------------------------


def schedule_layer_pj(layers, schedule=None, *, mode: str = "pipelined"):
    """Active energy per conv layer under a per-layer plane schedule:
    relation-(2) cycles at each layer's budget x that budget's per-cycle
    rate — the plane-proportional dynamic term rides on top of the cycle
    shrink, so truncation saves superlinearly."""
    if schedule is None:
        schedule = (N_BITS,)
    cycles = cm.schedule_layer_cycles(layers, schedule, mode=mode)
    return [
        c * active_rate_pj(cm._planes_for(schedule, i))
        for i, c in enumerate(cycles)
    ]


def schedule_pj(layers, schedule=None, *, mode: str = "pipelined") -> int:
    """Total active energy of one forward pass under ``schedule``."""
    return sum(schedule_layer_pj(layers, schedule, mode=mode))


# ---- speculative decode op classes -----------------------------------------


def spec_round_pj(
    *,
    k: int,
    draft_step_cycles: int,
    full_step_cycles: int,
    interval_cycles: int,
    draft_planes: int,
    planes: int = N_BITS,
    slots: int = 1,
    accepted: int | None = None,
) -> dict:
    """Energy of one speculative round, split by op class the way
    :func:`cm.lm_spec_step_cycles` splits cycles.

    Draft work runs the truncated ``draft_planes`` datapath (cheap per
    cycle *and* short); the verify pass runs the full-digit schedule.
    With ``accepted`` the wasted/useful split closes integer-exactly:
    ``useful_pj + wasted_pj == draft_pj + verify_pj``, with the wasted
    share priced per op class ((k-a) draft steps at the draft rate,
    (k-a) pipeline intervals at the full rate)."""
    if k < 1:
        raise ValueError(f"spec depth k {k} < 1")
    dr = active_rate_pj(draft_planes)
    fr = active_rate_pj(planes)
    draft_cycles = k * draft_step_cycles * slots
    verify_cycles = (full_step_cycles + k * interval_cycles) * slots
    out = dict(
        draft_rate_pj=dr,
        verify_rate_pj=fr,
        draft_cycles=draft_cycles,
        verify_cycles=verify_cycles,
        draft_pj=draft_cycles * dr,
        verify_pj=verify_cycles * fr,
    )
    out["total_pj"] = out["draft_pj"] + out["verify_pj"]
    if accepted is not None:
        if not 0 <= accepted <= k:
            raise ValueError(f"accepted {accepted} outside 0..{k}")
        wasted_draft = (k - accepted) * draft_step_cycles * slots
        wasted_verify = (k - accepted) * interval_cycles * slots
        out.update(
            wasted_draft_cycles=wasted_draft,
            wasted_verify_cycles=wasted_verify,
            wasted_pj=wasted_draft * dr + wasted_verify * fr,
        )
        out["useful_pj"] = out["total_pj"] - out["wasted_pj"]
        # the non-speculative cost of the tokens actually emitted
        out["baseline_pj"] = (accepted + 1) * full_step_cycles * fr * slots
    return out


# ---- calibration -----------------------------------------------------------


def calibration(mode: str = "pipelined") -> dict:
    """The golden-locked anchor: the calibrated full-8 U-Net, priced by
    this model, against Table 1's proposed row as printed."""
    layers = cm.unet_conv_layers(**cm.CALIBRATED_UNET)
    schedule = (N_BITS,)
    cycles = cm.schedule_cycles(layers, schedule, mode=mode)
    ops = cm.model_ops(layers)
    pj = schedule_pj(layers, schedule, mode=mode)
    row = PAPER_TABLE1["proposed"]
    gops_w = metered_gops_per_w(ops, pj)
    e_mj = pj_to_mj(pj)
    return dict(
        cycles=cycles,
        ops=ops,
        energy_pj=pj,
        energy_mj=e_mj,
        gops_w=gops_w,
        power_w=modeled_power_w(),
        paper_gops_w=row["gops_w"],
        paper_e_mj=row["e_mj"],
        paper_power_w=implied_chip_power_w(),
        rel_err_gops_w=(gops_w - row["gops_w"]) / row["gops_w"],
        rel_err_e_mj=(e_mj - row["e_mj"]) / row["e_mj"],
    )
