"""Bit-plane decomposition — the TPU-native form of the paper's digit-serial stream.

The FPGA design streams 8-bit activations one bit per cycle, MSB first, and
multiplies each bit against the parallel 8-bit weight via an AND gate array.
On TPU the analogue is *bit-plane decomposition*: an int8 tensor is the
Horner combination of 8 binary planes, and an inner product becomes 8 binary
(0/1) × int8 products combined MSB-first:

    acc <- 2*acc + plane_b @ w        (b = MSB .. LSB)

which is *exactly* the paper's residual recurrence (the residual is
left-shifted by one bit each cycle before the next partial products are
added, Sec. 3.2).

Signed handling: two's-complement int8 ``x`` is decomposed via the unsigned
offset form ``u = x + 128`` (planes of ``u`` are plain 0/1), and the exact
correction ``-128 * sum(w)`` is applied once at the end.  This keeps every
plane non-negative — matching the paper's unsigned activation stream (U-Net
activations are post-ReLU) — while supporting signed LM activations exactly.
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

N_BITS = 8
SIGNED_OFFSET = 128  # u = x + 128 for int8 x


def decompose(x: jax.Array, *, n_bits: int = N_BITS, signed: bool = True) -> jax.Array:
    """Decompose an int tensor into MSB-first binary planes.

    Args:
      x: int8 (signed=True) or uint8-valued int32/uint8 (signed=False) tensor.
      n_bits: number of planes (8 for the paper's quantization).
      signed: apply the +128 offset trick for two's-complement input.

    Returns:
      int8 tensor of shape ``(n_bits, *x.shape)`` with planes[0] = MSB.
    """
    u = x.astype(jnp.int32)
    if signed:
        u = u + SIGNED_OFFSET
    shifts = jnp.arange(n_bits - 1, -1, -1, dtype=jnp.int32)  # MSB first
    planes = (u[None, ...] >> shifts.reshape((n_bits,) + (1,) * x.ndim)) & 1
    return planes.astype(jnp.int8)


def recombine(planes: jax.Array, *, signed: bool = True) -> jax.Array:
    """Inverse of :func:`decompose` (Horner, MSB first)."""

    def body(acc, plane):
        return acc * 2 + plane.astype(jnp.int32), None

    acc, _ = jax.lax.scan(body, jnp.zeros(planes.shape[1:], jnp.int32), planes)
    if signed:
        acc = acc - SIGNED_OFFSET
    return acc


def truncate_to_planes(
    x: jax.Array, planes: int | jax.Array, *, signed: bool = True
) -> jax.Array:
    """Data-side form of plane truncation: returns ``x'`` such that a plain
    full-precision matmul ``x' @ w`` equals ``bitplane_matmul(x, w, planes)``.

    Identity (see ``kernels/ref.py``): consuming only the ``b`` MSB planes of
    ``u = x + 128`` and Horner-rescaling equals masking off the low ``8-b``
    bits of ``u``.  Because the mask is computed with jnp shifts, ``planes``
    may be a *traced* scalar — this is what lets a per-layer
    :class:`~repro.core.plane_schedule.PlaneSchedule` ride a ``lax.scan``
    over stacked layer params while every datapath (including the
    bit-parallel int8 baseline) sees ordinary int8 operands.
    """
    u = x.astype(jnp.int32)
    if signed:
        u = u + SIGNED_OFFSET
    dropped = N_BITS - jnp.asarray(planes, jnp.int32)
    mask = ~(jnp.left_shift(jnp.int32(1), dropped) - 1)
    u = u & mask
    if signed:
        return (u - SIGNED_OFFSET).astype(jnp.int8)
    return u.astype(x.dtype)


def normalize_planes(
    x: jax.Array, planes: int | jax.Array, *, signed: bool = True
) -> tuple[jax.Array, int]:
    """Resolve a per-call plane budget to (operand, static planes).

    Static Python ints are validated (1..N_BITS) and passed through — the
    kernel paths specialize on them and genuinely skip plane iterations.
    Traced scalars — one entry of a PlaneSchedule riding a ``lax.scan`` —
    are folded into the *data* via :func:`truncate_to_planes`, after which
    every datapath runs its full-precision path on the pre-truncated
    operand: identical numerics, one fused matmul.
    """
    if isinstance(planes, int):
        if not (1 <= planes <= N_BITS):
            raise ValueError(f"planes {planes} outside 1..{N_BITS}")
        return x, planes
    return truncate_to_planes(x, planes, signed=signed), N_BITS


def bitplane_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    planes: int = N_BITS,
    signed: bool = True,
    correction: Literal["none", "midpoint"] = "none",
) -> jax.Array:
    """Exact (planes=8) or progressively-truncated (planes<8) int matmul.

    Computes ``x @ w`` in int32 via MSB-first bit-plane accumulation — the
    pure-XLA reference of the MMA datapath (the Pallas kernel in
    ``repro.kernels.mma_matmul`` fuses the same recurrence into VMEM).

    With ``planes = b < 8`` only the ``b`` most significant planes are
    consumed — the paper's early termination.  The partial Horner sum is
    rescaled by ``2**(8-b)``; ``correction='midpoint'`` adds the expected
    value of the dropped planes (they are 0/1 each, expectation ~0.5) to
    halve the truncation bias.  The worst-case error is bounded by
    ``(2**(8-b) - 1) * sum(|w|, contraction)`` (see ``early_term.py``).

    Args:
      x: (..., K) int8 activations.
      w: (K, N) int8 weights.
      planes: number of MSB planes to consume, 1..8.
      signed: x is two's-complement int8.

    Returns:
      (..., N) int32.
    """
    n_bits = N_BITS
    pl = decompose(x, n_bits=n_bits, signed=signed)  # (8, ..., K) values 0/1
    w32 = w.astype(jnp.int32)

    # Python (unrolled) Horner loop: <= 8 iterations, keeps every plane's
    # FLOPs visible to cost analysis (a lax.scan body is counted once).
    out_shape = x.shape[:-1] + (w.shape[-1],)
    acc = jnp.zeros(out_shape, jnp.int32)
    for i in range(planes):
        plane = pl[i]
        part = jax.lax.dot_general(
            plane.astype(jnp.int8),
            w,
            (((plane.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        acc = acc * 2 + part

    dropped = n_bits - planes
    acc = acc * (2**dropped)
    colsum = jnp.sum(w32, axis=0)
    if correction == "midpoint" and dropped:
        # dropped planes contribute sum_{j<dropped} 2^j * plane_j @ w, each
        # plane entry ~ Bernoulli(1/2)  ->  E = (2^dropped - 1)/2 * colsum(w)
        acc = acc + ((2**dropped - 1) * colsum) // 2
    if signed:
        acc = acc - SIGNED_OFFSET * colsum
    return acc


def bitplane_matmul_cascade(
    x: jax.Array, w: jax.Array, *, planes: int = N_BITS, signed: bool = True
) -> jax.Array:
    """The *un-merged* baseline: per-plane partial products are materialized
    and then reduced in a separate pass — the TPU analogue of the cascaded
    MSDF multiplier + adder-tree design the paper improves on (each op is a
    separate HBM round-trip, like each FPGA unit paying its own initial
    delay).  Numerically identical to :func:`bitplane_matmul`; exists so the
    benchmark can expose the fusion win structurally (HLO bytes / op count).
    """
    pl = decompose(x, n_bits=N_BITS, signed=signed)[:planes]
    # Stage 1 (the "multipliers"): one partial-product tensor per plane.
    parts = [
        jax.lax.dot_general(
            p, w, (((p.ndim - 1,), (0,)), ((), ())), preferred_element_type=jnp.int32
        )
        for p in pl
    ]
    # Stage 2 (the "adder tree"): pairwise reduction over materialized parts.
    weights = [2 ** (planes - 1 - b) for b in range(planes)]
    parts = [p * w_ for p, w_ in zip(parts, weights)]
    while len(parts) > 1:
        nxt = [a + b for a, b in zip(parts[::2], parts[1::2])]
        if len(parts) % 2:
            nxt.append(parts[-1])
        parts = nxt
    acc = parts[0] * (2 ** (N_BITS - planes))
    if signed:
        acc = acc - SIGNED_OFFSET * jnp.sum(w.astype(jnp.int32), axis=0)
    return acc
