"""Cycle-level, bit-exact reference model of the paper's MSDF datapath.

This module reproduces the FPGA arithmetic *functionally*, cycle by cycle:

* signed-digit (SD) radix-2 digits in {-1, 0, 1} (the paper's redundant
  number system; we model digit *values*, the 2-bit IEN encoding is a
  gate-level detail with no arithmetic content),
* the merged multiply-add (MMA) unit of Sec. 3.2: per cycle it consumes one
  activation bit-plane across T_N channels (the AND-gate array), adds the
  partial products together with the left-shifted residual of the previous
  cycle, and — after an initial delay of delta = 2 cycles — emits one output
  digit per cycle through the output generation function (OGF),
* the MSDF online adder (delta = 2) and the KPB adder tree that combines the
  k*k = 9 MMA outputs (Eq. 1).

It is NOT part of the TPU compute path (see DESIGN.md — SD redundancy solves
an FPGA carry-chain problem that does not exist on the MXU); it exists to

* prove our TPU bit-plane datapath computes the same function the hardware
  does (tests assert bit-exact equality against integer dot products), and
* let the cycle model (``cycle_model.py``) cross-check relation (2)'s
  latency against a measured cycle count from this simulator.

Digit-selection rule: the classic online "round the residual" selection.
When emitting the digit of weight ``t`` the unit holds residual ``R`` (the
part of the final value not yet emitted, based on inputs seen so far) and
chooses ``d = +1 if R >= t/2, -1 if R <= -t/2, else 0``.  The redundancy of
the SD digit set absorbs the still-unseen input tail; the invariant
``|R| <= t`` before each selection (checked by tests) guarantees the final
residual is exactly zero, i.e. the digit stream reconstructs the value
exactly.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

DELTA_MMA = 2  # initial delay of the merged unit (paper: delta_x+ = 2)
DELTA_ADD = 2  # initial delay of the MSDF online adder (paper: delta_+)
DELTA_MUL = 3  # initial delay of a standalone online multiplier (baseline)


def sd_to_int(digits: list[int], msb_weight: int) -> int:
    """Value of an SD digit stream whose first digit has weight 2**msb_weight."""
    return sum(d * (2 ** (msb_weight - j)) for j, d in enumerate(digits))


@dataclass
class OnlineSerializer:
    """Generic MSDF emitter: consumes additive integer contributions with
    geometrically decreasing magnitude, emits SD digits MSB-first after an
    initial delay.  Both the MMA's OGF and the online adder instantiate it.

    Attributes:
      msb_weight: weight (power of two) of the first emitted digit.
      n_digits: total digits to emit (p_out).
      delay: initial delay in cycles before the first digit.
    """

    msb_weight: int
    n_digits: int
    delay: int
    residual: int = 0
    cycle: int = 0
    digits: list[int] = field(default_factory=list)
    max_abs_residual: int = 0  # instrumentation for the boundedness invariant

    def step(self, contribution: int = 0) -> int | None:
        """One clock cycle: absorb ``contribution`` and maybe emit a digit."""
        self.residual += int(contribution)
        out = None
        if self.cycle >= self.delay and len(self.digits) < self.n_digits:
            k = len(self.digits)
            t = 2 ** (self.msb_weight - k)
            r = self.residual
            half = (t + 1) // 2
            if r >= half:
                d = 1
            elif r <= -half:
                d = -1
            else:
                d = 0
            self.residual -= d * t
            self.digits.append(d)
            out = d
        self.max_abs_residual = max(self.max_abs_residual, abs(self.residual))
        self.cycle += 1
        return out

    @property
    def done(self) -> bool:
        return len(self.digits) == self.n_digits

    def value(self) -> int:
        return sd_to_int(self.digits, self.msb_weight)


@dataclass
class MMAUnit:
    """The merged multiply-add unit (Fig. 2) for ``t_n`` channels, n=8 bits.

    Per cycle: AND-gate array selects weights by the current activation bit
    (MSB first), the adder tree sums the 32 partial products *plus* the
    left-shifted residual of the previous cycle, and the OGF emits one SD
    digit (after the single merged initial delay of 2 cycles) — versus the
    cascaded design where the multiplier and every adder-tree level each pay
    their own delay.
    """

    weights: np.ndarray  # (t_n,) int8
    n_bits: int = 8
    t_n: int = 32

    def __post_init__(self):
        assert self.weights.shape == (self.t_n,)
        # p_out = 2n + ceil(log2(T_N)) digits cover the full product range.
        self.p_out = 2 * self.n_bits + math.ceil(math.log2(self.t_n))
        self.ogf = OnlineSerializer(
            msb_weight=self.p_out - 1, n_digits=self.p_out, delay=DELTA_MMA
        )
        self._bit = 0

    def step(self, act_bits: np.ndarray | None) -> int | None:
        """One cycle.  ``act_bits``: (t_n,) 0/1 vector — the b-th bit plane of
        all channels (MSB first) — or None once all 8 planes are consumed."""
        contribution = 0
        if act_bits is not None:
            # AND-gate array + adder tree: sum of selected weights, at the
            # weight of the current bit plane.
            p = int(np.dot(act_bits.astype(np.int64), self.weights.astype(np.int64)))
            contribution = p * (2 ** (self.n_bits - 1 - self._bit))
            self._bit += 1
        return self.ogf.step(contribution)

    def run(self, activations: np.ndarray) -> tuple[int, int]:
        """Feed 8-bit unsigned activations bit-serially; returns (value, cycles)."""
        assert activations.shape == (self.t_n,)
        cycles = 0
        for b in range(self.n_bits - 1, -1, -1):  # MSB first
            bits = (activations.astype(np.int64) >> b) & 1
            self.step(bits)
            cycles += 1
        while not self.ogf.done:
            self.step(None)
            cycles += 1
        return self.ogf.value(), cycles


@dataclass
class OnlineAdder:
    """MSDF online adder: consumes one SD digit from each operand per cycle,
    emits the sum's SD digits with initial delay DELTA_ADD after the first
    input digit arrives (``start`` = absolute cycle of the first input).

    Digit growth: a true SD carry-free adder grows the range by one digit;
    our generic round-the-residual selection needs |R| <= 1.5*t at every
    selection, which requires TWO leading digits of headroom (GROWTH = 2).
    Arithmetic values are identical; only the stream is one digit longer —
    noted as a conservative modeling choice in DESIGN.md.
    """

    GROWTH = 2

    msb_weight: int  # of the *inputs*
    n_digits: int  # of the *inputs*
    start: int = 0  # absolute cycle at which input digits begin

    def __post_init__(self):
        self.out = OnlineSerializer(
            msb_weight=self.msb_weight + self.GROWTH,
            n_digits=self.n_digits + self.GROWTH,
            delay=self.start + DELTA_ADD,
        )
        self._j = 0

    def step(self, dx: int | None, dy: int | None) -> int | None:
        c = 0
        if dx is not None or dy is not None:
            w = 2 ** (self.msb_weight - self._j)
            c = ((dx or 0) + (dy or 0)) * w
            self._j += 1
        return self.out.step(c)


def kpb_inner_product(
    activations: np.ndarray, weights: np.ndarray, t_n: int = 32
) -> tuple[int, int]:
    """Cycle-accurate Kernel Processing Block: k*k MMA units + the MSDF adder
    tree (Eq. 1).  ``activations``/``weights``: (k*k, t_n) uint8 / int8.

    Returns (inner product value, total cycles from first input bit to last
    output digit) — the measured counterpart of relation (2)'s per-output
    latency term.
    """
    taps, tn = activations.shape
    assert weights.shape == (taps, tn)
    n_bits = 8

    # Stage 1 — run each MMA, recording its digit timeline (index = cycle;
    # None = no digit that cycle, i.e. the unit is still in its initial
    # delay).  Digit-level pipelining: a digit emitted at cycle c is consumed
    # by the next tree level at cycle c.
    timelines: list[list[int | None]] = []
    mmas = [MMAUnit(weights[j], t_n=tn) for j in range(taps)]
    for j, m in enumerate(mmas):
        tl: list[int | None] = []
        for b in range(n_bits - 1, -1, -1):
            bits = (activations[j].astype(np.int64) >> b) & 1
            tl.append(m.step(bits))
        while not m.ogf.done:
            tl.append(m.step(None))
        timelines.append(tl)

    # Stage 2 — the MSDF adder tree.  All streams entering a level are
    # cycle-synchronized (same first-digit cycle f); each level adds
    # DELTA_ADD cycles of delay and one integer bit of range.  An odd
    # passthrough stream is re-aligned to the level's output timing/weight by
    # delaying it DELTA_ADD cycles and prepending a zero digit.
    level_streams = timelines
    level_msb, level_nd = mmas[0].p_out - 1, mmas[0].p_out
    g = OnlineAdder.GROWTH
    while len(level_streams) > 1:
        f = next(i for i, d in enumerate(level_streams[0]) if d is not None)
        adders = [
            (OnlineAdder(level_msb, level_nd, start=f), i)
            for i in range(0, len(level_streams) - 1, 2)
        ]
        out_streams: list[list[int | None]] = [[] for _ in adders]
        max_t = max(len(s) for s in level_streams) + level_nd + DELTA_ADD + g + 2
        for t in range(max_t):
            for k, (ad, i) in enumerate(adders):
                sx, sy = level_streams[i], level_streams[i + 1]
                dx = sx[t] if t < len(sx) else None
                dy = sy[t] if t < len(sy) else None
                out_streams[k].append(ad.step(dx, dy))
        nxt: list[list[int | None]] = out_streams
        if len(level_streams) % 2:
            # Odd stream passes through: delay by DELTA_ADD to stay aligned
            # with the adder outputs and prepend GROWTH zero digits so its
            # digit weights match the level's new msb weight.
            digits = [d for d in level_streams[-1] if d is not None]
            nxt.append([None] * (f + DELTA_ADD) + [0] * g + digits)  # type: ignore[list-item]
        level_streams = nxt
        level_msb += g
        level_nd += g

    final = [d for d in level_streams[0] if d is not None]
    last_idx = max(i for i, d in enumerate(level_streams[0]) if d is not None)
    return sd_to_int(final, level_msb), last_idx + 1
