"""Per-layer dynamic-precision schedules — the MSDF knob as a policy object.

The MSDF formulation exists so a consumer can stop after the most significant
digits; MINT (Usman et al.) makes that *per-layer* choice the headline.  A
:class:`PlaneSchedule` assigns each conv/linear layer its own plane budget
``b_l`` (1..8 MSB activation planes), built one of three ways:

  * ``PlaneSchedule.uniform(b, n_layers)``      — the old global knob
  * ``PlaneSchedule.from_list([...])``          — explicit per-layer budgets
  * ``PlaneSchedule.from_weights(ws, target)``  — fewest planes per layer such
    that the analytic worst-case relative error (``early_term``) meets a
    target: the layers with small ``sum|w|`` dynamic range get away with
    fewer digits, exactly the per-layer precision-assignment of MINT.

Schedules are consumed three ways downstream:

  * statically (U-Net, Pallas kernels): each distinct ``b_l`` compiles a
    specialized kernel variant that genuinely skips MXU iterations
    (``kernels.mma_matmul``);
  * dynamically (scan-rolled LMs): ``b_l`` rides the scan as data and the
    truncation applies via the exact bit-mask identity
    (``bitplane.truncate_to_planes``) — same numerics, one fused matmul;
  * analytically (``cycle_model.schedule_cycles``): relation-(2) cycles,
    GOPS and GOPS/W recomputed layer-by-layer under the schedule.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterator, Sequence

import jax
import jax.numpy as jnp

from . import early_term
from .bitplane import N_BITS


def layer_rel_bound(w_int8: jax.Array, planes: int) -> float:
    """Worst-case relative error of one layer truncated to ``planes`` MSB
    planes: max over output channels of truncation_bound / output_scale.

    Uses the *uncorrected* bound (midpoint=False): the datapaths a schedule
    drives apply plain truncation with no midpoint correction, and the
    half-sized midpoint bound would under-state their worst case by 2x.
    """
    denom = jnp.maximum(
        early_term.output_scale_bound(w_int8).astype(jnp.float32), 1.0
    )
    num = early_term.truncation_bound(
        w_int8, planes, midpoint=False
    ).astype(jnp.float32)
    return float(jnp.max(num / denom))


@dataclass(frozen=True)
class PlaneSchedule:
    """Immutable per-layer plane budgets with the bound that justified them.

    ``planes[l]`` is the number of MSB activation planes layer ``l`` consumes.
    ``layer_bounds[l]`` (when built from weights) is the analytic worst-case
    relative error of that layer at its budget; ``target_rel_err`` is the
    target the budgets were chosen against.
    """

    planes: tuple[int, ...]
    target_rel_err: float | None = None
    layer_bounds: tuple[float, ...] | None = None

    def __post_init__(self):
        if not self.planes:
            raise ValueError("empty schedule")
        for b in self.planes:
            if not (1 <= int(b) <= N_BITS):
                raise ValueError(f"plane count {b} outside 1..{N_BITS}")

    # ------------------------------------------------------------ builders

    @classmethod
    def uniform(cls, planes: int, n_layers: int) -> "PlaneSchedule":
        return cls(planes=(int(planes),) * n_layers)

    @classmethod
    def from_list(cls, planes: Sequence[int]) -> "PlaneSchedule":
        return cls(planes=tuple(int(b) for b in planes))

    @classmethod
    def from_weights(
        cls, weights_int8: Sequence[jax.Array], target_rel_err: float
    ) -> "PlaneSchedule":
        """Fewest planes per layer meeting ``target_rel_err`` (worst case).

        ``weights_int8[l]`` is layer ``l``'s int8 weight reshaped to (K, N) —
        for a conv, (kh*kw*cin, cout), matching how the KPB contracts it.
        """
        budgets, bounds = [], []
        for w in weights_int8:
            w2 = w.reshape(-1, w.shape[-1])
            b = early_term.choose_planes(w2, target_rel_err, midpoint=False)
            budgets.append(b)
            bounds.append(layer_rel_bound(w2, b))
        return cls(
            planes=tuple(budgets),
            target_rel_err=float(target_rel_err),
            layer_bounds=tuple(bounds),
        )

    # ----------------------------------------------------------- accessors

    def __len__(self) -> int:
        return len(self.planes)

    def __iter__(self) -> Iterator[int]:
        return iter(self.planes)

    def __getitem__(self, i: int) -> int:
        return self.planes[i]

    def planes_for(self, layer_idx: int) -> int:
        """Budget for layer ``layer_idx``; clamps to the last entry so a
        schedule built for N layers degrades gracefully on a deeper stack."""
        return self.planes[min(layer_idx, len(self.planes) - 1)]

    def as_array(self) -> jax.Array:
        """(L,) int32 — the form that rides a ``lax.scan`` over layers."""
        return jnp.asarray(self.planes, jnp.int32)

    # ----------------------------------------------------- tile refinement

    def refine(self, amp_ratio: float | Sequence[float]) -> "PlaneSchedule":
        """Content-adaptive *tile-level* refinement of this (layer-level)
        schedule, the per-region precision assignment of MINT.

        ``amp_ratio`` (0 <= r <= 1) is the activation amplitude of a spatial
        region (an image tile) relative to the level this schedule was
        certified at — a scalar applied to every layer, or a per-layer
        sequence of measured ratios (what ``repro.autotune`` calibrates,
        replacing the "same ratio at every depth" heuristic).  Dynamic
        per-tile quantization gives that region a scale ``r``x smaller, so
        each truncated digit costs ``r``x less *absolute* error; layer
        ``l`` may therefore drop extra LSB digits while staying inside the
        absolute budget its certified bound already pays for:

            largest d' such that (2^d' - 1) * r_l  <=  2^d_l - 1

        with ``d_l = 8 - planes[l]`` the drop the layer schedule certified.
        By construction the refined tile error, expressed in the schedule's
        calibration units, never exceeds ``layer_bounds[l]`` — flat
        background tiles consume fewer MSB digits for free.  Full-precision
        layers (``d_l = 0``, zero certified budget) are never refined,
        ``r = 1`` is the identity, and ``r = 0`` (an exactly-flat window,
        which quantizes to all-zero planes) refines maximally while never
        dropping below 1 plane.  Chained refinement composes soundly:
        ``s.refine(r1).refine(r2)`` satisfies the parent inequality at the
        product ratio ``r1*r2``, so it never exceeds ``s``'s certificate.

        NaN and infinite ratios are rejected — a calibration bug must fail
        loudly, not silently pick a precision.
        """
        ratios = self._validated_ratios(amp_ratio)
        refined = []
        for b, r in zip(self.planes, ratios):
            d = N_BITS - b
            if d == 0:
                refined.append(b)
                continue
            budget = float(2**d - 1)
            d2 = d
            while d2 < N_BITS - 1 and (2 ** (d2 + 1) - 1) * r <= budget:
                d2 += 1
            refined.append(N_BITS - d2)
        # layer_bounds stay valid: they bound the refined tile's error in
        # the original calibration units (the invariant ``refine`` keeps).
        return PlaneSchedule(
            planes=tuple(refined),
            target_rel_err=self.target_rel_err,
            layer_bounds=self.layer_bounds,
        )

    def _validated_ratios(self, amp_ratio) -> tuple[float, ...]:
        try:
            ratios = (float(amp_ratio),) * len(self.planes)
        except TypeError:
            ratios = tuple(float(r) for r in amp_ratio)
            if len(ratios) != len(self.planes):
                raise ValueError(
                    f"{len(ratios)} amplitude ratios for "
                    f"{len(self.planes)} layers — refine needs one ratio "
                    f"per layer (or a scalar)"
                )
        for r in ratios:
            if math.isnan(r) or math.isinf(r):
                raise ValueError(
                    f"amp_ratio {r} is not finite — amplitude calibration "
                    f"produced garbage; refusing to pick a precision from it"
                )
            if not (0.0 <= r <= 1.0):
                raise ValueError(f"amp_ratio {r} outside [0, 1]")
        return ratios

    # ------------------------------------------------------------- metrics

    def arithmetic_fraction(self) -> float:
        """Fraction of full-precision digit-serial work the schedule keeps
        (MSDF arithmetic is linear in digits consumed)."""
        return sum(self.planes) / (N_BITS * len(self.planes))

    def rel_err_bound(self) -> float:
        """Advertised end-to-end relative-error bound: first-order
        composition (sum) of the per-layer worst-case bounds.  Conv + ReLU
        stages are 1-Lipschitz in the relative metric to first order, so
        per-layer perturbations add; the per-layer bounds themselves are
        worst-case L1 bounds and extremely loose in practice."""
        if self.layer_bounds is not None:
            return float(sum(self.layer_bounds))
        if self.target_rel_err is not None:
            return self.target_rel_err * len(self.planes)
        # explicit/uniform schedules: all-planes-dropped worst case per layer
        return float(
            sum((2.0 ** (N_BITS - b) - 1.0) / 255.0 for b in self.planes)
        )

    def describe(self) -> str:
        frac = self.arithmetic_fraction()
        tgt = (
            f", target={self.target_rel_err:g}"
            if self.target_rel_err is not None
            else ""
        )
        return (
            f"PlaneSchedule({list(self.planes)}, kept={frac:.2f} of digit "
            f"work{tgt})"
        )
