"""Merged multiply-add (MMA) — the public API of the paper's technique.

``mma_dot`` computes an exact (or plane-truncated) int8 x int8 -> int32
matmul through one of four datapaths:

  impl='pallas'   the fused Pallas kernel (kernels/mma_matmul.py): bit-plane
                  Horner recurrence with the residual held in VMEM — the
                  TPU-native merged unit (single "initial delay" = one HBM
                  read of x and w).                       [paper's proposal]
  impl='xla'      same recurrence in pure XLA (lax.scan over planes).
  impl='cascade'  per-plane partials materialized then tree-reduced — the
                  un-merged baseline with per-stage round-trips. [baseline]
  impl='int8'     direct int8 dot_general — the bit-parallel baseline.
                                                          [baseline, Zhang'15]

``mma_linear`` wraps it as a float-in/float-out quantized linear layer
(dynamic per-tensor activation scale, per-channel weight scale) used by the
model zoo when ``quant.mode == 'mma_int8'``.
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from . import bitplane, quant

Impl = Literal["pallas", "xla", "cascade", "int8"]


def mma_dot(
    x_int8: jax.Array,
    w_int8: jax.Array,
    *,
    planes: int | jax.Array = bitplane.N_BITS,
    impl: Impl = "xla",
    signed: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """(..., K) int8 @ (K, N) int8 -> (..., N) int32, via the MMA datapath.

    ``planes`` is the per-call precision budget: a static int specializes the
    serial datapaths to that many MSB planes; a traced scalar applies the
    same truncation on the data side (schedule-in-scan form, see
    ``bitplane.normalize_planes``).
    """
    x_int8, planes = bitplane.normalize_planes(x_int8, planes, signed=signed)
    if impl == "int8":
        if planes != bitplane.N_BITS:
            # bit-parallel hardware has no serial early exit, but the *value*
            # of a truncated result is still computable: fold the truncation
            # into the operand and run the full-width matmul.
            x_int8 = bitplane.truncate_to_planes(x_int8, planes, signed=signed)
        return jax.lax.dot_general(
            x_int8,
            w_int8,
            (((x_int8.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
    if impl == "xla":
        return bitplane.bitplane_matmul(x_int8, w_int8, planes=planes, signed=signed)
    if impl == "cascade":
        return bitplane.bitplane_matmul_cascade(
            x_int8, w_int8, planes=planes, signed=signed
        )
    if impl == "pallas":
        from repro.kernels import ops  # local import: kernels dep is optional

        return ops.mma_matmul(
            x_int8, w_int8, planes=planes, signed=signed, interpret=interpret
        )
    raise ValueError(f"unknown impl {impl!r}")


def mma_linear(
    x: jax.Array,
    w: jax.Array,
    *,
    planes: int | jax.Array = bitplane.N_BITS,
    impl: Impl = "xla",
    w_q: quant.QTensor | None = None,
    batch_axis: int | None = None,
) -> jax.Array:
    """Quantized linear: float x (..., K) @ float w (K, N) -> float (..., N).

    The forward runs int8 through the MMA datapath; gradients flow via the
    straight-through estimator (the quantization is applied with
    stop_gradient so training sees the float path).  ``batch_axis`` selects
    per-row activation scales (see :func:`quant.quantize_acts`) — the
    serving path passes the batch axis so one slot's magnitudes never move
    another slot's quantization grid.
    """
    xq = quant.quantize_acts(x, batch_axis=batch_axis)
    wq = w_q if w_q is not None else quant.quantize_weights(w, channel_axis=-1)
    out_i32 = mma_dot(xq.values, wq.values, planes=planes, impl=impl)
    out = out_i32.astype(jnp.float32) * quant.quantized_matmul_scale(xq.scale, wq.scale)
    # Straight-through estimator: forward = quantized, backward = float.
    full = x @ w
    return full + jax.lax.stop_gradient(out - full)
