"""Cycle-accurate analytical model of the FPGA accelerator (relations 2, 3).

This reproduces the paper's performance model exactly as printed:

  relation (2):  cycles = (delta_x+ + p_out + ceil(log2 T_N))
                          * ceil(n_conv / KPBs) * ceil(N / T_N)
  relation (3):  n_conv = (floor((R + 2P - k)/S) + 1)
                          * (floor((C + 2P - k)/S) + 1) * ceil(M / T_M)

with delta_x+ = 2, p_out = 2n + ceil(log2 T_N) = 21 (n=8, T_N=32), KPBs=16,
T_M=1 — applied layer-by-layer to U-Net, plus the analytical latency of the
*cascaded* MSDF design the paper improves on
(delta_x + delta_+ * ceil(log2 T_N) + p_out per tile, Sec. 3.2).

The U-Net workload is under-specified in the paper (no layer table).  We
therefore *calibrate*: search standard U-Net configurations for the one whose
relation-(2) time and GOPS jointly match Table 1's proposed-design row
(53.25 ms, 52.95 GOPS), and report the calibrated config + residuals in
EXPERIMENTS.md.  Baseline rows of Table 1 (bit-parallel, bit-serial, MSDF,
CPU, GPU) are cited measurements from [12],[13],[11]; we reproduce their
*derived* columns (GOPS, GOPS/W, energy = P*t) and check internal
consistency.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass

# ---- paper constants -------------------------------------------------------
N_BITS = 8
T_N = 32
T_M = 1
KPBS = 16
K = 3
DELTA_MMA = 2  # merged multiply-add initial delay (delta_x+)
DELTA_ADD = 2  # online adder initial delay (delta_+)
DELTA_MUL = 3  # standalone online multiplier initial delay (delta_x)
FREQ_HZ = 100e6


def p_out(n_bits: int = N_BITS, t_n: int = T_N) -> int:
    return 2 * n_bits + math.ceil(math.log2(t_n))


def mma_tile_cycles(n_bits: int = N_BITS, t_n: int = T_N) -> int:
    """Inner term of relation (2): cycles per output tile, merged design."""
    return DELTA_MMA + p_out(n_bits, t_n) + math.ceil(math.log2(t_n))


def cascaded_tile_cycles(n_bits: int = N_BITS, t_n: int = T_N) -> int:
    """Per-tile cycles of the un-merged design (Sec. 3.2): the multiplier and
    every adder-tree level each pay their own initial delay."""
    return DELTA_MUL + DELTA_ADD * math.ceil(math.log2(t_n)) + p_out(n_bits, t_n)


def pipelined_tile_cycles(n_bits: int = N_BITS) -> int:
    """Steady-state pipelined initiation interval: a new output every 2n
    digit slots (the output stream is 2n+log2(T_N) digits, of which log2(T_N)
    overlap the next tile's initial delay + tree fill).

    Calibration finding (see EXPERIMENTS.md §Table1): relation (2) as printed
    (28 cycles/tile) reproduces Table 1's *time* but not its *GOPS*; the two
    columns are jointly consistent only under a ~16-cycle effective interval
    — i.e. Table 1 assumes pipelined steady-state throughput while relation
    (2) states per-output latency.  We model both.
    """
    return 2 * n_bits


@dataclass(frozen=True)
class ConvLayerSpec:
    """One conv layer: input H x W x Cin -> Cout, k x k, stride S, pad P."""

    h: int
    w: int
    cin: int
    cout: int
    k: int = K
    stride: int = 1
    pad: int = 1

    @property
    def out_h(self) -> int:
        return (self.h + 2 * self.pad - self.k) // self.stride + 1

    @property
    def out_w(self) -> int:
        return (self.w + 2 * self.pad - self.k) // self.stride + 1

    def n_conv(self, t_m: int = T_M) -> int:
        """Relation (3)."""
        return self.out_h * self.out_w * math.ceil(self.cout / t_m)

    def macs(self) -> int:
        return self.out_h * self.out_w * self.cout * self.cin * self.k * self.k

    def ops(self) -> int:
        return 2 * self.macs()

    def cycles(self, *, tile_cycles: int | None = None, kpbs: int = KPBS) -> int:
        """Relation (2) for this layer."""
        tc = mma_tile_cycles() if tile_cycles is None else tile_cycles
        return (
            tc * math.ceil(self.n_conv() / kpbs) * math.ceil(self.cin / T_N)
        )


def unet_conv_layers(
    hw: int | tuple[int, int] = 128,
    in_ch: int = 4,
    base: int = 32,
    depth: int = 4,
    convs_per_stage: int = 2,
) -> list[ConvLayerSpec]:
    """Standard U-Net 3x3 conv stack (encoder/bottleneck/decoder with skip
    concatenation).  2x2 up/down-sampling and the final 1x1 conv are not k=3
    convolutions and run off the accelerator (paper Sec. 3.1: larger/other
    kernels are decomposed or handled by reconfiguration).

    ``hw`` is a square size or an ``(h, w)`` pair — rectangular geometries
    cost halo tiles of the segmentation server (``repro.segserve``)."""
    layers: list[ConvLayerSpec] = []
    ch = in_ch
    size_h, size_w = (hw, hw) if isinstance(hw, int) else hw
    enc_ch = []
    for d in range(depth):
        c = base * (2**d)
        layers.append(ConvLayerSpec(size_h, size_w, ch, c))
        for _ in range(convs_per_stage - 1):
            layers.append(ConvLayerSpec(size_h, size_w, c, c))
        enc_ch.append(c)
        ch = c
        size_h //= 2
        size_w //= 2
    # bottleneck
    c = base * (2**depth)
    layers.append(ConvLayerSpec(size_h, size_w, ch, c))
    for _ in range(convs_per_stage - 1):
        layers.append(ConvLayerSpec(size_h, size_w, c, c))
    ch = c
    # decoder (skip concat doubles input channels of the first conv)
    for d in reversed(range(depth)):
        size_h *= 2
        size_w *= 2
        c = enc_ch[d]
        layers.append(ConvLayerSpec(size_h, size_w, c + ch, c))
        for _ in range(convs_per_stage - 1):
            layers.append(ConvLayerSpec(size_h, size_w, c, c))
        ch = c
    return layers


def model_cycles(layers: list[ConvLayerSpec], **kw) -> int:
    return sum(l.cycles(**kw) for l in layers)


def model_ops(layers: list[ConvLayerSpec]) -> int:
    return sum(l.ops() for l in layers)


# ---- dynamic precision (per-layer plane schedules) -------------------------
#
# Digit-serial cycles scale with digits consumed: a layer truncated to b MSB
# planes streams b activation digits instead of n=8, so its output stream is
# p_out(b) = 2b + ceil(log2 T_N) digits and relation (2) shrinks layer-by-
# layer under a schedule.  Accelerator power is held at the paper's implied
# constant (GOPS / (GOPS/W)); the energy win comes from finishing earlier —
# a conservative model, since an idle AND-array also burns less dynamic
# power per cycle.


def schedule_tile_cycles(planes: int, *, mode: str = "pipelined") -> int:
    """Per-output-tile cycles of one layer running at ``planes`` digits.

    mode='as_printed': relation (2) verbatim with n := planes.
    mode='pipelined': the 2n steady-state initiation interval (see
    ``pipelined_tile_cycles``), again with n := planes.
    """
    if mode == "as_printed":
        return mma_tile_cycles(n_bits=planes)
    if mode == "pipelined":
        return pipelined_tile_cycles(n_bits=planes)
    raise ValueError(f"unknown mode {mode!r}")


def _planes_for(schedule, i: int) -> int:
    # duck-typed over PlaneSchedule / list / tuple; clamps like
    # PlaneSchedule.planes_for so short schedules degrade gracefully
    return int(schedule[min(i, len(schedule) - 1)])


def schedule_layer_cycles(
    layers: list[ConvLayerSpec], schedule, *, mode: str = "pipelined"
) -> list[int]:
    """Relation (2) per layer under a per-layer plane schedule."""
    return [
        l.cycles(tile_cycles=schedule_tile_cycles(_planes_for(schedule, i), mode=mode))
        for i, l in enumerate(layers)
    ]


def schedule_cycles(
    layers: list[ConvLayerSpec], schedule, *, mode: str = "pipelined"
) -> int:
    return sum(schedule_layer_cycles(layers, schedule, mode=mode))


@functools.lru_cache(maxsize=65536)
def _unet_window_cycles_cached(
    hw: tuple[int, int], in_ch: int, base: int, depth: int,
    convs_per_stage: int, planes: tuple[int, ...], mode: str,
) -> int:
    layers = unet_conv_layers(hw, in_ch, base, depth, convs_per_stage)
    return schedule_cycles(layers, planes, mode=mode)


def unet_window_cycles(
    hw: int | tuple[int, int], in_ch: int, base: int, depth: int,
    convs_per_stage: int, schedule, *, mode: str = "pipelined",
) -> int:
    """Relation-(2) cycles of one U-Net forward over an ``hw`` window under a
    plane schedule, memoized on the (geometry, schedule) signature.  Tiled
    serving and the tile-size autotuner both price thousands of windows drawn
    from a handful of (shape, class-schedule) signatures — the cache turns
    the per-window rebuild of the layer stack into a dict hit."""
    key_hw = (hw, hw) if isinstance(hw, int) else (int(hw[0]), int(hw[1]))
    planes = tuple(int(b) for b in schedule)
    return _unet_window_cycles_cached(
        key_hw, in_ch, base, depth, convs_per_stage, planes, mode
    )


# ---- LM decode pricing (admission-control estimates) -----------------------
#
# The serving gateway co-schedules LM decode and segmentation against one
# modeled cycle budget, so it needs LM work in the same relation-(2)
# currency.  A decode step's block matmuls are priced as 1x1 "convolutions"
# (h = w = 1, k = 1 — relation (3) then counts exactly ceil(cout/T_M) output
# tiles of a plain matvec): the 4 attention projections (q, k, v, o — at
# their true head widths when ``n_heads``/``head_dim``/``n_kv_heads`` are
# given, GQA included), the attention score (q·K^T) and value (p·V)
# products against a ``context``-token cache, optional MoE routing (the
# router matmul plus ``top_k`` expert FFN passes instead of one dense
# pair), and the FFN matmuls.  With the attention/MoE kwargs omitted the
# itemization degrades to the original projections-plus-FFN estimate, so
# existing callers and goldens are unchanged.  Family quirks that are not
# matmuls (ssm scans, softmax, RoPE) remain un-itemized — they are not
# accelerator AND-array work in the paper's model.


def lm_block_layers(
    d_model: int,
    d_ff: int,
    *,
    n_heads: int | None = None,
    head_dim: int | None = None,
    n_kv_heads: int | None = None,
    context: int = 0,
    n_experts: int = 0,
    top_k: int = 1,
) -> list[ConvLayerSpec]:
    """One transformer block's decode-step matmuls as 1x1-conv specs.

    ``context`` > 0 (with ``n_heads``) itemizes the attention score/value
    products against a cache of that many tokens; ``n_experts`` > 0
    itemizes MoE routing (router matmul + ``top_k`` expert FFN passes).
    """
    if n_heads is None:
        q_width = kv_width = d_model
    else:
        hd = head_dim or d_model // n_heads
        q_width = n_heads * hd
        kv_width = (n_kv_heads or n_heads) * hd
    layers = [
        ConvLayerSpec(1, 1, d_model, q_width, k=1, pad=0),  # wq
        ConvLayerSpec(1, 1, d_model, kv_width, k=1, pad=0),  # wk
        ConvLayerSpec(1, 1, d_model, kv_width, k=1, pad=0),  # wv
        ConvLayerSpec(1, 1, q_width, d_model, k=1, pad=0),  # wo
    ]
    if context > 0 and n_heads:
        hd = head_dim or d_model // n_heads
        # q·K^T: per head a (1, hd)·(hd, T) matvec — T outputs contracting
        # over hd; p·V: (1, T)·(T, hd) — hd outputs contracting over T.
        layers.append(
            ConvLayerSpec(1, 1, hd, n_heads * context, k=1, pad=0)
        )
        layers.append(
            ConvLayerSpec(1, 1, context, n_heads * hd, k=1, pad=0)
        )
    ffn_passes = 1
    if n_experts > 0:
        layers.append(ConvLayerSpec(1, 1, d_model, n_experts, k=1, pad=0))
        ffn_passes = max(1, int(top_k))
    for _ in range(ffn_passes):
        layers.append(ConvLayerSpec(1, 1, d_model, d_ff, k=1, pad=0))
        layers.append(ConvLayerSpec(1, 1, d_ff, d_model, k=1, pad=0))
    return layers


@functools.lru_cache(maxsize=4096)
def _lm_step_cycles_cached(
    d_model: int, d_ff: int, n_layers: int, planes: tuple[int, ...],
    mode: str, attn_kw: tuple,
) -> int:
    total = 0
    specs = lm_block_layers(d_model, d_ff, **dict(attn_kw))
    for l in range(n_layers):
        tc = schedule_tile_cycles(_planes_for(planes, l), mode=mode)
        total += sum(spec.cycles(tile_cycles=tc) for spec in specs)
    return total


def lm_step_cycles(
    d_model: int, d_ff: int, n_layers: int, schedule=None, *,
    mode: str = "pipelined", **attn_kw,
) -> int:
    """Relation-(2) cycles of one decode step (one token, one sequence)
    through an ``n_layers`` block stack under a per-layer plane schedule
    (``None`` = full ``N_BITS`` digits everywhere), memoized on the
    signature like :func:`unet_window_cycles`.  Extra keyword args
    (``n_heads``/``head_dim``/``n_kv_heads``/``context``/``n_experts``/
    ``top_k``) pass through to :func:`lm_block_layers` for the sharper
    attention/MoE itemization."""
    planes = (
        (N_BITS,) * n_layers if schedule is None
        else tuple(int(b) for b in schedule)
    )
    return _lm_step_cycles_cached(
        d_model, d_ff, n_layers, planes, mode, tuple(sorted(attn_kw.items()))
    )


def lm_step_ops(d_model: int, d_ff: int, n_layers: int, **attn_kw) -> int:
    """Useful MAC ops of one decode step (same itemization as the cycles)."""
    return n_layers * sum(
        l.ops() for l in lm_block_layers(d_model, d_ff, **attn_kw)
    )


def lm_layer_cycles(
    d_model: int, d_ff: int, n_layers: int, schedule=None, *,
    mode: str = "pipelined", **attn_kw,
) -> list[int]:
    """Per-layer relation-(2) cycles of one decode step under a plane
    schedule — the itemization :func:`lm_step_cycles` sums.  The maximum
    entry is the layer-pipeline initiation interval of a multi-token pass
    whose inputs are known in advance (:func:`lm_spec_step_cycles`)."""
    planes = (
        (N_BITS,) * n_layers if schedule is None
        else tuple(int(b) for b in schedule)
    )
    specs = lm_block_layers(d_model, d_ff, **attn_kw)
    return [
        sum(
            spec.cycles(
                tile_cycles=schedule_tile_cycles(
                    _planes_for(planes, l), mode=mode
                )
            )
            for spec in specs
        )
        for l in range(n_layers)
    ]


# ---- speculative decode pricing --------------------------------------------
#
# The precision-speculative engine (repro.serve.specdecode) runs each decode
# round in two passes: a k-token *draft* chain under a truncated-plane
# schedule (greedy feedback — token t+1 needs token t's logits, so the k
# steps serialize at the draft schedule's step price), then one *verify*
# pass of the k+1 now-known tokens through the full-digit schedule.  The
# verify tokens have no feedback dependency, so consecutive positions
# pipeline through the layer stack: position t+1 enters layer l as soon as
# position t leaves it, and the pass costs one full step plus k initiation
# intervals (the widest layer's cycles) instead of k+1 full steps.  Only
# the emitted (accepted + one corrected) tokens earn op credit; every cycle
# of both passes counts toward time — rejected speculation is honest waste,
# so GOPS/W degrades with the miss rate instead of hiding it.


def lm_spec_step_cycles(
    d_model: int, d_ff: int, n_layers: int, *, k: int, draft_schedule,
    schedule=None, accepted: int | None = None, mode: str = "pipelined",
    **attn_kw,
) -> dict:
    """Relation-(2) account of one speculative decode round (one slot).

    ``k`` draft tokens priced at the ``draft_schedule`` step cost, one
    layer-pipelined verify pass of ``k+1`` known tokens at the full
    ``schedule`` (``None`` = uniform ``N_BITS``).  With ``accepted`` given
    (0..k drafts survived verification) the account splits integer-exactly
    into useful and wasted cycles: each rejected draft position wastes its
    draft step plus its verify pipeline interval, and
    ``useful + wasted == total`` always.
    """
    if int(k) < 0:
        raise ValueError(f"k {k} < 0")
    k = int(k)
    draft_step = lm_step_cycles(
        d_model, d_ff, n_layers, tuple(int(b) for b in draft_schedule),
        mode=mode, **attn_kw,
    )
    full_step = lm_step_cycles(
        d_model, d_ff, n_layers, schedule, mode=mode, **attn_kw
    )
    interval = max(
        lm_layer_cycles(d_model, d_ff, n_layers, schedule, mode=mode,
                        **attn_kw)
    )
    draft_cycles = k * draft_step
    verify_cycles = full_step + k * interval
    out = dict(
        k=k,
        draft_step_cycles=draft_step,
        full_step_cycles=full_step,
        interval_cycles=interval,
        draft_cycles=draft_cycles,
        verify_cycles=verify_cycles,
        total_cycles=draft_cycles + verify_cycles,
    )
    if accepted is not None:
        a = int(accepted)
        if not (0 <= a <= k):
            raise ValueError(f"accepted {a} outside 0..{k}")
        wasted = (k - a) * (draft_step + interval)
        out.update(
            accepted=a,
            tokens=a + 1,
            wasted_cycles=wasted,
            useful_cycles=out["total_cycles"] - wasted,
            baseline_cycles=(a + 1) * full_step,
        )
    return out


@dataclass
class PlatformRow:
    """One column of Table 1.  Derived metrics follow the paper's
    definitions: GOPS = ops/time, GOPS/W = GOPS/power, energy = power*time."""

    name: str
    time_ms: float
    power_w: float
    ops: int
    freq_mhz: float | None = None
    slices: int | None = None

    @property
    def gops(self) -> float:
        return self.ops / (self.time_ms * 1e-3) / 1e9

    @property
    def gops_per_w(self) -> float:
        return self.gops / self.power_w

    @property
    def energy_mj(self) -> float:
        return self.power_w * self.time_ms

    @property
    def gops_per_slice_e4(self) -> float | None:
        if self.slices is None:
            return None
        return self.gops / self.slices * 1e4


# Table 1 as printed (for validation targets). Power back-derived from
# GOPS / (GOPS/W); slices back-derived from GOPS / (GOPS/slice).
PAPER_TABLE1 = {
    "bit_parallel": dict(time_ms=57.20, gops=49.30, gops_w=2.65, e_mj=1064.43, aeff=10.59),
    "bit_serial": dict(time_ms=232.26, gops=12.14, gops_w=0.88, e_mj=3210.81, aeff=3.98),
    "msdf": dict(time_ms=133.94, gops=21.05, gops_w=3.01, e_mj=1644.77, aeff=2.61),
    "gpu": dict(time_ms=7.31, gops=385.99, gops_w=5.51, e_mj=511.35, aeff=None),
    "cpu": dict(time_ms=58.42, gops=48.27, gops_w=1.93, e_mj=1460.48, aeff=None),
    "proposed": dict(time_ms=53.25, gops=52.95, gops_w=15.14, e_mj=186.20, aeff=17.43),
}


def proposed_row(layers: list[ConvLayerSpec]) -> PlatformRow:
    """The proposed design, from relations (2)+(3) at 100 MHz.  Power is the
    paper's implied accelerator power (GOPS / (GOPS/W) = 3.497 W)."""
    cyc = model_cycles(layers)
    t_ms = cyc / FREQ_HZ * 1e3
    power = PAPER_TABLE1["proposed"]["gops"] / PAPER_TABLE1["proposed"]["gops_w"]
    slices = PAPER_TABLE1["proposed"]["gops"] / (PAPER_TABLE1["proposed"]["aeff"] * 1e-4)
    return PlatformRow(
        "proposed(model)", t_ms, power, model_ops(layers), freq_mhz=100, slices=int(slices)
    )


def schedule_row(
    layers: list[ConvLayerSpec],
    schedule,
    *,
    mode: str = "pipelined",
    name: str | None = None,
) -> PlatformRow:
    """Table-1-style row for the proposed design under a plane schedule:
    time from per-layer relation (2), ops counted at full precision (the
    schedule delivers the same outputs, just with fewer digits), power the
    paper's implied constant — so GOPS and GOPS/W scale with the speedup."""
    cyc = schedule_cycles(layers, schedule, mode=mode)
    t_ms = cyc / FREQ_HZ * 1e3
    power = PAPER_TABLE1["proposed"]["gops"] / PAPER_TABLE1["proposed"]["gops_w"]
    if name is None:
        name = f"proposed(sched-{'-'.join(str(_planes_for(schedule, i)) for i in range(len(layers)))})"
    return PlatformRow(name, t_ms, power, model_ops(layers), freq_mhz=100)


def cascaded_row(layers: list[ConvLayerSpec]) -> PlatformRow:
    """Same datapath but un-merged (multiplier + adder tree each with own
    initial delay) — the paper's own analytical comparison, Sec. 3.2."""
    tc = cascaded_tile_cycles()
    cyc = model_cycles(layers, tile_cycles=tc)
    t_ms = cyc / FREQ_HZ * 1e3
    power = PAPER_TABLE1["msdf"]["gops"] / PAPER_TABLE1["msdf"]["gops_w"]
    return PlatformRow("cascaded-msdf(model)", t_ms, power, model_ops(layers), freq_mhz=100)


def calibrate_unet(
    target_time_ms: float = 53.25,
    target_gops: float = 52.95,
    mode: str = "pipelined",
) -> tuple[dict, list[ConvLayerSpec], float, float]:
    """Search standard U-Net configs for the joint best match of Table 1's
    (time, GOPS); returns (config, layers, time_err%, gops_err%).

    mode='as_printed' uses relation (2) verbatim (28 cycles/tile; matches
    Table 1 time only), mode='pipelined' uses the 2n-cycle steady-state
    interval (jointly matches time and GOPS — see ``pipelined_tile_cycles``).
    """
    tile = mma_tile_cycles() if mode == "as_printed" else pipelined_tile_cycles()
    best = None
    for hw in (64, 80, 96, 112, 128, 144, 160, 176, 192, 208, 224, 240, 256):
        for in_ch in (1, 3, 4):
            for base in (8, 16, 24, 32, 48, 64):
                for depth in (3, 4, 5):
                    for cps in (1, 2):
                        if hw % (2**depth):
                            continue
                        layers = unet_conv_layers(hw, in_ch, base, depth, cps)
                        cyc = model_cycles(layers, tile_cycles=tile)
                        t_ms = cyc / FREQ_HZ * 1e3
                        gops = model_ops(layers) / (t_ms * 1e-3) / 1e9
                        e_t = abs(t_ms - target_time_ms) / target_time_ms
                        e_g = abs(gops - target_gops) / target_gops
                        err = e_t + (e_g if mode == "pipelined" else 0.0)
                        cfg = dict(hw=hw, in_ch=in_ch, base=base, depth=depth, convs_per_stage=cps)
                        if best is None or err < best[0]:
                            best = (err, cfg, layers, e_t * 100, e_g * 100)
    assert best is not None
    return best[1], best[2], best[3], best[4]


# The calibrated U-Net used throughout (mode='pipelined'):
#   input 80x80x4, base 48, depth 3, one 3x3 conv per stage
#   -> 53.76 ms (+1.0%) and 52.25 GOPS (-1.3%) vs Table 1.
CALIBRATED_UNET = dict(hw=80, in_ch=4, base=48, depth=3, convs_per_stage=1)
