"""Int8 quantization (FBGEMM-style symmetric) used by the MMA datapath.

The paper quantizes U-Net with the FBGEMM backend to 8-bit fixed point before
mapping convolutions onto the accelerator.  We mirror that: symmetric int8,
per-output-channel scales for weights, per-tensor dynamic scale for
activations.  ``fake_quant`` provides the straight-through estimator for
quantization-aware training.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


class QTensor(NamedTuple):
    """A quantized tensor: ``values * scale ~= original`` (scale broadcasts)."""

    values: jax.Array  # int8
    scale: jax.Array  # f32, broadcastable against values


def quantize_weights(w: jax.Array, *, channel_axis: int = -1) -> QTensor:
    """Symmetric per-channel int8 quantization (channel = output features)."""
    reduce_axes = tuple(a for a in range(w.ndim) if a != channel_axis % w.ndim)
    amax = jnp.max(jnp.abs(w), axis=reduce_axes, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / INT8_MAX
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return QTensor(q, scale.astype(jnp.float32))


def quantize_acts(x: jax.Array, *, batch_axis: int | None = None) -> QTensor:
    """Symmetric dynamic int8 quantization of activations.

    Default is one per-tensor scale.  ``batch_axis`` switches to one scale
    per index along that axis (every other axis reduced) — required for
    batched serving: with a tensor-wide amax, one batch row's activations
    move every other row's scale, so a slot's numerics depend on who it is
    batched with.  Per-row scales restore the slot-isolation invariant the
    vector-index decode path documents (and speculative verify relies on:
    the verify batch carries draft tokens in other rows, yet each row must
    reproduce its greedy logits bit-exactly).
    """
    if batch_axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        reduce_axes = tuple(
            a for a in range(x.ndim) if a != batch_axis % x.ndim
        )
        amax = jnp.max(jnp.abs(x), axis=reduce_axes, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / INT8_MAX
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return QTensor(q, scale.astype(jnp.float32))


def dequantize(q: QTensor) -> jax.Array:
    return q.values.astype(jnp.float32) * q.scale


def fake_quant(x: jax.Array, *, channel_axis: int | None = None) -> jax.Array:
    """Straight-through-estimator fake quantization for QAT."""
    if channel_axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        reduce_axes = tuple(a for a in range(x.ndim) if a != channel_axis % x.ndim)
        amax = jnp.max(jnp.abs(x), axis=reduce_axes, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / INT8_MAX
    q = jnp.clip(jnp.round(x / scale), -127, 127) * scale
    return x + jax.lax.stop_gradient(q - x)


def quantized_matmul_scale(x_scale: jax.Array, w_scale: jax.Array) -> jax.Array:
    """Output scale of an int8 x int8 -> int32 matmul."""
    return x_scale * jnp.squeeze(w_scale)


def quantize_params_int8(params, *, min_dim: int = 256):
    """Serving transform: replace every linear ``{'w': bf16 (…,K,N)}`` whose
    last two dims are >= min_dim with ``{'w_q': int8, 'w_scale': f32}``
    (per-output-channel scales).  Embeddings / norms / biases / small LoRA
    mats stay bf16.  Halves weight HBM bytes — the dominant term of
    memory-bound decode (EXPERIMENTS.md §Perf iteration 3)."""

    def walk(node):
        if isinstance(node, dict):
            if "w" in node and hasattr(node["w"], "ndim") and node["w"].ndim >= 2 \
                    and node["w"].shape[-1] >= min_dim and node["w"].shape[-2] >= min_dim:
                w = node["w"].astype(jnp.float32)
                # per-output-channel, per-layer (reduce the contraction dim)
                amax = jnp.max(jnp.abs(w), axis=-2, keepdims=True)
                scale = jnp.maximum(amax, 1e-8) / INT8_MAX
                qv = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
                out = {k: v for k, v in node.items() if k != "w"}
                out["w_q"] = qv
                out["w_scale"] = scale.astype(jnp.float32)
                return out
            return {k: walk(v) for k, v in node.items()}
        return node

    return walk(params)
