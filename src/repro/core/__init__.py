"""Core library: the paper's contribution (MSDF digit-serial merged
multiply-add) as composable JAX modules.  See DESIGN.md for the FPGA -> TPU
mapping."""
from . import bitplane, cycle_model, early_term, mma, msdf, plane_schedule, quant  # noqa: F401
from .mma import mma_dot, mma_linear  # noqa: F401
from .plane_schedule import PlaneSchedule  # noqa: F401
from .quant import QTensor, quantize_acts, quantize_weights  # noqa: F401
