"""Shared serving primitives: FIFO admission queue + bounded slot table.

Both engines — LM decode (``serve.engine.Engine``) and tiled segmentation
(``repro.segserve.engine.SegEngine``) — run the same outer loop: requests
wait in a FIFO, a bounded slot table caps how many are in flight, slots
free as requests finish and are refilled from the queue.  What differs is
the unit of batched work (one token per active sequence vs one micro-batch
of image tiles); that stays in each engine.  This module is the common
front door so a deployment can stack both behind one admission policy.
"""
from __future__ import annotations

from typing import Any, Callable, Generic, Iterable, TypeVar

T = TypeVar("T")


class SlotTable(Generic[T]):
    """Fixed-capacity table of in-flight requests, addressed by slot index.

    Slot indices are stable for a request's lifetime — LM decode keys KV
    cache rows by them, segmentation keys stitching canvases by request —
    so the table never compacts; it only occupies and releases.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity {capacity} < 1")
        self._slots: list[T | None] = [None] * capacity

    @property
    def capacity(self) -> int:
        return len(self._slots)

    def __getitem__(self, idx: int) -> T | None:
        return self._slots[idx]

    def free_index(self) -> int | None:
        """Lowest free slot index, or None when the table is full."""
        for i, s in enumerate(self._slots):
            if s is None:
                return i
        return None

    def occupy(self, item: T) -> int | None:
        """Place ``item`` in the lowest free slot; None when full."""
        idx = self.free_index()
        if idx is not None:
            self._slots[idx] = item
        return idx

    def release(self, idx: int) -> T:
        """Free slot ``idx`` and return what occupied it."""
        item = self._slots[idx]
        if item is None:
            raise KeyError(f"slot {idx} is already free")
        self._slots[idx] = None
        return item

    def active(self) -> list[tuple[int, T]]:
        """(slot, item) pairs of occupied slots, in slot order."""
        return [(i, s) for i, s in enumerate(self._slots) if s is not None]

    def any_active(self) -> bool:
        return any(s is not None for s in self._slots)


class FifoQueue(Generic[T]):
    """Admission queue: requests wait here until a slot frees up."""

    def __init__(self, items: Iterable[T] = ()):  # pragma: no branch
        self._items: list[T] = list(items)

    def push(self, item: T) -> None:
        self._items.append(item)

    def __len__(self) -> int:
        return len(self._items)

    def __bool__(self) -> bool:
        return bool(self._items)

    def pump(
        self,
        slots: SlotTable[Any],
        admit: Callable[[T], bool],
    ) -> int:
        """Admit queued requests in FIFO order while slots are free.

        ``admit`` does the engine-specific work (prefill, tile planning) and
        returns False to stop admission without consuming the request (e.g.
        the engine wants the batch to drain first).  Returns how many
        requests were admitted.
        """
        n = 0
        while self._items and slots.free_index() is not None:
            if not admit(self._items[0]):
                break
            self._items.pop(0)
            n += 1
        return n
