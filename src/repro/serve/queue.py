"""Shared serving primitives: FIFO admission queue + bounded slot table.

Both engines — LM decode (``serve.engine.Engine``) and tiled segmentation
(``repro.segserve.engine.SegEngine``) — run the same outer loop: requests
wait in a FIFO, a bounded slot table caps how many are in flight, slots
free as requests finish and are refilled from the queue.  What differs is
the unit of batched work (one token per active sequence vs one micro-batch
of image tiles); that stays in each engine.  This module is the common
front door so a deployment can stack both behind one admission policy.
"""
from __future__ import annotations

from typing import Any, Callable, Generic, Iterable, TypeVar

T = TypeVar("T")


class SlotTable(Generic[T]):
    """Fixed-capacity table of in-flight requests, addressed by slot index.

    Slot indices are stable for a request's lifetime — LM decode keys KV
    cache rows by them, segmentation keys stitching canvases by request —
    so the table never compacts; it only occupies and releases.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity {capacity} < 1")
        self._slots: list[T | None] = [None] * capacity

    @property
    def capacity(self) -> int:
        return len(self._slots)

    def __getitem__(self, idx: int) -> T | None:
        return self._slots[idx]

    def free_index(self) -> int | None:
        """Lowest free slot index, or None when the table is full."""
        for i, s in enumerate(self._slots):
            if s is None:
                return i
        return None

    def free_count(self) -> int:
        """How many slots are free (admission-policy headroom)."""
        return sum(1 for s in self._slots if s is None)

    def occupy(self, item: T) -> int | None:
        """Place ``item`` in the lowest free slot; None when full."""
        idx = self.free_index()
        if idx is not None:
            self._slots[idx] = item
        return idx

    def release(self, idx: int) -> T:
        """Free slot ``idx`` and return what occupied it."""
        item = self._slots[idx]
        if item is None:
            raise KeyError(f"slot {idx} is already free")
        self._slots[idx] = None
        return item

    def active(self) -> list[tuple[int, T]]:
        """(slot, item) pairs of occupied slots, in slot order."""
        return [(i, s) for i, s in enumerate(self._slots) if s is not None]

    def any_active(self) -> bool:
        return any(s is not None for s in self._slots)


class FifoQueue(Generic[T]):
    """Admission queue: requests wait here until a slot frees up.

    Arrival order is the queue's one invariant; policies that admit out of
    order (the gateway's fair-share and EDF) *inspect* in arrival order
    (``__iter__``, ``peek``) and remove by position (``pop_at``), so FIFO
    stays the default and reordering is an explicit policy decision at the
    call site, never queue state.

    Layout: a backing list with a head index.  ``list.pop(0)`` is O(n) in
    the backlog, which made the admission phase quadratic under fabric-
    scale replay (10–100x arrival rates); popping the head now just
    advances the index (amortized O(1) — the consumed prefix is compacted
    away once it dominates the backing list).  Interior ``pop_at`` stays
    O(n - i), which the scanning policies pay anyway.
    """

    # compact when the dead prefix is past this size *and* at least half
    # the backing list — amortized O(1) head pops, bounded slack memory
    _COMPACT_MIN = 64

    def __init__(self, items: Iterable[T] = ()):  # pragma: no branch
        self._items: list[T | None] = list(items)
        self._head = 0

    def push(self, item: T) -> None:
        self._items.append(item)

    def __len__(self) -> int:
        return len(self._items) - self._head

    def __bool__(self) -> bool:
        return self._head < len(self._items)

    def __iter__(self):
        """Arrival-order iteration (do not mutate while iterating)."""
        return iter(self._items[self._head:])

    def _index(self, i: int) -> int:
        """Backing-list index of logical position ``i`` (supports the
        usual negative indexing), bounds-checked against the live span."""
        idx = (len(self._items) if i < 0 else self._head) + i
        if not self._head <= idx < len(self._items):
            raise IndexError(f"queue index {i} out of range (len {len(self)})")
        return idx

    def peek(self, i: int = 0) -> T:
        """The ``i``-th waiting item (0 = oldest) without consuming it."""
        return self._items[self._index(i)]

    def pop_at(self, i: int) -> T:
        """Remove and return the ``i``-th waiting item (0 = oldest) — the
        out-of-order admission primitive for non-FIFO policies."""
        idx = self._index(i)
        item = self._items[idx]
        if idx == self._head:
            self._items[idx] = None  # drop the reference immediately
            self._head += 1
            if self._head >= self._COMPACT_MIN and \
                    self._head * 2 >= len(self._items):
                del self._items[:self._head]
                self._head = 0
        else:
            del self._items[idx]
        return item  # type: ignore[return-value]

    def pump(
        self,
        slots: SlotTable[Any],
        admit: Callable[[T], bool],
    ) -> int:
        """Admit queued requests in FIFO order while slots are free.

        ``admit`` does the engine-specific work (prefill, tile planning) and
        returns False to stop admission without consuming the request (e.g.
        the engine wants the batch to drain first).  Returns how many
        requests were admitted.
        """
        n = 0
        while self and slots.free_index() is not None:
            if not admit(self._items[self._head]):
                break
            self.pop_at(0)
            n += 1
        return n
