"""Modeled (pricing-only) engine adapters for capacity-scale benchmarks.

Fabric benchmarks replay traces at 10-100x arrival rates across N shards;
running the real jax engines at that scale would dominate CI wall time
while the things under test — routing, work stealing, fleet-ledger
additivity, per-class latency under load — are pure cycle-clock
scheduling.  These adapters speak the full gateway adapter protocol
(including protocol-v3 per-completion offsets, preemptive ``soft_limit``
segment boundaries and forced-progress overdrafts) and price work with
the same relation-(2) model the real adapters use
(:func:`cm.lm_step_cycles`, :func:`cm.unet_window_cycles`), but never
touch model weights: a fabric of N shards replays a 100x trace in
milliseconds with exact integer ops/cycles accounts.

Payloads are the *trace* payload specs themselves (lm:
``{prompt_len, max_new}``, seg: ``{h, w}``) — :func:`modeled_materializer`
passes them through, so no prompt/image bytes are ever materialized.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core import cycle_model as cm


def modeled_materializer():
    """Trace-spec pass-through for modeled adapters (any kind).

    Deterministic trivially: the submitted payload *is* the spec dict,
    a pure function of the trace request alone.
    """

    def mat(treq, trace_seed: int, index: int):
        return dict(treq.payload), {}

    return mat


@dataclass
class _LMJob:
    """One modeled LM request: token counts stand in for the KV cache."""

    rid: int
    prefill_remaining: int
    decode_remaining: int

    @property
    def done(self) -> bool:
        return self.prefill_remaining == 0 and self.decode_remaining == 0


@dataclass
class _SegJob:
    """One modeled segmentation request: a countdown of priced tiles."""

    rid: int
    tiles_remaining: int

    @property
    def done(self) -> bool:
        return self.tiles_remaining == 0


class _ModeledBase:
    """Shared protocol plumbing: slot accounting and inflight tracking."""

    plan = None
    fallback_reason = None
    # armed by Gateway.set_sink: when True, work() appends per-request
    # (rid, qos, cycles, offset) execution-attribution records
    obs_enabled = False
    obs_sink = None

    def __init__(self, *, slots: int):
        if slots < 1:
            raise ValueError(f"slots {slots} < 1")
        self._slots = int(slots)
        # admission order; gateway requests carry the jobs as handles
        self._order: list = []
        self.total_ops = 0
        self.exec_log: list[tuple] = []

    def verify_info(self):
        return None  # no tuned plan — nothing to invalidate

    def free_slots(self) -> int:
        return self._slots - len(self._order)

    def _matches(self, greq, qos) -> bool:
        return qos is None or greq.qos == qos

    def admit(self, greq) -> int:
        if self.free_slots() < 1:
            raise RuntimeError(f"admit called with no free {self.kind} slot")
        greq.handle = greq.payload
        self._order.append(greq)
        return 0  # preemptive: all work metered through work()

    def has_work(self, qos=None) -> bool:
        return any(
            self._matches(g, qos) and not g.handle.done for g in self._order
        )


class ModeledLMAdapter(_ModeledBase):
    """Continuous-batching LM decode, priced but not executed.

    Mirrors :class:`~repro.serve.gateway.LMAdapter`'s preemptive path:
    chunked prefill in admission order (each token charged at the step
    price), then batched decode — one modeled step advances every ready
    job, costing ``step_cycles`` per active job, and every job that
    finishes on a step completes at *that* step's offset.
    """

    kind = "lm"

    def __init__(self, *, batch: int, step_cycles: int, step_ops: int):
        super().__init__(slots=batch)
        self._step_cycles = int(step_cycles)
        self._step_ops = int(step_ops)

    @classmethod
    def from_config(cls, cfg, *, batch: int, max_seq: int):
        """Price from a model config exactly as LMAdapter does (same
        ``cm.lm_step_cycles`` itemization, same ``max_seq`` context
        bound) — no params, no engine build."""
        price_kw = dict(
            n_heads=cfg.n_heads, head_dim=cfg.hd,
            n_kv_heads=cfg.n_kv_heads, context=max_seq,
            n_experts=cfg.moe.n_experts, top_k=cfg.moe.top_k,
        )
        return cls(
            batch=batch,
            step_cycles=cm.lm_step_cycles(
                cfg.d_model, cfg.d_ff, cfg.n_layers,
                cfg.quant.plane_schedule, **price_kw,
            ),
            step_ops=cm.lm_step_ops(
                cfg.d_model, cfg.d_ff, cfg.n_layers, **price_kw
            ),
        )

    def prepare(self, payload, *, rid: int, max_new: int = 16):
        if isinstance(payload, _LMJob):
            return payload  # idempotent (router-side estimates re-prepare)
        spec = payload
        return _LMJob(
            rid=rid,
            prefill_remaining=int(spec["prompt_len"]),
            decode_remaining=int(spec.get("max_new", max_new)),
        )

    def estimate_cycles(self, job: _LMJob) -> int:
        return (
            job.prefill_remaining + job.decode_remaining
        ) * self._step_cycles

    def work(self, budget: int, qos=None, force: bool = False,
             soft_limit: int | None = None):
        completed: list[tuple] = []
        consumed, force = self._work_prefill(
            0, budget, qos, force, soft_limit
        )
        consumed = self._work_decode(
            budget, consumed, qos, force, soft_limit, completed
        )
        done = {id(g) for g, _ in completed}
        if done:
            self._order = [g for g in self._order if id(g) not in done]
        return consumed, completed, []

    def _work_prefill(self, consumed, budget, qos, force, soft_limit):
        """Chunked prefill in admission order; returns the consumed
        cycles and whether the forced-progress escape is still live."""
        sc = self._step_cycles
        for greq in self._order:
            if not self._matches(greq, qos):
                continue
            job = greq.handle
            if job.prefill_remaining <= 0:
                continue
            n = min((budget - consumed) // sc, job.prefill_remaining)
            if soft_limit is not None:
                n_soft = -(-max(soft_limit - consumed, 0) // sc)
                n = min(n, n_soft)
            if n <= 0 and force and consumed == 0:
                n = 1  # forced progress: one token, overdraft recorded
            if n <= 0:
                break
            force = False
            job.prefill_remaining -= n
            consumed += n * sc
            self.total_ops += n * self._step_ops
            if self.obs_enabled:
                self.exec_log.append((greq.rid, greq.qos, n * sc, consumed))
            if job.prefill_remaining:
                break  # budget exhausted mid-prompt
        return consumed, force

    def _ready(self, qos) -> list:
        return [
            g for g in self._order
            if self._matches(g, qos)
            and g.handle.prefill_remaining == 0
            and g.handle.decode_remaining > 0
        ]

    def _work_decode(self, budget, consumed, qos, force, soft_limit,
                     completed):
        """Batched decode: every ready matching job advances together."""
        sc = self._step_cycles
        while True:
            ready = self._ready(qos)
            if not ready:
                break
            cost = sc * len(ready)
            over_hard = consumed + cost > budget
            at_soft = soft_limit is not None and consumed >= soft_limit
            if (over_hard or at_soft) and not (force and consumed == 0):
                break
            force = False
            consumed += cost
            self.total_ops += self._step_ops * len(ready)
            for g in ready:
                g.handle.decode_remaining -= 1
                if self.obs_enabled:
                    self.exec_log.append((g.rid, g.qos, sc, consumed))
                if g.handle.done:
                    completed.append((g, consumed))
        return consumed


class ModeledSpecLMAdapter(ModeledLMAdapter):
    """Precision-speculative decode, priced but not executed.

    Mirrors :class:`~repro.serve.specdecode.SpecLMAdapter`'s chunked
    speculative rounds and its full event protocol — per-slot ``exec``
    attribution at the deterministic round price
    (:func:`cm.lm_spec_step_cycles` itemization: k sequential draft
    steps + one layer-pipelined verify pass), plus ``draft`` /
    ``verify`` / ``accept`` / ``rollback`` lifecycle annotations with
    the per-slot op-class cycle split the energy meter closes on —
    without touching weights.  Acceptance is a seed-free deterministic
    pattern (a pure function of the global round counter), so runs are
    byte-identical like every other modeled adapter.
    """

    def __init__(self, *, batch: int, step_cycles: int, step_ops: int,
                 draft_step_cycles: int, interval_cycles: int, k: int,
                 accept_pattern=(4, 4, 3, 4, 2, 4, 4, 3)):
        super().__init__(batch=batch, step_cycles=step_cycles,
                         step_ops=step_ops)
        if k < 1:
            raise ValueError(f"spec depth k {k} < 1")
        self._draft_step_cycles = int(draft_step_cycles)
        self._interval_cycles = int(interval_cycles)
        self._k = int(k)
        self._pattern = tuple(
            min(max(int(a), 0), self._k) for a in accept_pattern
        )
        if not self._pattern:
            raise ValueError("accept_pattern must be non-empty")
        self._spec_rounds = 0
        self.obs_log: list[tuple] = []

    @classmethod
    def from_config(cls, cfg, *, batch: int, max_seq: int,
                    draft_schedule=(2,), k: int = 4,
                    accept_pattern=(4, 4, 3, 4, 2, 4, 4, 3)):
        """Price drafts and verifies from a model config exactly as
        SpecLMAdapter does: draft steps under ``draft_schedule``, the
        verify pass layer-pipelined at the serve schedule's slowest
        layer interval."""
        price_kw = dict(
            n_heads=cfg.n_heads, head_dim=cfg.hd,
            n_kv_heads=cfg.n_kv_heads, context=max_seq,
            n_experts=cfg.moe.n_experts, top_k=cfg.moe.top_k,
        )
        return cls(
            batch=batch,
            step_cycles=cm.lm_step_cycles(
                cfg.d_model, cfg.d_ff, cfg.n_layers,
                cfg.quant.plane_schedule, **price_kw,
            ),
            step_ops=cm.lm_step_ops(
                cfg.d_model, cfg.d_ff, cfg.n_layers, **price_kw
            ),
            draft_step_cycles=cm.lm_step_cycles(
                cfg.d_model, cfg.d_ff, cfg.n_layers,
                tuple(int(p) for p in draft_schedule), **price_kw,
            ),
            interval_cycles=max(cm.lm_layer_cycles(
                cfg.d_model, cfg.d_ff, cfg.n_layers,
                cfg.quant.plane_schedule, **price_kw,
            )),
            k=k,
            accept_pattern=accept_pattern,
        )

    def _slot_cycles(self) -> int:
        """Deterministic per-slot round price, fixed before acceptance
        — the never-overdraft invariant SpecLMAdapter keeps."""
        return (self._k * self._draft_step_cycles + self._step_cycles
                + self._k * self._interval_cycles)

    def _work_decode(self, budget, consumed, qos, force, soft_limit,
                     completed):
        k = self._k
        ds, iv, sc = (self._draft_step_cycles, self._interval_cycles,
                      self._step_cycles)
        per_slot = self._slot_cycles()
        while True:
            ready = self._ready(qos)
            if not ready:
                break
            n = len(ready)
            cost = per_slot * n
            over_hard = consumed + cost > budget
            at_soft = soft_limit is not None and consumed >= soft_limit
            if (over_hard or at_soft) and not (force and consumed == 0):
                break
            force = False
            start = consumed
            consumed += cost
            accepted = self._pattern[
                self._spec_rounds % len(self._pattern)
            ]
            self._spec_rounds += 1
            if self.obs_enabled:
                draft_c = k * ds * n
                self.obs_log.append(("draft", dict(
                    k=k, slots=n, cycles=draft_c,
                ), start + draft_c))
                self.obs_log.append(("verify", dict(
                    tokens=k + 1, slots=n, cycles=cost - draft_c,
                ), consumed))
            for g in ready:
                # accepted drafts + the verify pass's one correction
                emit = min(accepted + 1, g.handle.decode_remaining)
                g.handle.decode_remaining -= emit
                self.total_ops += self._step_ops * emit
                if self.obs_enabled:
                    self.exec_log.append((g.rid, g.qos, per_slot,
                                          consumed))
                    self.obs_log.append(("accept", dict(
                        rid=g.rid, qos=g.qos, k=k, accepted=accepted,
                        emitted=emit,
                        draft_cycles=k * ds,
                        verify_cycles=sc + k * iv,
                        wasted_draft_cycles=(k - accepted) * ds,
                        wasted_verify_cycles=(k - accepted) * iv,
                    ), consumed))
                    if accepted < k:
                        self.obs_log.append(("rollback", dict(
                            rid=g.rid, qos=g.qos, rejected=k - accepted,
                        ), consumed))
                if g.handle.done:
                    completed.append((g, consumed))
        return consumed


class ModeledSegAdapter(_ModeledBase):
    """Tiled segmentation, priced but not executed.

    A request's micro-step is one halo tile at a fixed modeled price;
    requests drain oldest-first within the invoking class, and a request
    completes at the offset of its last tile.
    """

    kind = "seg"

    def __init__(self, *, slots: int, tile: int, tile_cycles: int,
                 tile_ops: int):
        super().__init__(slots=slots)
        self._tile = int(tile)
        self._tile_cycles = int(tile_cycles)
        self._tile_ops = int(tile_ops)

    @classmethod
    def from_geometry(cls, *, in_ch: int = 4, base: int = 8, depth: int = 2,
                      convs_per_stage: int = 1, planes: int = 8,
                      tile: int = 28, halo: int = 12, slots: int = 4):
        """Price one halo window (``tile + 2*halo`` square) through the
        U-Net conv stack at a uniform ``planes`` schedule."""
        win = tile + 2 * halo
        layers = cm.unet_conv_layers(
            (win, win), in_ch, base, depth, convs_per_stage
        )
        schedule = (planes,) * len(layers)
        return cls(
            slots=slots,
            tile=tile,
            tile_cycles=cm.unet_window_cycles(
                (win, win), in_ch, base, depth, convs_per_stage, schedule
            ),
            tile_ops=cm.model_ops(layers),
        )

    def prepare(self, payload, *, rid: int):
        if isinstance(payload, _SegJob):
            return payload  # idempotent (router-side estimates re-prepare)
        spec = payload
        n_tiles = -(-int(spec["h"]) // self._tile) * (
            -(-int(spec["w"]) // self._tile)
        )
        return _SegJob(rid=rid, tiles_remaining=n_tiles)

    def estimate_cycles(self, job: _SegJob) -> int:
        return job.tiles_remaining * self._tile_cycles

    def work(self, budget: int, qos=None, force: bool = False,
             soft_limit: int | None = None):
        consumed = 0
        completed: list[tuple] = []
        tc = self._tile_cycles
        for greq in self._order:
            if not self._matches(greq, qos) or greq.handle.done:
                continue
            job = greq.handle
            while job.tiles_remaining > 0:
                over_hard = consumed + tc > budget
                at_soft = soft_limit is not None and consumed >= soft_limit
                if (over_hard or at_soft) and not (force and consumed == 0):
                    break
                force = False
                job.tiles_remaining -= 1
                consumed += tc
                self.total_ops += self._tile_ops
                if self.obs_enabled:
                    self.exec_log.append((greq.rid, greq.qos, tc, consumed))
                if job.done:
                    completed.append((greq, consumed))
            else:
                continue
            break  # budget/boundary hit mid-request
        done = {id(g) for g, _ in completed}
        if done:
            self._order = [g for g in self._order if id(g) not in done]
        return consumed, completed, []
