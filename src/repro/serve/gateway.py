"""Unified admission-controlled serving gateway: one front door for both
engines, co-scheduled against a shared modeled cycle budget — with
preemptive chunked execution, per-request QoS classes and an open-loop
(mid-round) arrival path.

The LM Engine (``serve.engine``) and SegEngine (``segserve.engine``) each
own a correct inner loop over the shared ``serve.queue`` primitives, but a
deployment serving heterogeneous traffic needs a *single* admission point
that can (1) decide which request enters which engine when, (2) split the
accelerator's modeled cycle capacity between traffic classes each
scheduling round, and (3) refuse to serve a tuned plan whose weights have
drifted.  This module is that front door.

Scheduling model
----------------
Time is the relation-(2) cycle clock of ``core.cycle_model`` — the same
currency every bench and certificate in this repo is priced in.  The
gateway runs discrete *rounds* of ``round_budget`` modeled cycles.  Each
round: the admission policy moves requests from the gateway queue into
engine slots, then the execution policy spends the round's budget stepping
the engines' micro-batches, charged at their modeled price.  Three
policies ship:

``fifo``
    Strict arrival order, head-of-line blocking and all: admission stops
    at the first request whose engine is full, execution drains the class
    of the oldest incomplete request first.  The honest baseline.
``fair``
    Cycle-budget fair-share (deficit round-robin): each traffic class
    accrues ``share * round_budget`` cycles of quantum per round (deficit
    carries over while the class has work, resets while idle), admission
    interleaves classes oldest-first, and leftover budget is
    work-conserving slack.  No class can starve: a backlogged class
    receives at least its share of every round.
``edf``
    Earliest-deadline-first on the modeled clock, deadlines defaulting to
    ``deadline_factor x`` the request's admission estimate.  Admission and
    execution both follow the earliest live deadline.

QoS classes (PR 5)
------------------
The scheduling class of a request is its ``qos`` label, *decoupled from
the engine kind*: ``submit(..., qos='interactive')`` and ``qos='batch'``
may both land on one ``LMAdapter``, each with its own fair share and its
own latency account.  ``qos`` defaults to the adapter kind, so kind-level
scheduling (PR 4 behavior) is the degenerate labeling.  Every non-kind
class must be declared in ``shares`` — a silently share-less class would
void the starvation-freedom guarantee the fair policy exists for.

Preemptive chunked execution (PR 5)
-----------------------------------
Under ``preemptive=True`` (the default) adapters never overdraft a budget
they are handed:

* LM prefill is *chunked* — charged token-by-token through the round
  budget as it runs, instead of atomically at admission.  A long prompt
  no longer front-loads its whole cost into one round; the remainder
  yields to the next round.
* A SegEngine micro-batch whose relation-(2) price exceeds the class's
  remaining quantum is *not started*: the quantum carries (deficit is
  never driven negative) and the batch runs once the class has accrued
  enough.  This is the digit-serial (DSLR-CNN online-arithmetic) story:
  work is metered in small online chunks, so yielding between chunks is
  architecturally free.
* LM decode steps are class-scoped (``Engine.step(only=...)``): a class's
  quantum pays for its own slots only.

``preemptive=False`` restores the PR 4 atomic semantics (prefill charged
at admission, micro-steps run past the budget) — the bench's baseline.
Liveness: if *no* class makes progress for enough consecutive rounds to
prove the cheapest step can never fit (its price exceeds the full round
budget), the gateway forces exactly one micro-step and records the
overdraft in ``stats()['forced']``.

Open-loop arrivals (PR 5)
-------------------------
``step_round(arrivals=...)`` injects requests *inside* the round at their
stamped modeled cycle: execution proceeds to each arrival's offset, the
request is submitted with ``arrival_cycle`` equal to its stamp, and a
mid-round admission pass runs before execution resumes.  ``advance_to``
runs rounds until the clock reaches a target cycle.  The open-loop replay
harness (``repro.workload.replay``) drives this path from serialized
traces.

Plan invalidation and hot-reload
--------------------------------
An adapter serving a :class:`~repro.autotune.plan.TunedPlan` carries the
plan's ``params_fingerprint`` next to a fingerprint of the weights it is
*actually* serving.  Every submission re-checks the pair; on mismatch the
gateway either rejects the request with :class:`StalePlanError` (naming
both fingerprints) or — ``on_stale='fallback'`` — quarantines the plan and
rebuilds the engine on the certified uniform schedule before admitting.
:meth:`Gateway.swap_plan` is the hot-reload path: the incoming plan's
fingerprint is re-verified against the served params immediately, then the
plan installs at the first round boundary where the adapter is idle
(admission to it is held until the swap lands, so mid-stream requests
drain under the old plan and later ones serve under the new one).

Progressive results
-------------------
Segmentation work streams :class:`~repro.segserve.engine.TileEvent` s
through the gateway (``on_event`` / ``Gateway.tile_events``): with the
engine's structure-first tile prioritization, callers get the
high-information cores of an image while its background is still queued.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Any

from repro.core import cycle_model as cm
from repro.obs.events import NULL_SINK, Event, payload_spec

from .clock import RoundClock, exact_percentile
from .queue import FifoQueue

POLICIES = ("fifo", "fair", "edf")
_POLICY_ALIASES = {"fair_share": "fair", "fairshare": "fair"}


class StalePlanError(RuntimeError):
    """A tuned plan's fingerprint does not match the served params."""


def _check_plan(adapter, on_stale: str) -> None:
    """The admission-time plan-invalidation gate (ROADMAP item): verify the
    served plan's weights-only fingerprint against the weights the adapter
    actually holds, once per submission."""
    info = adapter.verify_info()
    if info is None:
        return
    plan_fp, served_fp = info
    if plan_fp == served_fp:
        return
    msg = (
        f"stale tuned plan on {adapter.kind!r}: plan was tuned for params "
        f"with fingerprint {plan_fp} but the engine serves params with "
        f"fingerprint {served_fp}; refusing to serve a certificate "
        f"conditioned on different weights"
    )
    if on_stale == "reject":
        raise StalePlanError(msg)
    adapter.install_fallback(msg)


def _served_fingerprint(adapter) -> str:
    """SHA-256 over the weights the adapter actually serves, computed once
    per adapter lifetime (weights are fixed) and cached."""
    if getattr(adapter, "_served_fp", None) is None:
        from repro.autotune.calibrate import params_fingerprint

        adapter._served_fp = params_fingerprint(adapter.params)
    return adapter._served_fp


def _plan_fingerprint(plan) -> str:
    return plan.params_fingerprint or (
        f"<unverifiable v1 plan {plan.fingerprint}>"
    )


def _verify_info(adapter):
    """The cached (plan binding, served binding) fingerprint pair for an
    adapter serving a tuned plan — the per-submission work is a string
    compare."""
    if adapter.plan is None:
        return None
    return _plan_fingerprint(adapter.plan), _served_fingerprint(adapter)


@dataclass
class GatewayRequest:
    """One typed request with its modeled-clock lifecycle timestamps."""

    rid: int
    kind: str  # adapter key: 'lm' | 'seg' | ...
    qos: str  # scheduling class (defaults to kind at submit)
    payload: Any  # engine-native request (serve.engine.Request / image)
    est_cycles: int  # relation-(2) admission estimate
    deadline: int | None  # absolute modeled-cycle deadline (EDF)
    arrival: int  # modeled clock at submit (trace stamp under open loop)
    admitted: int | None = None  # modeled clock at admission
    finished: int | None = None  # modeled clock at completion
    arrival_round: int = 0
    admitted_round: int | None = None
    finished_round: int | None = None
    handle: Any = None  # engine-side request object, set at admission

    @property
    def done(self) -> bool:
        return self.finished is not None

    @property
    def latency_cycles(self) -> int:
        if self.finished is None:
            raise ValueError(f"request {self.rid} not finished")
        return self.finished - self.arrival

    @property
    def latency_ms(self) -> float:
        return self.latency_cycles / cm.FREQ_HZ * 1e3


# --------------------------------------------------------------- adapters
#
# An adapter owns one engine and speaks the gateway's protocol:
#   kind            class name ('lm', 'seg')
#   free_slots()    admission headroom
#   estimate_cycles(payload)  relation-(2) cost estimate for admission
#   admit(greq)     occupy a slot; returns cycles charged up front
#                   (atomic-mode prefill; 0 under preemptive chunking)
#   has_work(qos=None)        admitted-but-unfinished micro-work pending
#                   (restricted to one QoS class when given)
#   work(budget, qos=None, force=False, soft_limit=None)
#                   run micro-steps charging at most ~budget cycles;
#                   preemptive adapters never exceed budget (the *hard*
#                   quantum bound) unless ``force`` (then exactly one
#                   micro-step may overdraft).  ``soft_limit`` marks a
#                   segment boundary (a mid-round arrival's offset): no
#                   new micro-step *starts* at or past it, but a step
#                   started before it may run across — arrivals queue
#                   behind in-flight work, they do not interrupt it.
#                   Returns (consumed, completions, events) where each
#                   completion is a (GatewayRequest, offset) pair: offset
#                   is the cycles consumed *within this call* at the
#                   micro-step the request finished on, so the gateway
#                   stamps each completion at its own point in the round
#                   instead of smearing a whole chunk's latency onto a
#                   request that finished on its first micro-step.
#                   Offsets must be non-decreasing in return order.
#                   (Bare GatewayRequests are accepted for backward
#                   compatibility and stamp at the call's full consumed.)
#   total_ops       useful-op account for aggregate GOPS/W
#   verify_info()   None, or (plan params fingerprint, served fingerprint)
#   install_fallback(reason)  drop a stale plan for the uniform schedule
#   install_plan(plan)        hot-swap a verified plan (adapter idle)
#
# The gateway itself never touches jax: policies are pure cycle-clock
# scheduling, so tests drive them with synthetic adapters at zero model
# cost and the property suite can sweep traffic shapes.


class LMAdapter:
    """Continuous-batching LM decode behind the gateway protocol.

    ``plan`` (a ``workload='lm'`` :class:`~repro.autotune.plan.TunedPlan`)
    installs the certified per-layer schedule via
    :func:`repro.autotune.api.apply_plan_lm` and arms the admission-time
    fingerprint check.  Work is priced per continuous-batching step at the
    sharper ``cm.lm_step_cycles`` itemization (true GQA projection widths,
    attention score/value products against a ``max_seq``-token cache — a
    conservative context upper bound — and MoE routing when the config has
    experts).  Under ``preemptive=True`` prefill runs in budget-sized
    chunks through ``work`` and decode steps are class-scoped;
    ``preemptive=False`` restores the PR 4 atomic path (prefill charged in
    full at admission).
    """

    kind = "lm"
    # armed by Gateway.set_sink: when True, work() appends per-request
    # (rid, qos, cycles, offset) execution-attribution records to
    # exec_log for the gateway to drain into the event bus
    obs_enabled = False
    obs_sink = None

    def __init__(self, cfg, params, *, batch: int, max_seq: int,
                 plan=None, extras=None, preemptive: bool = True):
        self.plan = plan
        self.params = params
        self._base_cfg = cfg
        self._batch = batch
        self._max_seq = max_seq
        self._extras = extras
        self.preemptive = bool(preemptive)
        self.fallback_reason: str | None = None
        self.exec_log: list[tuple] = []
        if plan is not None:
            from repro.autotune.api import apply_plan_lm

            cfg = apply_plan_lm(cfg, plan)
        self._build(cfg)
        # keyed by handle identity: pre-built Requests keep their own rid,
        # which need not match (or may collide with) the gateway's counter
        self._inflight: dict[int, GatewayRequest] = {}
        self._order: list[GatewayRequest] = []  # admission order (prefill)
        self.total_ops = 0

    def _make_engine(self, cfg):
        """Engine factory — the subclass hook (``specdecode.SpecLMAdapter``
        builds a :class:`~repro.serve.specdecode.SpecEngine` here)."""
        from .engine import Engine

        return Engine(
            cfg, self.params, batch=self._batch, max_seq=self._max_seq,
            extras=self._extras,
        )

    def _build(self, cfg) -> None:
        self.cfg = cfg
        self.engine = self._make_engine(cfg)
        self.engine.obs = self.obs_sink or NULL_SINK
        schedule = cfg.quant.plane_schedule
        self._price_kw = price_kw = dict(
            n_heads=cfg.n_heads, head_dim=cfg.hd, n_kv_heads=cfg.n_kv_heads,
            context=self._max_seq, n_experts=cfg.moe.n_experts,
            top_k=cfg.moe.top_k,
        )
        self._step_cycles = cm.lm_step_cycles(
            cfg.d_model, cfg.d_ff, cfg.n_layers, schedule, **price_kw
        )
        self._step_ops = cm.lm_step_ops(
            cfg.d_model, cfg.d_ff, cfg.n_layers, **price_kw
        )

    # -- plan invalidation / hot reload
    def verify_info(self):
        return _verify_info(self)

    def install_fallback(self, reason: str) -> None:
        """Quarantine the stale plan: rebuild on the uniform full-digit
        schedule (certified by construction — zero truncation error)."""
        import dataclasses

        self.plan = None
        self.fallback_reason = reason
        self._build(
            self._base_cfg.replace(
                quant=dataclasses.replace(
                    self._base_cfg.quant, plane_schedule=None, planes=8
                )
            )
        )

    def install_plan(self, plan) -> None:
        """Hot-swap to a (gateway-verified) tuned plan.  Only legal while
        idle — the rebuild drops engine slot state."""
        if self.has_work():
            raise RuntimeError("install_plan with requests in flight")
        from repro.autotune.api import apply_plan_lm

        self.plan = plan
        self.fallback_reason = None
        self._build(apply_plan_lm(self._base_cfg, plan))
        self._inflight.clear()
        self._order.clear()

    # -- gateway protocol
    def prepare(self, payload, *, rid: int, max_new: int = 16):
        import numpy as np

        from .engine import Request

        if isinstance(payload, Request):
            return payload
        return Request(rid=rid, prompt=np.asarray(payload), max_new=max_new)

    def free_slots(self) -> int:
        return self.engine.slots.free_count()

    def estimate_cycles(self, payload) -> int:
        return (len(payload.prompt) + payload.max_new) * self._step_cycles

    def admit(self, greq: GatewayRequest) -> int:
        if self.preemptive:
            ok = self.engine.admit_slot(greq.payload)
        else:
            ok = self.engine.admit(greq.payload)
        if not ok:
            raise RuntimeError("admit called with no free LM slot")
        greq.handle = greq.payload
        self._inflight[id(greq.handle)] = greq
        self._order.append(greq)
        if self.preemptive:
            return 0  # prefill is metered through work(), chunk by chunk
        n_prefill = len(greq.payload.prompt)
        self.total_ops += n_prefill * self._step_ops
        return n_prefill * self._step_cycles

    def _matches(self, greq: GatewayRequest, qos: str | None) -> bool:
        return qos is None or greq.qos == qos

    def has_work(self, qos: str | None = None) -> bool:
        return any(
            self._matches(g, qos) and not g.done
            for g in self._inflight.values()
        )

    def _ready_slots(self, qos: str | None):
        return [
            (i, r) for i, r in self.engine.ready_slots()
            if id(r) in self._inflight
            and self._matches(self._inflight[id(r)], qos)
        ]

    def work(self, budget: int, qos: str | None = None, force: bool = False,
             soft_limit: int | None = None):
        consumed = 0
        completed: list[tuple[GatewayRequest, int]] = []
        if self.preemptive:
            consumed, force = self._work_prefill(
                budget, qos, force, soft_limit
            )
        consumed = self._work_decode(
            budget, consumed, qos, force, soft_limit, completed
        )
        for greq, _ in completed:
            if greq in self._order:
                self._order.remove(greq)
        return consumed, completed, []

    def _work_prefill(self, budget: int, qos, force: bool, soft_limit):
        """Chunked prefill, admission order: each token charged at the
        step price as it enters the cache; an unaffordable remainder
        yields to the next round instead of overdrafting."""
        consumed = 0
        sc = self._step_cycles
        for greq in list(self._order):
            if greq.done or not self._matches(greq, qos):
                continue
            h = greq.handle
            if h.prefill_remaining <= 0:
                continue
            n = min((budget - consumed) // sc, h.prefill_remaining)
            if soft_limit is not None:
                # tokens may start only before the segment boundary
                # (the last one may run across it)
                n_soft = -(-max(soft_limit - consumed, 0) // sc)
                n = min(n, n_soft)
            if n <= 0 and force and consumed == 0:
                n = 1  # forced progress: one token, overdraft recorded
            if n <= 0:
                break
            force = False
            self.engine.prefill(h, int(n))
            consumed += n * sc
            self.total_ops += n * self._step_ops
            if self.obs_enabled:
                self.exec_log.append((greq.rid, greq.qos, n * sc,
                                      consumed))
            if h.prefill_remaining:
                break  # budget exhausted mid-prompt
        return consumed, force

    def _work_decode(self, budget: int, consumed: int, qos, force: bool,
                     soft_limit, completed) -> int:
        """Decode steps — class-scoped under the preemptive path *when
        the family supports slot isolation* (the per-slot cache index:
        excluded rows' junk writes land at their own positions and are
        overwritten before read).  Recurrent/scalar-index families have
        no position-addressed state, so a subset step would corrupt the
        excluded rows — they decode every ready slot instead, charged
        to the invoking class.  The atomic path always decodes every
        ready slot (PR 4 semantics)."""
        sc = self._step_cycles
        scoped = self.preemptive and self.engine._vector_index
        while True:
            slots = self._ready_slots(qos)
            if not slots:
                break
            decoding = slots if scoped else self.engine.ready_slots()
            cost = sc * len(decoding)
            if self.preemptive:
                over_hard = consumed + cost > budget
                at_soft = soft_limit is not None and consumed >= soft_limit
                if (over_hard or at_soft) and not (force and consumed == 0):
                    break
            elif consumed >= budget:
                break
            force = False
            finished = self.engine.step(
                only={i for i, _ in slots} if scoped else None
            )
            consumed += cost
            self.total_ops += self._step_ops * len(decoding)
            if self.obs_enabled:
                # per-slot attribution: each decoding request owns one
                # step price, whichever class invoked the batch step
                for _, r in decoding:
                    g2 = self._inflight.get(id(r))
                    if g2 is not None:
                        self.exec_log.append((g2.rid, g2.qos, sc, consumed))
            # every request that finished on this decode step finished at
            # *this* step's offset, not at the end of the whole chunk
            completed.extend(
                (self._inflight.pop(id(r)), consumed)
                for r in finished
                if id(r) in self._inflight
            )
        return consumed


class SegAdapter:
    """Tiled segmentation behind the gateway protocol.

    ``plan`` serves a tuned operating point through
    :func:`repro.autotune.api.apply_plan` semantics and arms the
    fingerprint check; without one the engine serves ``cfg`` as given.
    Work is the engine's own micro-batch step, charged at the summed
    relation-(2) price of the tiles it emitted.  Requests are labeled with
    their QoS class as the engine's tile *group*, so tiles of different
    classes never share a micro-batch and a class's quantum pays exactly
    for its own tiles.  Under ``preemptive=True`` a micro-batch whose
    price exceeds the remaining budget is not started (the quantum
    carries); ``preemptive=False`` restores the PR 4 atomic loop.
    Emitted :class:`~repro.segserve.engine.TileEvent` s pass through to
    the gateway's progressive stream.
    """

    kind = "seg"
    # armed by Gateway.set_sink (see LMAdapter.obs_enabled)
    obs_enabled = False
    obs_sink = None

    def __init__(self, cfg, params, *, plan=None, preemptive: bool = True,
                 **engine_kw):
        self.plan = plan
        self.params = params
        self._base_cfg = cfg
        self._engine_kw = dict(engine_kw)
        self.preemptive = bool(preemptive)
        self.fallback_reason: str | None = None
        self.exec_log: list[tuple] = []
        self._build(cfg, plan)
        self._inflight: dict[int, GatewayRequest] = {}
        self.total_ops = 0

    def _build(self, cfg, plan) -> None:
        from repro.segserve.engine import SegEngine

        if plan is not None:
            from repro.autotune.api import apply_plan

            cfg = apply_plan(cfg, plan)
        self.cfg = cfg
        self.engine = SegEngine(cfg, self.params, plan=plan, **self._engine_kw)
        self.engine.obs = self.obs_sink or NULL_SINK
        self._base_planes = tuple(self.engine._class_planes(0))

    # -- plan invalidation / hot reload
    def verify_info(self):
        return _verify_info(self)

    def install_fallback(self, reason: str) -> None:
        import dataclasses

        self.plan = None
        self.fallback_reason = reason
        kw = dict(self._engine_kw)
        # the stale plan owned the tile geometry; fall back to the smallest
        # stride the halo walk certifies viable for this net
        kw.setdefault("tile", self._base_cfg.min_viable_tile())
        self._engine_kw = kw
        self._build(
            dataclasses.replace(
                self._base_cfg, plane_schedule=None, planes=8
            ),
            None,
        )

    def install_plan(self, plan) -> None:
        """Hot-swap to a (gateway-verified) tuned plan.  Only legal while
        idle — the rebuild drops canvases and the task table."""
        if self.has_work() or self._inflight:
            raise RuntimeError("install_plan with requests in flight")
        self.fallback_reason = None
        self.plan = plan
        self._build(self._base_cfg, plan)
        self._inflight.clear()

    # -- gateway protocol
    def prepare(self, payload, *, rid: int):
        import numpy as np

        return np.asarray(payload)

    def free_slots(self) -> int:
        return self.engine.slots.free_count()

    def estimate_cycles(self, payload) -> int:
        """Upper admission estimate: every tile window priced at the
        class-0 (full-budget) schedule — adaptivity only lowers it."""
        from repro.segserve import tiling

        e = self.engine
        tplan = tiling.plan_tiles(
            payload.shape[0], payload.shape[1], depth=e.cfg.depth,
            convs_per_stage=e.cfg.convs_per_stage, tile=e.tile, halo=e.halo,
        )
        return sum(
            cm.unet_window_cycles(
                spec.in_shape, e.cfg.in_ch, e.cfg.base, e.cfg.depth,
                e.cfg.convs_per_stage, self._base_planes,
            )
            for spec in tplan.tiles
        )

    def admit(self, greq: GatewayRequest) -> int:
        handle = self.engine.submit(greq.payload, group=greq.qos)
        if not self.engine.queue.pump(self.engine.slots, self.engine._admit):
            raise RuntimeError("admit called with no free seg slot")
        greq.handle = handle
        # keyed by the engine-local rid the TileEvents will carry
        self._inflight[handle.rid] = greq
        return 0  # tile planning is host work, not accelerator cycles

    def has_work(self, qos: str | None = None) -> bool:
        if qos is None:
            return self.engine.has_work()
        return self.engine.has_work(group=qos)

    def work(self, budget: int, qos: str | None = None, force: bool = False,
             soft_limit: int | None = None):
        consumed = 0
        completed: list[tuple[GatewayRequest, int]] = []
        events = []
        group = ... if qos is None else qos
        while True:
            cost = self.engine.next_cost(group)
            if cost == 0:
                break
            if self.preemptive:
                # the preemption point: a micro-batch that would overdraft
                # the quantum yields; the deficit carries to the next round
                over_hard = consumed + cost > budget
                at_soft = soft_limit is not None and consumed >= soft_limit
                if (over_hard or at_soft) and not (force and consumed == 0):
                    break
            elif consumed >= budget:
                break
            force = False
            evs = self.engine.step(group)
            for ev in evs:
                consumed += ev.cycles
                if self.obs_enabled:
                    g2 = self._inflight.get(ev.rid)
                    if g2 is not None:
                        self.exec_log.append((g2.rid, g2.qos, ev.cycles,
                                              consumed))
                if ev.done:
                    greq = self._inflight.pop(ev.rid, None)
                    if greq is not None:
                        self.total_ops += ev.request.result.ops
                        # finished when its last tile emitted, offset-exact
                        completed.append((greq, consumed))
            events.extend(evs)
        return consumed, completed, events


# ---------------------------------------------------------------- gateway


class Gateway:
    """Admission-controlled front door over a set of engine adapters.

    Args:
      adapters: the served engines, e.g. ``[LMAdapter(...), SegAdapter(...)]``
        (or any object speaking the adapter protocol — tests use synthetic
        ones).  Keyed by ``adapter.kind``.
      policy: ``'fifo' | 'fair' | 'edf'`` (see module docstring).
      round_budget: modeled cycles one scheduling round may spend across
        all engines — the co-scheduling knob.
      shares: per-*class* fair-share fractions.  Keys are scheduling
        classes: an adapter kind (the default class of its unlabeled
        requests) or a QoS label requests carry (``submit(..., qos=...)``).
        Every submitted request's class must be declared here — submit
        rejects undeclared classes, so no class can silently arrive
        share-less.  Must sum to <= 1; unallocated share is
        work-conserving slack.  Default: equal across kinds.
      on_stale: ``'reject'`` (raise :class:`StalePlanError` at submission)
        or ``'fallback'`` (quarantine the plan, serve the uniform
        schedule) when a tuned plan's fingerprint mismatches the served
        params.
      deadline_factor: default EDF deadline = admission estimate x this.
      on_event: optional callback fed every streamed
        :class:`~repro.segserve.engine.TileEvent` (progressive display).
      max_kept_events: how many recent tile events ``Gateway.tile_events``
        retains (a bounded deque — the oldest drop off as new ones land).
        ``on_event`` stays the lossless path; dropped-event counts surface
        in ``stats()['tile_events_dropped']``.
      sink: optional telemetry sink (:mod:`repro.obs.events`): every
        scheduling-significant moment — queue-enter, admission, quantum
        grants, preemption yields, forced escapes, swap holds, per-request
        execution attribution, tile emissions, completions, round closes —
        is emitted as a cycle-stamped :class:`~repro.obs.events.Event`.
        Default is the null sink: no events are built and observable
        behavior (scheduling, stats, bench numbers) is bit-identical to an
        uninstrumented run.  Swap sinks later with :meth:`set_sink`.
    """

    def __init__(
        self,
        adapters,
        *,
        policy: str = "fair",
        round_budget: int = 1_000_000,
        shares: dict[str, float] | None = None,
        on_stale: str = "reject",
        deadline_factor: float = 4.0,
        on_event=None,
        max_kept_events: int = 100_000,
        sink=None,
    ):
        policy = _POLICY_ALIASES.get(policy, policy)
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
        if round_budget < 1:
            raise ValueError(f"round_budget {round_budget} < 1")
        if on_stale not in ("reject", "fallback"):
            raise ValueError(f"on_stale {on_stale!r}: 'reject' or 'fallback'")
        self.adapters: dict[str, Any] = {a.kind: a for a in adapters}
        if not self.adapters:
            raise ValueError("gateway needs at least one adapter")
        self.policy = policy
        self.round_budget = int(round_budget)
        self.on_stale = on_stale
        self.deadline_factor = float(deadline_factor)
        self.on_event = on_event
        kinds = list(self.adapters)
        if shares is None:
            shares = {k: 1.0 / len(kinds) for k in kinds}
        if any(s <= 0 for s in shares.values()) or sum(shares.values()) > 1 + 1e-9:
            raise ValueError(f"shares must be positive and sum <= 1: {shares}")
        # No silent share-less class: every request's scheduling class must
        # be declared here — submit() rejects undeclared classes loudly
        # (including a kind's own default class when traffic arrives
        # unlabeled), so the starvation-freedom guarantee cannot be voided
        # by an un-shared class slipping in.
        # keys beyond the kinds declare QoS classes requests may carry
        self.shares = dict(shares)
        self.queue: FifoQueue[GatewayRequest] = FifoQueue()
        self.requests: list[GatewayRequest] = []
        self._live: dict[int, GatewayRequest] = {}  # admitted, unfinished
        # bounded recent-events window (one small record per emitted tile;
        # unbounded growth was a documented leak, N-times worse per fabric
        # shard) — on_event remains the lossless streaming path
        if max_kept_events < 1:
            raise ValueError(f"max_kept_events {max_kept_events} < 1")
        self.tile_events: deque = deque(maxlen=int(max_kept_events))
        self._tile_events_seen = 0  # lifetime emitted (kept + dropped)
        # the modeled cycle clock + per-round ledger, extracted to
        # serve.clock so the single gateway and every fabric shard run
        # the exact same accounting arithmetic
        self._clock = RoundClock()
        self._deficit = {c: 0.0 for c in self.shares}
        self._admit_charges: dict[str, int] = {}
        self._granted = set()  # classes granted quantum this round
        self._class_stalled: dict[str, int] = {}  # consecutive dry rounds
        self._pending_swap: dict[str, Any] = {}
        self.plan_swaps: list[dict] = []  # installed hot-reloads
        self._next_rid = 0
        self._obs = NULL_SINK
        self._obs_on = False
        self.set_sink(sink)

    # Historical surface: ``gw.clock`` / ``gw.rounds`` / ``gw.forced`` were
    # plain counters before the RoundClock extraction; every test, bench
    # and replay harness reads them, so they stay as read-only views.
    @property
    def clock(self) -> int:
        """Absolute modeled clock (round start while stepping)."""
        return self._clock.cycles

    @property
    def rounds(self) -> int:
        return self._clock.rounds

    @property
    def forced(self) -> int:
        """Forced-progress overdraft steps (liveness escapes)."""
        return self._clock.forced

    @property
    def round_clock(self) -> RoundClock:
        """The underlying :class:`~repro.serve.clock.RoundClock` — read-only
        use (fleet-ledger additivity checks diff its cumulative counters)."""
        return self._clock

    def ledger_snapshot(self) -> dict:
        """Cumulative integer accounts a fleet ledger diffs per round."""
        return dict(
            ops=sum(a.total_ops for a in self.adapters.values()),
            worked=self._clock.worked_total,
            class_worked=dict(self._clock.class_worked_total),
        )

    # ---------------------------------------------------------- telemetry

    @property
    def sink(self):
        """The armed telemetry sink (:data:`~repro.obs.events.NULL_SINK`
        when observation is off)."""
        return self._obs

    def set_sink(self, sink) -> None:
        """Arm (or disarm, with ``None``) the telemetry sink.

        Arms the whole stack in one call: the gateway's own emission
        points, the :class:`~repro.serve.clock.RoundClock` round-close
        events, each adapter's execution-attribution log
        (``adapter.obs_enabled`` / ``adapter.exec_log``), and — for
        adapters that own an engine — the engine's sequence-stamped
        micro-step records.  Adapters without the attribute surface
        (synthetic test adapters) degrade gracefully: their per-request
        attribution is simply absent from the stream.
        """
        self._obs = NULL_SINK if sink is None else sink
        self._obs_on = bool(getattr(self._obs, "enabled", True))
        self._clock.obs = self._obs if self._obs_on else None
        for a in self.adapters.values():
            try:
                a.obs_enabled = self._obs_on
                a.obs_sink = self._obs if self._obs_on else None
            except AttributeError:
                continue
            eng = getattr(a, "engine", None)
            if eng is not None and hasattr(eng, "obs"):
                eng.obs = self._obs if self._obs_on else NULL_SINK

    # ------------------------------------------------------------- submit

    def submit(self, kind: str, payload, *, qos: str | None = None,
               deadline_cycles: int | None = None,
               arrival_cycle: int | None = None, **prepare_kw
               ) -> GatewayRequest:
        """Type, verify and enqueue one request.

        ``qos`` is the scheduling class (defaults to ``kind``); a non-kind
        class must be declared in ``shares``.  ``arrival_cycle`` stamps the
        request's arrival on the modeled clock (the open-loop replay path;
        defaults to the current clock).  Admission control starts here:
        the adapter's tuned plan (if any) is verified against its served
        params *before* the request may enter the system."""
        if kind not in self.adapters:
            raise ValueError(
                f"unknown request kind {kind!r}; served kinds: "
                f"{sorted(self.adapters)}"
            )
        qos = kind if qos is None else str(qos)
        if qos not in self.shares:
            raise ValueError(
                f"undeclared QoS class {qos!r}: declare it in shares= "
                f"(declared: {sorted(self.shares)})"
            )
        adapter = self.adapters[kind]
        _check_plan(adapter, self.on_stale)
        rid = self._next_rid
        self._next_rid += 1
        # the raw-payload spec must be read *before* prepare (preparation
        # is lossy) — it is what obs.capture rebuilds traces from
        spec = payload_spec(kind, payload, prepare_kw) if self._obs_on \
            else None
        payload = adapter.prepare(payload, rid=rid, **prepare_kw)
        est = int(adapter.estimate_cycles(payload))
        arrival = self.clock if arrival_cycle is None else int(arrival_cycle)
        if deadline_cycles is None:
            deadline = arrival + math.ceil(self.deadline_factor * est)
        else:
            deadline = arrival + int(deadline_cycles)
        greq = GatewayRequest(
            rid=rid, kind=kind, qos=qos, payload=payload, est_cycles=est,
            deadline=deadline, arrival=arrival,
            arrival_round=self.rounds,
        )
        self.queue.push(greq)
        self.requests.append(greq)
        if self._obs_on:
            self._obs.emit(Event(arrival, "submit", dict(
                rid=rid, kind=kind, qos=qos, est=est, deadline=deadline,
                spec=spec,
            )))
        return greq

    # ------------------------------------------------------ work stealing

    def export_queued(self, n: int) -> list[GatewayRequest]:
        """Give up to ``n`` *queued* requests from the queue tail — the
        work-stealing donor side (:class:`~repro.serve.fabric.Fabric`).

        Only never-admitted requests move: admitted work owns engine slot
        state (KV cache rows, stitching canvases) that cannot migrate.
        Taking from the tail preserves the donor's own FIFO semantics —
        its oldest requests keep their place.  Returned in arrival order.
        """
        take = min(int(n), len(self.queue))
        out = [self.queue.pop_at(len(self.queue) - 1) for _ in range(take)]
        out.reverse()  # popped newest-first; hand back in arrival order
        if out:
            gone = {id(g) for g in out}
            self.requests = [
                g for g in self.requests if id(g) not in gone
            ]
            if self._obs_on:
                for g in out:
                    self._obs.emit(Event(self.clock, "export",
                                         dict(rid=g.rid, qos=g.qos)))
        return out

    def import_queued(self, greqs) -> None:
        """Accept requests exported from another gateway (the thief side).

        Each request is re-keyed onto this gateway's rid counter — rids
        index the ``_live`` table, so an imported request keeping its
        donor-assigned rid could collide with a local one.  Arrival
        stamps travel with the request: latency is measured from the
        original arrival, wherever it completes.
        """
        for g in greqs:
            if g.kind not in self.adapters:
                raise ValueError(
                    f"imported request kind {g.kind!r} not served here "
                    f"(kinds: {sorted(self.adapters)})"
                )
            if g.qos not in self.shares:
                raise ValueError(
                    f"imported request class {g.qos!r} undeclared in "
                    f"shares (declared: {sorted(self.shares)})"
                )
            g.rid = self._next_rid
            self._next_rid += 1
            self.queue.push(g)
            self.requests.append(g)
            if self._obs_on:
                # span assembly treats an import as the (re-keyed)
                # request's queue-enter: the original arrival travels
                self._obs.emit(Event(self.clock, "import", dict(
                    rid=g.rid, kind=g.kind, qos=g.qos, arrival=g.arrival,
                    est=g.est_cycles, deadline=g.deadline,
                )))

    # --------------------------------------------------------- hot reload

    def swap_plan(self, kind: str, plan) -> None:
        """Queue a verified tuned plan for installation at a round
        boundary (plan hot-reload).

        The plan's ``params_fingerprint`` is re-verified against the
        served weights *now* — an operator swapping in a plan tuned for
        different weights gets :class:`StalePlanError` immediately, naming
        both fingerprints.  Installation waits until the adapter is idle:
        admission to ``kind`` is held (its queued requests wait), in-flight
        requests drain under the old plan, and the new plan installs at
        the next round boundary, after which admission resumes.
        """
        if kind not in self.adapters:
            raise ValueError(f"unknown kind {kind!r}")
        adapter = self.adapters[kind]
        if not hasattr(adapter, "install_plan"):
            raise TypeError(f"adapter {kind!r} does not support plan swaps")
        plan_fp = _plan_fingerprint(plan)
        served_fp = _served_fingerprint(adapter)
        if plan_fp != served_fp:
            raise StalePlanError(
                f"refusing to hot-swap a stale plan onto {kind!r}: plan "
                f"fingerprint {plan_fp} vs served params fingerprint "
                f"{served_fp}"
            )
        self._pending_swap[kind] = plan
        if self._obs_on:
            self._obs.emit(Event(self.clock, "swap-hold", dict(
                kind=kind, fingerprint=plan_fp,
            )))
        self._install_pending_swaps()

    def _install_pending_swaps(self) -> None:
        for kind in list(self._pending_swap):
            adapter = self.adapters[kind]
            if adapter.has_work() or any(
                g.kind == kind for g in self._live.values()
            ):
                continue  # drain first; admission to this kind is held
            plan = self._pending_swap.pop(kind)
            adapter.install_plan(plan)
            self.plan_swaps.append(
                dict(kind=kind, round=self.rounds,
                     fingerprint=plan.fingerprint)
            )
            if self._obs_on:
                # install_plan rebuilt the engine — re-arm its sink
                eng = getattr(adapter, "engine", None)
                if eng is not None and hasattr(eng, "obs"):
                    eng.obs = self._obs
                self._obs.emit(Event(self.clock, "swap-inst", dict(
                    kind=kind, round=self.rounds,
                    fingerprint=plan.fingerprint,
                )))

    # ---------------------------------------------------------- admission

    def _try_admit(self, idx: int) -> bool:
        """Admit the ``idx``-th queued request if its engine has a slot."""
        greq = self.queue.peek(idx)
        if greq.kind in self._pending_swap:
            return False  # admission held until the plan swap installs
        adapter = self.adapters[greq.kind]
        if adapter.free_slots() < 1:
            return False
        self.queue.pop_at(idx)
        charged = adapter.admit(greq)
        greq.admitted = self.clock
        greq.admitted_round = self.rounds
        self._live[greq.rid] = greq
        if self._obs_on:
            self._obs.emit(Event(self.clock, "admit", dict(
                rid=greq.rid, kind=greq.kind, qos=greq.qos,
                charged=int(charged),
            )))
        if charged:
            self._admit_charges[greq.qos] = (
                self._admit_charges.get(greq.qos, 0) + int(charged)
            )
        return True

    def _classes(self) -> list[str]:
        """Scheduling classes, declared-share order (kinds + QoS labels)."""
        return list(self.shares)

    def _admission_phase(self) -> None:
        # A kind whose plan swap is draining is *held* — an operator
        # action, not arrival-order semantics — so every policy's scan
        # skips held-kind requests instead of letting one freeze admission
        # for the other kinds behind it (the swap-hold head-of-line leak).
        held = self._pending_swap
        if self.policy == "fifo":
            # strict arrival order among admissible kinds: a full engine
            # at the (non-held) head blocks the whole queue — the classic
            # failure mode the other policies fix
            progress = True
            while progress and self.queue:
                progress = False
                idx = next(
                    (i for i, g in enumerate(self.queue)
                     if g.kind not in held),
                    None,
                )
                if idx is not None and self._try_admit(idx):
                    progress = True
        elif self.policy == "fair":
            # round-robin classes, oldest-first within a class; a blocked
            # class never blocks the others
            progress = True
            while progress and self.queue:
                progress = False
                for c in self._classes():
                    idx = next(
                        (i for i, g in enumerate(self.queue)
                         if g.qos == c and g.kind not in held),
                        None,
                    )
                    if idx is not None and self._try_admit(idx):
                        progress = True
        else:  # edf
            progress = True
            while progress and self.queue:
                progress = False
                order = sorted(
                    range(len(self.queue)),
                    key=lambda i: (
                        self.queue.peek(i).deadline,
                        self.queue.peek(i).arrival,
                    ),
                )
                for idx in order:
                    if self._try_admit(idx):
                        progress = True
                        break  # indices shifted; re-sort

    # ---------------------------------------------------------- execution

    def _class_order(self) -> list[str]:
        """Execution priority between classes for fifo/edf: the class of
        the most urgent incomplete admitted request first.  Derived from
        the gateway's own live-request table — adapters owe the protocol
        nothing about how they track in-flight work, and completed history
        is never rescanned."""
        live_by_class: dict[str, list[GatewayRequest]] = {}
        for g in self._live.values():
            live_by_class.setdefault(g.qos, []).append(g)

        def urgency(c: str):
            live = live_by_class.get(c)
            if not live:
                return (1, 0)
            if self.policy == "edf":
                return (0, min(g.deadline for g in live))
            return (0, min(g.arrival for g in live))

        return sorted(self._classes(), key=urgency)

    def _class_has_work(self, c: str) -> bool:
        return any(a.has_work(qos=c) for a in self.adapters.values())

    def _do_work(self, kind: str, budget: float, qos: str | None,
                 force: bool = False, soft: float | None = None) -> int:
        adapter = self.adapters[kind]
        base = self._clock.round_spent  # intra-round offset of this call
        consumed, completed, events = adapter.work(
            int(budget), qos=qos, force=force,
            soft_limit=None if soft is None else int(soft),
        )
        self._clock.record_work(consumed, qos)
        if self._obs_on:
            # drain the adapter's execution-attribution log: each entry is
            # (rid, qos, cycles, offset-in-call), stamped like completions
            # so Σ exec cycles reconciles with worked_total exactly
            log = getattr(adapter, "exec_log", None)
            if log:
                for rid, equos, cyc, off in log:
                    self._obs.emit(Event(
                        self.clock + min(base + off, self.round_budget),
                        "exec",
                        dict(rid=rid, kind=kind, qos=equos, cycles=cyc),
                    ))
                log.clear()
            # adapter-level lifecycle annotations (the speculative engine's
            # draft/verify/accept/rollback moments): (etype, data, offset)
            # triples stamped exactly like exec attribution.  These carry
            # no cycle account of their own — the exec entries do — so
            # span reconciliation is untouched by their presence.
            slog = getattr(adapter, "obs_log", None)
            if slog:
                for etype, data, off in slog:
                    self._obs.emit(Event(
                        self.clock + min(base + off, self.round_budget),
                        etype,
                        dict(kind=kind, **data),
                    ))
                slog.clear()
        prev_off = 0
        for item in completed:
            # protocol v3: (greq, offset) — stamp each completion at its
            # own micro-step's offset, so a request that finished on the
            # first step of a large quantum does not inherit the whole
            # chunk's latency.  Bare greqs (legacy adapters) stamp at the
            # call's full consumed, the pre-fix behavior.
            if isinstance(item, tuple):
                greq, off = item
            else:
                greq, off = item, consumed
            if off < prev_off:
                raise AssertionError(
                    f"adapter {kind!r} returned decreasing completion "
                    f"offsets ({off} after {prev_off})"
                )
            prev_off = off
            stamp = self.clock + min(base + off, self.round_budget)
            if stamp < greq.arrival:
                raise AssertionError(
                    f"completion stamp {stamp} precedes arrival "
                    f"{greq.arrival} for request {greq.rid}"
                )
            greq.finished = stamp
            greq.finished_round = self.rounds
            self._live.pop(greq.rid, None)
            if self._obs_on:
                self._obs.emit(Event(stamp, "complete", dict(
                    rid=greq.rid, kind=greq.kind, qos=greq.qos,
                    latency=greq.latency_cycles,
                )))
            # the result lives on greq.handle; drop the input payload so a
            # long-running gateway does not pin every served image/prompt
            greq.payload = None
        for ev in events:
            self.tile_events.append(ev)  # bounded: oldest drop off
            self._tile_events_seen += 1
            if self.on_event is not None:
                self.on_event(ev)
            if self._obs_on:
                self._obs.emit(Event(
                    self.clock + min(self._clock.round_spent,
                                     self.round_budget),
                    "tile",
                    dict(rid=ev.rid, klass=ev.klass, cycles=ev.cycles,
                         tile=ev.tile, done=bool(ev.done)),
                ))
        return consumed

    def _work_class(self, c: str, budget: float, force: bool = False,
                    soft: float | None = None) -> int:
        """Offer ``budget`` cycles (hard bound) to class ``c`` across its
        adapters; ``soft`` is the segment boundary no new step may start
        past."""
        used_total = 0
        for kind, adapter in self.adapters.items():
            if used_total >= budget and not force:
                break
            if adapter.has_work(qos=c):
                used = self._do_work(
                    kind, budget - used_total, c,
                    force=force and used_total == 0,
                    soft=None if soft is None else max(soft - used_total, 0),
                )
                used_total += used
                if used:
                    force = False
        if self._obs_on and used_total < budget and \
                self._class_has_work(c):
            # the preemption point: the class stopped with work pending
            # and budget in hand (next step unaffordable, or a mid-round
            # segment boundary) — its quantum carries to the next round
            self._obs.emit(Event(
                self.clock + min(self._clock.round_spent,
                                 self.round_budget),
                "preempt",
                dict(qos=c, used=used_total, budget=int(budget)),
            ))
        return used_total

    def _apply_admit_charges(self) -> None:
        """Atomic-mode prefill charges (PR 4 semantics): eat into the round
        before any micro-step, debited from the class's quantum — the
        overdraft the preemptive path exists to avoid."""
        for qos in list(self._admit_charges):
            charged = self._admit_charges.pop(qos)
            if charged:
                self._clock.record_spent(charged)
                if self.policy == "fair":
                    self._deficit[qos] = (
                        self._deficit.get(qos, 0.0) - charged
                    )

    def _accrue_quanta(self) -> None:
        self._granted = set()
        for c, share in self.shares.items():
            if self._class_has_work(c) or self._deficit[c] < 0:
                self._deficit[c] += share * self.round_budget
                self._granted.add(c)
                if self._obs_on:
                    self._obs.emit(Event(self.clock, "grant", dict(
                        qos=c, quantum=share * self.round_budget,
                        deficit=self._deficit[c],
                    )))
            else:
                self._deficit[c] = 0.0  # no banking while idle

    def _grant_midround(self) -> None:
        """Quantum for a class that became backlogged mid-round (open-loop
        arrival after the round-start accrual): its share of the round's
        *remaining* capacity — it was absent for the part already spent,
        so the grant is pro-rated, never retroactive."""
        if self.policy != "fair":
            return
        remaining = max(self.round_budget - self._clock.round_spent, 0)
        for c, share in self.shares.items():
            if c not in self._granted and self._class_has_work(c):
                self._deficit[c] += share * remaining
                self._granted.add(c)
                if self._obs_on:
                    self._obs.emit(Event(
                        self.clock + self._clock.round_spent, "grant",
                        dict(qos=c, quantum=share * remaining,
                             deficit=self._deficit[c], midround=True),
                    ))

    def _execute(self, limit: float) -> None:
        """Spend modeled cycles until the round's intra-round clock
        reaches ``limit`` or no class can start an affordable micro-step.
        Called multiple times per round — mid-round arrivals partition the
        round into segments at their stamped offsets.  Modeled time flows
        to the segment boundary regardless: capacity nobody could (or was
        entitled to) use before an arrival is spent as idle, never banked
        — so completion stamps after an arrival are never earlier than
        the arrival itself."""
        limit = min(int(limit), self.round_budget)
        clk = self._clock
        self._apply_admit_charges()
        progress = True
        while progress and clk.round_spent < limit:
            progress = False
            soft = limit - clk.round_spent  # segment boundary offset
            room = self.round_budget - clk.round_spent  # physical round
            if room < 1:
                break
            if self.policy == "fair":
                # largest-deficit-first: when round capacity only fits one
                # micro-step, a fixed iteration order would systematically
                # serve earlier-declared classes and stall the rest even
                # as their banked quanta grow — the class with the most
                # credit goes first (stable sort: declared order on ties)
                order = sorted(
                    self._classes(),
                    key=lambda c: -self._deficit.get(c, 0.0),
                )
                for c in order:
                    soft = limit - clk.round_spent
                    room = self.round_budget - clk.round_spent
                    if soft <= 0 or room < 1:
                        break
                    budget = min(self._deficit.get(c, 0.0), room)
                    if budget < 1:
                        continue
                    used = self._work_class(c, budget, soft=soft)
                    if used:
                        # preemptive adapters never exceed the offered
                        # budget, so the quantum is never driven negative;
                        # an atomic adapter's overshoot past the budget is
                        # real service and stays as debt (PR 4 semantics)
                        # rather than being forgiven by the floor
                        if used <= budget:
                            self._deficit[c] = max(
                                self._deficit[c] - used, 0.0
                            )
                        else:
                            self._deficit[c] -= used
                        progress = True
                if not progress:
                    # quanta exhausted (or unaffordable) with budget left:
                    # work-conserving slack, un-charged (quanta stay
                    # non-negative), handed out in urgency order — the
                    # oldest live class first, not declaration order
                    for c in self._class_order():
                        soft = limit - clk.round_spent
                        room = self.round_budget - clk.round_spent
                        if soft <= 0 or room < 1:
                            break
                        used = self._work_class(c, room, soft=soft)
                        if used:
                            progress = True
            else:
                for c in self._class_order():
                    soft = limit - clk.round_spent
                    room = self.round_budget - clk.round_spent
                    if soft <= 0 or room < 1:
                        break
                    if self._work_class(c, room, soft=soft):
                        progress = True
        # idle time flows: the intra-round clock reaches the boundary
        clk.idle_to(limit)

    def _stall_limit(self) -> int:
        """Consecutive zero-progress rounds that prove a class's cheapest
        pending micro-step can never fit a round budget.  Under fair, a
        backlogged class's quantum grows by share x round_budget per
        round, so after ceil(1/min_share) rounds its deficit exceeds a
        full round budget — further stalling means the step itself is
        bigger than a round.  Other policies offer the whole round every
        round."""
        if self.policy == "fair":
            return math.ceil(1.0 / min(self.shares.values())) + 1
        return 1

    def _check_starvation(self) -> None:
        """Liveness escape for micro-steps larger than a whole round.

        Under ``fair`` the check is *per class*: a class with pending work
        and zero progress for ``_stall_limit`` consecutive rounds — even
        while other classes kept the gateway busy — is holding a step its
        ever-growing quantum can never fit inside a round; run exactly one
        such step, overdraft and all, and leave the overdraft as quantum
        debt so the class repays it.  Under ``fifo``/``edf`` per-class
        starvation is the *policy's own semantics* (head-of-line blocking
        is what FIFO means), so only a globally idle round with work
        pending — nothing anywhere could start — triggers the escape.
        Forced steps are counted in ``stats()['forced']`` — a
        modeled-capacity smell either way."""
        if self.policy != "fair":
            if self._clock.round_worked == 0 and any(
                a.has_work() for a in self.adapters.values()
            ):
                for c in self._class_order():
                    if self._class_has_work(c):
                        used = self._work_class(c, self.round_budget,
                                                force=True)
                        if used:
                            self._clock.forced += 1
                            if self._obs_on:
                                self._obs.emit(Event(
                                    self.clock, "forced",
                                    dict(qos=c, cycles=used),
                                ))
                            return
            return
        for c in self._classes():
            if not self._class_has_work(c) or \
                    self._clock.round_class_worked.get(c, 0) > 0:
                self._class_stalled[c] = 0
                continue
            self._class_stalled[c] = self._class_stalled.get(c, 0) + 1
            if self._class_stalled[c] < self._stall_limit():
                continue
            used = self._work_class(c, self.round_budget, force=True)
            if used:
                self._clock.forced += 1
                self._deficit[c] = self._deficit.get(c, 0.0) - used
                if self._obs_on:
                    self._obs.emit(Event(self.clock, "forced",
                                         dict(qos=c, cycles=used)))
            self._class_stalled[c] = 0

    # ------------------------------------------------------------- rounds

    def pending(self) -> bool:
        return bool(self.queue) or any(
            a.has_work() for a in self.adapters.values()
        )

    def step_round(self, arrivals=()) -> None:
        """One scheduling round: admit per policy, execute against the
        shared cycle budget, advance the modeled clock.

        ``arrivals`` is an iterable of ``(cycle, kind, payload, kwargs)``
        tuples injected open-loop: execution runs to each arrival's offset
        within the round, the request is submitted with its stamped
        ``arrival_cycle``, and a mid-round admission pass runs before
        execution resumes — so a request arriving mid-round can be served
        in the same round instead of waiting for the next boundary.
        Arrivals stamped at or past the round's end are rejected (a
        future-stamped request admitted early could finish before it
        "arrived" and corrupt the latency account) — feed each round only
        its own window, as ``workload.replay`` does.
        """
        arr = sorted(arrivals, key=lambda a: a[0])
        if arr and arr[-1][0] >= self.clock + self.round_budget:
            raise ValueError(
                f"arrival stamped at cycle {arr[-1][0]} is outside this "
                f"round [{self.clock}, {self.clock + self.round_budget}) — "
                f"defer it to its own round"
            )
        self._clock.begin_round()
        self._install_pending_swaps()
        # backlog: arrivals stamped at or before the round start
        while arr and arr[0][0] <= self.clock:
            cyc, kind, payload, kw = arr.pop(0)
            self.submit(kind, payload, arrival_cycle=cyc, **kw)
        self._admission_phase()
        if self.policy == "fair":
            self._accrue_quanta()
        for cyc, kind, payload, kw in arr:
            self._execute(max(cyc - self.clock, 0))
            self.submit(kind, payload, arrival_cycle=cyc, **kw)
            self._admission_phase()
            self._grant_midround()
        self._execute(self.round_budget)
        self._check_starvation()
        self._clock.end_round(self.round_budget)

    def advance_to(self, cycle: int) -> None:
        """Run scheduling rounds until the modeled clock reaches
        ``cycle`` (the open-loop replay idle path)."""
        while self.clock < cycle:
            self.step_round()

    def drain(self, *, max_rounds: int = 100_000) -> None:
        """Run rounds until nothing is queued or in flight."""
        while self.pending():
            if self.rounds >= max_rounds:
                raise RuntimeError(
                    f"gateway did not drain within {max_rounds} rounds "
                    f"(queue={len(self.queue)}, policy={self.policy})"
                )
            self.step_round()

    # -------------------------------------------------------------- stats

    def stats(self) -> dict:
        """Per-class modeled-latency distribution + aggregate GOPS/W.
        Classes are QoS labels (adapter kinds for unlabeled traffic).
        Percentiles are exact order statistics
        (:func:`~repro.serve.clock.exact_percentile`): every reported
        p50/p99 is an actual observed latency, never an interpolation."""
        classes = list(self.shares)
        for g in self.requests:
            if g.qos not in classes:
                classes.append(g.qos)
        per_class: dict[str, dict] = {}
        for c in classes:
            of_c = [g for g in self.requests if g.qos == c]
            if not of_c and c not in self.adapters:
                continue
            lats = [g.latency_ms for g in of_c if g.done]
            p50 = exact_percentile(lats, 50)
            p99 = exact_percentile(lats, 99)
            per_class[c] = dict(
                n=len(of_c),
                completed=len(lats),
                p50_ms=None if p50 is None else float(p50),
                p99_ms=None if p99 is None else float(p99),
                max_ms=float(max(lats)) if lats else None,
                # every request carries an absolute deadline (explicit
                # deadline_cycles or deadline_factor x estimate) — misses
                # reconcile with the SloMonitor's online counts
                deadline_misses=sum(
                    1 for g in of_c if g.done and g.finished > g.deadline
                ),
            )
        total_ops = sum(a.total_ops for a in self.adapters.values())
        elapsed_s = self.clock / cm.FREQ_HZ
        power = (
            cm.PAPER_TABLE1["proposed"]["gops"]
            / cm.PAPER_TABLE1["proposed"]["gops_w"]
        )
        gops = total_ops / elapsed_s / 1e9 if elapsed_s > 0 else 0.0
        out = dict(
            policy=self.policy,
            rounds=self.rounds,
            clock_cycles=self.clock,
            per_class=per_class,
            total_ops=total_ops,
            gops=gops,
            gops_w=gops / power,
            forced=self.forced,
            worked_cycles=self._clock.worked_total,
            class_worked_cycles=dict(self._clock.class_worked_total),
            tile_events_seen=self._tile_events_seen,
            tile_events_kept=len(self.tile_events),
            tile_events_dropped=self._tile_events_seen
            - len(self.tile_events),
            plan_swaps=list(self.plan_swaps),
            fallbacks={
                k: a.fallback_reason
                for k, a in self.adapters.items()
                if getattr(a, "fallback_reason", None)
            },
        )
        # an armed SloMonitor (directly, teed, or shard-wrapped) surfaces
        # its burn rates + miss attribution for this gateway's scope
        from repro.obs.slo import find_monitor

        mon, shard = find_monitor(self._obs)
        if mon is not None:
            out["slo"] = mon.summary(scope=shard)
        # an armed EnergyMeter surfaces the joule ledger for the same
        # scope; metered GOPS/W divides this gateway's ops by *metered*
        # energy (active + idle), refining the analytic constant above
        from repro.core import energy_model as em
        from repro.obs.energy import find_meter

        meter, eshard = find_meter(self._obs)
        if meter is not None:
            eb = meter.summary(scope=eshard)
            eb["metered_gops_w"] = em.metered_gops_per_w(
                total_ops, eb["total_pj"]
            )
            eb["analytic_gops_w"] = out["gops_w"]
            out["energy"] = eb
        return out
