"""Unified admission-controlled serving gateway: one front door for both
engines, co-scheduled against a shared modeled cycle budget.

The LM Engine (``serve.engine``) and SegEngine (``segserve.engine``) each
own a correct inner loop over the shared ``serve.queue`` primitives, but a
deployment serving heterogeneous traffic needs a *single* admission point
that can (1) decide which request enters which engine when, (2) split the
accelerator's modeled cycle capacity between the two workloads each
scheduling round, and (3) refuse to serve a tuned plan whose weights have
drifted.  This module is that front door.

Scheduling model
----------------
Time is the relation-(2) cycle clock of ``core.cycle_model`` — the same
currency every bench and certificate in this repo is priced in.  The
gateway runs discrete *rounds* of ``round_budget`` modeled cycles.  Each
round: the admission policy moves requests from the gateway queue into
engine slots, then the execution policy spends the round's budget stepping
the engines' micro-batches (one LM continuous-batching decode step / one
seg tile micro-batch at a time, charged at its modeled price).  Three
policies ship:

``fifo``
    Strict arrival order, head-of-line blocking and all: admission stops
    at the first request whose engine is full, execution drains the class
    of the oldest incomplete request first.  The honest baseline.
``fair``
    Cycle-budget fair-share (deficit round-robin): each traffic class
    accrues ``share * round_budget`` cycles of quantum per round (deficit
    carries over while the class has work, resets while idle), admission
    interleaves classes oldest-first, and leftover budget is
    work-conserving.  No class can starve: a backlogged class receives at
    least its share of every round.
``edf``
    Earliest-deadline-first on the modeled clock, deadlines defaulting to
    ``deadline_factor x`` the request's admission estimate.  Admission and
    execution both follow the earliest live deadline.

Plan invalidation at admission
------------------------------
An adapter serving a :class:`~repro.autotune.plan.TunedPlan` carries the
plan's ``params_fingerprint`` next to a fingerprint of the weights it is
*actually* serving.  Every submission re-checks the pair; on mismatch the
gateway either rejects the request with :class:`StalePlanError` (naming
both fingerprints) or — ``on_stale='fallback'`` — quarantines the plan and
rebuilds the engine on the certified uniform schedule (full 8-plane
digits, zero truncation error) before admitting.  A certificate conditioned
on dead weights is never silently served.

Progressive results
-------------------
Segmentation work streams :class:`~repro.segserve.engine.TileEvent` s
through the gateway (``on_event`` / ``Gateway.tile_events``): with the
engine's structure-first tile prioritization, callers get the
high-information cores of an image while its background is still queued.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

from repro.core import cycle_model as cm

from .queue import FifoQueue

POLICIES = ("fifo", "fair", "edf")
_POLICY_ALIASES = {"fair_share": "fair", "fairshare": "fair"}


class StalePlanError(RuntimeError):
    """A tuned plan's fingerprint does not match the served params."""


def _check_plan(adapter, on_stale: str) -> None:
    """The admission-time plan-invalidation gate (ROADMAP item): verify the
    served plan's weights-only fingerprint against the weights the adapter
    actually holds, once per submission."""
    info = adapter.verify_info()
    if info is None:
        return
    plan_fp, served_fp = info
    if plan_fp == served_fp:
        return
    msg = (
        f"stale tuned plan on {adapter.kind!r}: plan was tuned for params "
        f"with fingerprint {plan_fp} but the engine serves params with "
        f"fingerprint {served_fp}; refusing to serve a certificate "
        f"conditioned on different weights"
    )
    if on_stale == "reject":
        raise StalePlanError(msg)
    adapter.install_fallback(msg)


def _verify_info(adapter):
    """The cached (plan binding, served binding) fingerprint pair for an
    adapter serving a tuned plan.  The served weights are fixed for the
    adapter's lifetime, so the SHA-256 over them is computed once and
    reused by every admission check — the per-submission work is a string
    compare."""
    if adapter.plan is None:
        return None
    if getattr(adapter, "_served_fp", None) is None:
        from repro.autotune.calibrate import params_fingerprint

        adapter._served_fp = params_fingerprint(adapter.params)
    plan_fp = adapter.plan.params_fingerprint or (
        f"<unverifiable v1 plan {adapter.plan.fingerprint}>"
    )
    return plan_fp, adapter._served_fp


@dataclass
class GatewayRequest:
    """One typed request with its modeled-clock lifecycle timestamps."""

    rid: int
    kind: str  # adapter key: 'lm' | 'seg' | ...
    payload: Any  # engine-native request (serve.engine.Request / image)
    est_cycles: int  # relation-(2) admission estimate
    deadline: int | None  # absolute modeled-cycle deadline (EDF)
    arrival: int  # modeled clock at submit
    admitted: int | None = None  # modeled clock at admission
    finished: int | None = None  # modeled clock at completion
    arrival_round: int = 0
    admitted_round: int | None = None
    finished_round: int | None = None
    handle: Any = None  # engine-side request object, set at admission

    @property
    def done(self) -> bool:
        return self.finished is not None

    @property
    def latency_cycles(self) -> int:
        if self.finished is None:
            raise ValueError(f"request {self.rid} not finished")
        return self.finished - self.arrival

    @property
    def latency_ms(self) -> float:
        return self.latency_cycles / cm.FREQ_HZ * 1e3


# --------------------------------------------------------------- adapters
#
# An adapter owns one engine and speaks the gateway's protocol:
#   kind            class name ('lm', 'seg')
#   free_slots()    admission headroom
#   estimate_cycles(payload)  relation-(2) cost estimate for admission
#   admit(greq)     occupy a slot; returns cycles charged up front (prefill)
#   has_work()      admitted-but-unfinished micro-work pending
#   work(budget)    run micro-steps until ~budget cycles are consumed;
#                   returns (consumed, completed GatewayRequests, events)
#   total_ops       useful-op account for aggregate GOPS/W
#   verify_info()   None, or (plan params fingerprint, served fingerprint)
#   install_fallback(reason)  drop a stale plan for the uniform schedule
#
# The gateway itself never touches jax: policies are pure cycle-clock
# scheduling, so tests drive them with synthetic adapters at zero model
# cost and the property suite can sweep traffic shapes.


class LMAdapter:
    """Continuous-batching LM decode behind the gateway protocol.

    ``plan`` (a ``workload='lm'`` :class:`~repro.autotune.plan.TunedPlan`)
    installs the certified per-layer schedule via
    :func:`repro.autotune.api.apply_plan_lm` and arms the admission-time
    fingerprint check.  Decode work is priced per continuous-batching step:
    ``cm.lm_step_cycles`` x active slots; prefill is charged at admission
    (prompt length x step price).
    """

    kind = "lm"

    def __init__(self, cfg, params, *, batch: int, max_seq: int,
                 plan=None, extras=None):
        self.plan = plan
        self.params = params
        self._base_cfg = cfg
        self._batch = batch
        self._max_seq = max_seq
        self._extras = extras
        self.fallback_reason: str | None = None
        if plan is not None:
            from repro.autotune.api import apply_plan_lm

            cfg = apply_plan_lm(cfg, plan)
        self._build(cfg)
        # keyed by handle identity: pre-built Requests keep their own rid,
        # which need not match (or may collide with) the gateway's counter
        self._inflight: dict[int, GatewayRequest] = {}
        self.total_ops = 0

    def _build(self, cfg) -> None:
        from .engine import Engine

        self.cfg = cfg
        self.engine = Engine(
            cfg, self.params, batch=self._batch, max_seq=self._max_seq,
            extras=self._extras,
        )
        schedule = cfg.quant.plane_schedule
        self._step_cycles = cm.lm_step_cycles(
            cfg.d_model, cfg.d_ff, cfg.n_layers, schedule
        )
        self._step_ops = cm.lm_step_ops(cfg.d_model, cfg.d_ff, cfg.n_layers)

    # -- plan invalidation
    def verify_info(self):
        return _verify_info(self)

    def install_fallback(self, reason: str) -> None:
        """Quarantine the stale plan: rebuild on the uniform full-digit
        schedule (certified by construction — zero truncation error)."""
        import dataclasses

        self.plan = None
        self.fallback_reason = reason
        self._build(
            self._base_cfg.replace(
                quant=dataclasses.replace(
                    self._base_cfg.quant, plane_schedule=None, planes=8
                )
            )
        )

    # -- gateway protocol
    def prepare(self, payload, *, rid: int, max_new: int = 16):
        import numpy as np

        from .engine import Request

        if isinstance(payload, Request):
            return payload
        return Request(rid=rid, prompt=np.asarray(payload), max_new=max_new)

    def free_slots(self) -> int:
        return self.engine.slots.free_count()

    def estimate_cycles(self, payload) -> int:
        return (len(payload.prompt) + payload.max_new) * self._step_cycles

    def admit(self, greq: GatewayRequest) -> int:
        if not self.engine.admit(greq.payload):
            raise RuntimeError("admit called with no free LM slot")
        greq.handle = greq.payload
        self._inflight[id(greq.handle)] = greq
        n_prefill = len(greq.payload.prompt)
        self.total_ops += n_prefill * self._step_ops
        return n_prefill * self._step_cycles

    def has_work(self) -> bool:
        return self.engine.slots.any_active()

    def work(self, budget: int):
        consumed = 0
        completed: list[GatewayRequest] = []
        while consumed < budget:
            n_active = len(self.engine.slots.active())
            if n_active == 0:
                break
            finished = self.engine.step()
            consumed += self._step_cycles * n_active
            self.total_ops += self._step_ops * n_active
            completed.extend(
                self._inflight.pop(id(r))
                for r in finished
                if id(r) in self._inflight
            )
        return consumed, completed, []


class SegAdapter:
    """Tiled segmentation behind the gateway protocol.

    ``plan`` serves a tuned operating point through
    :func:`repro.autotune.api.engine_from_plan` semantics and arms the
    fingerprint check; without one the engine serves ``cfg`` as given.
    Work is the engine's own micro-batch step, charged at the summed
    relation-(2) price of the tiles it emitted; emitted
    :class:`~repro.segserve.engine.TileEvent` s pass through to the
    gateway's progressive stream.
    """

    kind = "seg"

    def __init__(self, cfg, params, *, plan=None, **engine_kw):
        self.plan = plan
        self.params = params
        self._base_cfg = cfg
        self._engine_kw = dict(engine_kw)
        self.fallback_reason: str | None = None
        self._build(cfg, plan)
        self._inflight: dict[int, GatewayRequest] = {}
        self.total_ops = 0

    def _build(self, cfg, plan) -> None:
        from repro.segserve.engine import SegEngine

        if plan is not None:
            from repro.autotune.api import apply_plan

            cfg = apply_plan(cfg, plan)
        self.cfg = cfg
        self.engine = SegEngine(cfg, self.params, plan=plan, **self._engine_kw)
        self._base_planes = tuple(self.engine._class_planes(0))

    # -- plan invalidation
    def verify_info(self):
        return _verify_info(self)

    def install_fallback(self, reason: str) -> None:
        import dataclasses

        self.plan = None
        self.fallback_reason = reason
        kw = dict(self._engine_kw)
        # the stale plan owned the tile geometry; fall back to the smallest
        # stride the halo walk certifies viable for this net
        kw.setdefault("tile", self._base_cfg.min_viable_tile())
        self._engine_kw = kw
        self._build(
            dataclasses.replace(
                self._base_cfg, plane_schedule=None, planes=8
            ),
            None,
        )

    # -- gateway protocol
    def prepare(self, payload, *, rid: int):
        import numpy as np

        return np.asarray(payload)

    def free_slots(self) -> int:
        return self.engine.slots.free_count()

    def estimate_cycles(self, payload) -> int:
        """Upper admission estimate: every tile window priced at the
        class-0 (full-budget) schedule — adaptivity only lowers it."""
        from repro.segserve import tiling

        e = self.engine
        tplan = tiling.plan_tiles(
            payload.shape[0], payload.shape[1], depth=e.cfg.depth,
            convs_per_stage=e.cfg.convs_per_stage, tile=e.tile, halo=e.halo,
        )
        return sum(
            cm.unet_window_cycles(
                spec.in_shape, e.cfg.in_ch, e.cfg.base, e.cfg.depth,
                e.cfg.convs_per_stage, self._base_planes,
            )
            for spec in tplan.tiles
        )

    def admit(self, greq: GatewayRequest) -> int:
        handle = self.engine.submit(greq.payload)
        if not self.engine.queue.pump(self.engine.slots, self.engine._admit):
            raise RuntimeError("admit called with no free seg slot")
        greq.handle = handle
        # keyed by the engine-local rid the TileEvents will carry
        self._inflight[handle.rid] = greq
        return 0  # tile planning is host work, not accelerator cycles

    def has_work(self) -> bool:
        return bool(self.engine._tasks)

    def work(self, budget: int):
        consumed = 0
        completed: list[GatewayRequest] = []
        events = []
        while consumed < budget and self.engine._tasks:
            evs = self.engine.step()
            for ev in evs:
                consumed += ev.cycles
                if ev.done:
                    greq = self._inflight.pop(ev.rid, None)
                    if greq is not None:
                        self.total_ops += ev.request.result.ops
                        completed.append(greq)
            events.extend(evs)
        return consumed, completed, events


# ---------------------------------------------------------------- gateway


class Gateway:
    """Admission-controlled front door over a set of engine adapters.

    Args:
      adapters: the served engines, e.g. ``[LMAdapter(...), SegAdapter(...)]``
        (or any object speaking the adapter protocol — tests use synthetic
        ones).  Keyed by ``adapter.kind``.
      policy: ``'fifo' | 'fair' | 'edf'`` (see module docstring).
      round_budget: modeled cycles one scheduling round may spend across
        all engines — the co-scheduling knob.
      shares: per-kind fair-share fractions (default: equal).  Must sum
        to <= 1; unallocated share is work-conserving slack.
      on_stale: ``'reject'`` (raise :class:`StalePlanError` at submission)
        or ``'fallback'`` (quarantine the plan, serve the uniform
        schedule) when a tuned plan's fingerprint mismatches the served
        params.
      deadline_factor: default EDF deadline = admission estimate x this.
      on_event: optional callback fed every streamed
        :class:`~repro.segserve.engine.TileEvent` (progressive display).
    """

    def __init__(
        self,
        adapters,
        *,
        policy: str = "fair",
        round_budget: int = 1_000_000,
        shares: dict[str, float] | None = None,
        on_stale: str = "reject",
        deadline_factor: float = 4.0,
        on_event=None,
    ):
        policy = _POLICY_ALIASES.get(policy, policy)
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
        if round_budget < 1:
            raise ValueError(f"round_budget {round_budget} < 1")
        if on_stale not in ("reject", "fallback"):
            raise ValueError(f"on_stale {on_stale!r}: 'reject' or 'fallback'")
        self.adapters: dict[str, Any] = {a.kind: a for a in adapters}
        if not self.adapters:
            raise ValueError("gateway needs at least one adapter")
        self.policy = policy
        self.round_budget = int(round_budget)
        self.on_stale = on_stale
        self.deadline_factor = float(deadline_factor)
        self.on_event = on_event
        kinds = list(self.adapters)
        if shares is None:
            shares = {k: 1.0 / len(kinds) for k in kinds}
        unknown = set(shares) - set(kinds)
        if unknown:
            raise ValueError(f"shares for unknown kinds {sorted(unknown)}")
        missing = set(kinds) - set(shares)
        if missing:
            # a silently share-less class would void the starvation-freedom
            # guarantee the fair policy exists for
            raise ValueError(
                f"explicit shares must cover every served kind; missing "
                f"{sorted(missing)}"
            )
        if any(s <= 0 for s in shares.values()) or sum(shares.values()) > 1 + 1e-9:
            raise ValueError(f"shares must be positive and sum <= 1: {shares}")
        self.shares = dict(shares)
        self.queue: FifoQueue[GatewayRequest] = FifoQueue()
        self.requests: list[GatewayRequest] = []
        self._live: dict[int, GatewayRequest] = {}  # admitted, unfinished
        # NOTE: grows for the life of the gateway (one small record per
        # emitted tile); long-running consumers should pass on_event and
        # clear this list between reporting windows.
        self.tile_events: list = []
        self.clock = 0  # modeled cycles
        self.rounds = 0
        self._deficit = {k: 0.0 for k in kinds}
        self._admit_charges = {k: 0 for k in kinds}
        self._next_rid = 0

    # ------------------------------------------------------------- submit

    def submit(self, kind: str, payload, *, deadline_cycles: int | None = None,
               **prepare_kw) -> GatewayRequest:
        """Type, verify and enqueue one request.

        Admission control starts here: the adapter's tuned plan (if any)
        is verified against its served params *before* the request may
        enter the system — a stale certificate rejects (or falls back)
        now, not after cycles were spent.
        """
        if kind not in self.adapters:
            raise ValueError(
                f"unknown request kind {kind!r}; served kinds: "
                f"{sorted(self.adapters)}"
            )
        adapter = self.adapters[kind]
        _check_plan(adapter, self.on_stale)
        rid = self._next_rid
        self._next_rid += 1
        payload = adapter.prepare(payload, rid=rid, **prepare_kw)
        est = int(adapter.estimate_cycles(payload))
        if deadline_cycles is None:
            deadline = self.clock + math.ceil(self.deadline_factor * est)
        else:
            deadline = self.clock + int(deadline_cycles)
        greq = GatewayRequest(
            rid=rid, kind=kind, payload=payload, est_cycles=est,
            deadline=deadline, arrival=self.clock,
            arrival_round=self.rounds,
        )
        self.queue.push(greq)
        self.requests.append(greq)
        return greq

    # ---------------------------------------------------------- admission

    def _try_admit(self, idx: int) -> bool:
        """Admit the ``idx``-th queued request if its engine has a slot."""
        greq = self.queue.peek(idx)
        adapter = self.adapters[greq.kind]
        if adapter.free_slots() < 1:
            return False
        self.queue.pop_at(idx)
        charged = adapter.admit(greq)
        greq.admitted = self.clock
        greq.admitted_round = self.rounds
        self._live[greq.rid] = greq
        self._admit_charges[greq.kind] += int(charged)
        return True

    def _admission_phase(self) -> None:
        if self.policy == "fifo":
            # strict arrival order: a full engine at the head blocks the
            # whole queue (the classic failure mode the other policies fix)
            while self.queue and self._try_admit(0):
                pass
        elif self.policy == "fair":
            # round-robin classes, oldest-first within a class; a blocked
            # class never blocks the others
            progress = True
            while progress and self.queue:
                progress = False
                for kind in self.adapters:
                    idx = next(
                        (i for i, g in enumerate(self.queue) if g.kind == kind),
                        None,
                    )
                    if idx is not None and self._try_admit(idx):
                        progress = True
        else:  # edf
            progress = True
            while progress and self.queue:
                progress = False
                order = sorted(
                    range(len(self.queue)),
                    key=lambda i: (
                        self.queue.peek(i).deadline,
                        self.queue.peek(i).arrival,
                    ),
                )
                for idx in order:
                    if self._try_admit(idx):
                        progress = True
                        break  # indices shifted; re-sort

    # ---------------------------------------------------------- execution

    def _class_order(self) -> list[str]:
        """Execution priority between classes for fifo/edf: the class of
        the most urgent incomplete admitted request first.  Derived from
        the gateway's own live-request table — adapters owe the protocol
        nothing about how they track in-flight work, and completed history
        is never rescanned."""
        live_by_kind: dict[str, list[GatewayRequest]] = {}
        for g in self._live.values():
            live_by_kind.setdefault(g.kind, []).append(g)

        def urgency(kind: str):
            live = live_by_kind.get(kind)
            if not live:
                return (1, 0)
            if self.policy == "edf":
                return (0, min(g.deadline for g in live))
            return (0, min(g.arrival for g in live))

        return sorted(self.adapters, key=urgency)

    def _do_work(self, kind: str, budget: float, spent_before: int):
        adapter = self.adapters[kind]
        consumed, completed, events = adapter.work(int(budget))
        stamp = self.clock + min(
            spent_before + consumed, self.round_budget
        )
        for greq in completed:
            greq.finished = stamp
            greq.finished_round = self.rounds
            self._live.pop(greq.rid, None)
            # the result lives on greq.handle; drop the input payload so a
            # long-running gateway does not pin every served image/prompt
            greq.payload = None
        for ev in events:
            self.tile_events.append(ev)
            if self.on_event is not None:
                self.on_event(ev)
        return consumed

    def _execution_phase(self) -> None:
        spent = 0
        # prefill charged at admission eats into the round before decode
        for kind, charged in self._admit_charges.items():
            spent += charged
            if self.policy == "fair":
                self._deficit[kind] -= charged
            self._admit_charges[kind] = 0

        if self.policy == "fair":
            for kind, share in self.shares.items():
                if self.adapters[kind].has_work() or self._deficit[kind] < 0:
                    self._deficit[kind] += share * self.round_budget
                else:
                    self._deficit[kind] = 0.0  # no banking while idle
            for kind in self.adapters:
                if self._deficit[kind] > 0 and self.adapters[kind].has_work():
                    used = self._do_work(kind, self._deficit[kind], spent)
                    self._deficit[kind] -= used
                    spent += used
        else:
            for kind in self._class_order():
                if spent >= self.round_budget:
                    break
                if self.adapters[kind].has_work():
                    spent += self._do_work(
                        kind, self.round_budget - spent, spent
                    )

        # work-conserving: hand leftover budget to any class with work
        guard = len(self.adapters) + 1
        while spent < self.round_budget and guard:
            guard -= 1
            busy = [k for k in self.adapters if self.adapters[k].has_work()]
            if not busy:
                break
            for kind in busy:
                if spent >= self.round_budget:
                    break
                used = self._do_work(kind, self.round_budget - spent, spent)
                if self.policy == "fair":
                    self._deficit[kind] -= used
                spent += used

    # ------------------------------------------------------------- rounds

    def pending(self) -> bool:
        return bool(self.queue) or any(
            a.has_work() for a in self.adapters.values()
        )

    def step_round(self) -> None:
        """One scheduling round: admit per policy, execute against the
        shared cycle budget, advance the modeled clock."""
        self._admission_phase()
        self._execution_phase()
        self.clock += self.round_budget
        self.rounds += 1

    def drain(self, *, max_rounds: int = 100_000) -> None:
        """Run rounds until nothing is queued or in flight."""
        while self.pending():
            if self.rounds >= max_rounds:
                raise RuntimeError(
                    f"gateway did not drain within {max_rounds} rounds "
                    f"(queue={len(self.queue)}, policy={self.policy})"
                )
            self.step_round()

    # -------------------------------------------------------------- stats

    def stats(self) -> dict:
        """Per-class modeled-latency distribution + aggregate GOPS/W."""
        import numpy as np

        per_class: dict[str, dict] = {}
        for kind in self.adapters:
            lats = [
                g.latency_ms for g in self.requests
                if g.kind == kind and g.done
            ]
            n_total = sum(1 for g in self.requests if g.kind == kind)
            per_class[kind] = dict(
                n=n_total,
                completed=len(lats),
                p50_ms=float(np.percentile(lats, 50)) if lats else None,
                p99_ms=float(np.percentile(lats, 99)) if lats else None,
                max_ms=float(max(lats)) if lats else None,
            )
        total_ops = sum(a.total_ops for a in self.adapters.values())
        elapsed_s = self.clock / cm.FREQ_HZ
        power = (
            cm.PAPER_TABLE1["proposed"]["gops"]
            / cm.PAPER_TABLE1["proposed"]["gops_w"]
        )
        gops = total_ops / elapsed_s / 1e9 if elapsed_s > 0 else 0.0
        return dict(
            policy=self.policy,
            rounds=self.rounds,
            clock_cycles=self.clock,
            per_class=per_class,
            total_ops=total_ops,
            gops=gops,
            gops_w=gops / power,
            fallbacks={
                k: a.fallback_reason
                for k, a in self.adapters.items()
                if getattr(a, "fallback_reason", None)
            },
        )
