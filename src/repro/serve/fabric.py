"""Sharded serving fabric: N gateways behind a router, one fleet ledger.

One :class:`~repro.serve.gateway.Gateway` is one modeled chip; the ROADMAP
north star (millions of users) needs a fleet.  The fabric runs N gateway
shards — each on its own :class:`~repro.serve.clock.RoundClock`, advanced
in lock-step rounds of the shared ``round_budget`` — behind an arrival
router, with optional work stealing for idle capacity and a
:class:`~repro.serve.clock.FleetLedger` accumulating per-round integer
deltas so aggregate ops/cycles equal the per-shard sums *exactly*
(MINT's compounding-error lesson, PAPERS.md).

Routers (all deterministic under a fixed ``seed``):

``'class'``
    Per-class sharding: each declared QoS class is pinned to one shard
    (sorted classes round-robin over shards).  Strongest isolation; load
    balance is whatever the class mix gives.
``'p2c'``
    Power-of-two-choices: two shards drawn from the counter-PRNG
    (:func:`repro.workload.arrivals.counter_uniform` keyed on the
    dispatch counter), the less loaded one (queue depth, then
    outstanding estimated cycles) wins.  Classic near-optimal balance
    at O(1) state.
``'deficit'``
    Deficit-aware: the shard with the least outstanding *estimated*
    cycles (admission estimates added at dispatch, drained by actual
    worked cycles each round) gets the request — balances modeled work,
    not request counts.

Work stealing moves only **queued** (never admitted) requests — admitted
work owns engine slot state that cannot migrate — from the most
backlogged shard's queue tail to an idle shard, so the donor's own FIFO
order and per-class quanta are untouched.

The fabric duck-types the surface :func:`repro.workload.replay.replay`
drives (``adapters``/``shares``/``clock``/``round_budget``/``rounds``/
``step_round``/``pending``/``stats``/``policy``), so the open-loop replay
harness serves a fabric unchanged: routing happens at arrival injection,
and each shard sees the same open-loop contract a single gateway does.

Shards are assumed homogeneous (same kinds, same pricing, same
``round_budget``) — shard 0's adapters price the routing estimates.
"""
from __future__ import annotations

from typing import Any

from repro.core import cycle_model as cm
from repro.obs.events import NULL_SINK, Event, ShardSink
from repro.workload.arrivals import counter_uniform

from .clock import FleetLedger, exact_percentile

ROUTERS = ("class", "p2c", "deficit")


class Fabric:
    """N gateway shards behind a deterministic router + fleet ledger.

    Args:
      shards: the gateway instances (homogeneous: identical kinds,
        shares and ``round_budget``; independent clocks).
      router: ``'class' | 'p2c' | 'deficit'`` (module docstring).
      seed: PRNG seed for the p2c router's counter-keyed draws.
      steal: move queued requests from backlogged to idle shards at
        round boundaries.
      steal_batch: max requests moved per thief per round.
      sink: optional telemetry sink (:mod:`repro.obs.events`).  The
        fabric emits its own routing/steal events and arms every shard
        through a :class:`~repro.obs.events.ShardSink`, so the combined
        stream carries a ``shard`` tag on every shard-side event.
        Default: the null sink (no events, no behavior change).
    """

    def __init__(self, shards, *, router: str = "p2c", seed: int = 0,
                 steal: bool = True, steal_batch: int = 4, sink=None):
        shards = list(shards)
        if not shards:
            raise ValueError("fabric needs at least one shard")
        if router not in ROUTERS:
            raise ValueError(f"unknown router {router!r}; one of {ROUTERS}")
        budgets = {g.round_budget for g in shards}
        if len(budgets) > 1:
            raise ValueError(
                f"shards must share one round_budget (lock-step rounds), "
                f"got {sorted(budgets)}"
            )
        kinds0 = set(shards[0].adapters)
        shares0 = set(shards[0].shares)
        for i, g in enumerate(shards[1:], start=1):
            if set(g.adapters) != kinds0 or set(g.shares) != shares0:
                raise ValueError(
                    f"shard {i} serves kinds {sorted(g.adapters)} / classes "
                    f"{sorted(g.shares)} but shard 0 serves "
                    f"{sorted(kinds0)} / {sorted(shares0)} — fabric shards "
                    f"must be homogeneous"
                )
        if any(g.clock != shards[0].clock for g in shards):
            raise ValueError("shards must start on equal clocks (lock-step)")
        self.shards = shards
        self.router = router
        self.seed = int(seed)
        self.steal = bool(steal)
        self.steal_batch = int(steal_batch)
        n = len(shards)
        self.ledger = FleetLedger(n)
        # per-class pinning for the 'class' router: sorted declared
        # classes round-robin over shards — deterministic by construction
        classes = sorted(shards[0].shares)
        self.class_map = {c: i % n for i, c in enumerate(classes)}
        self._dispatch_counter = 0
        self._outstanding = [0] * n  # routed-but-undrained estimated cycles
        self._prev = [g.ledger_snapshot() for g in shards]
        self.dispatched = [0] * n  # arrivals routed per shard
        # router-decision quality counters (always maintained — integer
        # bumps, independent of any armed sink, so instrumented and
        # uninstrumented runs report identical stats):
        #   decided          routing decisions with a real alternative
        #   chose_shallower  chosen queue strictly shallower than the alt
        #   tie              chosen and alternative depths equal
        #   depth_gap_sum    Σ (alt depth - chosen depth) over decisions
        self.route_quality = dict(
            decided=0, chose_shallower=0, tie=0, depth_gap_sum=0,
        )
        self.stolen = 0  # requests moved by work stealing (lifetime)
        self.stolen_from = [0] * n
        self.stolen_to = [0] * n
        self._obs = NULL_SINK
        self._obs_on = False
        self.set_sink(sink)

    # ---------------------------------------------------------- telemetry

    @property
    def sink(self):
        return self._obs

    def set_sink(self, sink) -> None:
        """Arm (or disarm, with ``None``) one telemetry sink fleet-wide:
        the fabric's own route/steal events plus every shard's stream,
        shard-tagged through :class:`~repro.obs.events.ShardSink`."""
        self._obs = NULL_SINK if sink is None else sink
        self._obs_on = bool(getattr(self._obs, "enabled", True))
        for i, g in enumerate(self.shards):
            g.set_sink(ShardSink(self._obs, i) if self._obs_on else None)

    # ------------------------------------------------- replay duck-typing

    @property
    def adapters(self) -> dict[str, Any]:
        """Served kinds (shard 0's adapters — shards are homogeneous)."""
        return self.shards[0].adapters

    @property
    def shares(self) -> dict[str, float]:
        return self.shards[0].shares

    @property
    def round_budget(self) -> int:
        return self.shards[0].round_budget

    @property
    def clock(self) -> int:
        """The lock-step fleet clock (all shards agree between rounds)."""
        return self.shards[0].clock

    @property
    def rounds(self) -> int:
        return self.shards[0].rounds

    @property
    def policy(self) -> str:
        """Descriptive label in the shape replay row names expect."""
        return (
            f"fabric{len(self.shards)}x-{self.router}"
            f"-{self.shards[0].policy}"
        )

    @property
    def requests(self) -> list:
        """All requests fleet-wide, shard-major (stolen requests appear
        under the shard that completed them)."""
        return [g for shard in self.shards for g in shard.requests]

    def pending(self) -> bool:
        return any(g.pending() for g in self.shards)

    # ------------------------------------------------------------ routing

    def _estimate(self, kind: str, payload, kw: dict):
        """Prepare once (idempotent at the shard) and price the admission
        estimate with shard 0's adapter — shards price identically."""
        adapter = self.shards[0].adapters[kind]
        prep_kw = {
            k: v for k, v in kw.items()
            if k not in ("qos", "deadline_cycles")
        }
        prepared = adapter.prepare(payload, rid=-1, **prep_kw)
        return prepared, int(adapter.estimate_cycles(prepared))

    def _route(self, qos: str, est: int) -> tuple[int, int | None]:
        """Pick the destination shard; returns ``(dst, alt)`` where
        ``alt`` is the shard the decision rejected (the p2c losing draw,
        the deficit router's most-loaded shard) — ``None`` when the
        decision had no alternative (class pinning, single shard, p2c
        drawing the same shard twice)."""
        n = len(self.shards)
        if n == 1:
            return 0, None
        if self.router == "class":
            return self.class_map[qos], None
        if self.router == "deficit":
            # least outstanding modeled work; ties to the lowest index
            dst = min(range(n), key=lambda s: (self._outstanding[s], s))
            alt = max(range(n), key=lambda s: (self._outstanding[s], -s))
            return dst, (None if alt == dst else alt)
        # p2c: two counter-keyed draws, the less loaded shard wins
        k = self._dispatch_counter
        i = int(counter_uniform(self.seed, 2 * k) * n)
        j = int(counter_uniform(self.seed, 2 * k + 1) * n)
        load = lambda s: (len(self.shards[s].queue), self._outstanding[s], s)
        dst = min(i, j, key=load)
        return dst, (None if i == j else (j if dst == i else i))

    def _record_route_quality(self, dst: int, alt: int | None) -> None:
        rq = self.route_quality
        rq["decided"] += 1
        if alt is None:  # pinned / single shard / p2c same draw:
            return       # no alternative to compare against
        dq, aq = len(self.shards[dst].queue), len(self.shards[alt].queue)
        if dq < aq:
            rq["chose_shallower"] += 1
        elif dq == aq:
            rq["tie"] += 1
        rq["depth_gap_sum"] += aq - dq

    # ------------------------------------------------------ work stealing

    def _steal_pass(self) -> None:
        """Round-boundary rebalance: an idle shard (empty queue, free
        slots) takes up to ``steal_batch`` queued requests from the most
        backlogged shard's tail.  Donor keeps at least one queued request
        (it will admit next round anyway); only never-admitted requests
        move, so donor per-class accounting is untouched."""
        n = len(self.shards)
        for t, thief in enumerate(self.shards):
            if len(thief.queue) > 0:
                continue
            free = sum(a.free_slots() for a in thief.adapters.values())
            if free < 1:
                continue
            d = max(range(n), key=lambda s: (len(self.shards[s].queue), -s))
            donor = self.shards[d]
            surplus = len(donor.queue) - 1
            take = min(self.steal_batch, free, surplus)
            if d == t or take < 1:
                continue
            src_q = len(donor.queue)  # donor depth at the decision
            moved = donor.export_queued(take)
            thief.import_queued(moved)
            est_moved = sum(g.est_cycles for g in moved)
            if self._obs_on and moved:
                # src_q/dst_q: queue depths the decision saw (thief was
                # empty by the steal precondition) — steal pressure is
                # readable off the stream without replaying state
                self._obs.emit(Event(self.clock, "steal", dict(
                    src=d, dst=t, n=len(moved), est=est_moved,
                    src_q=src_q, dst_q=0,
                )))
            self._outstanding[d] = max(self._outstanding[d] - est_moved, 0)
            self._outstanding[t] += est_moved
            self.stolen += len(moved)
            self.stolen_from[d] += len(moved)
            self.stolen_to[t] += len(moved)

    # ------------------------------------------------------------- rounds

    def step_round(self, arrivals=()) -> None:
        """One lock-step fleet round: route this round's arrivals to
        shards, rebalance idle capacity, step every shard one round, and
        post each shard's integer deltas to the fleet ledger."""
        n = len(self.shards)
        by_shard: list[list] = [[] for _ in range(n)]
        for cyc, kind, payload, kw in sorted(arrivals, key=lambda a: a[0]):
            prepared, est = self._estimate(kind, payload, kw)
            qos = kw.get("qos") or kind
            s, alt = self._route(qos, est)
            self._record_route_quality(s, alt)
            if self._obs_on:
                # chosen-vs-alternative depths make router quality
                # inspectable from the stream (p2c's classic diagnostic);
                # emitted before the counters move, at decision state
                data = dict(kind=kind, qos=qos, dst=s, est=est,
                            q=len(self.shards[s].queue))
                if alt is not None:
                    data.update(alt=alt, alt_q=len(self.shards[alt].queue))
                self._obs.emit(Event(int(cyc), "route", data))
            self._dispatch_counter += 1
            self.dispatched[s] += 1
            self._outstanding[s] += est
            by_shard[s].append((cyc, kind, prepared, kw))
        if self.steal:
            self._steal_pass()
        for s, gw in enumerate(self.shards):
            gw.step_round(arrivals=by_shard[s])
        # post per-round deltas to the fleet ledger — the incremental
        # path additivity() later verifies against the direct sums
        for s, gw in enumerate(self.shards):
            snap = gw.ledger_snapshot()
            prev = self._prev[s]
            d_class = {
                c: v - prev["class_worked"].get(c, 0)
                for c, v in snap["class_worked"].items()
                if v - prev["class_worked"].get(c, 0)
            }
            d_worked = snap["worked"] - prev["worked"]
            self.ledger.record_round(
                s,
                d_ops=snap["ops"] - prev["ops"],
                d_worked=d_worked,
                d_class_worked=d_class,
            )
            self._prev[s] = snap
            self._outstanding[s] = max(self._outstanding[s] - d_worked, 0)
        self.ledger.rounds += 1

    def advance_to(self, cycle: int) -> None:
        while self.clock < cycle:
            self.step_round()

    def drain(self, *, max_rounds: int = 100_000) -> None:
        while self.pending():
            if self.rounds >= max_rounds:
                raise RuntimeError(
                    f"fabric did not drain within {max_rounds} rounds "
                    f"(queues={[len(g.queue) for g in self.shards]})"
                )
            self.step_round()

    # -------------------------------------------------------------- stats

    def additivity(self) -> dict:
        """The fleet ledger's exact-additivity check against the shards'
        own cumulative counters (the fabric bench gates on ``holds``)."""
        return self.ledger.additivity(
            [g.ledger_snapshot()["ops"] for g in self.shards],
            [g.round_clock for g in self.shards],
        )

    def stats(self) -> dict:
        """Fleet-aggregate stats in the single-gateway ``stats()`` shape
        (plus fabric extras), so ``workload.replay.summarize`` and the
        bench tracker consume a fabric unchanged.

        GOPS/W is fleet-honest: total ops over the lock-step elapsed
        time, against N chips' worth of the paper's modeled power.
        Percentiles are exact order statistics, matching the single
        gateway's ``stats()`` semantics.
        """
        classes = list(self.shares)
        for g in self.requests:
            if g.qos not in classes:
                classes.append(g.qos)
        per_class: dict[str, dict] = {}
        for c in classes:
            of_c = [g for g in self.requests if g.qos == c]
            if not of_c and c not in self.adapters:
                continue
            lats = [g.latency_ms for g in of_c if g.done]
            p50 = exact_percentile(lats, 50)
            p99 = exact_percentile(lats, 99)
            per_class[c] = dict(
                n=len(of_c),
                completed=len(lats),
                p50_ms=None if p50 is None else float(p50),
                p99_ms=None if p99 is None else float(p99),
                max_ms=float(max(lats)) if lats else None,
                # fleet-wide: stolen requests count under the shard that
                # completed them, so the sum over shards is exact
                deadline_misses=sum(
                    1 for g in of_c if g.done and g.finished > g.deadline
                ),
            )
        add = self.additivity()
        total_ops = add["ledger_total_ops"]
        elapsed_s = max(g.clock for g in self.shards) / cm.FREQ_HZ
        chip_power = (
            cm.PAPER_TABLE1["proposed"]["gops"]
            / cm.PAPER_TABLE1["proposed"]["gops_w"]
        )
        power = chip_power * len(self.shards)
        gops = total_ops / elapsed_s / 1e9 if elapsed_s > 0 else 0.0
        out = dict(
            policy=self.policy,
            n_shards=len(self.shards),
            router=self.router,
            rounds=self.rounds,
            clock_cycles=max(g.clock for g in self.shards),
            per_class=per_class,
            total_ops=total_ops,
            gops=gops,
            gops_w=gops / power,
            forced=sum(g.forced for g in self.shards),
            worked_cycles=add["ledger_total_worked"],
            additivity=add,
            dispatched=list(self.dispatched),
            router_stats=dict(router=self.router, **self.route_quality),
            stolen=self.stolen,
            stolen_from=list(self.stolen_from),
            stolen_to=list(self.stolen_to),
            # fleet totals are the exact sums of the per_shard addends
            # below — same additivity discipline the ledger is gated on
            tile_events_seen=sum(
                g._tile_events_seen for g in self.shards
            ),
            tile_events_kept=sum(
                len(g.tile_events) for g in self.shards
            ),
            tile_events_dropped=sum(
                g._tile_events_seen - len(g.tile_events)
                for g in self.shards
            ),
            per_shard=[
                dict(
                    rounds=g.rounds,
                    clock_cycles=g.clock,
                    queue=len(g.queue),
                    ops=self.ledger.ops[s],
                    worked=self.ledger.worked[s],
                    forced=g.forced,
                    tile_events_seen=g._tile_events_seen,
                    tile_events_kept=len(g.tile_events),
                    tile_events_dropped=g._tile_events_seen
                    - len(g.tile_events),
                )
                for s, g in enumerate(self.shards)
            ],
        )
        # an armed SloMonitor surfaces fleet-aggregated burn rates +
        # miss attribution (per-shard scopes via monitor.summary(shard))
        from repro.obs.slo import FLEET, find_monitor

        mon, _ = find_monitor(self._obs)
        if mon is not None:
            out["slo"] = mon.summary(scope=FLEET)
        # an armed EnergyMeter surfaces the fleet joule ledger; metered
        # GOPS/W divides fleet ops by fleet metered energy — the
        # per-shard scopes stay queryable via meter.summary(shard)
        from repro.core import energy_model as em
        from repro.obs.energy import find_meter

        meter, _ = find_meter(self._obs)
        if meter is not None:
            eb = meter.summary(scope=FLEET)
            eb["metered_gops_w"] = em.metered_gops_per_w(
                total_ops, eb["total_pj"]
            )
            eb["analytic_gops_w"] = out["gops_w"]
            out["energy"] = eb
        return out
