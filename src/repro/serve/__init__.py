"""Serving front door.

:class:`~repro.serve.gateway.Gateway` is the deployment entry point: one
admission-controlled queue fronting both engines — LM decode
(``serve.engine.Engine``) and tiled segmentation
(``repro.segserve.engine.SegEngine``) — co-scheduled against a shared
modeled cycle budget under a pluggable policy (FIFO / cycle-budget
fair-share / EDF), with tuned-plan fingerprint verification at admission
and progressive tile streaming.  The engines and the shared queue/slot
primitives stay importable directly for single-workload use.  Heavy
engine imports (jax, models) are deferred until an adapter is built;
``SegEngine`` re-exports lazily so importing one workload never pays for
the other.

:class:`~repro.serve.fabric.Fabric` scales the gateway out: N shards on
independent :class:`~repro.serve.clock.RoundClock` instances behind a
deterministic router, with work stealing and a
:class:`~repro.serve.clock.FleetLedger` whose aggregates are exact to
the integer.  :mod:`repro.serve.modeled` provides pricing-only adapters
so fabric-scale benchmarks never build a jax engine.
"""
from . import clock, engine, fabric, gateway, modeled, queue, serve_step  # noqa: F401
from .clock import FleetLedger, RoundClock  # noqa: F401
from .fabric import Fabric  # noqa: F401
from .gateway import (  # noqa: F401
    Gateway,
    GatewayRequest,
    LMAdapter,
    SegAdapter,
    StalePlanError,
)
from .modeled import ModeledLMAdapter, ModeledSegAdapter, modeled_materializer  # noqa: F401
from .queue import FifoQueue, SlotTable  # noqa: F401
from . import specdecode  # noqa: F401
from .specdecode import SpecEngine, SpecLMAdapter  # noqa: F401


def __getattr__(name):
    if name == "SegEngine":
        from repro.segserve.engine import SegEngine

        return SegEngine
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
