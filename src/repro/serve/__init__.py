from . import engine, serve_step  # noqa: F401
