"""Serving front door: shared queue/slot primitives plus the two engines —
LM decode (``serve.engine.Engine``) and tiled segmentation
(``repro.segserve.engine.SegEngine``, re-exported lazily as ``SegEngine``
so importing one workload never pays for the other)."""
from . import engine, queue, serve_step  # noqa: F401
from .queue import FifoQueue, SlotTable  # noqa: F401


def __getattr__(name):
    if name == "SegEngine":
        from repro.segserve.engine import SegEngine

        return SegEngine
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
