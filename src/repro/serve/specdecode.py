"""Precision-speculative decoding: truncated-plane drafts verified by the
full-digit datapath.

MSDF early termination makes truncated-plane compute a *cheap exact
prefix* of full-precision compute: ``core.bitplane.truncate_to_planes``
masks the int8 weight planes below the budget, so a low-plane "draft"
forward shares weights, KV cache and kernels with the full-digit
"verifier" — no second model, no second cache.  This module lifts that
identity from per-layer dynamic precision (MINT-style, PR 1) to
*per-token* speculation, the ROADMAP's named next engine mode:

1. **Draft** — decode ``k`` tokens greedily under the draft plane
   schedule (one low-plane step per token; the chain serializes on the
   argmax feedback).  Draft KV rows land in the shared cache at the
   slot's own positions.
2. **Verify** — roll the per-slot cache index back to the round's base
   length and run the ``k+1`` now-known tokens through the *full-digit*
   schedule.  The verify pass overwrites every draft KV row with its
   full-precision value, so the surviving cache state is bit-identical
   to a greedy run's.  Because the verify tokens carry no feedback
   dependency, consecutive positions pipeline through the layer stack —
   :func:`repro.core.cycle_model.lm_spec_step_cycles` prices the pass at
   one full step plus ``k`` initiation intervals, not ``k+1`` full steps.
3. **Accept** — take the longest prefix of drafts matching the
   verifier's greedy choices, emit those tokens plus the verifier's one
   correction, and roll the cache index back past the first mismatch
   (stale rows above it are overwritten before any read — the same
   vector-index invariant that makes class-scoped decode safe).

Greedy equivalence is exact, not approximate: the verify pass runs the
*same jitted executable* (``engine.shared_decode``) on the same tokens
at the same positions as a non-speculative engine would, and the
accepted state (``_last_logits``, cache rows, lengths) equals the state
after ``emitted`` greedy steps by induction.  The property suite pins
token-identity across seeds and draft schedules; the bench gates it.

Both passes must run the digit-serial datapath (``quant.mode =
'mma_int8'``): integer matmul accumulation is associative, so outputs
are bit-stable across runs and batch compositions — the float path's
last-ulp reduction jitter (see ``benchmarks/gateway.py``) would make
"exact prefix" a coin flip near tied logits.

:class:`SpecLMAdapter` exposes the engine behind the gateway (adapter
protocol v2): drafting is *chunked* — a speculative round's cost is
deterministic before it starts (draft + verify price is independent of
how many drafts survive), so the adapter yields at quantum boundaries
exactly like the base decode loop and never overdrafts.  QoS classes,
admission, plan verification and hot swap are inherited unchanged.  Only
emitted tokens earn op credit; every draft/verify cycle counts toward
time, so GOPS/W degrades honestly with the miss rate.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import cycle_model as cm
from repro.core.bitplane import N_BITS
from repro.obs.events import Event

from .engine import Engine, Request, shared_decode
from .gateway import LMAdapter


class SpecEngine(Engine):
    """Continuous-batching engine whose decode loop speculates.

    ``draft_schedule`` is the per-layer plane budget of the draft pass
    (``k`` tokens per round); verification always runs the engine's own
    (full) schedule.  Requires a vector-index family (dense/moe/vlm) —
    the rollback step is a per-slot cache-index rewind, which only means
    anything when slots own their position tracks — and the digit-serial
    datapath (see module docstring).
    """

    def __init__(self, cfg, params, *, batch: int, max_seq: int,
                 draft_schedule, k: int, extras=None):
        super().__init__(cfg, params, batch=batch, max_seq=max_seq,
                         extras=extras)
        if not self._vector_index:
            raise ValueError(
                f"speculative decode needs a per-slot cache-index family "
                f"(dense/moe/vlm); {cfg.family!r} has no position-addressed "
                f"state to roll back"
            )
        if cfg.quant.mode != "mma_int8":
            raise ValueError(
                "speculative decode needs the digit-serial datapath "
                "(quant.mode='mma_int8'): the draft is a bit-mask prefix "
                "of the full-digit compute, and integer accumulation is "
                "what makes acceptance bit-stable"
            )
        if int(k) < 1:
            raise ValueError(f"speculation depth k {k} < 1")
        sched = tuple(int(p) for p in draft_schedule)
        if len(sched) != cfg.n_layers:
            raise ValueError(
                f"draft schedule covers {len(sched)} layers, cfg has "
                f"{cfg.n_layers}"
            )
        for p in sched:
            if not (1 <= p <= N_BITS):
                raise ValueError(f"draft plane count {p} outside 1..{N_BITS}")
        self.k = int(k)
        self.draft_schedule = sched
        self._draft_cfg = cfg.replace(
            quant=dataclasses.replace(cfg.quant, plane_schedule=sched)
        )
        # same lru-cached jit family as the verifier — the draft shares
        # weights, cache layout and kernels, differing only in how many
        # MSB planes the matmuls consume
        self.draft_fn = shared_decode(self._draft_cfg, batch, max_seq)
        # one record per speculative round (k, per-slot accepted/emitted);
        # the adapter drains it for pricing + obs, standalone callers
        # (tune_spec, tests) read it directly
        self.spec_trace: list[dict] = []

    # ------------------------------------------------------------ planning

    def plan_k(self, only: set[int] | None = None) -> int:
        """The speculation depth the next :meth:`spec_step` will use for
        this slot set — deterministic *before* stepping, so the adapter
        can price the round against its quantum first.  0 means the round
        degenerates to one greedy step (no headroom to speculate)."""
        active = self.ready_slots()
        if only is not None:
            active = [(i, r) for i, r in active if i in only]
        if not active:
            return 0
        # every slot needs room for k drafts + 1 correction before the
        # sequence cap; drafting past the neediest slot's remaining
        # max_new is pure waste, so cap there too
        headroom = min(
            self.max_seq - 1 - int(self.lengths[i]) for i, _ in active
        ) - 1
        need = max(r.max_new - len(r.out) for _, r in active) - 1
        return max(min(self.k, headroom, need), 0)

    # ------------------------------------------------------------- decode

    def spec_step(self, only: set[int] | None = None):
        """One speculative decode round for all ready slots (``only``
        scopes like :meth:`Engine.step`).  Returns ``(completed, record)``
        where ``record`` is the round's spec-trace entry — ``None`` when
        the round fell back to a plain greedy step (no speculation
        headroom)."""
        active = self.ready_slots()
        if only is not None:
            active = [(i, r) for i, r in active if i in only]
        if not active:
            return [], None
        k = self.plan_k(only)
        if k < 1:
            return super().step(only), None
        base = {i: int(self.lengths[i]) for i, _ in active}

        # 1. draft chain: k truncated-plane steps with greedy feedback
        feed = {
            i: int(np.argmax(getattr(r, "_last_logits"))) for i, r in active
        }
        drafts: dict[int, list[int]] = {i: [] for i, _ in active}
        for _ in range(k):
            toks = np.zeros((self.batch, 1), np.int32)
            for i, _ in active:
                toks[i, 0] = feed[i]
            dlogits, self.cache = self.draft_fn(
                self.params, jnp.asarray(toks), self.cache,
                jnp.asarray(self.lengths), self.extras,
            )
            for i, _ in active:
                y = int(np.argmax(np.asarray(dlogits[i, -1])))
                drafts[i].append(y)
                feed[i] = y
                self.lengths[i] += 1

        # 2. rewind to base: draft KV rows stay in the cache but above
        # the index — the verify pass overwrites each with its
        # full-precision value before anything reads it
        for i, _ in active:
            self.lengths[i] = base[i]

        # 3. verify: k+1 known tokens through the full-digit schedule.
        # No argmax feedback — the token stream is fixed — which is what
        # lets lm_spec_step_cycles price the pass layer-pipelined.
        vlogits: dict[int, list[np.ndarray]] = {i: [] for i, _ in active}
        for t in range(k + 1):
            toks = np.zeros((self.batch, 1), np.int32)
            for i, r in active:
                if t == 0:
                    toks[i, 0] = int(np.argmax(getattr(r, "_last_logits")))
                else:
                    toks[i, 0] = drafts[i][t - 1]
            logits, self.cache = self.decode_fn(
                self.params, jnp.asarray(toks), self.cache,
                jnp.asarray(self.lengths), self.extras,
            )
            for i, _ in active:
                vlogits[i].append(np.asarray(logits[i, -1]))
            for i, _ in active:
                self.lengths[i] += 1

        # 4. accept longest matching prefix; roll back past the mismatch
        completed: list[Request] = []
        per_slot: list[dict] = []
        for i, req in active:
            v = vlogits[i]
            a = 0
            while a < k and int(np.argmax(v[a])) == drafts[i][a]:
                a += 1
            emit = [int(np.argmax(v[t])) for t in range(a + 1)]
            emit = emit[: req.max_new - len(req.out)]
            n = len(emit)  # >= 1: active implies max_new not yet reached
            req.out.extend(emit)
            req._last_logits = v[n - 1]
            self.lengths[i] = base[i] + n  # the rollback
            per_slot.append(dict(
                slot=int(i), rid=req.rid, accepted=int(a), emitted=int(n),
            ))
            if len(req.out) >= req.max_new or \
                    self.lengths[i] >= self.max_seq - 1:
                req.done = True
                self.slots.release(i)
                completed.append(req)
        record = dict(
            k=int(k),
            slots=per_slot,
            drafted=k * len(active),
            accepted=sum(s["accepted"] for s in per_slot),
            emitted=sum(s["emitted"] for s in per_slot),
        )
        self.spec_trace.append(record)
        if self.obs.enabled:
            self._obs_seq += 1
            self.obs.emit(Event(self._obs_seq, "lm-spec", dict(
                slots=len(active), k=int(k),
                accepted=record["accepted"], emitted=record["emitted"],
                completed=len(completed),
            )))
        return completed, record


class SpecLMAdapter(LMAdapter):
    """Gateway adapter serving :class:`SpecEngine` — the speculative
    engine mode.

    Draft knobs come either directly (``draft_schedule``, ``k``) or from
    a v3 :class:`~repro.autotune.plan.TunedPlan` carrying ``spec_planes``
    / ``spec_k`` (the :func:`repro.autotune.api.tune_spec` output);
    explicit arguments win.  Everything else — admission, chunked
    prefill, QoS scoping, plan fingerprint verification, hot swap — is
    the base LM adapter, unchanged.  Each speculative round is priced
    with :func:`repro.core.cycle_model.lm_spec_step_cycles` *before* it
    runs (the cost is independent of acceptance), so the preemptive
    never-overdraft invariant holds with no special cases.
    """

    def __init__(self, cfg, params, *, batch: int, max_seq: int,
                 plan=None, extras=None, preemptive: bool = True,
                 draft_schedule=None, k: int | None = None):
        if plan is not None and getattr(plan, "spec_planes", None):
            if draft_schedule is None:
                draft_schedule = plan.spec_planes
            if k is None:
                k = plan.spec_k
        if draft_schedule is None or k is None:
            raise ValueError(
                "SpecLMAdapter needs draft_schedule and k — pass them "
                "directly or via a TunedPlan with spec_planes/spec_k "
                "(autotune.tune_spec)"
            )
        self._draft_schedule = tuple(int(p) for p in draft_schedule)
        self._spec_k = int(k)
        # lifecycle annotations (draft/verify/accept/rollback) the
        # gateway drains into cycle-stamped events next to exec
        self.obs_log: list[tuple] = []
        super().__init__(cfg, params, batch=batch, max_seq=max_seq,
                         plan=plan, extras=extras, preemptive=preemptive)

    def _make_engine(self, cfg):
        return SpecEngine(
            cfg, self.params, batch=self._batch, max_seq=self._max_seq,
            extras=self._extras, draft_schedule=self._draft_schedule,
            k=self._spec_k,
        )

    def _build(self, cfg) -> None:
        super()._build(cfg)
        kw = self._price_kw
        self._draft_step_cycles = cm.lm_step_cycles(
            cfg.d_model, cfg.d_ff, cfg.n_layers, self._draft_schedule, **kw
        )
        self._interval_cycles = max(cm.lm_layer_cycles(
            cfg.d_model, cfg.d_ff, cfg.n_layers,
            cfg.quant.plane_schedule, **kw
        ))

    def _spec_slot_cycles(self, k: int) -> int:
        """Per-slot price of one speculative round at depth ``k`` —
        fixed before the round runs, regardless of acceptance."""
        if k < 1:
            return self._step_cycles
        return (k * self._draft_step_cycles + self._step_cycles
                + k * self._interval_cycles)

    def _work_decode(self, budget: int, consumed: int, qos, force: bool,
                     soft_limit, completed) -> int:
        scoped = self.preemptive  # SpecEngine is always vector-index
        while True:
            slots = self._ready_slots(qos)
            if not slots:
                break
            decoding = slots if scoped else self.engine.ready_slots()
            only = {i for i, _ in decoding}
            k = self.engine.plan_k(only)
            per_slot = self._spec_slot_cycles(k)
            cost = per_slot * len(decoding)
            if self.preemptive:
                over_hard = consumed + cost > budget
                at_soft = soft_limit is not None and consumed >= soft_limit
                if (over_hard or at_soft) and not (force and consumed == 0):
                    break
            elif consumed >= budget:
                break
            force = False
            start = consumed
            finished, rec = self.engine.spec_step(
                only=only if scoped else None
            )
            consumed += cost
            if rec is None:
                # greedy fallback round: base-path semantics and credit
                emitted = len(decoding)
            else:
                emitted = rec["emitted"]
                slot_req = {i: r for i, r in decoding}
            # op credit for emitted tokens only; the full round price
            # (draft + verify, wasted speculation included) counts
            # toward time — GOPS/W stays honest
            self.total_ops += self._step_ops * emitted
            if self.obs_enabled:
                for _, r in decoding:
                    g2 = self._inflight.get(id(r))
                    if g2 is not None:
                        self.exec_log.append(
                            (g2.rid, g2.qos, per_slot, consumed)
                        )
                if rec is not None:
                    n = len(decoding)
                    draft_off = start + rec["k"] * \
                        self._draft_step_cycles * n
                    self.obs_log.append(("draft", dict(
                        k=rec["k"], slots=n,
                        cycles=rec["k"] * self._draft_step_cycles * n,
                    ), draft_off))
                    self.obs_log.append(("verify", dict(
                        tokens=rec["k"] + 1, slots=n,
                        cycles=cost - (draft_off - start),
                    ), consumed))
                    for s in rec["slots"]:
                        g2 = self._inflight.get(id(slot_req[s["slot"]]))
                        if g2 is None:
                            continue
                        # per-slot cycle split by op class: the energy
                        # meter re-derives the round-level draft/verify
                        # totals from these (two independent event
                        # paths, gated equal) and prices the wasted
                        # share — (k-a) draft steps at the draft-plane
                        # rate, (k-a) pipeline intervals at full digits
                        rej = rec["k"] - s["accepted"]
                        self.obs_log.append(("accept", dict(
                            rid=g2.rid, qos=g2.qos, k=rec["k"],
                            accepted=s["accepted"], emitted=s["emitted"],
                            draft_cycles=rec["k"]
                            * self._draft_step_cycles,
                            verify_cycles=self._step_cycles
                            + rec["k"] * self._interval_cycles,
                            wasted_draft_cycles=rej
                            * self._draft_step_cycles,
                            wasted_verify_cycles=rej
                            * self._interval_cycles,
                        ), consumed))
                        if s["accepted"] < rec["k"]:
                            self.obs_log.append(("rollback", dict(
                                rid=g2.rid, qos=g2.qos,
                                rejected=rec["k"] - s["accepted"],
                            ), consumed))
            completed.extend(
                (self._inflight.pop(id(r)), consumed)
                for r in finished
                if id(r) in self._inflight
            )
        return consumed
