"""Shared cycle-clock + per-round ledger primitives.

Every scheduler in this repo advances the same currency — relation-(2)
modeled cycles (``core.cycle_model``) — in discrete rounds.  PR 4–6 grew
three consumers of that bookkeeping: the single :class:`~repro.serve.
gateway.Gateway` (one modeled chip), each shard of the
:class:`~repro.serve.fabric.Fabric` (N chips on independent clocks), and
the fleet ledger that must aggregate them *exactly*.  This module is the
extracted primitive all of them consume, so the accounting is written
once and a fabric of N gateways cannot drift from N copies of the single
gateway's arithmetic.

Two layers:

:class:`RoundClock`
    One scheduler's modeled clock and per-round work ledger: the absolute
    cycle counter, the round counter, intra-round spent/worked split
    (*spent* includes admission charges and idle flow to segment
    boundaries; *worked* is cycles actually consumed by micro-steps), the
    per-class worked account the fair policy's starvation escape watches,
    and cumulative totals the fleet ledger aggregates.  All integers, no
    floats — exactness is the point.

:class:`FleetLedger`
    Cross-shard aggregate accounting, accumulated **incrementally** from
    per-round deltas rather than recomputed from totals.  MINT's lesson
    (PAPERS.md) is that per-unit accounting errors compound silently when
    parallel instances are summed after the fact; here the incremental
    path and the direct sum are both kept, and
    :meth:`FleetLedger.additivity` verifies they agree to the integer —
    the fabric bench gates on it.
"""
from __future__ import annotations

import math


def exact_percentile(values, pct: float):
    """Exact order statistic: the smallest observed value with at least
    ``ceil(pct/100 * n)`` observations at or below it.

    The stack's single percentile semantics (gateway ``stats()``, fabric
    ``stats()``, replay summaries, span breakdowns): a p99 is always an
    *actual observed latency* — never ``np.percentile``'s interpolation
    between two observations, which on the small per-class samples the
    bench gates compare can manufacture values nobody experienced.
    Returns ``None`` on an empty sample.
    """
    vals = sorted(values)
    if not vals:
        return None
    k = math.ceil(pct / 100.0 * len(vals))
    return vals[min(max(k, 1), len(vals)) - 1]


class RoundClock:
    """Modeled cycle clock + per-round ledger for one scheduler.

    Lifecycle per scheduling round::

        clk.begin_round()
        clk.record_spent(charge)          # admission charges (atomic mode)
        clk.record_work(consumed, qos)    # each micro-step batch
        clk.idle_to(limit)                # time flows to a segment boundary
        clk.end_round(round_budget)       # clock advances one round

    ``cycles`` is the *round-start* absolute clock while a round is in
    flight (``end_round`` advances it), matching the gateway's historical
    ``Gateway.clock`` semantics exactly.
    """

    __slots__ = (
        "cycles", "rounds", "forced",
        "worked_total", "class_worked_total",
        "round_spent", "round_worked", "round_class_worked",
        "obs",
    )

    def __init__(self) -> None:
        self.cycles = 0  # absolute modeled clock (round start)
        self.rounds = 0
        self.forced = 0  # forced-progress overdraft steps (liveness)
        self.worked_total = 0  # cumulative cycles consumed by micro-steps
        self.class_worked_total: dict[str, int] = {}
        self.round_spent = 0  # intra-round modeled time (work + idle)
        self.round_worked = 0  # cycles actually consumed this round
        self.round_class_worked: dict[str, int] = {}
        # optional telemetry sink (repro.obs.events); None keeps this
        # module dependency-free and the hot path a single None check
        self.obs = None

    # ------------------------------------------------------------- rounds

    def begin_round(self) -> None:
        self.round_spent = 0
        self.round_worked = 0
        self.round_class_worked = {}

    def record_spent(self, cycles: int) -> None:
        """Charge intra-round modeled time that is *not* micro-step work
        (atomic-mode admission charges): it eats the round but does not
        count as class progress for the starvation escape."""
        self.round_spent += int(cycles)

    def record_work(self, consumed: int, qos: str | None = None) -> None:
        """Charge ``consumed`` cycles of real micro-step work, attributed
        to scheduling class ``qos`` when given."""
        consumed = int(consumed)
        self.round_spent += consumed
        self.round_worked += consumed
        self.worked_total += consumed
        if qos is not None:
            self.round_class_worked[qos] = (
                self.round_class_worked.get(qos, 0) + consumed
            )
            self.class_worked_total[qos] = (
                self.class_worked_total.get(qos, 0) + consumed
            )

    def idle_to(self, limit: int) -> None:
        """Modeled time flows to an intra-round boundary: capacity nobody
        could use is spent as idle, never banked."""
        self.round_spent = max(self.round_spent, int(limit))

    def end_round(self, round_budget: int) -> None:
        if self.obs is not None:
            from repro.obs.events import Event

            self.obs.emit(Event(
                self.cycles + int(round_budget), "round",
                dict(round=self.rounds, spent=self.round_spent,
                     worked=self.round_worked),
            ))
        self.cycles += int(round_budget)
        self.rounds += 1

    # -------------------------------------------------------------- views

    def snapshot(self) -> dict:
        """The cumulative counters a fleet ledger aggregates."""
        return dict(
            cycles=self.cycles,
            rounds=self.rounds,
            forced=self.forced,
            worked_total=self.worked_total,
            class_worked_total=dict(self.class_worked_total),
        )


class FleetLedger:
    """Exact aggregate accounting over N shard clocks.

    The fabric calls :meth:`record_round` once per shard per fabric round
    with that round's integer deltas (ops emitted, cycles worked, per-
    class worked).  Totals are therefore accumulated along the same path
    the work happened on; :meth:`additivity` re-derives the same totals
    directly from the shards' own cumulative counters and reports whether
    the two agree exactly — the compounding-error gate.
    """

    def __init__(self, n_shards: int) -> None:
        if n_shards < 1:
            raise ValueError(f"n_shards {n_shards} < 1")
        self.n_shards = int(n_shards)
        self.ops = [0] * self.n_shards  # accumulated per-round ops deltas
        self.worked = [0] * self.n_shards  # accumulated worked-cycle deltas
        self.class_worked: list[dict[str, int]] = [
            {} for _ in range(self.n_shards)
        ]
        self.rounds = 0

    def record_round(self, shard: int, *, d_ops: int, d_worked: int,
                     d_class_worked: dict[str, int] | None = None) -> None:
        if d_ops < 0 or d_worked < 0:
            raise ValueError(
                f"negative per-round delta on shard {shard}: "
                f"ops={d_ops} worked={d_worked}"
            )
        self.ops[shard] += int(d_ops)
        self.worked[shard] += int(d_worked)
        if d_class_worked:
            cw = self.class_worked[shard]
            for c, d in d_class_worked.items():
                cw[c] = cw.get(c, 0) + int(d)

    @property
    def total_ops(self) -> int:
        return sum(self.ops)

    @property
    def total_worked(self) -> int:
        return sum(self.worked)

    def additivity(self, shard_ops, shard_clocks) -> dict:
        """Verify the incrementally-accumulated aggregates equal the
        direct per-shard sums *exactly* (integer equality, no tolerance).

        ``shard_ops`` is each shard's own cumulative useful-op counter;
        ``shard_clocks`` its :class:`RoundClock`.  Returns the comparison
        (both sides of each total) with ``holds`` — the fabric bench and
        the property tests gate on it.
        """
        direct_ops = [int(o) for o in shard_ops]
        direct_worked = [c.worked_total for c in shard_clocks]
        holds = (
            self.ops == direct_ops
            and self.worked == direct_worked
            and all(
                self.class_worked[s] == shard_clocks[s].class_worked_total
                for s in range(self.n_shards)
            )
        )
        return dict(
            holds=bool(holds),
            ledger_total_ops=self.total_ops,
            direct_total_ops=sum(direct_ops),
            ledger_total_worked=self.total_worked,
            direct_total_worked=sum(direct_worked),
            per_shard_ops=list(self.ops),
            per_shard_worked=list(self.worked),
        )
