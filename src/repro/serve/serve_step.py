"""Serving steps: prefill (prompt -> cache) and decode (one token, cache of
seq_len) for every family.  The decode step is what the assigned
``decode_32k`` / ``long_500k`` cells lower: one new token against a KV cache
(attention archs) or an O(1) recurrent state (ssm/hybrid archs).

The MMA quantized datapath (cfg.quant.mode='mma_int8') applies here — this
is where the paper's early-termination knob (quant.planes) meets LM serving.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro import models


def make_prefill(cfg):
    mod = models.build(cfg)

    def prefill(params, tokens, extras):
        if cfg.family in ("dense", "moe", "vlm"):
            logits = mod.forward(
                params, tokens, cfg, prefix_embeds=extras.get("patches")
            )
            return logits
        if cfg.family == "encdec":
            memory = mod.encode(params, extras["frames"], cfg)
            return mod.decode(params, tokens, memory, cfg)
        if cfg.family in ("hybrid", "ssm"):
            return mod.forward(params, tokens, cfg)
        raise ValueError(cfg.family)

    return prefill


def make_decode(cfg, batch: int, max_seq: int):
    """Returns (decode_fn, abstract_cache).  decode_fn(params, tokens, cache,
    index, extras) -> (logits, new_cache)."""
    mod = models.build(cfg)

    cache_dtype = jnp.int8 if cfg.quant.kv_int8 else jnp.bfloat16
    if cfg.family in ("dense", "moe", "vlm"):
        ab_cache = jax.eval_shape(
            lambda: mod.init_cache(cfg, batch, max_seq, dtype=cache_dtype))

        def decode(params, tokens, cache, index, extras):
            return mod.decode_step(params, tokens, cache, index, cfg)

    elif cfg.family == "encdec":
        ab_cache = jax.eval_shape(
            lambda: mod.init_cache(cfg, batch, max_seq, dtype=cache_dtype))

        def decode(params, tokens, cache, index, extras):
            return mod.decode_step(
                params, tokens, cache, index, cfg, memory=extras["memory"],
                cross_kv=extras.get("cross_kv"),
            )

    elif cfg.family == "hybrid":
        ab_cache = jax.eval_shape(lambda: mod.init_state(cfg, batch, max_seq))

        def decode(params, tokens, cache, index, extras):
            return mod.decode_step(params, tokens, cache, index, cfg)

    elif cfg.family == "ssm":
        ab_cache = jax.eval_shape(lambda: mod.init_state(cfg, batch))

        def decode(params, tokens, cache, index, extras):
            return mod.decode_step(params, tokens, cache, index, cfg)

    else:
        raise ValueError(cfg.family)

    return decode, ab_cache


def cache_shardings(abstract_cache, cfg, mesh, batch: int, max_seq: int = 0):
    """Shard caches.  Attention KV caches (identified by a ``max_seq``-sized
    dim) shard batch over ('pod','data') and the *sequence* dim over 'model'
    — decode attention then runs as partial-softmax per seq shard with an
    O(B*H*d) psum, instead of all-gathering the cache (matches the 'kv_seq'
    constraint in layers.attention).  Recurrent states (ssm/rwkv/conv) shard
    batch over dp and their last |model|-divisible dim over 'model'."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    dpa = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dpsize = 1
    for a in dpa:
        dpsize *= mesh.shape[a]
    msize = mesh.shape.get("model", 1)

    def one(sds):
        shape = sds.shape
        axes: list = [None] * len(shape)
        bdim = -1
        if batch > 1 and batch % dpsize == 0:
            for i, d in enumerate(shape):
                if d == batch:
                    axes[i] = dpa if len(dpa) > 1 else dpa[0]
                    bdim = i
                    break
        sdim = -1
        if max_seq:
            for i in range(bdim + 1, len(shape)):
                if shape[i] == max_seq and shape[i] % msize == 0:
                    axes[i] = "model"
                    sdim = i
                    break
        if sdim < 0:
            for i in range(len(shape) - 1, bdim, -1):
                if axes[i] is None and shape[i] % msize == 0 and shape[i] >= msize:
                    axes[i] = "model"
                    break
        return NamedSharding(mesh, P(*axes))

    return jax.tree.map(one, abstract_cache)
