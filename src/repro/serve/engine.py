"""Batched serving engine: continuous batching over a slot table.

Requests enter a queue; the engine packs up to ``batch`` active slots,
prefills new prompts into their cache rows, then decodes one token per step
for every active slot (the classic continuous-batching loop).  Slots free as
sequences hit EOS/max length and are refilled from the queue — the serving
counterpart of the trainer.

The engine is family-agnostic: it drives the (prefill, decode) pair from
``serve_step.make_*`` so dense KV-cache archs and O(1)-state ssm archs serve
through the same loop.  With cfg.quant.mode='mma_int8' the whole decode path
runs the paper's digit-serial datapath.  Precision is governed by a
*per-layer* :class:`~repro.core.plane_schedule.PlaneSchedule`
(``cfg.quant.plane_schedule``, built from the served weights via
:func:`lm_schedule_from_params`) rather than one global ``planes`` knob:
layers whose weight dynamic range tolerates it consume fewer MSB digits,
trading bounded accuracy loss for serving energy (MINT-style dynamic
precision).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.events import NULL_SINK, Event

from . import serve_step as ss
from .queue import FifoQueue, SlotTable


@functools.lru_cache(maxsize=32)
def shared_decode(cfg, batch: int, max_seq: int):
    """Process-wide jitted decode step, shared by every Engine at the same
    (cfg, batch, max_seq) signature — the LM counterpart of segserve's
    ``_shared_forward``.  One compilation serves repeated engine builds
    (the gateway bench constructs an engine per policy/mode run), and —
    load-bearing for the preemption bench's bit-identity gate — two
    engines compared against each other run the *same executable*:
    separately jitted closures can compile to instruction orders that
    differ in last-ulp float reduction behavior, which greedy argmax over
    near-tied logits amplifies into different tokens."""
    fn, _ = ss.make_decode(cfg, batch, max_seq)
    return jax.jit(fn)


def lm_schedule_from_params(params, cfg, target_rel_err: float):
    """Per-layer plane budgets for a scan-rolled LM from its actual weights.

    Uses each layer's FFN up-projection (the widest, most truncation-
    sensitive matmul of a block) as the representative weight: quantize it
    per-channel int8 and pick the fewest planes whose analytic worst-case
    relative error (``core.early_term``) meets ``target_rel_err``.  Install
    the result with ``cfg.replace(quant=dataclasses.replace(cfg.quant,
    plane_schedule=tuple(sched)))``.
    """
    from repro import models
    from repro.core import quant
    from repro.core.plane_schedule import PlaneSchedule

    if cfg.family not in models.PLANE_SCHEDULE_FAMILIES:
        raise NotImplementedError(
            f"per-layer plane schedules need a transformer block stack "
            f"({models.PLANE_SCHEDULE_FAMILIES}); {cfg.family!r} archs "
            f"serve with the global quant.planes knob"
        )
    blocks = params["blocks"]
    if "mlp" in blocks:
        ws = blocks["mlp"]["w_up"]["w"]  # (L, d_model, d_ff), stacked
    else:  # MoE blocks: fall back to the attention query projection
        ws = blocks["attn"]["wq"]["w"]
    wq = [
        quant.quantize_weights(ws[l].astype(jnp.float32), channel_axis=-1).values
        for l in range(cfg.n_layers)
    ]
    return PlaneSchedule.from_weights(wq, target_rel_err)


def lm_schedule_from_plan(plan, cfg):
    """The serving-time half of the autotuner: a *certified*
    :class:`~repro.autotune.plan.TunedPlan` (from
    :func:`repro.autotune.tune_lm`, which seeds from
    :func:`lm_schedule_from_params` and then measures-and-repairs on a
    calibration token batch) turned back into the per-layer policy the
    engine installs.  Prefer this over the raw analytic policy when a plan
    exists: the analytic per-layer bound compounds loosely end to end,
    while the plan's budgets were validated against the measured logits
    error."""
    from repro.core.plane_schedule import PlaneSchedule

    if getattr(plan, "workload", None) != "lm":
        raise ValueError("lm_schedule_from_plan needs an LM TunedPlan")
    if len(plan.planes) != cfg.n_layers:
        raise ValueError(
            f"plan covers {len(plan.planes)} layers but cfg has "
            f"{cfg.n_layers}"
        )
    return PlaneSchedule(
        planes=tuple(plan.planes),
        target_rel_err=plan.target_rel_err,
        layer_bounds=plan.layer_bounds,
    )


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int = 16
    out: list[int] = field(default_factory=list)
    done: bool = False
    prefill_pos: int = 0  # prompt tokens already prefetched into the cache

    @property
    def prefill_remaining(self) -> int:
        return max(len(self.prompt) - self.prefill_pos, 0)

    @property
    def ready(self) -> bool:
        """Prefill complete — the request may join decode micro-batches."""
        return self.prefill_pos >= len(self.prompt)


# Families whose decode path supports a per-slot cache-index *vector*:
# each slot writes K/V at its own length and attends only its own history,
# so a request's numerics depend solely on its own tokens — serving order
# (chunked prefill, preemption, slot reuse) cannot perturb outputs.  The
# recurrent/scalar-index families keep the legacy shared-index
# approximation (their state update is not position-addressed).
VECTOR_INDEX_FAMILIES = ("dense", "moe", "vlm")


class Engine:
    def __init__(self, cfg, params, *, batch: int, max_seq: int, extras=None):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.extras = extras or {}  # encdec: {"memory": (B, T_enc, D)}
        from repro import models

        self.mod = models.build(cfg)
        if cfg.family == "encdec" and "memory" in self.extras \
                and "cross_kv" not in self.extras:
            self.extras["cross_kv"] = self.mod.precompute_cross_kv(
                params, self.extras["memory"], cfg
            )
        self.decode_fn = shared_decode(cfg, batch, max_seq)
        if cfg.family in ("dense", "moe", "vlm", "encdec"):
            self.cache = self.mod.init_cache(cfg, batch, max_seq)
        elif cfg.family == "hybrid":
            self.cache = self.mod.init_state(cfg, batch, max_seq)
        else:
            self.cache = self.mod.init_state(cfg, batch)
        self.slots: SlotTable[Request] = SlotTable(batch)
        self.lengths = np.zeros(batch, np.int32)
        self._vector_index = cfg.family in VECTOR_INDEX_FAMILIES
        # telemetry (repro.obs.events): engine-local micro-step records.
        # The engine has no view of the modeled clock, so events are
        # sequence-stamped (monotonic per engine) — the gateway's exec
        # attribution carries the cycle-exact account
        self.obs = NULL_SINK
        self._obs_seq = 0

    def _index(self, slot: int):
        """The cache index argument for a call driven by ``slot``: the
        per-slot length vector when the family supports slot isolation,
        else the legacy scalar (that slot's own length for prefill)."""
        if self._vector_index:
            return jnp.asarray(self.lengths)
        return jnp.int32(self.lengths[slot])

    # ---------------------------------------------------------- admission

    def admit_slot(self, req: Request) -> bool:
        """Occupy a slot for ``req`` without prefilling — the chunked-
        prefill entry point.  Prefill is then metered through
        :meth:`prefill` (the serving gateway charges it against round
        budgets instead of atomically at admission); the request joins
        decode batches once ``req.ready``."""
        slot = self.slots.occupy(req)
        if slot is None:
            return False
        if self._vector_index:
            # fresh position track: the new occupant's writes overwrite the
            # predecessor's rows before any of its own reads reach them
            self.lengths[slot] = 0
        req.prefill_pos = 0
        return True

    def prefill(self, req: Request, max_tokens: int | None = None) -> int:
        """Run up to ``max_tokens`` prompt tokens of ``req`` through the
        decode path (token-by-token, slot-isolated); returns how many were
        processed.  Call with ``None`` to finish the prompt."""
        active = {id(r): i for i, r in self.slots.active()}
        slot = active.get(id(req))
        if slot is None:
            raise ValueError(f"request {req.rid} holds no slot")
        n = req.prefill_remaining if max_tokens is None else min(
            int(max_tokens), req.prefill_remaining
        )
        toks = req.prompt.astype(np.int32)
        for _ in range(n):
            tok = jnp.full((self.batch, 1), 0, jnp.int32).at[slot, 0].set(
                int(toks[req.prefill_pos])
            )
            logits, self.cache = self.decode_fn(
                self.params, tok, self.cache, self._index(slot), self.extras,
            )
            # Serialize dispatch: with several prefill calls in flight the
            # CPU backend partitions float reductions by available
            # concurrency, so overlapped calls produce ulp-different cache
            # rows run to run — which greedy argmax amplifies into
            # different tokens.  Decode steps are already serialized by
            # their argmax feedback; this is the one unsynced loop.
            jax.block_until_ready(logits)
            self.lengths[slot] += 1
            req.prefill_pos += 1
        if n and req.ready:
            req._last_logits = np.asarray(logits[slot, -1])  # type: ignore[attr-defined]
        if n and self.obs.enabled:
            self._obs_seq += 1
            self.obs.emit(Event(self._obs_seq, "lm-prefill", dict(
                rid=req.rid, tokens=int(n), slot=int(slot),
            )))
        return n

    def admit(self, req: Request) -> bool:
        """Atomic admission: occupy a slot and prefill the whole prompt
        (the pre-gateway path; :meth:`Engine.run` and single-workload
        callers keep this one-call surface)."""
        if not self.admit_slot(req):
            return False
        self.prefill(req)
        return True

    # ------------------------------------------------------------- decode

    def ready_slots(self) -> list[tuple[int, Request]]:
        """Active slots whose occupant finished prefill — the decode
        micro-batch :meth:`step` will run."""
        return [(i, r) for i, r in self.slots.active() if r.ready]

    def step(self, only: set[int] | None = None) -> list[Request]:
        """One continuous-batching decode step for all *ready* slots
        (slots mid-prefill under the chunked path are skipped); returns
        the requests that completed on this step (empty when idle — falsy,
        so boolean call sites keep working).  ``only`` restricts the step
        to a subset of slot indices (the gateway's class-quantum scoping;
        under the vector-index families slot numerics are isolated, so a
        subset step leaves excluded slots bit-exactly untouched).  The
        gateway's LM adapter consumes the completions to stamp
        modeled-clock finish times without re-scanning the slot table."""
        active = self.ready_slots()
        if only is not None:
            active = [(i, r) for i, r in active if i in only]
        if not active:
            return []
        toks = np.zeros((self.batch, 1), np.int32)
        for i, req in active:
            last = getattr(req, "_last_logits")
            toks[i, 0] = int(np.argmax(last))
        if self._vector_index:
            # per-slot positions: each row writes at its own length and
            # attends only its own history — numerics are slot-isolated,
            # so serving order and slot reuse cannot change outputs
            idx = jnp.asarray(self.lengths)
        else:
            # legacy approximation for recurrent families: decode at the
            # max index and rely on causal masking via positions
            idx = jnp.int32(int(max(self.lengths[i] for i, _ in active)))
        logits, self.cache = self.decode_fn(
            self.params, jnp.asarray(toks), self.cache, idx, self.extras,
        )
        completed: list[Request] = []
        for i, req in active:
            tok = int(np.argmax(np.asarray(logits[i, -1])))
            req.out.append(tok)
            req._last_logits = np.asarray(logits[i, -1])
            self.lengths[i] += 1
            if len(req.out) >= req.max_new or self.lengths[i] >= self.max_seq - 1:
                req.done = True
                self.slots.release(i)
                completed.append(req)
        if self.obs.enabled:
            self._obs_seq += 1
            self.obs.emit(Event(self._obs_seq, "lm-step", dict(
                slots=len(active), completed=len(completed),
            )))
        return completed

    def run(self, requests: list[Request]) -> list[Request]:
        """Serve ``requests`` to completion standalone (the engine owning
        its own FIFO loop).  Deployments serving heterogeneous traffic
        front this engine with :class:`repro.serve.gateway.Gateway`
        instead, which owns admission and drives ``admit``/``step``
        directly against a shared cycle budget."""
        pending: FifoQueue[Request] = FifoQueue(requests)
        done: list[Request] = []
        while pending or self.slots.any_active():
            pending.pump(self.slots, self.admit)
            done.extend(self.step())
        return done
