"""Batched serving engine: continuous batching over a slot table.

Requests enter a queue; the engine packs up to ``batch`` active slots,
prefills new prompts into their cache rows, then decodes one token per step
for every active slot (the classic continuous-batching loop).  Slots free as
sequences hit EOS/max length and are refilled from the queue — the serving
counterpart of the trainer.

The engine is family-agnostic: it drives the (prefill, decode) pair from
``serve_step.make_*`` so dense KV-cache archs and O(1)-state ssm archs serve
through the same loop.  With cfg.quant.mode='mma_int8' the whole decode path
runs the paper's digit-serial datapath.  Precision is governed by a
*per-layer* :class:`~repro.core.plane_schedule.PlaneSchedule`
(``cfg.quant.plane_schedule``, built from the served weights via
:func:`lm_schedule_from_params`) rather than one global ``planes`` knob:
layers whose weight dynamic range tolerates it consume fewer MSB digits,
trading bounded accuracy loss for serving energy (MINT-style dynamic
precision).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from . import serve_step as ss
from .queue import FifoQueue, SlotTable


def lm_schedule_from_params(params, cfg, target_rel_err: float):
    """Per-layer plane budgets for a scan-rolled LM from its actual weights.

    Uses each layer's FFN up-projection (the widest, most truncation-
    sensitive matmul of a block) as the representative weight: quantize it
    per-channel int8 and pick the fewest planes whose analytic worst-case
    relative error (``core.early_term``) meets ``target_rel_err``.  Install
    the result with ``cfg.replace(quant=dataclasses.replace(cfg.quant,
    plane_schedule=tuple(sched)))``.
    """
    from repro import models
    from repro.core import quant
    from repro.core.plane_schedule import PlaneSchedule

    if cfg.family not in models.PLANE_SCHEDULE_FAMILIES:
        raise NotImplementedError(
            f"per-layer plane schedules need a transformer block stack "
            f"({models.PLANE_SCHEDULE_FAMILIES}); {cfg.family!r} archs "
            f"serve with the global quant.planes knob"
        )
    blocks = params["blocks"]
    if "mlp" in blocks:
        ws = blocks["mlp"]["w_up"]["w"]  # (L, d_model, d_ff), stacked
    else:  # MoE blocks: fall back to the attention query projection
        ws = blocks["attn"]["wq"]["w"]
    wq = [
        quant.quantize_weights(ws[l].astype(jnp.float32), channel_axis=-1).values
        for l in range(cfg.n_layers)
    ]
    return PlaneSchedule.from_weights(wq, target_rel_err)


def lm_schedule_from_plan(plan, cfg):
    """The serving-time half of the autotuner: a *certified*
    :class:`~repro.autotune.plan.TunedPlan` (from
    :func:`repro.autotune.tune_lm`, which seeds from
    :func:`lm_schedule_from_params` and then measures-and-repairs on a
    calibration token batch) turned back into the per-layer policy the
    engine installs.  Prefer this over the raw analytic policy when a plan
    exists: the analytic per-layer bound compounds loosely end to end,
    while the plan's budgets were validated against the measured logits
    error."""
    from repro.core.plane_schedule import PlaneSchedule

    if getattr(plan, "workload", None) != "lm":
        raise ValueError("lm_schedule_from_plan needs an LM TunedPlan")
    if len(plan.planes) != cfg.n_layers:
        raise ValueError(
            f"plan covers {len(plan.planes)} layers but cfg has "
            f"{cfg.n_layers}"
        )
    return PlaneSchedule(
        planes=tuple(plan.planes),
        target_rel_err=plan.target_rel_err,
        layer_bounds=plan.layer_bounds,
    )


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int = 16
    out: list[int] = field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg, params, *, batch: int, max_seq: int, extras=None):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.extras = extras or {}  # encdec: {"memory": (B, T_enc, D)}
        from repro import models

        self.mod = models.build(cfg)
        if cfg.family == "encdec" and "memory" in self.extras \
                and "cross_kv" not in self.extras:
            self.extras["cross_kv"] = self.mod.precompute_cross_kv(
                params, self.extras["memory"], cfg
            )
        self.decode_fn, _ = ss.make_decode(cfg, batch, max_seq)
        self.decode_fn = jax.jit(self.decode_fn)
        if cfg.family in ("dense", "moe", "vlm", "encdec"):
            self.cache = self.mod.init_cache(cfg, batch, max_seq)
        elif cfg.family == "hybrid":
            self.cache = self.mod.init_state(cfg, batch, max_seq)
        else:
            self.cache = self.mod.init_state(cfg, batch)
        self.slots: SlotTable[Request] = SlotTable(batch)
        self.lengths = np.zeros(batch, np.int32)

    def admit(self, req: Request) -> bool:
        """Prefill a request into a free slot (per-slot prefill keeps the
        batch decode hot; a production engine would chunk prefills)."""
        slot = self.slots.free_index()
        if slot is None:
            return False
        # Prefill token-by-token through the decode path (slot-isolated);
        # cheap at smoke scale and requires no batched prompt alignment.
        toks = req.prompt.astype(np.int32)
        for t_idx in range(len(toks)):
            tok = jnp.full((self.batch, 1), 0, jnp.int32).at[slot, 0].set(int(toks[t_idx]))
            logits, self.cache = self.decode_fn(
                self.params, tok, self.cache, jnp.int32(self.lengths[slot]),
                self.extras,
            )
            self.lengths[slot] += 1
        occupied = self.slots.occupy(req)
        assert occupied == slot
        req._last_logits = np.asarray(logits[slot, -1])  # type: ignore[attr-defined]
        return True

    def step(self) -> list[Request]:
        """One continuous-batching decode step for all active slots;
        returns the requests that completed on this step (empty when idle
        — falsy, so boolean call sites keep working).  The gateway's LM
        adapter consumes the completions to stamp modeled-clock finish
        times without re-scanning the slot table."""
        active = self.slots.active()
        if not active:
            return []
        toks = np.zeros((self.batch, 1), np.int32)
        for i, req in active:
            last = getattr(req, "_last_logits")
            toks[i, 0] = int(np.argmax(last))
        # NOTE: per-slot cache_index differs; we decode with the max index and
        # rely on causal masking per-slot via positions.  For heterogeneous
        # lengths a production engine passes a per-slot index vector; here we
        # step slots at equal length after admission (smoke-scale).  The same
        # approximation covers slot reuse: lengths and cache rows carry over
        # from the previous occupant, so a refilled slot continues from its
        # predecessor's position instead of 0 — fine for throughput smoke
        # tests, wrong for content; the per-slot index vector fixes both.
        idx = int(max(self.lengths[i] for i, _ in active))
        logits, self.cache = self.decode_fn(
            self.params, jnp.asarray(toks), self.cache, jnp.int32(idx),
            self.extras,
        )
        completed: list[Request] = []
        for i, req in active:
            tok = int(np.argmax(np.asarray(logits[i, -1])))
            req.out.append(tok)
            req._last_logits = np.asarray(logits[i, -1])
            self.lengths[i] += 1
            if len(req.out) >= req.max_new or self.lengths[i] >= self.max_seq - 1:
                req.done = True
                self.slots.release(i)
                completed.append(req)
        return completed

    def run(self, requests: list[Request]) -> list[Request]:
        """Serve ``requests`` to completion standalone (the engine owning
        its own FIFO loop).  Deployments serving heterogeneous traffic
        front this engine with :class:`repro.serve.gateway.Gateway`
        instead, which owns admission and drives ``admit``/``step``
        directly against a shared cycle budget."""
        pending: FifoQueue[Request] = FifoQueue(requests)
        done: list[Request] = []
        while pending or self.slots.any_active():
            pending.pump(self.slots, self.admit)
            done.extend(self.step())
        return done
