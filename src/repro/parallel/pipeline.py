"""Pipeline parallelism (GPipe-style) via shard_map + lax.ppermute.

The layer stack is split into |model| contiguous stages (the stacked (L,...)
param leaves shard over 'model' on their layer dim — no weight reshuffling);
microbatches flow stage-to-stage through collective-permutes.  With the
production mesh this realizes PP=16 x DP=16 (TP=1) — the right regime for
mid-size dense models whose TP collectives dominate (yi/granite train cells,
see EXPERIMENTS.md §Roofline), trading them for the pipeline bubble
(S-1)/(S-1+n_micro).

Schedule: classic GPipe fill-drain over T = n_micro + S - 1 ticks.  At tick
t, stage 0 ingests microbatch t (if any); every stage applies its layers;
activations ppermute to the next stage; the last stage emits microbatch
t-S+1.  Differentiable end-to-end (grads flow back through ppermute), so
``pipelined_loss_fn`` drops into the standard train step.

Embedding runs on stage 0 and the LM head on the last stage (weights
replicated across stages for simplicity; a production variant would place
them).  Shapes: n_micro must be >= 1; batch shards over ('pod','data').
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.parallel.sharding import current_mesh


def _stage_apply(blocks, h, cfg, positions):
    """Apply this stage's layer slice (scan over local layers)."""

    def body(carry, blk):
        hh = carry
        a, _ = L.attention(
            blk["attn"], L.rmsnorm(blk["ln1"], hh, cfg.norm_eps), cfg,
            positions=positions,
        )
        hh = hh + a
        hh = hh + L.mlp(blk["mlp"], L.rmsnorm(blk["ln2"], hh, cfg.norm_eps), cfg)
        return hh, None

    fn = body
    if cfg.remat == "full":
        fn = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(fn, h, blocks, unroll=cfg.scan_unroll)
    return h


def pipelined_loss_fn(params, batch, cfg, *, n_micro: int, axis: str = "model"):
    """Cross-entropy loss of a dense decoder-only LM under PP over ``axis``.

    batch = {"tokens": (B, S+1)}.  Must run under an active mesh whose
    ``axis`` size divides cfg.n_layers.  Returns (loss, metrics).
    """
    mesh = current_mesh()
    assert mesh is not None, "pipelined_loss_fn requires an active mesh"
    n_stages = mesh.shape[axis]
    assert cfg.n_layers % n_stages == 0
    dpa = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = dpa if len(dpa) > 1 else dpa[0]

    tokens_all = batch["tokens"][:, :-1]
    targets_all = batch["tokens"][:, 1:]
    b, s = tokens_all.shape
    assert b % n_micro == 0
    mb = b // n_micro
    tok_mb = tokens_all.reshape(n_micro, mb, s)
    tgt_mb = targets_all.reshape(n_micro, mb, s)

    def body(blocks, embed, ln_f, head, toks, tgts):
        from repro.parallel import sharding as shd

        # inside shard_map every mesh axis is manual: the model's GSPMD
        # sharding constraints must no-op (shard_map owns the layout here)
        ctx = shd.use_mesh(None)
        ctx.__enter__()
        stage = jax.lax.axis_index(axis)
        last = n_stages - 1
        positions = jnp.arange(s)[None, :]
        mb_loc = toks.shape[1]

        h = jnp.zeros((mb_loc, s, cfg.d_model), jnp.bfloat16)
        loss_sum = jnp.float32(0)
        n_out = 0
        for t in range(n_micro + n_stages - 1):
            # stage 0 ingests microbatch t
            if t < n_micro:
                fresh = L.embed(embed, toks[t])
                h = jnp.where(stage == 0, fresh, h)
            h = _stage_apply(blocks, h, cfg, positions)
            # last stage emits microbatch t-(S-1)
            mi = t - (n_stages - 1)
            if 0 <= mi < n_micro:
                x = L.rmsnorm(ln_f, h, cfg.norm_eps)
                logits = L.linear(head, x, cfg.quant).astype(jnp.float32)
                logz = jax.scipy.special.logsumexp(logits, axis=-1)
                gold = jnp.take_along_axis(
                    logits, tgts[mi][..., None], axis=-1)[..., 0]
                nll = (logz - gold).mean()
                loss_sum = loss_sum + jnp.where(stage == last, nll, 0.0)
                n_out += 1
            # ppermute activations stage i -> i+1 (ring; stage0's recv is
            # overwritten by the next ingest)
            h = jax.lax.ppermute(
                h, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
        # share the last stage's loss with every stage (grad flows back
        # through psum's transpose correctly: each stage contributed 0 or nll)
        loss = jax.lax.psum(loss_sum, axis) / n_out
        # batch-mean across DP shards
        for a in (dpa if isinstance(dp, tuple) else (dp,)):
            loss = jax.lax.pmean(loss, a)
        ctx.__exit__(None, None, None)
        return loss

    blocks_spec = jax.tree.map(lambda _: P(axis), params["blocks"])
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(blocks_spec, P(), P(), P(),
                  P(None, dp, None), P(None, dp, None)),
        out_specs=P(),
        check_rep=False,
    )
    loss = fn(params["blocks"], params["embed"], params["ln_f"],
              params["head"], tok_mb, tgt_mb)
    return loss, {"nll": loss}


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe bubble overhead: (S-1) / (S-1+M)."""
    return (n_stages - 1) / (n_stages - 1 + n_micro)
