"""Derive per-parameter PartitionSpecs (TP/EP layout) for any model family.

Strategy: shape-based defaults + path-name overrides, applied to the
*abstract* param tree (eval_shape), so the dry-run never allocates.

Defaults (2-D weights, after skipping the stacked-layer leading dim):
  (vocab, d)    -> ('vocab', None)      sharded embedding
  (d, vocab)    -> (None, 'vocab')      sharded LM head
  (d_in, d_out) -> (None, 'model')      column-parallel (Megatron "f")
  row-parallel overrides by name: wo / w_down / out_proj / proj / wv(cm)
                -> ('model', None)      contract the sharded dim -> psum
  3-D (E, ., .) MoE expert stacks -> ('experts', None/'model' per shape)
  1-D / norms / small -> replicated

Divisibility is re-checked against the mesh at use time (sharding.spec_for).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from . import sharding as shd

ROW_PARALLEL_NAMES = ("wo", "w_down", "out_proj", "proj", "wv_cm")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def logical_for_leaf(path: str, shape: tuple[int, ...], cfg) -> tuple:
    """Logical axis names for one param leaf (full shape incl. stacked L)."""
    names: list[str | None] = [None] * len(shape)
    # Stacked-layer leading dims: blocks/* leaves carry (L, ...) (zamba2
    # groups carry (G, g, ...)).  Detect by path prefix.
    skip = 0
    if any(seg in path for seg in ("blocks/", "groups/", "tail/", "enc_blocks/", "dec_blocks/")):
        skip = 1
        if "groups/" in path:
            skip = 2
    core = shape[skip:]
    v = cfg.vocab if hasattr(cfg, "vocab") else -1

    is_row = any(path.endswith(f"{n}/w") or path.endswith(f"{n}/w_q")
                 for n in ROW_PARALLEL_NAMES)
    # rwkv channel-mix 'wv' is (d_ff, d) row-parallel (unlike attention wv)
    is_row = is_row or path.endswith("channel_mix/wv/w") \
        or path.endswith("channel_mix/wv/w_q")

    if len(core) == 2:
        r, c = core
        if r == v:
            names[skip], names[skip + 1] = "vocab", None
        elif c == v:
            names[skip], names[skip + 1] = None, "vocab"
        elif is_row:
            names[skip], names[skip + 1] = "ffn", None
        else:
            names[skip], names[skip + 1] = None, "ffn"
    elif len(core) == 3 and ("moe/" in path or "experts" in path):
        # (E, d, f) / (E, f, d): experts over 'model'
        names[skip] = "experts"
    # conv / norm / 1-D leaves stay replicated
    return tuple(names)


def param_specs(abstract_params, cfg):
    """PartitionSpec pytree matching the abstract param tree."""

    def one(path, leaf):
        return P(*logical_for_leaf(_path_str(path), leaf.shape, cfg))

    return jax.tree_util.tree_map_with_path(one, abstract_params)


def param_logical(abstract_params, cfg):
    """Logical-name-tuple pytree (resolved lazily under a mesh)."""

    def one(path, leaf):
        return logical_for_leaf(_path_str(path), leaf.shape, cfg)

    return jax.tree_util.tree_map_with_path(one, abstract_params)


def named_shardings(abstract_params, cfg, mesh, rules=None):
    """NamedSharding pytree (divisibility-guarded) for jit in_shardings."""
    if rules is None:
        rules = shd.RULE_SETS.get(getattr(cfg, "shard_rules", "default"),
                                  shd.DEFAULT_RULES)

    def one(path, leaf):
        logical = logical_for_leaf(_path_str(path), leaf.shape, cfg)
        with shd.use_mesh(mesh, rules):
            return shd.named_sharding(*logical, shape=leaf.shape)

    return jax.tree_util.tree_map_with_path(one, abstract_params)
