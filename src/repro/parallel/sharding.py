"""Logical-axis sharding rules (DP / TP / EP / SP, plus the pod axis).

Models annotate tensors with *logical* axis names; this module maps them to
mesh axes and applies ``with_sharding_constraint``.  The mapping is a rule
list (MaxText-style) so perf iterations can re-shard without touching model
code — several §Perf hillclimb steps are pure rule edits.

Key rules (production mesh ("pod", "data", "model")):
  batch        -> ("pod", "data")   pure DP across pods and the data axis
  seq          -> "model"           Megatron-style sequence parallelism for
                                    the residual stream (activations between
                                    blocks are seq-sharded; attention/MLP
                                    internals re-shard to heads/ffn, GSPMD
                                    inserts the boundary all-to-alls)
  heads/kv_heads/q_heads -> "model" tensor parallelism inside attention
  ffn / experts -> "model"          TP for MLPs, EP for MoE experts
  vocab        -> "model"           sharded embedding + logits

Divisibility guard: a dim whose size does not divide the mapped axis size is
left unsharded (e.g. kv_heads=4 on a 16-way model axis falls back to
replicated; callers can instead shard head_dim).  This keeps one rule set
valid across all 10 assigned architectures.

The active mesh is carried in a contextvar (set by ``use_mesh``) so model
code works unchanged in smoke tests (no mesh, constraints become no-ops) and
in the dry-run/trainer (mesh set).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: contextvars.ContextVar[Mesh | None] = contextvars.ContextVar("mesh", default=None)
_RULES: contextvars.ContextVar[dict | None] = contextvars.ContextVar("rules", default=None)

# Default logical -> mesh-axis rules.  Values are a mesh axis name, a tuple of
# axis names, or None (replicated).
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "seq": "model",          # sequence parallelism on the residual stream
    "act_embed": None,
    "embed": None,
    "heads": "model",
    "q_heads": "model",
    "kv_heads": "model",
    "kv_seq": "model",  # decode: KV cache sharded along sequence
    "head_dim": None,
    "kv_head_dim": None,
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "expert_capacity": ("pod", "data"),  # EP: capacity dim carries the DP split
    "conv_window": None,
    "ssm_state": None,
    "unsharded": None,
}

# DeepSpeed-MoE-style layout for expert models: the model axis carries ONLY
# experts; batch parallelism spans every axis (non-expert layers run pure DP
# with zero TP collectives; the MoE all-to-all is the only activation
# collective).  §Perf iteration 1b.
EP_DP_RULES: dict[str, object] = {
    **DEFAULT_RULES,
    "batch": ("pod", "data", "model"),
    "seq": None,
    "heads": None,
    "q_heads": None,
    "kv_heads": None,
    "ffn": None,
    "vocab": None,
    "experts": "model",
    "expert_capacity": ("pod", "data"),
}

RULE_SETS = {"default": DEFAULT_RULES, "ep_dp": EP_DP_RULES}


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None, rules: dict | None = None):
    t1 = _MESH.set(mesh)
    t2 = _RULES.set({**DEFAULT_RULES, **(rules or {})})
    try:
        yield
    finally:
        _MESH.reset(t1)
        _RULES.reset(t2)


def current_mesh() -> Mesh | None:
    return _MESH.get()


def active_rules() -> dict:
    return _RULES.get() or DEFAULT_RULES


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def spec_for(logical: Sequence[str | None], shape: Sequence[int] | None = None) -> P:
    """Resolve logical names to a PartitionSpec under the active mesh/rules,
    dropping any mapping that fails divisibility (when ``shape`` given) or
    whose axis is absent from the mesh."""
    mesh = current_mesh()
    rules = active_rules()
    entries = []
    used: set[str] = set()
    for i, name in enumerate(logical):
        axes = rules.get(name) if name else None
        if axes is None or mesh is None:
            entries.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        axes = tuple(a for a in axes if a in mesh.shape and a not in used)
        if not axes:
            entries.append(None)
            continue
        # longest PREFIX of the axis tuple that divides the dim (e.g. batch
        # 32 on ('pod','data','model') falls back to ('pod','data')).
        while axes and shape is not None and shape[i] % _axis_size(mesh, axes) != 0:
            axes = axes[:-1]
        if not axes:
            entries.append(None)
            continue
        used.update(axes)
        entries.append(axes if len(axes) > 1 else axes[0])
    return P(*entries)


def constrain(x: jax.Array, *logical: str | None) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = spec_for(logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*logical: str | None, shape: Sequence[int] | None = None) -> NamedSharding:
    mesh = current_mesh()
    assert mesh is not None, "named_sharding requires an active mesh"
    return NamedSharding(mesh, spec_for(logical, shape))


def tree_specs(logical_tree, shape_tree) -> object:
    """Map a pytree of logical-name tuples + matching ShapeDtypeStructs to
    NamedShardings (used to build in_shardings for jit)."""
    mesh = current_mesh()
    assert mesh is not None

    def one(names, sds):
        return NamedSharding(mesh, spec_for(names, sds.shape))

    return jax.tree.map(one, logical_tree, shape_tree, is_leaf=lambda t: isinstance(t, tuple))
