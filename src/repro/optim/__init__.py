from . import adamw, grad_compress, schedule  # noqa: F401
