"""AdamW with mixed precision (bf16 params, f32 master copies + moments),
global-norm clipping, and decoupled weight decay.  Pure pytree functions —
no optax dependency — so optimizer state sharding follows param sharding
exactly (moments inherit the param's PartitionSpec).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # i32 scalar
    master: object  # f32 copies of params
    m: object
    v: object


def init(params) -> AdamWState:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        master=jax.tree.map(f32, params),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def update(
    params,
    grads,
    state: AdamWState,
    *,
    lr: jax.Array | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t

    def upd(p_master, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p_master
        return p_master - lr * delta, m, v

    out = jax.tree.map(upd, state.master, grads, state.m, state.v)
    new_master = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(
        lambda mast, p: mast.astype(p.dtype), new_master, params
    )
    return new_params, AdamWState(step, new_master, new_m, new_v), {
        "grad_norm": gnorm,
        "lr": jnp.asarray(lr, jnp.float32),
    }
