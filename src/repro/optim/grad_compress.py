"""Gradient compression for the data-parallel sync: error-feedback int8.

At 1000+-node scale the DP gradient all-reduce dominates the step at small
per-node batch; int8 compression cuts those bytes 4x (vs f32) with the
error-feedback trick (Seide et al.; 1-bit SGD lineage) keeping convergence:

    e'   <- g + e                (add residual carried from last step)
    q    <- int8(e' / s),  s = max|e'| / 127     (per-leaf scale)
    g~   <- allreduce_mean(q * s)                (the only cross-node bytes)
    e    <- e' - q * s           (new residual, stays local)

Exposed two ways:
  * ``compress/decompress + error feedback`` pure functions (unit-tested,
    usable inside any train step), and
  * ``compressed_psum_shardmap`` — an explicit shard_map collective over the
    DP axes, used by the trainer when cfg.grad_compression is on (the
    per-shard int8 payload is what crosses the network; on the production
    mesh this is the 'pod'+'data' axes sync).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def compress(e: jax.Array) -> tuple[jax.Array, jax.Array]:
    """f32 -> (int8 q, f32 scale) with q*s ~= e."""
    amax = jnp.max(jnp.abs(e))
    s = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(e / s), -127, 127).astype(jnp.int8)
    return q, s


def decompress(q: jax.Array, s: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * s


def ef_step(g: jax.Array, err: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One error-feedback compression step on a local gradient leaf.

    Returns (q, scale, new_err).  The caller exchanges (q, scale).
    """
    e = g.astype(jnp.float32) + err
    q, s = compress(e)
    new_err = e - decompress(q, s)
    return q, s, new_err


def ef_tree_step(grads, err_tree):
    qs = jax.tree.map(lambda g, e: ef_step(g, e), grads, err_tree)
    q = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[2], qs, is_leaf=lambda x: isinstance(x, tuple))
    return q, s, new_err


def compressed_psum_shardmap(mesh, axis_names=("data",)):
    """Build a shard_map'd compressed mean-all-reduce over ``axis_names``.

    f(local_grads_tree, err_tree) -> (synced_grads_tree, new_err_tree).
    The int8 payload is the only data crossing ``axis_names``.
    """

    def body(grads, err):
        q, s, new_err = ef_tree_step(grads, err)
        # Exchange: mean of dequantized leaves across the DP axes.  XLA sends
        # the int8 tensor + f32 scalar; the dequant-mean runs post-exchange.
        def sync(qq, ss):
            deq = decompress(qq, ss)
            for ax in axis_names:
                deq = jax.lax.pmean(deq, ax)
            return deq

        synced = jax.tree.map(sync, q, s)
        return synced, new_err

    from jax.experimental.shard_map import shard_map

    spec = P(*axis_names)
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=(spec, spec),
        check_rep=False,
    )
