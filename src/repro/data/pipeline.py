"""Deterministic, restart-resumable data pipeline.

Batches are a pure function of (seed, step) — after a restart the trainer
asks for step N and gets bit-identical data with no iterator state to
persist (the checkpoint only stores the step counter).  Sources:

  * ``synthetic``: seeded token stream (zipf-ish marginals so losses move),
  * ``memmap``: fixed-length samples from a token file (np.memmap), step-
    indexed with a seeded shuffle — the production path for real corpora.

``host_prefetch`` overlaps host batch construction with device compute
(double buffering) — on a real cluster each host builds only its addressable
shard via ``jax.make_array_from_process_local_data``; here (single process)
we place the global batch.
"""
from __future__ import annotations

import threading
import queue as queue_mod
from dataclasses import dataclass

import numpy as np


@dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    microbatches: int = 1
    seed: int = 0
    source: str = "synthetic"  # synthetic | memmap
    path: str | None = None
    extras: dict | None = None  # e.g. vlm patches / whisper frames specs


def _rng_for(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def synthetic_batch(cfg: DataConfig, step: int) -> dict:
    rng = _rng_for(cfg.seed, step)
    b, s = cfg.global_batch, cfg.seq_len
    # zipf-flavored marginals, clipped into vocab
    toks = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64) % cfg.vocab
    out = {"tokens": toks.astype(np.int32)}
    for name, shape in (cfg.extras or {}).items():
        out[name] = rng.standard_normal((b, *shape), dtype=np.float32)
    return out


def memmap_batch(cfg: DataConfig, step: int) -> dict:
    data = np.memmap(cfg.path, dtype=np.int32, mode="r")
    n_samples = data.shape[0] // (cfg.seq_len + 1)
    rng = _rng_for(cfg.seed, step)
    idx = rng.integers(0, n_samples, size=cfg.global_batch)
    rows = np.stack(
        [data[i * (cfg.seq_len + 1) : (i + 1) * (cfg.seq_len + 1)] for i in idx]
    )
    return {"tokens": rows % np.int32(cfg.vocab)}


def get_batch(cfg: DataConfig, step: int) -> dict:
    batch = (memmap_batch if cfg.source == "memmap" else synthetic_batch)(cfg, step)
    if cfg.microbatches > 1:
        def split(a):
            b = a.shape[0]
            mb = cfg.microbatches
            return a.reshape(mb, b // mb, *a.shape[1:])
        batch = {k: split(v) for k, v in batch.items()}
    return batch


class host_prefetch:
    """Double-buffered batch iterator: builds batch N+1 on a worker thread
    while the device runs step N."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 2):
        self.cfg = cfg
        self.step = start_step
        self.q: queue_mod.Queue = queue_mod.Queue(maxsize=depth)
        self.stop = threading.Event()
        self.t = threading.Thread(target=self._work, daemon=True)
        self.t.start()

    def _work(self):
        s = self.step
        while not self.stop.is_set():
            batch = get_batch(self.cfg, s)
            self.q.put((s, batch))
            s += 1

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self.stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue_mod.Empty:
            pass
