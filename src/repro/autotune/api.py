"""Autotune front door: (model, validation batch, error budget, geometry)
-> a serialized, certified :class:`~repro.autotune.plan.TunedPlan`.

``tune_unet`` runs the full pipeline for tiled segmentation:

  1. **calibrate** — instrumented forwards record per-layer amplitudes,
     per-tile ratio gains, the occupied amplitude octaves and the measured
     single-layer truncation sensitivities (``calibrate.calibrate_unet``);
  2. **search** — greedy cycles-per-error descent over per-layer plane
     budgets, validated against the measured whole-canvas error; budget
     classes from the calibrated thresholds; core stride picked by
     minimizing modeled relation-(2) cycles over the calibration images
     (``search``);
  3. **certify** — the exact serving path (``SegEngine`` with the plan,
     per-tile quantization) is replayed on the calibration images against
     its full-8 twin; planes are re-added until the measured end-to-end
     error fits ``slack * target``, and the certificate is that measurement
     inflated by ``margin`` (so ``measured <= cert <= target`` — the gate
     ``benchmarks/segserve.py`` enforces in CI).  The unconditionally sound
     interval bound (``calibrate.tiled_sound_bound``) is recorded alongside.

``tune_lm`` is the LM analogue: seed from the analytic
``serve.engine.lm_schedule_from_params`` policy, then measure-and-repair
against the quantized forward on a calibration token batch.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import cycle_model as cm
from repro.core.bitplane import N_BITS
from repro.core.plane_schedule import PlaneSchedule, layer_rel_bound
from repro.models import unet

from . import calibrate as _calibrate
from . import search as _search
from .plan import TunedPlan

DEFAULT_MARGIN = 1.25


def _check_budget_split(slack: float, margin: float) -> None:
    if margin < 1.0:
        raise ValueError(f"margin {margin} < 1 cannot cover its measurement")
    if slack * margin > 1.0 + 1e-9:
        raise ValueError(
            f"slack*margin = {slack * margin:.3f} > 1: the certificate "
            f"(measured*margin) could exceed the target the search met"
        )


def _quantized_weights(params):
    from repro.core import quant

    return [
        quant.quantize_weights(w, channel_axis=-1).values.reshape(
            -1, w.shape[-1]
        )
        for w in unet.conv_weights_in_order(params)
    ]


def _layer_bounds(params, planes) -> tuple[float, ...]:
    return tuple(
        float(layer_rel_bound(w, int(b)))
        for w, b in zip(_quantized_weights(params), planes)
    )


def apply_plan(cfg: unet.UNetConfig, plan: TunedPlan) -> unet.UNetConfig:
    """Install a plan's certified layer schedule into a ``UNetConfig``."""
    if plan.workload != "unet":
        raise ValueError(f"cannot apply a {plan.workload!r} plan to a U-Net")
    return dataclasses.replace(cfg, plane_schedule=tuple(plan.planes))


def reference_plan(plan: TunedPlan) -> TunedPlan:
    """The plan's full-8 twin: identical tiling, thresholds and grouping,
    every budget at 8 planes — the reference a measured certificate (and the
    bench's ``full-8`` row) is defined against."""
    n = len(plan.planes)
    return dataclasses.replace(
        plan,
        planes=(N_BITS,) * n,
        layer_bounds=None,
        class_planes=(
            None
            if plan.class_planes is None
            else ((N_BITS,) * n,) * len(plan.class_planes)
        ),
        certificate=dict(plan.certificate, reference=True),
        modeled={},
    )


def engine_from_plan(cfg: unet.UNetConfig, params, plan: TunedPlan, **kw):
    """A :class:`~repro.segserve.engine.SegEngine` serving ``plan``'s tuned
    operating point (tile, halo, calibrated classes, per-tile quant)."""
    from repro.segserve.engine import SegEngine

    return SegEngine(apply_plan(cfg, plan), params, plan=plan, **kw)


def _engine_logits(params, cfg, images, plan, *, batch: int) -> list:
    """Stitched logits of every image served through ``plan``'s engine."""
    eng = engine_from_plan(cfg, params, plan, batch=batch)
    return [
        eng.run([np.asarray(image, np.float32)])[0].logits
        for image in images
    ]


def _engine_measured(params, cfg, images, plan, *, batch: int,
                     ref_logits=None) -> float:
    """Measured end-to-end rel-err of the exact serving path on the
    calibration images, against the plan's full-8 twin.  ``ref_logits``
    reuses precomputed reference outputs — the certify loop's reference
    (tile, thresholds, all-8 planes) is invariant across repairs."""
    if ref_logits is None:
        ref_logits = _engine_logits(
            params, cfg, images, reference_plan(plan), batch=batch
        )
    got = _engine_logits(params, cfg, images, plan, batch=batch)
    return max(
        _calibrate.rel_err(g, w) for g, w in zip(got, ref_logits)
    )


def tune_unet(
    params,
    cfg: unet.UNetConfig,
    images,
    *,
    target_rel_err: float,
    tile: int | None = None,
    tile_candidates: tuple[int, ...] | None = None,
    max_class: int = 6,
    slack: float = _search.DEFAULT_SLACK,
    margin: float = DEFAULT_MARGIN,
    mode: str = "pipelined",
    batch: int = 4,
    sound_bound: bool = True,
    max_repair: int | None = None,
    calibration: _calibrate.Calibration | None = None,
) -> TunedPlan:
    """Calibrate, search and certify a tuned plan for tiled U-Net serving.

    ``images`` is the calibration/validation set ((H, W, Cin) arrays) the
    certificate is conditioned on — serve the distribution you calibrated.
    ``tile`` pins the core stride (validated); otherwise the tile-size
    search picks it.  ``slack * margin <= 1`` is enforced so the final
    certificate (measured error x ``margin``) provably fits the target.
    ``calibration`` reuses a precomputed (target-independent)
    :func:`~repro.autotune.calibrate.calibrate_unet` record — the frontier
    bench tunes a sweep of targets off one instrumented pass.
    """
    _check_budget_split(slack, margin)
    images = [np.asarray(im, np.float32) for im in images]
    if tile is not None:
        cfg.validate_tile(tile)

    calib = calibration if calibration is not None else (
        _calibrate.calibrate_unet(params, cfg, images, max_class=max_class)
    )
    layers = cfg.conv_layers()
    n_layers = len(layers)

    planes = list(
        _search.greedy_schedule(
            calib, layers, target_rel_err, slack=slack, mode=mode,
            validate=_calibrate.make_rel_err_validator(params, cfg, images),
        )
    )

    def class_tables(base_planes):
        base = PlaneSchedule(
            planes=tuple(base_planes), target_rel_err=target_rel_err
        )
        return tuple(
            base.refine(calib.class_ratios[c]).planes
            for c in range(len(calib.class_thresholds))
        )

    class_planes = class_tables(planes)
    if tile is None:
        from repro.segserve.adaptive import budget_class_from_thresholds

        tile, _ = _search.search_tile(
            cfg, images,
            lambda r: budget_class_from_thresholds(
                r, calib.class_thresholds
            ),
            lambda k: class_planes[k],
            candidates=tile_candidates, mode=mode,
        )
    from repro.segserve.tiling import halo_for

    halo = halo_for(cfg.depth, cfg.convs_per_stage)

    geometry = dict(
        hw=cfg.hw, in_ch=cfg.in_ch, base=cfg.base, depth=cfg.depth,
        convs_per_stage=cfg.convs_per_stage, n_classes=cfg.n_classes,
        impl=cfg.impl, pad_mode=cfg.pad_mode,
    )

    def build(planes_now, class_planes_now, certificate) -> TunedPlan:
        return TunedPlan(
            workload="unet",
            geometry=geometry,
            planes=tuple(planes_now),
            target_rel_err=float(target_rel_err),
            certificate=certificate,
            fingerprint=_calibrate.fingerprint(
                params, images, calibration=calib.fingerprint,
                target_rel_err=target_rel_err, tile=tile, slack=slack,
                margin=margin, mode=mode, batch=batch,
            ),
            params_fingerprint=_calibrate.params_fingerprint(params),
            layer_bounds=_layer_bounds(params, planes_now),
            tile=int(tile),
            halo=int(halo),
            class_thresholds=calib.class_thresholds,
            class_planes=class_planes_now,
            layer_gain=calib.layer_gain,
        )

    # ---- certify through the exact serving path -------------------------
    # The full-8 reference depends only on (tile, thresholds, geometry) —
    # invariant across repairs — so it is served exactly once.  The repair
    # itself is amortized: the re-add order is deterministic given the
    # sensitivity table (``search.repair_sequence``), so the loop reduces
    # to finding the fewest repair steps whose *measured* error fits, and
    # ``search.bisect_repair`` gallops/bisects that depth in O(log) engine
    # replays instead of one replay per re-added plane.
    budget = slack * target_rel_err
    cap = max_repair if max_repair is not None else N_BITS * n_layers
    ref_logits = _engine_logits(
        params, cfg, images,
        reference_plan(build(planes, class_planes, {})), batch=batch,
    )
    seq = _search.repair_sequence(planes, calib.sensitivity, cap)

    def planes_after(t: int) -> list[int]:
        p = list(planes)
        for l in seq[:t]:
            p[l] += 1
        return p

    def measure(t: int) -> float:
        p = planes_after(t)
        return _engine_measured(
            params, cfg, images, build(p, class_tables(p), {}), batch=batch,
            ref_logits=ref_logits,
        )

    repairs, measured, measure_calls = _search.bisect_repair(
        measure, len(seq), budget
    )
    planes = planes_after(repairs)
    class_planes = class_tables(planes)

    cert = float(measured * margin)
    certificate = dict(
        target_rel_err=float(target_rel_err),
        measured_rel_err=float(measured),
        cert=cert,
        margin=float(margin),
        slack=float(slack),
        n_images=len(images),
        repairs=repairs,
        measure_calls=measure_calls,
        holds=bool(cert <= target_rel_err),
    )
    plan = build(planes, class_planes, certificate)
    if sound_bound:
        sb = max(
            _calibrate.tiled_sound_bound(params, cfg, im, plan)
            for im in images
        )
        certificate["sound_bound"] = float(sb)
        plan = build(planes, class_planes, certificate)

    # advisory relation-(2) account for the tracker
    modeled_cycles = sum(
        _search.plan_cycles(
            cfg, im, plan.tile, plan.classify, plan.class_schedule,
            halo=plan.halo, mode=mode,
        )
        for im in images
    )
    full8_cycles = sum(
        _search.plan_cycles(
            cfg, im, plan.tile, lambda r: 0, lambda k: (N_BITS,) * n_layers,
            halo=plan.halo, mode=mode,
        )
        for im in images
    )
    plan = dataclasses.replace(
        plan,
        modeled=dict(
            cycles_calib=int(modeled_cycles),
            full8_cycles_calib=int(full8_cycles),
            mode=mode,
        ),
    )
    return plan


# --------------------------------------------------------------------- LM


def tune_lm(
    params,
    cfg,
    tokens,
    *,
    target_rel_err: float,
    slack: float = _search.DEFAULT_SLACK,
    margin: float = DEFAULT_MARGIN,
    max_repair: int | None = None,
) -> TunedPlan:
    """Measured-and-certified per-layer budgets for a scan-rolled LM.

    Seeds from the analytic weight-only policy
    (:func:`repro.serve.engine.lm_schedule_from_params`), measures the
    end-to-end logits error on ``tokens`` against the full 8-plane
    datapath, and re-adds planes until the measurement fits
    ``slack * target``; the certificate is the final measurement inflated
    by ``margin``.  Install with :func:`apply_plan_lm`.
    """
    from repro import models
    from repro.configs.base import QuantConfig
    from repro.serve.engine import lm_schedule_from_params

    _check_budget_split(slack, margin)
    mod = models.build(cfg)
    toks = jnp.asarray(np.asarray(tokens, np.int32))
    ref = mod.forward(
        params, toks, cfg.replace(quant=QuantConfig(mode="mma_int8", planes=8))
    ).astype(jnp.float32)
    denom = max(float(jnp.max(jnp.abs(ref))), 1e-8)

    def measured(planes) -> float:
        qcfg = cfg.replace(
            quant=QuantConfig(
                mode="mma_int8", planes=8, plane_schedule=tuple(planes)
            )
        )
        out = mod.forward(params, toks, qcfg).astype(jnp.float32)
        return float(jnp.max(jnp.abs(out - ref))) / denom

    seed = lm_schedule_from_params(params, cfg, target_rel_err)
    planes = list(seed.planes)
    budget = slack * target_rel_err
    cap = max_repair if max_repair is not None else N_BITS * len(planes)
    repairs = 0
    m = measured(planes)
    while m > budget and repairs < cap:
        # repair the layer with the fewest planes (ties: largest analytic
        # bound) — the fewest-digit layer is the dominant error source
        fixable = [l for l in range(len(planes)) if planes[l] < N_BITS]
        if not fixable:
            break
        bounds = seed.layer_bounds or (0.0,) * len(planes)
        worst = min(fixable, key=lambda l: (planes[l], -bounds[l]))
        planes[worst] += 1
        repairs += 1
        m = measured(planes)

    cert = float(m * margin)
    return TunedPlan(
        workload="lm",
        geometry=dict(
            family=cfg.family, n_layers=cfg.n_layers,
            d_model=getattr(cfg, "d_model", None),
        ),
        planes=tuple(planes),
        target_rel_err=float(target_rel_err),
        certificate=dict(
            target_rel_err=float(target_rel_err),
            measured_rel_err=float(m),
            cert=cert,
            margin=float(margin),
            slack=float(slack),
            n_tokens=int(toks.size),
            repairs=repairs,
            holds=bool(cert <= target_rel_err),
        ),
        fingerprint=_calibrate.fingerprint(
            params, [np.asarray(toks)], target_rel_err=target_rel_err,
            slack=slack, margin=margin, family=cfg.family,
        ),
        params_fingerprint=_calibrate.params_fingerprint(params),
        layer_bounds=seed.layer_bounds,
    )


def tune_spec(
    params,
    cfg,
    prompts,
    *,
    plan: TunedPlan,
    batch: int = 2,
    max_seq: int = 64,
    max_new: int = 16,
    k_candidates: tuple[int, ...] = (2, 3, 4),
    plane_candidates: tuple[int, ...] = (2, 4, 6),
    mode: str = "pipelined",
) -> TunedPlan:
    """Search the speculative operating point (draft plane budget, depth
    ``k``) that maximizes *accepted tokens per modeled cycle*, and record
    it on an existing certified LM plan (schema v3: ``spec_planes`` /
    ``spec_k``).

    Each candidate runs the real :class:`~repro.serve.specdecode.SpecEngine`
    on the calibration ``prompts`` — acceptance rate is a property of the
    served weights and the draft schedule, not something the cycle model
    can predict — and every round is priced with
    :func:`repro.core.cycle_model.lm_spec_step_cycles` (wasted speculation
    included), so the score is the same honest account the serving ledger
    keeps.  The verify schedule is the plan's certified ``planes``; the
    certificate is untouched because verification runs it end to end —
    speculation changes *when* tokens are computed, never their values.
    """
    from repro.serve.engine import Request
    from repro.serve.specdecode import SpecEngine

    if plan.workload != "lm":
        raise ValueError("tune_spec extends an LM plan")
    qcfg = apply_plan_lm(cfg, plan)
    full_sched = tuple(plan.planes)
    kw = dict(
        n_heads=cfg.n_heads, head_dim=cfg.hd, n_kv_heads=cfg.n_kv_heads,
        context=max_seq, n_experts=cfg.moe.n_experts, top_k=cfg.moe.top_k,
    )
    full_step = cm.lm_step_cycles(
        cfg.d_model, cfg.d_ff, cfg.n_layers, full_sched, mode=mode, **kw
    )
    prompts = [np.asarray(p, np.int32) for p in prompts]

    def run(draft_sched, k):
        eng = SpecEngine(
            qcfg, params, batch=batch, max_seq=max_seq,
            draft_schedule=draft_sched, k=k,
        )
        pending = [
            Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)
        ]
        cycles = emitted = accepted = drafted = 0
        while pending or eng.ready_slots():
            while pending and eng.admit(pending[0]):
                pending.pop(0)
            slots = eng.ready_slots()
            if not slots:
                break
            _, rec = eng.spec_step()
            if rec is None:  # no speculation headroom: plain greedy round
                cycles += full_step * len(slots)
                emitted += len(slots)
                continue
            sc = cm.lm_spec_step_cycles(
                cfg.d_model, cfg.d_ff, cfg.n_layers,
                k=rec["k"], draft_schedule=draft_sched,
                schedule=full_sched, mode=mode, **kw,
            )
            cycles += sc["total_cycles"] * len(rec["slots"])
            emitted += rec["emitted"]
            accepted += rec["accepted"]
            drafted += rec["drafted"]
        return dict(
            cycles=int(cycles), emitted=int(emitted),
            accepted=int(accepted), drafted=int(drafted),
            tokens_per_cycle=emitted / cycles if cycles else 0.0,
        )

    grid = []
    for p in plane_candidates:
        draft_sched = (int(p),) * cfg.n_layers
        for k in k_candidates:
            r = run(draft_sched, int(k))
            grid.append(dict(planes=int(p), k=int(k), **r))
    best = max(grid, key=lambda r: r["tokens_per_cycle"])
    return dataclasses.replace(
        plan,
        spec_planes=(int(best["planes"]),) * cfg.n_layers,
        spec_k=int(best["k"]),
        modeled=dict(
            plan.modeled,
            spec=dict(
                grid=grid,
                best=dict(planes=best["planes"], k=best["k"]),
                # modeled decode speedup at the measured acceptance rate:
                # tokens-per-cycle relative to one full step per token
                speedup=best["tokens_per_cycle"] * full_step,
                mode=mode,
            ),
        ),
        version=max(int(plan.version), 3),
    )


def apply_plan_lm(cfg, plan: TunedPlan):
    """Install an LM plan into an ``ArchConfig`` (rides the layer scan as
    data via ``quant.plane_schedule``, same as the serving engine)."""
    import dataclasses as _dc

    if plan.workload != "lm":
        raise ValueError(f"cannot apply a {plan.workload!r} plan to an LM")
    return cfg.replace(
        quant=_dc.replace(cfg.quant, mode="mma_int8",
                          plane_schedule=tuple(plan.planes))
    )
