"""Calibrated, certified precision/tile planning (the autotune subsystem).

Turns (model, validation batch, error budget, geometry) into a serialized
:class:`TunedPlan` that makes every precision/geometry knob in the stack
self-configuring:

``calibrate`` — instrumented forwards: per-layer activation amplitudes and
               octave histograms, measured per-tile ratio gains (replacing
               the "first-conv ratio holds at every depth" heuristic), the
               single-layer truncation sensitivity table, and the per-tile
               extension of the sound interval certificate;
``search``    — greedy cycles-per-error descent over per-layer plane
               budgets + tile-size search, both minimizing relation-(2)
               cycles subject to the measured error budget;
``plan``      — the :class:`TunedPlan` artifact (schedule, tile/halo,
               calibrated class thresholds, two-tier certificate,
               calibration fingerprint) with atomic JSON round-trip;
``api``       — :func:`tune_unet` / :func:`tune_lm` and the wiring into
               ``UNetConfig``, ``SegEngine`` and the LM serving config.
"""
from . import api, calibrate, plan, search  # noqa: F401
from .api import (  # noqa: F401
    apply_plan,
    apply_plan_lm,
    engine_from_plan,
    reference_plan,
    tune_lm,
    tune_spec,
    tune_unet,
)
from .calibrate import (  # noqa: F401
    Calibration,
    calibrate_unet,
    rel_err,
    tiled_sound_bound,
)
from .plan import TunedPlan  # noqa: F401
