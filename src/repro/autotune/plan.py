"""TunedPlan — the serialized, certified artifact the autotuner produces.

A plan is everything a serving engine needs to run a workload at a tuned
operating point, plus the evidence that made the point trustworthy:

  * the per-layer plane schedule (``planes``) and, for tiled segmentation,
    the tile/halo geometry and the calibrated budget-class table
    (``class_thresholds`` + ``class_planes`` — thresholds come from the
    calibration histogram, per-class refinements from *measured* per-layer
    amplitude ratios, not the fixed-octave heuristic);
  * a two-tier certificate: ``certificate['cert']`` is the bound the CI
    gate enforces — the maximum end-to-end error *measured on the
    calibration set through the exact serving path*, inflated by
    ``certificate['margin']`` and kept ``<= target_rel_err`` by the search;
    ``certificate['sound_bound']`` is the worst-case interval-propagated
    bound (``unet.forward_with_error_bound`` extended per tile) — sound
    unconditionally but loose, recorded for transparency;
  * a ``fingerprint`` binding the plan to the exact weights, calibration
    inputs and knobs it was derived from, so a stale plan is detectable —
    plus a ``params_fingerprint`` over the weights alone, the half a
    serving gateway can re-derive at admission time (it holds the served
    params but not the calibration set) to reject or quarantine a plan
    tuned against different weights (``repro.serve.gateway``).

Plans round-trip losslessly through JSON (``to_json`` / ``from_json``) and
persist with the checkpoint module's crash-safety discipline
(``save`` / ``load`` use :func:`repro.checkpoint.save_json_atomic`).
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field

from repro.core.bitplane import N_BITS
from repro.core.plane_schedule import PlaneSchedule

# v2: + params_fingerprint (weights-only binding, verified at gateway
# admission).  v1 plans load with it as None — unverifiable, so the gateway
# treats them as stale.
# v3: + spec_planes/spec_k (the tune_spec operating point for
# precision-speculative decode).  v1/v2 plans load with both as None —
# speculation simply stays off.
PLAN_VERSION = 3


def _opt_tuple(v, conv=float):
    return None if v is None else tuple(conv(x) for x in v)


@dataclass(frozen=True)
class TunedPlan:
    """Immutable tuned operating point for one workload.

    ``workload`` is ``'unet'`` (tiled segmentation: tile/halo/class fields
    populated) or ``'lm'`` (layer schedule + certificate only).
    ``geometry`` carries the workload-specific shape record the plan was
    tuned against (and is part of the fingerprint's meaning); ``modeled``
    carries advisory relation-(2) accounting for the bench tracker.
    """

    workload: str
    geometry: dict
    planes: tuple[int, ...]
    target_rel_err: float
    certificate: dict
    fingerprint: str
    params_fingerprint: str | None = None
    layer_bounds: tuple[float, ...] | None = None
    tile: int | None = None
    halo: int | None = None
    class_thresholds: tuple[float, ...] | None = None
    class_planes: tuple[tuple[int, ...], ...] | None = None
    layer_gain: tuple[float, ...] | None = None
    modeled: dict = field(default_factory=dict)
    spec_planes: tuple[int, ...] | None = None
    spec_k: int | None = None
    version: int = PLAN_VERSION

    def __post_init__(self):
        if self.workload not in ("unet", "lm"):
            raise ValueError(f"unknown workload {self.workload!r}")
        if not self.planes:
            raise ValueError("empty plane schedule")
        for b in self.planes:
            if not (1 <= int(b) <= N_BITS):
                raise ValueError(f"plane count {b} outside 1..{N_BITS}")
        if not (0.0 < float(self.target_rel_err)):
            raise ValueError(f"target_rel_err {self.target_rel_err} <= 0")
        if (self.class_thresholds is None) != (self.class_planes is None):
            raise ValueError(
                "class_thresholds and class_planes must be set together"
            )
        if self.class_thresholds is not None:
            t = self.class_thresholds
            if not t or t[0] != 1.0:
                raise ValueError(
                    f"class_thresholds must start at 1.0, got {t}"
                )
            if any(a <= b for a, b in zip(t, t[1:])):
                raise ValueError(
                    f"class_thresholds must strictly descend, got {t}"
                )
            if len(self.class_planes) != len(t):
                raise ValueError(
                    f"{len(self.class_planes)} class schedules for "
                    f"{len(t)} thresholds"
                )
            for cp in self.class_planes:
                if len(cp) != len(self.planes):
                    raise ValueError(
                        "every class schedule must cover every layer"
                    )
        if (self.spec_planes is None) != (self.spec_k is None):
            raise ValueError("spec_planes and spec_k must be set together")
        if self.spec_planes is not None:
            if self.workload != "lm":
                raise ValueError("speculative fields are lm-only")
            if len(self.spec_planes) != len(self.planes):
                raise ValueError(
                    f"spec schedule covers {len(self.spec_planes)} layers, "
                    f"plan has {len(self.planes)}"
                )
            for b in self.spec_planes:
                if not (1 <= int(b) <= N_BITS):
                    raise ValueError(
                        f"spec plane count {b} outside 1..{N_BITS}"
                    )
            if int(self.spec_k) < 1:
                raise ValueError(f"spec_k {self.spec_k} < 1")
        if self.workload == "unet":
            if self.tile is None or self.halo is None:
                raise ValueError("a unet plan needs tile and halo")
            # the satellite guard: the halo walk must not prove the tile
            # degenerate for the tuned geometry
            self._unet_config_cls()(
                depth=int(self.geometry["depth"]),
                convs_per_stage=int(self.geometry["convs_per_stage"]),
            ).validate_tile(int(self.tile), halo=int(self.halo))

    @staticmethod
    def _unet_config_cls():
        from repro.models.unet import UNetConfig  # lazy: models are heavy

        return UNetConfig

    # ----------------------------------------------------------- accessors

    def schedule(self) -> PlaneSchedule:
        """The certified layer-level policy as a core schedule object."""
        return PlaneSchedule(
            planes=self.planes,
            target_rel_err=self.target_rel_err,
            layer_bounds=self.layer_bounds,
        )

    @property
    def n_classes(self) -> int:
        """Number of calibrated budget classes (1 when non-adaptive)."""
        return 1 if self.class_thresholds is None else len(self.class_thresholds)

    def classify(self, ratio: float) -> int:
        """Budget class of a tile at ``ratio`` of the image amplitude,
        under the *calibrated* thresholds (largest class whose threshold
        still bounds the ratio — conservative for ratios calibration never
        saw)."""
        from repro.segserve.adaptive import budget_class_from_thresholds

        if self.class_thresholds is None:
            return 0
        return budget_class_from_thresholds(ratio, self.class_thresholds)

    def class_schedule(self, k: int) -> tuple[int, ...]:
        """Per-layer planes micro-batches of class-``k`` tiles run."""
        if self.class_planes is None:
            if k != 0:
                raise ValueError(f"non-adaptive plan has no class {k}")
            return self.planes
        return self.class_planes[k]

    # --------------------------------------------------------- persistence

    def to_json(self) -> dict:
        return asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "TunedPlan":
        d = dict(d)
        version = int(d.pop("version", PLAN_VERSION))
        if version > PLAN_VERSION:
            raise ValueError(
                f"plan version {version} is newer than this code "
                f"({PLAN_VERSION}) — refusing to misread a certificate"
            )
        return cls(
            workload=str(d["workload"]),
            geometry=dict(d["geometry"]),
            planes=tuple(int(b) for b in d["planes"]),
            target_rel_err=float(d["target_rel_err"]),
            certificate=dict(d["certificate"]),
            fingerprint=str(d["fingerprint"]),
            params_fingerprint=(
                None if d.get("params_fingerprint") is None
                else str(d["params_fingerprint"])
            ),
            layer_bounds=_opt_tuple(d.get("layer_bounds")),
            tile=None if d.get("tile") is None else int(d["tile"]),
            halo=None if d.get("halo") is None else int(d["halo"]),
            class_thresholds=_opt_tuple(d.get("class_thresholds")),
            class_planes=(
                None
                if d.get("class_planes") is None
                else tuple(
                    tuple(int(b) for b in cp) for cp in d["class_planes"]
                )
            ),
            layer_gain=_opt_tuple(d.get("layer_gain")),
            modeled=dict(d.get("modeled") or {}),
            spec_planes=_opt_tuple(d.get("spec_planes"), int),
            spec_k=None if d.get("spec_k") is None else int(d["spec_k"]),
            version=version,
        )

    def save(self, path) -> None:
        """Atomic JSON write (crash-safe, same discipline as checkpoints)."""
        from repro.checkpoint import save_json_atomic

        save_json_atomic(path, self.to_json())

    @classmethod
    def load(cls, path) -> "TunedPlan":
        from repro.checkpoint import load_json

        return cls.from_json(load_json(path))

    # ------------------------------------------------------------ describe

    def describe(self) -> str:
        cert = self.certificate.get("cert")
        parts = [
            f"TunedPlan[{self.workload}] planes={list(self.planes)}",
            f"target={self.target_rel_err:g}",
            f"cert={cert:.4g}" if cert is not None else "cert=?",
        ]
        if self.tile is not None:
            parts.append(f"tile={self.tile}(halo {self.halo})")
        if self.class_thresholds is not None:
            parts.append(f"classes={len(self.class_thresholds)}")
        if self.spec_planes is not None:
            parts.append(
                f"spec=k{self.spec_k}@{list(self.spec_planes)}"
            )
        return " ".join(parts)
