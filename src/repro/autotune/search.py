"""Budget/geometry search: cycles minimized subject to a measured error budget.

The objective is hardware-meaningful by construction — relation (2) of the
paper, recomputed per layer under a candidate schedule
(``cycle_model.schedule_cycles``) and per tile window under a candidate
tile size (``cycle_model.unet_window_cycles`` × the halo overhead the
window geometry implies).  The constraint is the *measured* end-to-end
error on the calibration set: the greedy descent steers by the calibrated
first-order sensitivity table (drop the plane with the best
cycles-per-error ratio), then a validation loop re-adds planes — most
error-expensive first — until the measured error fits inside
``slack * target``.  The terminal state (all layers at 8 planes) has zero
truncation error, so the repair always terminates.
"""
from __future__ import annotations

from repro.core import cycle_model as cm
from repro.core.bitplane import N_BITS

from .calibrate import Calibration

# Predicted error must undershoot the target so the certificate's margin
# still fits under it: cert = measured * margin <= slack * margin * target,
# and slack * margin <= 1 is asserted by the API layer.
DEFAULT_SLACK = 0.6


def predicted_err(calib: Calibration, planes) -> float:
    """First-order composition of the measured single-layer sensitivities."""
    return float(
        sum(calib.sensitivity[l][int(b) - 1] for l, b in enumerate(planes))
    )


def greedy_schedule(
    calib: Calibration,
    layers: list[cm.ConvLayerSpec],
    target_rel_err: float,
    *,
    slack: float = DEFAULT_SLACK,
    mode: str = "pipelined",
    validate=None,
) -> tuple[int, ...]:
    """Fewest-cycle per-layer budgets whose error fits the budget.

    Greedy steepest descent on measured sensitivities: repeatedly drop the
    single plane with the best (cycles saved / predicted error added) ratio
    while the first-order error prediction stays within ``slack * target``.
    If a ``validate(planes) -> measured`` callback is given (the traced
    whole-canvas forward), a repair loop then re-adds planes — largest
    sensitivity contribution first — until the *measured* error also fits:
    sensitivities compose only to first order, and the measurement, not the
    prediction, is what the certificate will be built from.
    """
    if not (0.0 < slack <= 1.0):
        raise ValueError(f"slack {slack} outside (0, 1]")
    n_layers = len(layers)
    if calib.n_layers != n_layers:
        raise ValueError(
            f"calibration covers {calib.n_layers} layers, geometry has "
            f"{n_layers}"
        )
    budget = slack * target_rel_err

    def layer_cycles(l: int, b: int) -> int:
        return layers[l].cycles(
            tile_cycles=cm.schedule_tile_cycles(b, mode=mode)
        )

    planes = [N_BITS] * n_layers
    pred = 0.0
    while True:
        best = None
        for l in range(n_layers):
            b = planes[l]
            if b <= 1:
                continue
            d_err = (
                calib.sensitivity[l][b - 2] - calib.sensitivity[l][b - 1]
            )
            if pred + max(d_err, 0.0) > budget:
                continue
            d_cyc = layer_cycles(l, b) - layer_cycles(l, b - 1)
            score = d_cyc / max(d_err, 1e-12)
            if best is None or score > best[0]:
                best = (score, l, d_err)
        if best is None:
            break
        _, l, d_err = best
        planes[l] -= 1
        pred += max(d_err, 0.0)

    if validate is not None:
        while validate(tuple(planes)) > budget:
            # re-add the plane whose sensitivity contribution is largest
            worst = max(
                (l for l in range(n_layers) if planes[l] < N_BITS),
                key=lambda l: calib.sensitivity[l][planes[l] - 1],
                default=None,
            )
            if worst is None:
                break  # all layers back at 8 planes: zero truncation error
            planes[worst] += 1
    return tuple(planes)


def repair_sequence(planes, sensitivity, cap: int) -> list[int]:
    """The deterministic order repair re-adds planes in: repeatedly the
    fixable layer whose measured sensitivity contribution is largest (the
    dominant error source), exactly the rule the one-at-a-time loop used.
    Returns the layer index per step; applying a prefix of length ``t``
    gives the plane vector after ``t`` repairs."""
    p = list(planes)
    seq: list[int] = []
    while len(seq) < cap:
        worst = max(
            (l for l in range(len(p)) if p[l] < N_BITS),
            key=lambda l: sensitivity[l][p[l] - 1],
            default=None,
        )
        if worst is None:
            break
        p[worst] += 1
        seq.append(worst)
    return seq


def bisect_repair(measure, seq_len: int, budget: float):
    """Fewest repair steps whose measured error fits ``budget``, amortized.

    ``measure(t) -> float`` serves the calibration set at the plane vector
    after ``t`` repair steps — the expensive call (a full engine replay per
    invocation).  The one-at-a-time loop paid ``t* + 1`` measurements for a
    repair depth of ``t*``; this gallops (probe 1, 2, 4, ... until the
    error fits) and then bisects the bracketed interval, so deep repairs
    cost ``O(log t*)`` measurements while shallow ones (``t* <= 2``, the
    common case) pay exactly what the linear scan did.  Assumes error is
    non-increasing in repair depth — the same assumption the linear loop
    made; a non-monotone landscape still terminates at a *valid* certified
    point (the certificate is built from the measurement at the served
    vector), it just may not be the minimal one.

    Returns ``(t, measured_at_t, n_measure_calls)``.  When even the full
    sequence fails the budget the full depth is returned (the caller's cap
    semantics: serve the best achievable point, certificate records the
    miss).
    """
    calls = 0

    def m(t: int) -> float:
        nonlocal calls
        calls += 1
        return measure(t)

    got = m(0)
    if got <= budget or seq_len == 0:
        return 0, got, calls
    lo = 0  # known to fail
    t = 1
    while True:
        t = min(t, seq_len)
        got = m(t)
        if got <= budget:
            hi, m_hi = t, got
            break
        lo = t
        if t == seq_len:
            return seq_len, got, calls
        t *= 2
    while hi - lo > 1:
        mid = (lo + hi) // 2
        got = m(mid)
        if got <= budget:
            hi, m_hi = mid, got
        else:
            lo = mid
    return hi, m_hi, calls


def tile_candidates(cfg, images, *, limit: int = 8) -> tuple[int, ...]:
    """Viable core strides for ``images`` under ``cfg``'s geometry: multiples
    of ``2**depth`` from the minimum viable tile (the halo-walk guard) up to
    the largest canvas edge, thinned to at most ``limit`` candidates."""
    mult = 2**cfg.depth
    lo = cfg.min_viable_tile()
    hi = 0
    for im in images:
        h, w = im.shape[0], im.shape[1]
        hi = max(hi, -(-h // mult) * mult, -(-w // mult) * mult)
    hi = max(hi, lo)
    cands = list(range(lo, hi + 1, mult))
    if len(cands) > limit:
        step = (len(cands) - 1) / (limit - 1)
        cands = sorted({cands[round(i * step)] for i in range(limit)})
    return tuple(cands)


def plan_cycles(
    cfg, image, tile: int, classify, class_schedule, *,
    halo: int | None = None, mode: str = "pipelined",
) -> int:
    """Modeled relation-(2) cycles of serving one image at core stride
    ``tile`` under a class table: every tile window priced at its class's
    refined schedule (budget classes from the *input* canvas, exactly as
    admission will assign them).  ``classify(ratio) -> k`` and
    ``class_schedule(k) -> planes`` are the plan's calibrated tables."""
    import numpy as np

    from repro.segserve import tiling
    from repro.segserve.adaptive import amplitude_ratio

    image = np.asarray(image, np.float32)
    tplan = tiling.plan_tiles(
        image.shape[0], image.shape[1], depth=cfg.depth,
        convs_per_stage=cfg.convs_per_stage, tile=tile, halo=halo,
    )
    canvas = tiling.pad_canvas(image, tplan)
    amax = float(np.max(np.abs(canvas)))
    total = 0
    for spec in tplan.tiles:
        r = amplitude_ratio(canvas[spec.y0 : spec.y1, spec.x0 : spec.x1], amax)
        total += cm.unet_window_cycles(
            (spec.in_h, spec.in_w), cfg.in_ch, cfg.base, cfg.depth,
            cfg.convs_per_stage, class_schedule(classify(r)), mode=mode,
        )
    return total


def search_tile(
    cfg,
    images,
    classify,
    class_schedule,
    *,
    candidates: tuple[int, ...] | None = None,
    mode: str = "pipelined",
) -> tuple[int, int]:
    """Pick the core stride minimizing total modeled cycles over the
    calibration images (halo overhead vs adaptivity is the trade: big tiles
    amortize the halo, small tiles isolate quiet background into cheap
    budget classes).  Returns ``(tile, modeled_cycles)``."""
    if candidates is None:
        candidates = tile_candidates(cfg, images)
    if not candidates:
        raise ValueError("no viable tile candidates")
    best: tuple[int, int] | None = None
    for tile in candidates:
        cfg.validate_tile(tile)
        total = 0
        for image in images:
            total += plan_cycles(
                cfg, image, tile, classify, class_schedule, mode=mode
            )
        if best is None or total < best[1] or (
            total == best[1] and tile < best[0]
        ):
            best = (tile, total)
    return best
