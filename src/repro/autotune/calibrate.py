"""Calibration: measured activation statistics the search derives budgets from.

The per-layer schedule of PR 1 is chosen from an analytic *weight-only*
bound, and the per-tile refinement of PR 2 assumes the input-window
amplitude ratio holds at every depth ("exact at the first conv, heuristic
deeper" — ROADMAP).  MINT and DSLR-CNN both derive digit budgets from
*measured activation statistics*; this module measures them:

  * **per-layer amplitude** — instrumented full-precision forwards over a
    validation set record each conv's post-ReLU abs-max (``unet.forward``'s
    ``taps`` hook), per whole canvas and per halo tile window;
  * **per-layer tile ratios** — how a tile's amplitude at depth ``l``
    relates to its *input* ratio: the measured gain table replaces the
    deeper-layer heuristic, and per-class direct maxima catch the bias
    floor of flat windows;
  * **octave histogram → calibrated thresholds** — budget-class boundaries
    come from the amplitude octaves the data actually occupies (empty
    octaves collapse, so the serving engine compiles fewer class
    signatures);
  * **per-layer sensitivity** — measured end-to-end relative error of
    truncating exactly one layer to each budget, swept in a *single
    compilation* via the traced ``planes_arr`` hook (the budgets ride in as
    data through the exact bit-mask identity);
  * **sound per-tile certificate** — :func:`tiled_sound_bound` extends the
    interval machinery of ``unet.forward_with_error_bound`` to a tiled,
    class-refined deployment: worst-case interval propagation per tile
    window at its refined schedule, normalized by the whole-canvas
    full-precision amplitude.

Everything is deterministic given (params, images, knobs); the
``fingerprint`` binds a downstream :class:`~repro.autotune.plan.TunedPlan`
to exactly those inputs.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitplane import N_BITS
from repro.models import unet
from repro.segserve import tiling
from repro.segserve.adaptive import (
    amplitude_ratio,
    budget_class,
    budget_class_from_thresholds,
)

# Ratios below this floor contribute to per-class direct maxima but not to
# the gain table: gain = ratio_l / ratio_in diverges as ratio_in -> 0, and
# flat windows are governed by their measured bias floor instead.
GAIN_FLOOR = 2.0**-12


def _hash_arrays(h, arrays) -> None:
    for leaf in arrays:
        a = np.asarray(leaf)
        h.update(str((a.shape, str(a.dtype))).encode())
        h.update(np.ascontiguousarray(a).tobytes())


def params_fingerprint(params) -> str:
    """SHA-256 over the exact served weights alone — the half of a plan's
    binding a serving gateway can re-derive at admission time (it holds the
    params but not the calibration inputs), so a plan tuned against
    different weights is detectable before a single request runs on it."""
    h = hashlib.sha256()
    _hash_arrays(h, jax.tree.leaves(params))
    return h.hexdigest()


def fingerprint(params, images, **knobs) -> str:
    """SHA-256 over the exact weights, calibration inputs and knobs a plan
    was derived from — byte-level, so any drift invalidates the plan."""
    h = hashlib.sha256()
    _hash_arrays(h, jax.tree.leaves(params))
    _hash_arrays(h, images)
    h.update(repr(sorted((k, repr(v)) for k, v in knobs.items())).encode())
    return h.hexdigest()


@dataclass(frozen=True)
class Calibration:
    """Measured statistics of one (params, validation set, geometry) triple.

    ``sensitivity[l][b-1]`` is the measured end-to-end relative error of the
    whole-canvas forward with *only* layer ``l`` truncated to ``b`` planes
    (max over the calibration images; ``sensitivity[l][7] == 0`` by
    construction).  ``class_ratios[c][l]`` is the calibrated per-layer
    amplitude-ratio bound for threshold class ``c`` —
    ``min(1, max(measured direct max, threshold * layer_gain))`` — the
    ratio :meth:`repro.core.PlaneSchedule.refine` consumes per class.
    """

    fingerprint: str
    n_images: int
    tile: int
    max_class: int
    layer_amax: tuple[float, ...]
    layer_gain: tuple[float, ...]
    sensitivity: tuple[tuple[float, ...], ...]
    octave_hist: tuple[int, ...]
    class_thresholds: tuple[float, ...]
    class_ratios: tuple[tuple[float, ...], ...]
    class_counts: tuple[int, ...]

    @property
    def n_layers(self) -> int:
        return len(self.layer_amax)


def _require_quant(cfg: unet.UNetConfig) -> None:
    if cfg.quant_mode != "mma_int8":
        raise ValueError(
            "autotune calibrates the digit-serial datapath; pass a "
            "UNetConfig with quant_mode='mma_int8' (the float path has no "
            "plane budgets to tune)"
        )


def _full8(cfg: unet.UNetConfig) -> unet.UNetConfig:
    return dataclasses.replace(cfg, plane_schedule=None, planes=8)


def rel_err(out, ref) -> float:
    """The one error metric of the subsystem: max |out - ref| over a
    guarded max |ref| — shared by calibration, certification and the
    benches so the certificate and the gate can never drift apart."""
    denom = max(float(jnp.max(jnp.abs(ref))), 1e-8)
    return float(jnp.max(jnp.abs(jnp.asarray(out) - jnp.asarray(ref)))) / denom


_rel_err = rel_err


@functools.lru_cache(maxsize=32)
def _planes_forward(full_cfg: unet.UNetConfig):
    """Process-wide jitted schedule-sweep forward (one compile per geometry
    x window shape; candidate budgets are traced data) — the calibration
    sensitivity sweep and the search's validation loop share it."""
    return jax.jit(
        lambda p, x, arr: unet.forward(p, x, full_cfg, planes_arr=arr)
    )


def calibrate_unet(
    params,
    cfg: unet.UNetConfig,
    images,
    *,
    tile: int | None = None,
    max_class: int = 6,
    budgets: tuple[int, ...] = (7, 6, 5, 4, 3, 2, 1),
) -> Calibration:
    """Instrumented calibration pass over ``images`` (each (H, W, Cin)).

    ``tile`` is the stats tiling (defaults to the geometry's minimum viable
    tile); the measured ratio/gain tables generalize across nearby tile
    sizes and the tile-size search re-prices geometry analytically.
    """
    _require_quant(cfg)
    if not images:
        raise ValueError("calibration needs at least one image")
    full_cfg = _full8(cfg)
    if tile is None:
        tile = cfg.min_viable_tile()
    else:
        cfg.validate_tile(tile)
    n_layers = len(cfg.conv_layers())

    # one jitted taps forward per window shape (windows share few shapes)
    def _taps_forward(p, x):
        taps: list = []
        out = unet.forward(p, x, full_cfg, taps=taps)
        return out, tuple(jnp.max(jnp.abs(t)) for t in taps)

    taps_fwd = jax.jit(_taps_forward)

    # one compilation serves every sensitivity schedule (traced planes_arr)
    planes_fwd = _planes_forward(full_cfg)

    layer_amax = np.zeros(n_layers)
    octave_hist = np.zeros(max_class + 1, np.int64)
    # raw per-tile records: (input ratio, per-layer ratios)
    tile_records: list[tuple[float, np.ndarray]] = []
    sens = np.zeros((n_layers, N_BITS))

    for image in images:
        image = np.asarray(image, np.float32)
        plan = tiling.plan_tiles(
            image.shape[0], image.shape[1], depth=cfg.depth,
            convs_per_stage=cfg.convs_per_stage, tile=tile,
        )
        canvas = tiling.pad_canvas(image, plan)
        x = jnp.asarray(canvas[None])
        _, canvas_taps = taps_fwd(params, x)
        canvas_taps = np.asarray([float(t) for t in canvas_taps])
        layer_amax = np.maximum(layer_amax, canvas_taps)
        canvas_amax = float(np.max(np.abs(canvas)))

        for spec in plan.tiles:
            win = canvas[spec.y0 : spec.y1, spec.x0 : spec.x1]
            r_in = amplitude_ratio(win, canvas_amax)
            octave_hist[budget_class(r_in, max_class=max_class)] += 1
            _, win_taps = taps_fwd(params, jnp.asarray(win[None]))
            ratios = np.asarray([float(t) for t in win_taps]) / np.maximum(
                canvas_taps, 1e-12
            )
            tile_records.append((r_in, np.minimum(ratios, 1.0)))

        # per-layer sensitivity sweep, one executable
        ref = planes_fwd(params, x, jnp.full((n_layers,), 8, jnp.int32))
        for l in range(n_layers):
            for b in budgets:
                arr = np.full((n_layers,), 8, np.int32)
                arr[l] = b
                out = planes_fwd(params, x, jnp.asarray(arr))
                sens[l, b - 1] = max(sens[l, b - 1], _rel_err(out, ref))

    # ---- calibrated thresholds: collapse unoccupied amplitude octaves ----
    occupied = sorted({0} | {k for k in range(max_class + 1) if octave_hist[k]})
    thresholds = tuple(2.0**-k if k else 1.0 for k in occupied)

    # ---- measured gain table + per-class direct maxima ------------------
    gains = np.ones(n_layers)
    direct = np.zeros((len(thresholds), n_layers))
    counts = np.zeros(len(thresholds), np.int64)
    for r_in, ratios in tile_records:
        if r_in >= GAIN_FLOOR:
            gains = np.maximum(gains, ratios / r_in)
        c = budget_class_from_thresholds(r_in, thresholds)
        counts[c] += 1
        direct[c] = np.maximum(direct[c], ratios)

    class_ratios = []
    for c, t in enumerate(thresholds):
        rho = np.minimum(1.0, np.maximum(direct[c], t * gains))
        class_ratios.append(tuple(float(v) for v in rho))

    return Calibration(
        fingerprint=fingerprint(
            params, images, cfg=repr(cfg), tile=tile, max_class=max_class,
            budgets=budgets,
        ),
        n_images=len(images),
        tile=tile,
        max_class=max_class,
        layer_amax=tuple(float(v) for v in layer_amax),
        layer_gain=tuple(float(v) for v in gains),
        sensitivity=tuple(tuple(float(v) for v in row) for row in sens),
        octave_hist=tuple(int(v) for v in octave_hist),
        class_thresholds=thresholds,
        class_ratios=tuple(class_ratios),
        class_counts=tuple(int(v) for v in counts),
    )


def make_rel_err_validator(params, cfg: unet.UNetConfig, images):
    """``validate(planes) -> measured rel err`` (whole-canvas, vs the full
    8-plane datapath, max over ``images``) — the search's fast validator.
    The per-image full-8 references depend only on (params, images), so they
    are computed once here and every candidate schedule pays a single
    forward per image (one compilation; budgets ride in as data)."""
    _require_quant(cfg)
    fwd = _planes_forward(_full8(cfg))
    n_layers = len(cfg.conv_layers())
    xs, refs = [], []
    for image in images:
        image = np.asarray(image, np.float32)
        plan = tiling.plan_tiles(
            image.shape[0], image.shape[1], depth=cfg.depth,
            convs_per_stage=cfg.convs_per_stage, tile=cfg.min_viable_tile(),
        )
        x = jnp.asarray(tiling.pad_canvas(image, plan)[None])
        xs.append(x)
        refs.append(fwd(params, x, jnp.full((n_layers,), 8, jnp.int32)))

    def validate(planes) -> float:
        arr = jnp.asarray(np.asarray(planes, np.int32))
        if arr.shape != (n_layers,):
            raise ValueError(f"schedule shape {arr.shape} != ({n_layers},)")
        return max(
            _rel_err(fwd(params, x, arr), ref) for x, ref in zip(xs, refs)
        )

    return validate


def measured_rel_err(params, cfg: unet.UNetConfig, images, planes) -> float:
    """One-shot form of :func:`make_rel_err_validator`."""
    return make_rel_err_validator(params, cfg, images)(planes)


def tiled_sound_bound(params, cfg: unet.UNetConfig, image, plan) -> float:
    """Worst-case *sound* bound for a tiled, class-refined deployment of
    ``plan`` on ``image``: the interval machinery of
    ``unet.forward_with_error_bound`` run per tile window at the window's
    refined schedule, abs bounds taken against the whole-canvas
    full-precision amplitude.  Unconditionally sound for the per-tile-
    quantized serving path — and honestly loose: op-norm propagation
    compounds worst cases the measured certificate does not."""
    _require_quant(cfg)
    image = np.asarray(image, np.float32)
    tplan = tiling.plan_tiles(
        image.shape[0], image.shape[1], depth=cfg.depth,
        convs_per_stage=cfg.convs_per_stage, tile=plan.tile, halo=plan.halo,
    )
    canvas = tiling.pad_canvas(image, tplan)
    canvas_amax = float(np.max(np.abs(canvas)))
    out_full = unet.forward(params, jnp.asarray(canvas[None]), _full8(cfg))
    denom = max(float(jnp.max(jnp.abs(out_full))), 1e-8)
    worst_abs = 0.0
    for spec in tplan.tiles:
        win = canvas[spec.y0 : spec.y1, spec.x0 : spec.x1]
        k = plan.classify(amplitude_ratio(win, canvas_amax))
        ccfg = dataclasses.replace(
            cfg, plane_schedule=tuple(plan.class_schedule(k)), planes=8
        )
        _, out_f, rel = unet.forward_with_error_bound(
            params, jnp.asarray(win[None]), ccfg
        )
        worst_abs = max(worst_abs, rel * float(jnp.max(jnp.abs(out_f))))
    return worst_abs / denom
