from . import ckpt  # noqa: F401
from .ckpt import Checkpointer, load_json, save_json_atomic  # noqa: F401
