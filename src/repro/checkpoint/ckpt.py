"""Fault-tolerant checkpointing: atomic, async, elastic-restorable.

Layout (one directory per step):
    <dir>/step_000123/
        manifest.json     tree structure, per-leaf shape/dtype, step
        leaf_00000.npy    one file per leaf (np.save)
    <dir>/LATEST          text file naming the last *committed* step dir

Guarantees:
  * **atomic commit** — writes go to ``step_X.tmp`` then os.rename; LATEST
    is updated last, so a crash mid-save never corrupts the restore point.
  * **async** — ``save_async`` snapshots device arrays to host (blocking
    only on device->host copy) and writes files on a worker thread; the
    train loop overlaps the next steps with the disk write (checkpoint/
    restart requirement at scale: write time >> step time must not stall).
  * **elastic restore** — leaves are stored unsharded (gathered); restoring
    under a *different* mesh re-shards via ``jax.device_put`` with the new
    NamedShardings, so node counts can change between runs.  At real
    multi-pod scale this becomes per-host shard files + a gather-free
    restore; the manifest format already carries the leaf -> spec mapping.
  * **retention** — keep the last ``keep`` checkpoints, delete older.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np

# numpy can't serialize ml_dtypes (bfloat16, fp8, ...): store the raw bits as
# a same-width uint view and record the logical dtype in the manifest.
_RAW_VIEW = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def save_json_atomic(path: str | os.PathLike, obj) -> Path:
    """Write a JSON document with the same crash-safety discipline as a
    checkpoint step: serialize to ``<path>.tmp`` first, fsync-free rename
    last, so a reader never sees a torn file.  Used for small sidecar
    artifacts (``repro.autotune.TunedPlan``, bench payloads) that must be
    restorable next to the weights they describe."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_text(json.dumps(obj, indent=2, sort_keys=True) + "\n")
    os.rename(tmp, path)
    return path


def load_json(path: str | os.PathLike):
    """Read a document written by :func:`save_json_atomic`."""
    return json.loads(Path(path).read_text())


def _to_storable(a: np.ndarray) -> tuple[np.ndarray, str]:
    a = np.asarray(a)
    if a.dtype.kind in "biufc":
        return a, str(a.dtype)
    return a.view(_RAW_VIEW[a.dtype.itemsize]), str(a.dtype)


def _from_storable(a: np.ndarray, dtype_str: str) -> np.ndarray:
    if str(a.dtype) == dtype_str:
        return a
    return a.view(np.dtype(dtype_str))


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _manifest(treedef, leaves, step: int) -> dict:
    return {
        "step": step,
        "treedef": str(treedef),
        "leaves": [
            {"shape": list(np.shape(l)), "dtype": str(np.asarray(l).dtype)}
            for l in leaves
        ],
    }


class Checkpointer:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state) -> Path:
        self.wait()
        host = jax.tree.map(lambda a: np.asarray(a), state)
        return self._write(step, host)

    def save_async(self, step: int, state) -> None:
        """Snapshot to host now; write on a background thread."""
        self.wait()
        host = jax.tree.map(lambda a: np.asarray(a), state)  # D2H copy
        self._thread = threading.Thread(target=self._write, args=(step, host))
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state) -> Path:
        leaves, treedef = _flatten(host_state)
        final = self.dir / f"step_{step:09d}"
        tmp = self.dir / f"step_{step:09d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        for i, leaf in enumerate(leaves):
            arr, _ = _to_storable(np.asarray(leaf))
            np.save(tmp / f"leaf_{i:05d}.npy", arr)
        (tmp / "manifest.json").write_text(json.dumps(_manifest(treedef, leaves, step)))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        (self.dir / "LATEST.tmp").write_text(final.name)
        os.rename(self.dir / "LATEST.tmp", self.dir / "LATEST")
        self._gc()
        return final

    def _gc(self):
        steps = sorted(p for p in self.dir.glob("step_*") if p.is_dir() and not p.name.endswith(".tmp"))
        for p in steps[: -self.keep]:
            shutil.rmtree(p, ignore_errors=True)

    # ---------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        f = self.dir / "LATEST"
        if not f.exists():
            return None
        name = f.read_text().strip()
        if not (self.dir / name / "manifest.json").exists():
            return None
        return int(name.split("_")[1])

    def restore(self, like, step: int | None = None, shardings=None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        NamedShardings for elastic re-shard on a (possibly different) mesh."""
        if step is None:
            step = self.latest_step()
            assert step is not None, "no checkpoint found"
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        like_leaves, treedef = _flatten(like)
        leaves = [
            _from_storable(np.load(d / f"leaf_{i:05d}.npy"),
                           manifest["leaves"][i]["dtype"])
            for i in range(len(like_leaves))
        ]
        if shardings is not None:
            sh_leaves = jax.tree.leaves(
                shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
            )
            leaves = [jax.device_put(l, s) for l, s in zip(leaves, sh_leaves)]
        else:
            leaves = [
                jax.numpy.asarray(l, dtype=ll.dtype)
                for l, ll in zip(leaves, like_leaves)
            ]
        return jax.tree.unflatten(treedef, leaves), step
