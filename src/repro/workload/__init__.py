"""Workload subsystem: trace-driven open-loop load generation.

Three layers, one artifact:

* :mod:`repro.workload.arrivals` — seeded arrival processes (deterministic
  / Poisson / Markov-modulated on-off bursts) on the modeled cycle clock,
  pure functions of ``(seed, index)`` via a counter PRNG;
* :mod:`repro.workload.trace` — the versioned, serialized
  :class:`~repro.workload.trace.Trace` schema (request kind + payload spec
  + QoS class + arrival cycle + deadline), persisted atomically; canonical
  traces live under ``traces/`` in the repo root;
* :mod:`repro.workload.replay` — the open-loop harness that injects a
  trace's arrivals *inside* gateway rounds at their stamped cycles and
  summarizes per-class latency / GOPS-per-W in the bench tracker schema;
  :func:`~repro.workload.replay.replay_stream` is its lazy twin for
  generator feeds that never materialize;
* :mod:`repro.workload.diurnal` — streaming diurnal/burst generators:
  infinite prefix-stable twins of the arrival processes, day-curve
  thinning (:func:`~repro.workload.diurnal.modulate`), and
  :func:`~repro.workload.diurnal.stream_requests` composing them into
  the lazy feed the capacity planner drives.
"""
from . import arrivals, diurnal, replay, trace  # noqa: F401
from .replay import (  # noqa: F401
    lm_materializer,
    replay as replay_trace,
    replay_stream,
    seg_materializer,
)
from .trace import Trace, TraceRequest, from_streams  # noqa: F401
