"""Workload subsystem: trace-driven open-loop load generation.

Three layers, one artifact:

* :mod:`repro.workload.arrivals` — seeded arrival processes (deterministic
  / Poisson / Markov-modulated on-off bursts) on the modeled cycle clock,
  pure functions of ``(seed, index)`` via a counter PRNG;
* :mod:`repro.workload.trace` — the versioned, serialized
  :class:`~repro.workload.trace.Trace` schema (request kind + payload spec
  + QoS class + arrival cycle + deadline), persisted atomically; canonical
  traces live under ``traces/`` in the repo root;
* :mod:`repro.workload.replay` — the open-loop harness that injects a
  trace's arrivals *inside* gateway rounds at their stamped cycles and
  summarizes per-class latency / GOPS-per-W in the bench tracker schema.
"""
from . import arrivals, replay, trace  # noqa: F401
from .replay import lm_materializer, replay as replay_trace, seg_materializer  # noqa: F401
from .trace import Trace, TraceRequest, from_streams  # noqa: F401
