"""Seeded open-loop arrival processes on the modeled cycle clock.

A load generator for the serving gateway must be *open-loop* (arrivals do
not wait for completions — the classic closed-loop bench bug that hides
queueing collapse) and *reproducible* (a trace regenerated from its seed
is bit-identical, so benches and CI replay the same traffic forever).

Every process here is a pure function of ``(seed, index)`` via a
counter-based PRNG (SplitMix64 mixing of the seed and a draw counter —
the same construction counter-mode Philox/Threefry engines use): no
stateful generator object, no global RNG, and — per the repo's modeled-
clock discipline — no wall-clock anywhere.  All timestamps are integer
**modeled cycles** (the relation-(2) clock of ``core.cycle_model``, 100
cycles per microsecond at the paper's 100 MHz).

Three process families cover the serving-paper traffic shapes:

``deterministic``
    Evenly spaced arrivals — the isolation baseline.
``poisson``
    Memoryless arrivals at a mean interval: exponential gaps by inverse-
    CDF over counter-PRNG uniforms.
``on_off``
    A two-state Markov-modulated Poisson process: exponentially
    distributed ON dwells emitting Poisson arrivals, silent OFF dwells —
    the bursty shape that separates fair-share from FIFO and preemptive
    from atomic execution in the gateway bench.
"""
from __future__ import annotations

import math

_M64 = (1 << 64) - 1


def _splitmix64(x: int) -> int:
    """One SplitMix64 output for counter ``x`` — the standard 64-bit
    finalizer (Steele et al.), bijective and well-mixed."""
    x = (x + 0x9E3779B97F4A7C15) & _M64
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
    return (z ^ (z >> 31)) & _M64


def counter_uniform(seed: int, *counters: int) -> float:
    """Uniform in [0, 1) as a pure function of ``(seed, *counters)``.

    Folds the seed and each counter through SplitMix64 (chained, so
    distinct counter tuples decorrelate) and keeps 53 mantissa bits."""
    h = _splitmix64(int(seed) & _M64)
    for c in counters:
        h = _splitmix64(h ^ (int(c) & _M64))
    return (h >> 11) / float(1 << 53)


def _exp_gap(seed: int, mean: float, *counters: int) -> float:
    """Exponential inter-arrival gap by inverse CDF (never returns inf:
    the uniform is drawn in (0, 1])."""
    u = 1.0 - counter_uniform(seed, *counters)
    return -mean * math.log(u)


def deterministic(n: int, *, interval: int, start: int = 0) -> list[int]:
    """``n`` evenly spaced arrivals: ``start, start+interval, ...``."""
    if n < 0:
        raise ValueError(f"n {n} < 0")
    if interval < 1:
        raise ValueError(f"interval {interval} < 1 cycle")
    return [start + i * int(interval) for i in range(n)]


def poisson(n: int, *, mean_interval: float, seed: int,
            start: int = 0) -> list[int]:
    """``n`` Poisson arrivals at ``mean_interval`` modeled cycles between
    arrivals (rate = 1/mean_interval), stamped from ``start``.

    Arrival ``i`` is the rounded cumulative sum of ``i+1`` exponential
    gaps, each a pure function of ``(seed, i)`` — same seed, same trace.
    """
    if n < 0:
        raise ValueError(f"n {n} < 0")
    if mean_interval <= 0:
        raise ValueError(f"mean_interval {mean_interval} <= 0")
    out: list[int] = []
    t = float(start)
    for i in range(n):
        t += _exp_gap(seed, mean_interval, 0x9015504E, i)
        out.append(int(round(t)))
    return out


def on_off(
    n: int,
    *,
    seed: int,
    burst_interval: float,
    on_mean: float,
    off_mean: float,
    start: int = 0,
) -> list[int]:
    """``n`` arrivals from a two-state Markov-modulated Poisson process.

    The source alternates exponentially distributed ON dwells (mean
    ``on_mean`` cycles) emitting Poisson arrivals at ``burst_interval``
    mean spacing, and silent OFF dwells (mean ``off_mean``).  The process
    starts ON at ``start``.  Dwell ``d`` and arrival ``i`` are pure
    functions of ``(seed, d)`` / ``(seed, i)``, so truncating or extending
    ``n`` never reshuffles earlier arrivals.
    """
    if n < 0:
        raise ValueError(f"n {n} < 0")
    for name, v in (("burst_interval", burst_interval),
                    ("on_mean", on_mean), ("off_mean", off_mean)):
        if v <= 0:
            raise ValueError(f"{name} {v} <= 0")
    out: list[int] = []
    t = float(start)  # current clock
    dwell = 0  # dwell counter (even = ON, odd = OFF)
    i = 0  # arrival counter
    next_gap = _exp_gap(seed, burst_interval, 0x0A44117A, i)
    while len(out) < n:
        on_len = _exp_gap(seed, on_mean, 0x00FFDEAD, dwell)
        on_end = t + on_len
        # emit arrivals that land inside this ON dwell
        while len(out) < n and t + next_gap <= on_end:
            t += next_gap
            out.append(int(round(t)))
            i += 1
            next_gap = _exp_gap(seed, burst_interval, 0x0A44117A, i)
        if len(out) >= n:
            break
        # the pending gap straddles the OFF dwell: the residual carries
        next_gap -= on_end - t
        t = on_end + _exp_gap(seed, off_mean, 0x0FF0FF00, dwell + 1)
        dwell += 2
    return out


PROCESSES = ("deterministic", "poisson", "on_off")


def generate(process: str, n: int, **kw) -> list[int]:
    """Dispatch by name (the trace builder's serialization-friendly
    surface): ``generate('poisson', 100, mean_interval=5e5, seed=7)``."""
    if process == "deterministic":
        return deterministic(n, **kw)
    if process == "poisson":
        return poisson(n, **kw)
    if process == "on_off":
        return on_off(n, **kw)
    raise ValueError(f"unknown arrival process {process!r}; one of {PROCESSES}")
