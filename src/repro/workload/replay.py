"""Open-loop trace replay against the serving gateway.

The harness walks a :class:`~repro.workload.trace.Trace` on the gateway's
modeled cycle clock: each round, the arrivals stamped inside that round's
cycle span are handed to :meth:`Gateway.step_round`, which injects them
*mid-round* at their exact offsets (execution runs to the stamp, the
request is submitted with ``arrival_cycle`` set, a mid-round admission
pass runs, execution resumes).  Arrivals never wait for completions —
the load is open-loop, so queueing delay shows up as latency instead of
silently throttling the generator.

Payloads are materialized from each request's compact spec and the trace
seed (deterministic per request index), so replaying the same trace twice
— or on different machines — submits bit-identical prompts and images.

The same harness drives a :class:`~repro.serve.fabric.Fabric`: the fabric
exposes the gateway surface (``clock``/``round_budget``/``step_round``/
``pending``/``stats``), its ``step_round`` routes each injected arrival
to a shard, and the shard then sees the identical open-loop contract a
single gateway does — arrivals at exact mid-round offsets, never waiting
on completions.  Scheduling currency is the lock-step fleet clock, so
one trace replays against one chip or N without edits (the fabric bench
replays the same scaled trace against both and compares).

``replay`` returns a summary in the shared bench-tracker schema: one row
per QoS class (modeled p50/p99 latency) plus the aggregate GOPS/W row,
and the raw per-class stats dict for programmatic gates.
"""
from __future__ import annotations

from repro.core import cycle_model as cm

from .trace import Trace, TraceRequest


# ----------------------------------------------------------- materializers
#
# A materializer turns a TraceRequest's payload *spec* into the engine-
# native payload plus submit() keyword arguments.  Determinism contract:
# the result is a pure function of (trace seed, request index, spec).


def _rng(trace_seed: int, index: int):
    import numpy as np

    return np.random.default_rng((int(trace_seed), int(index)))


def lm_materializer(vocab: int):
    """Prompts of ``prompt_len`` uniform tokens from a ``vocab``."""

    def mat(treq: TraceRequest, trace_seed: int, index: int):
        spec = treq.payload
        prompt = _rng(trace_seed, index).integers(
            0, vocab, size=int(spec["prompt_len"])
        )
        return prompt, dict(max_new=int(spec["max_new"]))

    return mat


def seg_materializer(in_ch: int):
    """Synthetic phantom images at the spec's (h, w) geometry."""

    def mat(treq: TraceRequest, trace_seed: int, index: int):
        from repro.segserve.synth import phantom_image

        spec = treq.payload
        # phantom_image seeds its own rng; fold the request index in so
        # every image differs but replays identically
        return phantom_image(
            int(spec["h"]), int(spec["w"]), in_ch,
            seed=int(trace_seed) * 100_003 + index,
        ), {}

    return mat


# ----------------------------------------------------------------- replay


def replay(
    gateway,
    trace: Trace,
    materializers: dict,
    *,
    max_rounds: int = 100_000,
    capture=None,
) -> dict:
    """Drive ``gateway`` through ``trace`` open-loop; returns the summary.

    ``gateway`` is a single :class:`~repro.serve.gateway.Gateway` or a
    :class:`~repro.serve.fabric.Fabric` (routing happens inside the
    fabric's ``step_round``, at arrival injection).  ``materializers``
    maps adapter kind to a materializer (see :func:`lm_materializer` /
    :func:`seg_materializer`; modeled adapters use
    :func:`repro.serve.modeled.modeled_materializer`).  Every QoS class
    the trace carries must be declared in the gateway's ``shares``.

    ``capture`` (a :class:`repro.obs.capture.CaptureSink`) records the
    replayed arrivals back into trace schema v1 as they happen — the
    capture→replay round-trip.  It is armed *in addition to* any sink the
    gateway already carries (teed), and the combined sink is left armed.
    """
    if capture is not None:
        from repro.obs.events import NULL_SINK, TeeSink

        prior = getattr(gateway, "sink", NULL_SINK)
        gateway.set_sink(
            capture if prior is NULL_SINK else TeeSink([prior, capture])
        )
    missing = set(trace.kinds) - set(gateway.adapters)
    if missing:
        raise ValueError(
            f"trace {trace.name!r} needs adapters for kinds "
            f"{sorted(missing)}"
        )
    undeclared = set(trace.qos_classes) - set(gateway.shares)
    if undeclared:
        raise ValueError(
            f"trace {trace.name!r} carries QoS classes {sorted(undeclared)} "
            f"not declared in gateway shares {sorted(gateway.shares)}"
        )
    feed = []
    for idx, treq in enumerate(trace.requests):
        payload, prep_kw = materializers[treq.kind](treq, trace.seed, idx)
        kw = dict(qos=treq.qos, **prep_kw)
        if treq.deadline_cycles is not None:
            kw["deadline_cycles"] = treq.deadline_cycles
        feed.append((treq.arrival_cycle, treq.kind, payload, kw))

    i = 0
    while i < len(feed) or gateway.pending():
        if gateway.rounds >= max_rounds:
            raise RuntimeError(
                f"replay of {trace.name!r} did not drain within "
                f"{max_rounds} rounds"
            )
        window_end = gateway.clock + gateway.round_budget
        due = []
        while i < len(feed) and feed[i][0] < window_end:
            due.append(feed[i])
            i += 1
        gateway.step_round(arrivals=due)
    return summarize(gateway, trace)


def replay_stream(gateway, feed, *, label: str = "stream",
                  max_rounds: int = 1_000_000) -> dict:
    """Open-loop replay from a *lazy* arrival feed — the streaming twin
    of :func:`replay` for workloads too large to materialize.

    ``feed`` is a sorted iterable of ``(cycle, kind, payload, kw)``
    tuples, e.g. :func:`repro.workload.diurnal.stream_requests` over
    generator arrivals: only one round's window of arrivals is ever held
    in memory, so a million-request day streams through in O(in-flight)
    space.  Payloads must already be engine-native (modeled adapters
    take spec dicts directly — the capacity planner's path).

    Returns the :func:`summarize` schema with a ``stream`` block
    (``label`` + fed count) in place of ``trace``.
    """
    it = iter(feed)
    nxt = next(it, None)
    fed = 0
    while nxt is not None or gateway.pending():
        if gateway.rounds >= max_rounds:
            raise RuntimeError(
                f"stream replay {label!r} did not drain within "
                f"{max_rounds} rounds"
            )
        window_end = gateway.clock + gateway.round_budget
        due = []
        while nxt is not None and nxt[0] < window_end:
            due.append(nxt)
            fed += 1
            nxt = next(it, None)
        gateway.step_round(arrivals=due)
    out = _summary(gateway, f"stream/{label}")
    out["stream"] = dict(label=label, n_requests=fed)
    return out


def _summary(gateway, row_prefix: str) -> dict:
    """The shared summary core (:func:`summarize` adds the trace block,
    :func:`replay_stream` the stream block).  Percentiles inherit the
    stack-wide exact-order-statistic semantics
    (:func:`repro.serve.clock.exact_percentile`) from ``gateway.stats()``;
    the ``overall`` aggregate applies the same helper across every
    completed request regardless of class."""
    from repro.serve.clock import exact_percentile

    st = gateway.stats()
    all_lats = [g.latency_ms for g in gateway.requests if g.done]
    overall_p50 = exact_percentile(all_lats, 50)
    overall_p99 = exact_percentile(all_lats, 99)
    rows = []
    for qos, pc in st["per_class"].items():
        if pc["n"] == 0 or not pc["completed"]:
            continue
        rows.append(
            (
                f"{row_prefix}/{gateway.policy}/{qos}",
                (pc["p99_ms"] or 0.0) * 1e3,  # modeled us, like segserve
                f"n={pc['n']};completed={pc['completed']};"
                f"p50_ms={pc['p50_ms']:.3f};p99_ms={pc['p99_ms']:.3f};"
                f"misses={pc['deadline_misses']}",
            )
        )
    out = dict(
        policy=gateway.policy,
        rounds=st["rounds"],
        clock_cycles=st["clock_cycles"],
        time_ms=st["clock_cycles"] / cm.FREQ_HZ * 1e3,
        total_ops=st["total_ops"],
        gops=st["gops"],
        gops_w=st["gops_w"],
        per_class=st["per_class"],
        overall=dict(
            completed=len(all_lats),
            p50_ms=None if overall_p50 is None else float(overall_p50),
            p99_ms=None if overall_p99 is None else float(overall_p99),
        ),
        # fleet/gateway total, reconciled with the per-class counters
        # gateway.stats() derives and the SloMonitor's online counts
        deadline_misses=sum(
            pc.get("deadline_misses", 0)
            for pc in st["per_class"].values()
        ),
        forced=st["forced"],
        rows=rows,
    )
    if "slo" in st:
        out["slo"] = st["slo"]
    if "energy" in st:
        out["energy"] = st["energy"]
    return out


def summarize(gateway, trace: Trace) -> dict:
    """The replay summary in the shared bench-tracker schema."""
    out = _summary(gateway, f"replay/{trace.name}")
    out["trace"] = dict(
        name=trace.name,
        version=trace.version,
        seed=trace.seed,
        n_requests=len(trace),
        span_cycles=trace.span_cycles,
        qos_classes=trace.qos_classes,
    )
    return out
