"""Streaming diurnal/burst arrival generation on the modeled clock.

:mod:`repro.workload.arrivals` materializes arrival lists — right for
committed canonical traces, wrong for capacity planning, where a day of
fleet traffic is millions of requests.  This module provides the same
process families as lazy **generators**, plus a day-curve modulation
combinator, so arbitrarily long workloads stream through
:func:`repro.workload.replay.replay_stream` without ever materializing
a trace in memory.

Determinism contract (same as :mod:`.arrivals`): every draw is a pure
function of ``(seed, counter)`` via the counter PRNG, and the counters
advance only with the *candidate index* — so each stream is
**prefix-stable**: truncating or extending it never reshuffles earlier
arrivals, and re-iterating from the same seed reproduces the identical
prefix (property-tested).

Composition model — day-shaped rate curves are built from the existing
families, not a new process:

* :func:`iter_poisson` / :func:`iter_on_off` — infinite generator twins
  of :func:`.arrivals.poisson` / :func:`.arrivals.on_off`, sharing their
  exact domain tags, so ``list(islice(iter_poisson(...), n)) ==
  poisson(n, ...)`` to the integer.
* :func:`day_curve` — a raised-cosine relative-rate curve in
  ``[floor, 1]`` over one ``period`` (trough at phase 0).
* :func:`modulate` — Lewis–Shedler thinning of any sorted arrival
  stream by the day curve: candidate ``i`` survives iff
  ``U(seed, i) < day_curve(t_i)``.  Thinning a Poisson stream at peak
  rate yields an exact non-homogeneous Poisson with the day-shaped
  intensity; thinning an on-off stream yields diurnal bursts.
* :func:`diurnal` — the common case: ``modulate(iter_poisson(...))``.
* :func:`merge` / :func:`take` / :func:`take_until` — lazy stream
  plumbing.
* :func:`stream_requests` — compose per-class streams into the sorted
  lazy ``(cycle, kind, payload, kw)`` feed ``replay_stream`` drives.
"""
from __future__ import annotations

import heapq
import itertools
import math

from .arrivals import _exp_gap, counter_uniform

# domain tags shared with arrivals.py (prefix-identity with the list
# builders) + this module's own thinning domain
_POISSON_TAG = 0x9015504E
_ON_ARRIVAL_TAG = 0x0A44117A
_ON_DWELL_TAG = 0x00FFDEAD
_OFF_DWELL_TAG = 0x0FF0FF00
_THIN_TAG = 0xD1024EA7


def day_curve(cycle: int, *, period: int, floor: float = 0.15,
              phase: float = 0.0) -> float:
    """Relative rate in ``[floor, 1]`` at ``cycle``: a raised cosine
    over one ``period`` (modeled cycles), trough at ``phase=0`` — the
    canonical day shape (overnight trough, midday peak).  ``phase`` is
    in fractions of a period."""
    if period <= 0:
        raise ValueError(f"period {period} <= 0")
    if not 0.0 <= floor <= 1.0:
        raise ValueError(f"floor {floor} not in [0, 1]")
    rel = 0.5 - 0.5 * math.cos(2.0 * math.pi * (cycle / period + phase))
    return floor + (1.0 - floor) * rel


def iter_poisson(*, seed: int, mean_interval: float, start: int = 0):
    """Infinite Poisson arrival generator — prefix-identical to
    :func:`.arrivals.poisson` (same seed, same tags, same rounding)."""
    if mean_interval <= 0:
        raise ValueError(f"mean_interval {mean_interval} <= 0")
    t = float(start)
    for i in itertools.count():
        t += _exp_gap(seed, mean_interval, _POISSON_TAG, i)
        yield int(round(t))


def iter_on_off(*, seed: int, burst_interval: float, on_mean: float,
                off_mean: float, start: int = 0):
    """Infinite Markov-modulated on-off generator — prefix-identical to
    :func:`.arrivals.on_off` (same dwell/arrival counters, straddling
    gap residual included)."""
    for name, v in (("burst_interval", burst_interval),
                    ("on_mean", on_mean), ("off_mean", off_mean)):
        if v <= 0:
            raise ValueError(f"{name} {v} <= 0")
    t = float(start)
    dwell = 0
    i = 0
    next_gap = _exp_gap(seed, burst_interval, _ON_ARRIVAL_TAG, i)
    while True:
        on_end = t + _exp_gap(seed, on_mean, _ON_DWELL_TAG, dwell)
        while t + next_gap <= on_end:
            t += next_gap
            yield int(round(t))
            i += 1
            next_gap = _exp_gap(seed, burst_interval, _ON_ARRIVAL_TAG, i)
        next_gap -= on_end - t
        t = on_end + _exp_gap(seed, off_mean, _OFF_DWELL_TAG, dwell + 1)
        dwell += 2


def modulate(stream, *, seed: int, period: int, floor: float = 0.15,
             phase: float = 0.0):
    """Thin a sorted arrival stream by the day curve (Lewis–Shedler):
    candidate ``i`` at cycle ``t_i`` survives iff ``U(seed, i) <
    day_curve(t_i)``.  The acceptance draw is keyed by the *candidate*
    index, so the thinned stream inherits the base stream's prefix
    stability.  Thinning a peak-rate Poisson stream gives an exact
    non-homogeneous Poisson at the day-shaped intensity."""
    for i, t in enumerate(stream):
        if counter_uniform(seed, _THIN_TAG, i) < day_curve(
            t, period=period, floor=floor, phase=phase
        ):
            yield t


def diurnal(*, seed: int, peak_interval: float, period: int,
            floor: float = 0.15, phase: float = 0.0, start: int = 0):
    """Day-shaped Poisson arrivals: mean interval ``peak_interval`` at
    the midday peak, ``peak_interval / floor`` at the overnight trough
    — ``modulate(iter_poisson(...))`` with shared seed (distinct
    domain tags decorrelate the candidate and acceptance draws)."""
    return modulate(
        iter_poisson(seed=seed, mean_interval=peak_interval, start=start),
        seed=seed, period=period, floor=floor, phase=phase,
    )


def merge(*streams):
    """Lazy heap-merge of sorted arrival streams into one sorted stream
    of ``(cycle, stream_index)`` pairs (ties break by stream order)."""
    def _tag(k, s):
        # bound through default-free closure args, NOT the genexp loop
        # variable — late binding would tag every arrival with the last
        # stream index
        return ((t, k) for t in s)

    return heapq.merge(*(_tag(k, s) for k, s in enumerate(streams)))


def take(stream, n: int) -> list[int]:
    """Materialize the first ``n`` arrivals (trace-building helper)."""
    return list(itertools.islice(stream, int(n)))


def take_until(stream, end_cycle: int):
    """Yield arrivals strictly before ``end_cycle`` — how an infinite
    stream becomes a bounded run without picking a count."""
    for t in stream:
        if t >= end_cycle:
            return
        yield t


def stream_requests(streams, *, until: int | None = None,
                    limit: int | None = None):
    """Compose per-class arrival generators into the sorted lazy
    ``(cycle, kind, payload, kw)`` feed that
    :func:`repro.workload.replay.replay_stream` drives — the streaming
    analogue of :func:`repro.workload.trace.from_streams` + ``replay``,
    with nothing materialized.

    Each stream dict: ``kind`` (adapter kind), ``arrivals`` (a sorted,
    possibly infinite iterable of cycles), ``payload`` (a spec dict, or
    a callable ``index -> spec`` for per-request variation), optional
    ``qos`` (default: the kind) and ``deadline_cycles`` (relative, like
    trace schema v1).  ``until`` stops at a cycle bound, ``limit`` at a
    request count — give at least one when any stream is infinite.
    """
    streams = list(streams)
    for s in streams:
        if "kind" not in s or "arrivals" not in s or "payload" not in s:
            raise ValueError(
                f"stream needs kind/arrivals/payload keys, got "
                f"{sorted(s)}"
            )
    per_stream_idx = [0] * len(streams)
    emitted = 0
    for t, k in merge(*(s["arrivals"] for s in streams)):
        if until is not None and t >= until:
            return
        s = streams[k]
        i = per_stream_idx[k]
        per_stream_idx[k] += 1
        payload = s["payload"](i) if callable(s["payload"]) \
            else dict(s["payload"])
        kw = dict(qos=s.get("qos", s["kind"]))
        if s.get("deadline_cycles") is not None:
            kw["deadline_cycles"] = int(s["deadline_cycles"])
        yield int(t), s["kind"], payload, kw
        emitted += 1
        if limit is not None and emitted >= limit:
            return
