"""Versioned, serialized request traces — the workload artifact.

A :class:`Trace` is the unit of reproducible load: a named, seeded list of
:class:`TraceRequest` s, each carrying *what* arrives (``kind`` — the
gateway adapter — plus a compact payload **spec**, not the payload
itself), *as what* (``qos`` scheduling class), *when* (``arrival_cycle``
on the modeled clock) and *by when* (optional ``deadline_cycles``).
Payloads are materialized at replay time from the spec and the trace seed
(``repro.workload.replay``), so a committed trace is a few KB of JSON, not
megabytes of tensors, and regenerating payloads is bit-reproducible.

Traces persist with the checkpoint module's crash-safety discipline
(:func:`repro.checkpoint.save_json_atomic`) and carry a schema version:
a reader refuses versions newer than it understands (same posture as
``TunedPlan``), and the bench tracker (``scripts/bench_diff.py``) treats a
version bump as a target change — rows from different trace schemas are
never diffed against each other.

Payload spec conventions (enforced by :func:`validate_payload`):

``kind='lm'``   ``{"prompt_len": int, "max_new": int}``
``kind='seg'``  ``{"h": int, "w": int}``

Other kinds pass through unvalidated (synthetic adapters in tests).
"""
from __future__ import annotations

from dataclasses import dataclass, field

TRACE_SCHEMA = "repro.workload.trace"
TRACE_VERSION = 1

_PAYLOAD_KEYS = {
    "lm": ("prompt_len", "max_new"),
    "seg": ("h", "w"),
}


def validate_payload(kind: str, payload: dict) -> dict:
    """Check a payload spec carries its kind's required integer fields."""
    required = _PAYLOAD_KEYS.get(kind)
    if required is None:
        return dict(payload)
    missing = [k for k in required if k not in payload]
    if missing:
        raise ValueError(
            f"{kind!r} payload spec missing {missing}: {payload}"
        )
    for k in required:
        if int(payload[k]) < 1:
            raise ValueError(f"{kind!r} payload {k}={payload[k]} < 1")
    return dict(payload)


@dataclass(frozen=True)
class TraceRequest:
    """One arrival: spec, class, stamp."""

    kind: str
    qos: str
    arrival_cycle: int
    payload: dict
    deadline_cycles: int | None = None

    def __post_init__(self):
        if self.arrival_cycle < 0:
            raise ValueError(f"arrival_cycle {self.arrival_cycle} < 0")
        if self.deadline_cycles is not None and self.deadline_cycles < 1:
            raise ValueError(f"deadline_cycles {self.deadline_cycles} < 1")
        validate_payload(self.kind, self.payload)

    def to_json(self) -> dict:
        d = dict(kind=self.kind, qos=self.qos,
                 arrival_cycle=int(self.arrival_cycle),
                 payload=dict(self.payload))
        if self.deadline_cycles is not None:
            d["deadline_cycles"] = int(self.deadline_cycles)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "TraceRequest":
        return cls(
            kind=str(d["kind"]),
            qos=str(d.get("qos", d["kind"])),
            arrival_cycle=int(d["arrival_cycle"]),
            payload=dict(d["payload"]),
            deadline_cycles=(
                None if d.get("deadline_cycles") is None
                else int(d["deadline_cycles"])
            ),
        )


@dataclass(frozen=True)
class Trace:
    """A named, seeded, versioned request trace (requests sorted by
    arrival cycle at construction — replay order is the schema, not an
    accident of builder order)."""

    name: str
    seed: int
    requests: tuple[TraceRequest, ...]
    description: str = ""
    meta: dict = field(default_factory=dict)
    version: int = TRACE_VERSION

    def __post_init__(self):
        object.__setattr__(
            self, "requests",
            tuple(sorted(self.requests, key=lambda r: (r.arrival_cycle,))),
        )
        if not self.name:
            raise ValueError("a trace needs a name")

    def __len__(self) -> int:
        return len(self.requests)

    @property
    def qos_classes(self) -> list[str]:
        """Distinct scheduling classes, in first-arrival order."""
        seen: list[str] = []
        for r in self.requests:
            if r.qos not in seen:
                seen.append(r.qos)
        return seen

    @property
    def kinds(self) -> list[str]:
        seen: list[str] = []
        for r in self.requests:
            if r.kind not in seen:
                seen.append(r.kind)
        return seen

    @property
    def span_cycles(self) -> int:
        """Cycles from 0 to the last arrival."""
        return self.requests[-1].arrival_cycle if self.requests else 0

    # --------------------------------------------------------- persistence

    def to_json(self) -> dict:
        return dict(
            schema=TRACE_SCHEMA,
            version=self.version,
            name=self.name,
            seed=int(self.seed),
            description=self.description,
            meta=dict(self.meta),
            requests=[r.to_json() for r in self.requests],
        )

    @classmethod
    def from_json(cls, d: dict) -> "Trace":
        if d.get("schema") not in (None, TRACE_SCHEMA):
            raise ValueError(f"not a workload trace: schema={d.get('schema')!r}")
        version = int(d.get("version", TRACE_VERSION))
        if version > TRACE_VERSION:
            raise ValueError(
                f"trace version {version} is newer than this code "
                f"({TRACE_VERSION}) — refusing to misread a workload"
            )
        return cls(
            name=str(d["name"]),
            seed=int(d["seed"]),
            requests=tuple(
                TraceRequest.from_json(r) for r in d["requests"]
            ),
            description=str(d.get("description", "")),
            meta=dict(d.get("meta") or {}),
            version=version,
        )

    def save(self, path) -> None:
        """Atomic JSON write (crash-safe, same discipline as checkpoints
        and tuned plans)."""
        from repro.checkpoint import save_json_atomic

        save_json_atomic(path, self.to_json())

    @classmethod
    def load(cls, path) -> "Trace":
        from repro.checkpoint import load_json

        return cls.from_json(load_json(path))

    def describe(self) -> str:
        per_qos = {
            q: sum(1 for r in self.requests if r.qos == q)
            for q in self.qos_classes
        }
        return (
            f"Trace[{self.name}] v{self.version} seed={self.seed} "
            f"n={len(self)} span={self.span_cycles} cycles "
            f"classes={per_qos}"
        )


def from_streams(name: str, seed: int, streams, *, description: str = "",
                 meta: dict | None = None) -> Trace:
    """Assemble a trace from labeled arrival streams.

    ``streams`` is an iterable of dicts, one per traffic class::

        dict(kind='lm', qos='interactive',
             arrivals=[...cycles...],          # e.g. from workload.arrivals
             payload={'prompt_len': 4, 'max_new': 8},   # spec or fn(i)
             deadline_cycles=None)

    ``payload`` may be a callable ``f(i) -> dict`` for per-request specs.
    """
    reqs: list[TraceRequest] = []
    for s in streams:
        payload = s["payload"]
        for i, cyc in enumerate(s["arrivals"]):
            spec = payload(i) if callable(payload) else dict(payload)
            reqs.append(
                TraceRequest(
                    kind=s["kind"],
                    qos=s.get("qos", s["kind"]),
                    arrival_cycle=int(cyc),
                    payload=spec,
                    deadline_cycles=s.get("deadline_cycles"),
                )
            )
    return Trace(name=name, seed=seed, requests=tuple(reqs),
                 description=description, meta=dict(meta or {}))
