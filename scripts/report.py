#!/usr/bin/env python
"""Regenerate the ledger report from committed artifacts — no benches
are re-run.

Reads ``BENCH_LEDGER.jsonl`` (the per-revision headline ledger
``scripts/bench_diff.py --ledger`` maintains) and the ``BENCH_*.json``
payloads, and renders GOPS/W + latency trend tables per bench plus the
span-breakdown tables (queued / executing / preempted decomposition of
the exact p50/p99 requests) carried by instrumented bench payloads.
When ``BENCH_capacity.json`` is present, the report also renders the
cost-per-SLO capacity frontier and the per-grid-point SLO burn +
miss-attribution tables; ``BENCH_energy.json`` adds the metered-joules
frontier and per-class joule-breakdown tables.

    python scripts/report.py [--ledger BENCH_LEDGER.jsonl]
                             [--benches BENCH_*.json ...]
                             [--out REPORT.md] [--json report.json]

Exit status: 0 when a report was produced (even if sections are empty —
a fresh repo has no ledger yet), 1 when *none* of the inputs exist.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(_HERE, "..", "src"))

from repro.obs.report import build_report  # noqa: E402

DEFAULT_BENCHES = (
    "BENCH_segserve.json",
    "BENCH_autotune.json",
    "BENCH_gateway.json",
    "BENCH_fabric.json",
    "BENCH_capacity.json",
    "BENCH_energy.json",
    "BENCH_specdecode.json",
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--ledger", default="BENCH_LEDGER.jsonl")
    ap.add_argument("--benches", nargs="*", default=list(DEFAULT_BENCHES))
    ap.add_argument("--out", default="REPORT.md",
                    help="markdown report path")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="optional JSON twin of the report")
    args = ap.parse_args(argv)

    have_ledger = os.path.exists(args.ledger)
    have_benches = [p for p in args.benches if os.path.exists(p)]
    if not have_ledger and not have_benches:
        print(f"report: no inputs found (ledger={args.ledger!r}, "
              f"benches={list(args.benches)})", file=sys.stderr)
        return 1

    md, payload = build_report(args.ledger, args.benches)
    with open(args.out, "w") as fh:
        fh.write(md)
    if args.json_out:
        with open(args.json_out, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
    print(f"report: {payload['ledger_entries']} ledger entries, "
          f"{len(have_benches)} bench payloads -> {args.out}"
          + (f" + {args.json_out}" if args.json_out else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
