"""Regenerate EXPERIMENTS.md from results/dryrun/*.json + the cycle model.

    PYTHONPATH=src python scripts/gen_experiments.py
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))

from benchmarks import roofline as rl  # noqa: E402
from repro.core import cycle_model as cm  # noqa: E402


def j(name):
    return json.loads((ROOT / "results" / "dryrun" / f"{name}.json").read_text())


def terms(r):
    roof = r["roofline"]
    return (roof["compute_s"] * 1e3, roof["memory_s"] * 1e3,
            roof["collective_s"] * 1e3, roof["step_time_lower_bound_s"] * 1e3,
            roof["dominant"])


def fmt_before_after(name_b, name_a):
    b, a = j(name_b), j(name_a)
    tb, ta = terms(b), terms(a)
    return b, a, tb, ta


def table1_section():
    layers = cm.unet_conv_layers(**cm.CALIBRATED_UNET)
    tile = cm.pipelined_tile_cycles()
    cyc = cm.model_cycles(layers, tile_cycles=tile)
    t_ms = cyc / cm.FREQ_HZ * 1e3
    gops = cm.model_ops(layers) / (t_ms * 1e-3) / 1e9
    row = cm.proposed_row(layers)
    casc = cm.cascaded_row(layers)
    return f"""## §Table1 — paper reproduction (cycle-accurate model)

The paper gives relations (2)+(3) but no U-Net layer table; we calibrated a
standard U-Net against Table 1 (`cycle_model.calibrate_unet`):
**input 80x80x4, base 48, depth 3, one 3x3 conv per stage** (0.833 GMAC).

| row | time (ms) | GOPS | GOPS/W | energy (mJ) | vs paper |
|---|---|---|---|---|---|
| proposed (paper, printed)        | 53.25 | 52.95 | 15.14 | 186.20 | — |
| **proposed (our model, pipelined 2n-cycle interval)** | {t_ms:.2f} | {gops:.2f} | {gops/(52.95/15.14):.2f} | {(52.95/15.14)*t_ms:.1f} | time +1.0%, GOPS −1.3% |
| proposed (relation 2 as printed, 28 cyc/tile) | {row.time_ms:.2f} | {row.gops:.2f} | {row.gops_per_w:.2f} | {row.energy_mj:.1f} | time only matches under a different calibration (see below) |
| cascaded-MSDF (un-merged, same datapath) | {casc.time_ms:.2f} | {casc.gops:.2f} | — | — | merged speedup = 34/28 = 1.214x |
| CPU (measured on this host, float U-Net) | ~61 | ~46 | — | — | paper CPU row: 58.42 ms / 48.27 GOPS |

**Reproduction findings**
1. *Relation (2) vs Table 1*: relation (2) as printed (28 cycles/tile) can
   match Table 1's **time** (calibration hw=80, base=32, depth=4 → 53.76 ms)
   but then under-predicts GOPS by ~40%. Both columns are jointly consistent
   only under a **16 = 2n cycle steady-state initiation interval**, i.e.
   relation (2) is per-output *latency* while Table 1 assumes *pipelined
   throughput*. We model both (`mma_tile_cycles` / `pipelined_tile_cycles`).
2. *Table 1 internal consistency*: 5 of 6 rows satisfy
   `energy = GOPS/(GOPS/W) x time` within 0.2%; the **MSDF row does not**
   (6.99 W x 133.94 ms = 936.7 mJ vs printed 1644.77 mJ → implies 12.28 W).
   Pinned in `tests/test_core.py::test_paper_table1_internal_consistency`.
3. *Merged vs cascaded*: the MMA's per-tile win is 34→28 cycles (1.214x);
   the paper's 2.52x claim vs the MSDF accelerator [11] additionally
   reflects that design's different unit counts (cited measurement, not
   derivable from relation 2).
4. The bit-exact MSDF digit-serial simulator (`core/msdf.py`) confirms the
   datapath: one MMA inner product = delta(2) + p_out(21) = 23 cycles
   (relation 2's inner term adds ceil(log2 T_N)=5 pipeline-fill cycles), and
   the 9-tap KPB tree completes in 39 cycles with digit-level pipelining —
   vs 9x23 = 207 if units ran back-to-back.
"""


def _fleet_rows():
    pairs = [
        ("olmoe_1b_7b__train_4k__16_16", "olmoe_1b_7b__train_4k__16_16__epdp", "olmoe train_4k (EP+ep_dp)"),
        ("olmoe_1b_7b__prefill_32k__16_16", "olmoe_1b_7b__prefill_32k__16_16__ep", "olmoe prefill_32k (EP)"),
        ("dbrx_132b__train_4k__16_16", "dbrx_132b__train_4k__16_16__ep", "dbrx train_4k (EP)"),
        ("dbrx_132b__prefill_32k__16_16", "dbrx_132b__prefill_32k__16_16__ep", "dbrx prefill_32k (EP)"),
        ("minitron_4b__prefill_32k__16_16", "minitron_4b__prefill_32k__16_16__cp", "minitron prefill_32k (CP)"),
        ("minitron_4b__train_4k__16_16", "minitron_4b__train_4k__16_16__cp", "minitron train_4k (CP)"),
        ("whisper_large_v3__prefill_32k__16_16", "whisper_large_v3__prefill_32k__16_16__cp", "whisper prefill_32k (CP)"),
        ("whisper_large_v3__train_4k__16_16", "whisper_large_v3__train_4k__16_16__cp", "whisper train_4k (CP)"),
        ("yi_6b__decode_32k__16_16", "yi_6b__decode_32k__16_16__mma_int8", "yi decode_32k (int8 W+KV)"),
        ("zamba2_7b__decode_32k__16_16", "zamba2_7b__decode_32k__16_16__dusfix", "zamba2 decode_32k (cache-layout fix)"),
        ("zamba2_7b__long_500k__16_16", "zamba2_7b__long_500k__16_16__dusfix", "zamba2 long_500k (cache-layout fix)"),
        ("olmoe_1b_7b__decode_32k__16_16", "olmoe_1b_7b__decode_32k__16_16__dusfix", "olmoe decode_32k (cache-layout fix)"),
    ]
    lines = []
    for before, after, label in pairs:
        try:
            b, a = j(before), j(after)
        except FileNotFoundError:
            continue
        tb, ta = terms(b), terms(a)
        lines.append(
            f"| {label} | {tb[3]:.1f} ms ({tb[4]}) | {ta[3]:.1f} ms ({ta[4]}) "
            f"| **{tb[3]/ta[3]:.1f}x** | {b['useful_flops_fraction']:.2f}→"
            f"{a['useful_flops_fraction']:.2f} |"
        )
    return "\n".join(lines)


def _cell(name):
    try:
        return f"{terms(j(name))[3]:.1f}"
    except FileNotFoundError:
        return "n/a"


def _int8_rows():
    pairs = [
        ("yi_6b", "decode_32k"), ("granite_20b", "decode_32k"),
        ("internvl2_76b", "decode_32k"), ("minitron_4b", "decode_32k"),
        ("h2o_danube_3_4b", "long_500k"),
    ]
    lines = []
    for arch, shape in pairs:
        try:
            b = j(f"{arch}__{shape}__16_16")
            a = j(f"{arch}__{shape}__16_16__mma_int8")
        except FileNotFoundError:
            continue
        tb, ta = terms(b), terms(a)
        lines.append(f"| {arch} x {shape} | {tb[3]:.2f} ms | {ta[3]:.2f} ms "
                     f"| {tb[3]/ta[3]:.2f}x |")
    return "\n".join(lines)


def perf_section():
    o0 = j("olmoe_1b_7b__train_4k__16_16")
    o1 = j("olmoe_1b_7b__train_4k__16_16__ep")
    o2 = j("olmoe_1b_7b__train_4k__16_16__epdp")
    m0 = j("minitron_4b__prefill_32k__16_16")
    m1 = j("minitron_4b__prefill_32k__16_16__cp")
    w0 = j("whisper_large_v3__prefill_32k__16_16")
    w1 = j("whisper_large_v3__prefill_32k__16_16__cp")
    y0 = j("yi_6b__decode_32k__16_16")
    y1 = j("yi_6b__decode_32k__16_16__mma_int8")

    def t(r):
        return terms(r)

    return f"""## §Perf — hillclimbing log (hypothesis → change → measure → validate)

Three cells selected per the assignment: the worst roofline fraction
(olmoe train_4k: bound/compute = {t(o0)[3]/t(o0)[0]:.0f}x), the most
collective-bound (same cell; minitron prefill as the compute-replication
counterpoint), and the cell most representative of the paper's technique
(yi decode_32k: memory-bound serving, where the int8 digit-serial datapath
pays).  All numbers are single-pod (16,16), per-chip, per-step.

### Cell 1: olmoe_1b_7b x train_4k (MoE, 64e top-8)

| iteration | compute | memory | collective | bound | useful |
|---|---|---|---|---|---|
| baseline (GSPMD scatter dispatch) | {t(o0)[0]:.0f} ms | {t(o0)[1]:.0f} ms | **{t(o0)[2]:.0f} ms** | {t(o0)[3]:.0f} ms | {o0['useful_flops_fraction']:.2f} |
| iter 1: shard_map EP all-to-all | {t(o1)[0]:.0f} ms | {t(o1)[1]:.0f} ms | **{t(o1)[2]:.0f} ms** | {t(o1)[3]:.0f} ms | {o1['useful_flops_fraction']:.2f} |
| iter 2: ep_dp rule set (DeepSpeed-MoE layout) | {t(o2)[0]:.0f} ms | {t(o2)[1]:.0f} ms | **{t(o2)[2]:.0f} ms** | {t(o2)[3]:.0f} ms | {o2['useful_flops_fraction']:.2f} |

*Iter 1 hypothesis*: GSPMD cannot shard a data-dependent scatter; the
dispatch replicates every token to every expert shard (baseline collective
term 243 s ≈ 64 experts' worth of token traffic x layers). Napkin: explicit
all-to-all moves only t_loc x top_k x d bytes/chip/layer ≈ 134 MB vs ~15 GB.
**Confirmed**: 243 s → 1.6 s (150x) with `moe.ep=True`
(`moe_ffn_ep`: local top-k routing → (M, E_loc, C, D) send buffer →
`lax.all_to_all` over 'model' → local expert einsum → reverse a2a).

*Iter 2 hypothesis*: a 1B-active model is over-TP'd at 16-way — the
remaining term is per-layer SP/TP boundary collectives of the *dense* parts.
Mapping batch over ('pod','data','model') and keeping ONLY experts on
'model' (rule set `ep_dp`) removes them; the MoE a2a becomes the only
activation collective. **Confirmed**: 1.62 s → 0.71 s; useful 0.57→0.69.

*Iter 3 (analysis, stopped)*: remaining a2a = t_loc·k·d·2B x 2 dir x fwd+bwd
x L ≈ 17 GB/chip-step — the routing-theoretic floor for top-8 at d=2048.
Next lever would be hierarchical a2a or expert-choice routing (changes the
paper-assigned architecture, out of scope). Total: **340x** on the dominant
term; bound 243.3 s → 0.71 s.

### Cell 2: minitron_4b x prefill_32k (24 heads on a 16-way model axis)

| iteration | compute | memory | collective | bound | useful |
|---|---|---|---|---|---|
| baseline (head-sharding fails → replicated attention) | **{t(m0)[0]:.0f} ms** | {t(m0)[1]:.0f} ms | {t(m0)[2]:.0f} ms | {t(m0)[3]:.0f} ms | {m0['useful_flops_fraction']:.2f} |
| iter 1: context-parallel fallback | {t(m1)[0]:.0f} ms | {t(m1)[1]:.0f} ms | **{t(m1)[2]:.0f} ms** | {t(m1)[3]:.0f} ms | {m1['useful_flops_fraction']:.2f} |

*Hypothesis*: 24 q-heads (and kv=8) don't divide 16, so the divisibility
guard leaves attention unsharded on 'model' → all attention FLOPs replicated
16x (HLO flops 20x the 2·N·D model estimate at 32k where attention
dominates). Fix: when heads % |model| != 0, shard **q's sequence dim** over
'model' (context parallelism), kv replicated. **Confirmed**: compute
{t(m0)[0]/1e3:.1f} s → {t(m1)[0]:.0f} ms (10x); dominance flips to the
KV all-gather (~8.6 GB/step of the {o1 and m1['cost']['coll_bytes']/1e9:.0f} GB collective total).

*Iter 2 (analysis, stopped)*: the remaining KV-AG floor could only move with
ring attention (collective-permute pipeline), which GSPMD cannot synthesize
from constraints — a Pallas ring-attention kernel is the future lever.
Same fix applied to whisper's cross-attention (20 heads):
prefill bound {t(w0)[3]/1e3:.1f} s → {t(w1)[3]:.0f} ms ({t(w0)[3]/t(w1)[3]:.1f}x), useful {w0['useful_flops_fraction']:.2f}→{w1['useful_flops_fraction']:.2f}.

### Cell 3: yi_6b x decode_32k (the paper's technique at serving time)

| iteration | compute | memory | collective | bound | bytes/token/chip |
|---|---|---|---|---|---|
| baseline (bf16 weights + bf16 KV) | {t(y0)[0]:.2f} ms | **{t(y0)[1]:.2f} ms** | {t(y0)[2]:.2f} ms | {t(y0)[3]:.2f} ms | {y0['hbm_traffic_model']['total']/1e6:.0f} MB |
| iter 1: int8 weights + int8 KV cache (MMA datapath) | {t(y1)[0]:.2f} ms | **{t(y1)[1]:.2f} ms** | {t(y1)[2]:.2f} ms | {t(y1)[3]:.2f} ms | {y1['hbm_traffic_model']['total']/1e6:.0f} MB |

*Hypothesis*: decode is memory-bound (weights {y0['hbm_traffic_model']['parts']['weights']/1e6:.0f} MB +
cache {y0['hbm_traffic_model']['parts']['cache']/1e6:.0f} MB per token-step/chip); storing weights as
pre-quantized int8 (+per-channel scales, `quantize_params_int8`) and the KV
cache as int8 (static calibrated scale) halves both. **Confirmed**:
bound {t(y0)[3]:.2f} → {t(y1)[3]:.2f} ms/token ({t(y0)[3]/t(y1)[3]:.2f}x) — on the FPGA this is
exactly the paper's GOPS/W argument; on TPU it converts to ~2x decode
throughput/J at the HBM roofline. Earlier-termination (planes<8) reduces the
*compute* term further (progressive precision demo:
`examples/progressive_decode.py` — planes=6 keeps top-1 agreement ≈ 1.0) but
decode stays bandwidth-bound, so the bytes win is the one that pays here.

*Iter 2 (analysis, stopped)*: next 1.5x would need int4 KV (+packing) or
windowed caches (arch change). Weight bytes are at the int8 floor.

### Fleet-wide effect of the three fixes (bonus cells, same mesh)

The three §Perf changes are *framework* changes (EP a2a dispatch is now the
MoE default, the CP fallback is automatic, int8 serving is a config flag), so
every affected cell improves:

| cell | before (bound) | after (bound) | speedup | useful before→after |
|---|---|---|---|---|
{_fleet_rows()}

### Cell 4 (bonus): zamba2_7b x prefill_32k — packed-projection alignment

*Hypothesis*: Mamba2's packed in_proj (z|xBC|dt, width 14576) splits at
offsets 7168/14448 that don't align with 16-way shard boundaries (911/shard),
forcing an all-to-all + collective-permutes per layer (baseline breakdown:
1.0e10 a2a + 4.5e9 permute bytes per probe body).  Splitting into three
independent projections (identical math and parameter count) makes each
output cleanly shardable.  **Partially confirmed**: bound
{_cell('zamba2_7b__prefill_32k__16_16')} → {_cell('zamba2_7b__prefill_32k__16_16__splitproj')} ms (-20%);
the remaining term is the out_proj row-parallel all-reduce floor
(~470 MB x 81 layers), inherent to TP-16 on a 7B model.

### Cell 5 (bonus, hypothesis REFINED): whisper decode — cached cross-KV

*Hypothesis*: whisper decode re-projects the 1500-frame encoder memory
through every layer's cross-attn k/v each token — caching the cross-KV once
per request (standard GPU-serving practice) should cut both compute and the
5.03 ms collective term (the replicated 20-head projections AR per layer).
*Napkin check first*: the cached cross-KV read is ~2 GB/chip/token — but the
recompute path ALSO materializes the same k/v activations to HBM, so the
memory term is equivalent; only the FLOPs and collectives differ.
**Measured** (with the memory model extended to count per-request extras):
baseline 1.04/3.67*/5.03 ms (compute/mem/coll, *mem understated by the same
untracked activation traffic) → cached 0.28/6.11/0.01 ms.  Collectives
eliminated, compute 3.7x down, and the honest bound is the cross-KV read
floor (~6 ms at B=128 x 1500 enc positions) either way — the iteration's
value is the *corrected memory model* and knowing decode is at its
bandwidth floor, not the scheduling change itself.

### int8 MMA serving across the family (beyond the 3 assigned cells)

Decode bytes/token with `--quant mma_int8` (int8 weights + int8 KV):

| arch x shape | bf16 bound | int8 bound | speedup |
|---|---|---|---|
{_int8_rows()}

### Multi-pod validation of the optimized configs (2x16x16 = 512 chips)

* olmoe train_4k + EP: {_cell('olmoe_1b_7b__train_4k__2_16_16__ep')} ms (multi-pod baseline was dispatch-bound like single-pod).
  NOTE: the single-pod-optimal `ep_dp` rule set *regresses* at 512 chips
  (batch 256 < chips → the prefix fallback leaves the model axis idle and
  attention replicates): 5310 ms vs 836 ms with default rules + EP.  Layout
  choice is scale-dependent — the rule-set config exists precisely for this.
* zamba2 decode_32k cache-layout fix: {_cell('zamba2_7b__decode_32k__2_16_16__dusfix')} ms, memory-bound (vs 227.6 ms collective-bound before).
* yi decode_32k int8: {_cell('yi_6b__decode_32k__2_16_16__mma_int8')} ms at 512 chips (batch 128 spread over 2x more chips).

### Cell 6 (bonus): pipeline parallelism as the TP-collective alternative

The dense train cells are bound by Megatron-TP boundary collectives
(yi train: 4.6 s collective vs 1.1 s compute).  PP=16 x DP=16 (GPipe over
the 'model' axis, `parallel/pipeline.py`: stage-sharded layer stacks +
ppermute handoffs, differentiable end-to-end) compiles on the production
mesh (`launch/dryrun_pp.py`): the collective schedule collapses to 60
collective-permutes (~512 MB activations each, ~32 GB total vs TP's 230 GB)
+ 4 all-reduces — ~7x less collective traffic — at the cost of the GPipe
bubble: (S-1)/(S-1+M) = 48% at M=16 (global batch 256 with DP=16 caps M;
the bubble amortizes at larger global batch, or with 1F1B scheduling —
future lever).  Correctness: PP(2) x DP(4) loss matches single-device
within 2% and grads flow through every stage
(`tests/test_pipeline.py`).

### Cache-layout fixes found through the roofline (global)

1. The decode KV cache was initially sharded on head_dim, conflicting with
   head-sharded q — GSPMD emitted "involuntary full rematerialization"
   (cache all-gathers): yi decode collective 2.2 GB → 17 MB (43 ms → 2.2 ms
   bound) by sharding the cache on the *sequence* dim ('kv_seq' → model) and
   computing decode attention as partial-softmax + O(B·H·d) psum.
2. For archs whose kv-head count divides |model| (zamba2 kv=32, olmoe
   kv=16), the per-token k/v were head-sharded BEFORE the cache
   dynamic-update-slice, so GSPMD all-to-all'ed the entire cache between
   head- and seq-sharded layouts every token (12 GB/step for zamba2).
   Constraining decode k/v to the cache layout before the DUS:
   zamba2 decode 455 → 8.6 ms (53x), long_500k 902 → 16 ms (56x),
   olmoe decode 166 → 3.7 ms (45x) — all now memory-bound (weights+cache),
   which is the physical floor for autoregressive decode.

## §e2e — training driver

`launch/train.py --arch yi_6b --smoke --steps 120 --batch 8 --seq 128` (CPU,
reduced config): loss 6.82 → 4.15, ~110 ms/step, async checkpoints every 25
steps, straggler watchdog active (0 flagged); `--resume` restarts from the
latest atomic checkpoint (bit-determinism covered by
tests/test_checkpoint.py).
"""


def main():
    single = rl.markdown_tables("16x16")
    multi = rl.markdown_tables("2x16x16")
    opt = rl.markdown_tables("16x16", tag="opt")
    opt_multi = rl.markdown_tables("2x16x16", tag="opt")
    if opt_multi.count("\n") < 2:
        opt_multi = "(multi-pod optimized sweep pending — see results/dryrun_opt_multi.log)"
    dr_single = rl.dryrun_table("16x16")
    # fleet summary: sum of bounds, baseline vs optimized defaults
    tot_b = tot_o = 0.0
    for p in sorted((ROOT / "results" / "dryrun").glob("*__16_16__opt.json")):
        o = json.loads(p.read_text())
        b = json.loads((p.parent / p.name.replace("__opt", "")).read_text())
        tot_b += b["roofline"]["step_time_lower_bound_s"]
        tot_o += o["roofline"]["step_time_lower_bound_s"]
    fleet_summary = (f"{tot_b:.0f} s -> {tot_o:.0f} s ({tot_b/max(tot_o,1e-9):.1f}x)"
                     if tot_o else "n/a")

    md = f"""# EXPERIMENTS

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
Container is CPU-only: all parallel results are **dry-run compiles**
(lower → compile → memory/cost analysis on the real production meshes with
512 forced host devices); arithmetic results run on CPU (Pallas kernels in
interpret mode).

{table1_section()}

## §Dry-run — 40 cells x 2 meshes, all compile

Meshes: single-pod `(16,16)` = 256 chips `('data','model')`; multi-pod
`(2,16,16)` = 512 chips `('pod','data','model')` (the pod axis proves DCN-
level data parallelism shards).  Every runnable (arch x shape) cell lowers
AND compiles on both meshes — 33 runnable cells (7 `long_500k` cells are
assignment-SKIPs for full-attention archs, see DESIGN.md) x 2 meshes = 66
compiles, 0 failures (`results/dryrun_single.log`, `results/dryrun_multi.log`).

Method notes (documented limitations):
* `cost_analysis()` counts a `scan`/while body ONCE regardless of trip count
  (verified empirically). True per-step FLOPs/bytes/collective-bytes are
  recovered by compiling small UNROLLED probes (L=1,2; zamba2 {{6,9,12}}) and
  extrapolating linearly in depth (`launch/dryrun.py::probe_costs`).
* `bytes accessed` ignores fusion (>10x upper bound), so the roofline memory
  term uses an explicit per-chip HBM traffic model
  (`hlo_analysis.analytic_hbm_bytes`: weights x3/microbatch + grad-accum +
  optimizer + saved residuals + logits for train; weights + cache + logits
  for decode). Raw HLO bytes are kept in the JSONs as the upper bound.
* Collective bytes = sum of operand bytes of every all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute in the post-SPMD
  per-device HLO, probe-extrapolated. Terms are seconds/step/chip.
* RWKV's per-token recurrence traffic is under-counted by cost analysis (its
  sequential scan is not probe-recoverable); its memory term is a lower
  bound — noted for rwkv6 rows.
* whisper multi-pod train/prefill rows were compiled after the §Perf
  context-parallel fix landed; its single-pod rows are the pre-fix baseline
  (before/after recorded in §Perf).

### Per-cell dry-run summary (single-pod)

{dr_single}

## §Roofline — single-pod (16,16), per chip per step

Columns: the three terms in ms; dominant term; step-time lower bound;
MODEL_FLOPS/HLO_FLOPS (useful fraction: 6·N·D train / 2·N·D inference —
catches remat + replication waste; for 32k-prefill cells attention's
quadratic FLOPs make <1 expected even at perfect sharding).

{single}

### Multi-pod (2,16,16) — proves the pod axis shards (512 chips)

{multi}

### Optimized defaults — the same 33 cells after the §Perf changes landed

The EP MoE dispatch, CP-attention fallback, decode cache-layout fix and
split mamba projections are now framework DEFAULTS; re-running the full
single-pod sweep under them gives the shipping config's roofline
(`--tag opt`; bf16 serving — the int8 deploy mode is the separate
`--quant mma_int8` column in §Perf).  **Summed step-time lower bound across
all 33 cells: {fleet_summary} — 14 cells improved, 0 regressed.**

{opt}

### Optimized defaults, multi-pod (2,16,16)

{opt_multi}

Reading the table (baseline analysis, one line per family):
* **train cells** are collective-bound across the dense archs — the
  inherent Megatron-TP/SP boundary traffic at 16-way model parallelism with
  4k sequences; compute terms put the large dense archs (granite, internvl)
  at 0.6–0.75 useful fraction (remat accounts for ~6/8 ideal).
* **MoE cells** (olmoe, dbrx) were catastrophically dispatch-bound at
  baseline → fixed in §Perf (shard_map EP all-to-all; 340x).
* **decode cells** are memory-bound (weights+cache per token) — as expected;
  ssm/hybrid decode (rwkv6, zamba2) carries O(1) state and is the cheapest.
* **long_500k** runs for the three sub-quadratic archs; h2o-danube's
  SWA-bounded KV and rwkv/zamba's O(1)/linear state fit per-chip HBM.
* **prefill cells** split compute-bound (whisper, minitron — pre-fix
  replication, see §Perf) vs collective-bound (the rest).

{perf_section()}

## §Train — end-to-end runs (CPU, reduced configs)

* `examples/train_unet.py`: U-Net loss 1.09 → 0.25 in 60 steps; float acc
  0.943 vs MMA-int8 0.944 (planes=8), 0.946 (planes=6), 0.884 (planes=4) —
  the early-termination accuracy/arithmetic trade of the paper's Sec. 5.
* `tests/test_checkpoint.py::test_trainer_restart_is_bit_deterministic`:
  kill-and-resume reproduces the uninterrupted run bit-exactly (step-indexed
  data + atomic checkpoints).
* `tests/test_distributed.py`: 8-device sharded train step matches the
  single-device loss; error-feedback int8 gradient compression drift stays
  within one quant step over 20 steps.
"""
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print(f"wrote EXPERIMENTS.md ({len(md)} chars)")


if __name__ == "__main__":
    main()
