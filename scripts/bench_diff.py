"""Cross-revision bench tracker: diff the committed BENCH_*.json baselines
at the merge-base against the freshly regenerated ones, and fail on
*frontier* regressions — not just invariant violations.

The per-revision benches already gate their own invariants (certificates
hold, tuned plans dominate, fair-share protects the minority class).  What
they cannot see is drift *between* revisions: a change that costs 6% of
GOPS/W at the same error target, or quietly loosens a certificate, passes
every in-revision assert and merges clean.  This script closes that hole:

  * **GOPS/W regression** — any row present in both revisions at an equal
    error target whose GOPS/W dropped by more than ``--gops-w-tol``
    (default 5%) fails the diff;
  * **metered-energy regression** — energy-bench rows gate their metered
    GOPS/W through the same check, and metered energy-per-request growth
    beyond ``--gops-w-tol`` fails on its own;
  * **certificate loosening** — any certified row at an equal target whose
    certified bound grew by more than ``--cert-tol`` (default 1%) fails
    (a *larger* certified error at the same target means the tuner now
    promises less);
  * rows whose error target changed are reported as not-comparable and
    skipped (a frontier at a different target is a different frontier).
    Gateway rows are keyed by their workload trace (name + trace schema
    version), so a trace-schema bump or a new canonical trace reads as a
    target change — skipped, never failed;
  * latency shifts in the gateway bench are reported as warnings only
    (scheduling latency is a trade the gateway bench gates in-revision).

Baselines come from ``git show <merge-base>:<file>`` so the tracker needs
no external storage — the committed JSONs *are* the trajectory.  A file
with no baseline (new bench, first revision) passes with a note.

Multi-revision ledger
---------------------
Pairwise diffs cannot show *trends*.  ``--ledger BENCH_LEDGER.jsonl``
appends one datapoint per revision — revision + committer date from git
metadata, and each bench's headline GOPS/W + certificate — to a committed
JSONL ledger (idempotent: re-running on the same revision replaces its
entry).  The append doubles as a trend check: a headline GOPS/W drop
beyond ``--gops-w-tol`` against the previous comparable ledger entry
(same bench, same target/trace key) fails, exactly like the pairwise
diff.  CI appends on every run and uploads the ledger with the bench
artifacts; the committed file is refreshed at merge.

    python scripts/bench_diff.py [--base-ref REF] [--out bench_diff.json]
                                 [--ledger BENCH_LEDGER.jsonl]

Exit status: 0 clean, 1 on any regression.  The JSON report (and the
human-readable table on stdout) is uploaded as a CI artifact either way.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys

DEFAULT_FILES = (
    "BENCH_segserve.json",
    "BENCH_autotune.json",
    "BENCH_gateway.json",
    "BENCH_fabric.json",
    "BENCH_capacity.json",
    "BENCH_energy.json",
    "BENCH_specdecode.json",
)


def _git(*args: str) -> str | None:
    try:
        out = subprocess.run(
            ["git", *args], capture_output=True, text=True, check=True
        )
    except (subprocess.CalledProcessError, FileNotFoundError):
        return None
    return out.stdout


def resolve_base_ref(explicit: str | None) -> str | None:
    """The revision to diff against: an explicit ref, else the merge-base
    with origin/main (falling back to local main)."""
    if explicit:
        return explicit
    for upstream in ("origin/main", "main"):
        mb = _git("merge-base", "HEAD", upstream)
        if mb:
            return mb.strip()
    return None


def load_baseline(ref: str, path: str) -> dict | None:
    blob = _git("show", f"{ref}:{path}")
    if blob is None:
        return None
    try:
        return json.loads(blob)
    except json.JSONDecodeError:
        return None


def comparable_rows(payload: dict):
    """Normalize one BENCH payload into (row_id, target, metrics) triples.

    ``target`` is the error target the row was produced at (None when the
    bench has no error axis, e.g. gateway rows); rows only compare across
    revisions when both id and target match.
    """
    bench = payload.get("bench", "?")
    if bench == "gateway":
        minority = payload.get("gate", {}).get("minority")
        # rows are only comparable on the same workload: key them by the
        # replayed trace's (name, schema version).  Pre-trace payloads
        # (PR 4) key as None, so the schema migration skips, not fails.
        tr = payload.get("trace")
        target = f"{tr['name']}@v{tr['version']}" if tr else None
        for r in payload.get("rows", []):
            metrics = dict(gops_w=r.get("gops_w"))
            pc = r.get("per_class", {})
            if minority in pc and pc[minority].get("p99_ms") is not None:
                metrics["minority_p99_ms"] = pc[minority]["p99_ms"]
            yield f"policy:{r['policy']}", target, metrics
        return
    if bench == "fabric":
        # comparable only on the same trace set: key by every replayed
        # trace's (name, schema version), so a trace regen or schema bump
        # reads as a target change — skipped, never failed
        trs = payload.get("traces", {})
        target = ";".join(
            f"{t['name']}@v{t['version']}" for _, t in sorted(trs.items())
        ) or None
        for r in payload.get("rows", []):
            metrics = dict(gops_w=r.get("gops_w"))
            pc = r.get("per_class", {})
            if "seg" in pc and pc["seg"].get("p99_ms") is not None:
                metrics["minority_p99_ms"] = pc["seg"]["p99_ms"]
            yield f"run:{r['label']}", target, metrics
        return
    if bench == "capacity":
        # comparable only on the identical sweep: the payload's ``key``
        # encodes workload generator + seed + span + trace schema + the
        # full grid, so any grid or workload change reads as a target
        # change — skipped, never failed
        target = payload.get("key")
        for r in payload.get("rows", []):
            metrics = dict(gops_w=r.get("gops_w"))
            pc = r.get("per_class", {})
            if "interactive" in pc and \
                    pc["interactive"].get("p99_ms") is not None:
                metrics["minority_p99_ms"] = pc["interactive"]["p99_ms"]
            yield f"cap:{r['label']}", target, metrics
        return
    if bench == "energy":
        # comparable only on the identical sweep + rate model: the
        # payload's ``key`` encodes workload, grid, draft planes and the
        # power cap, so any grid change reads as a target change —
        # skipped, never failed.  ``gops_w`` here is the *metered*
        # figure, so the standard regression check gates it; metered
        # energy-per-request growth is gated by the ``epr_pj`` check.
        target = payload.get("key")
        for r in payload.get("rows", []):
            metrics = dict(gops_w=r.get("metered_gops_w"),
                           epr_pj=r.get("energy_per_request_pj"))
            spec = r.get("spec")
            if spec and spec.get("accept_rate") is not None:
                metrics["accept_rate"] = spec["accept_rate"]
            yield f"en:{r['label']}", target, metrics
        return
    if bench == "specdecode":
        # comparable only on the same engineered model, geometry and
        # tuned operating point: a different attractor, depth or draft
        # schedule is a different frontier — skipped, never failed
        model = payload["model"]
        geom = payload["geometry"]
        plan = payload["plan"]
        target = (
            f"{model['name']}xL{model['n_layers']}"
            f"@g{model['embed_sharpen']:g}"
            f";k{plan['spec_k']}@p{plan['spec_planes'][0]}"
            f";new{geom['max_new']}x{geom['n_prompts']}"
        )
        gate = payload["gate"]
        yield "spec", target, dict(
            speedup=gate["speedup"], accept_rate=gate["accept_rate"]
        )
        return
    file_target = payload.get("target_rel_err")
    for r in payload.get("rows", []):
        target = r.get("target_rel_err", file_target)
        yield r.get("name", "?"), target, dict(
            gops_w=r.get("gops_w"), cert=r.get("cert")
        )


def diff_file(path: str, base: dict | None, new: dict | None,
              *, gops_w_tol: float, cert_tol: float) -> list[dict]:
    entries: list[dict] = []

    def entry(status, row, metric, base_v=None, new_v=None, note=""):
        entries.append(
            dict(file=path, row=row, metric=metric, status=status,
                 base=base_v, new=new_v, note=note)
        )

    if new is None:
        entry("regression", "*", "presence", note="bench output missing — "
              "the tracker cannot see this frontier any more")
        return entries
    if base is None:
        entry("note", "*", "presence", note="no baseline at merge-base "
              "(new bench target) — nothing to diff, skipping")
        return entries

    # A baseline payload can predate the bench's current schema (the
    # merge-base was committed before this target grew a field the
    # normalizer now indexes).  That is a target change, not a frontier
    # regression — and emphatically not a tracker crash.
    try:
        base_rows = {(rid, tgt): m for rid, tgt, m in comparable_rows(base)}
    except KeyError as e:
        entry("warning", "*", "schema", note=f"baseline payload missing "
              f"key {e} (schema predates this bench's shape) — skipped")
        return entries
    try:
        new_rows = {(rid, tgt): m for rid, tgt, m in comparable_rows(new)}
    except KeyError as e:
        entry("regression", "*", "schema", note=f"freshly generated "
              f"payload missing key {e} — the bench no longer emits what "
              f"the tracker diffs")
        return entries
    base_ids = {rid for rid, _ in base_rows}
    for (rid, tgt), nm in sorted(new_rows.items(), key=lambda kv: str(kv[0])):
        if (rid, tgt) not in base_rows:
            if rid in base_ids:
                entry("skipped", rid, "target", note=f"error target changed "
                      f"(now {tgt}) — frontiers not comparable")
            else:
                entry("note", rid, "presence", note="new row")
            continue
        bm = base_rows[(rid, tgt)]
        b_g, n_g = bm.get("gops_w"), nm.get("gops_w")
        if b_g is not None and n_g is None:
            # a metric the tracker was watching vanished from the bench —
            # must not silently narrow the gate
            entry("warning", rid, "gops_w", b_g, None,
                  note="metric disappeared from the bench")
        elif b_g and n_g is not None:
            drop = (b_g - n_g) / b_g
            status = "regression" if drop > gops_w_tol else "ok"
            entry(status, rid, "gops_w", b_g, n_g,
                  note=f"{-drop:+.1%} at target {tgt}")
        b_c, n_c = bm.get("cert"), nm.get("cert")
        if b_c is not None and n_c is None:
            entry("warning", rid, "cert", b_c, None,
                  note="certified row lost its certificate")
        elif b_c is not None and n_c is not None:
            if b_c > 0:
                loosen = (n_c - b_c) / b_c
                status = "regression" if loosen > cert_tol else "ok"
                note = f"{loosen:+.1%} at target {tgt}"
            else:  # an exact (cert == 0) row may not grow a bound at all
                status = "regression" if n_c > 1e-12 else "ok"
                note = f"was exact at target {tgt}"
            entry(status, rid, "cert", b_c, n_c,
                  note=note + (" — certificate loosened"
                               if status == "regression" else ""))
        b_s, n_s = bm.get("speedup"), nm.get("speedup")
        if b_s and n_s is not None:
            drop = (b_s - n_s) / b_s
            status = "regression" if drop > gops_w_tol else "ok"
            entry(status, rid, "speedup", b_s, n_s,
                  note=f"{-drop:+.1%} at target {tgt}")
        b_e, n_e = bm.get("epr_pj"), nm.get("epr_pj")
        if b_e and n_e is not None:
            growth = (n_e - b_e) / b_e
            status = "regression" if growth > gops_w_tol else "ok"
            entry(status, rid, "epr_pj", b_e, n_e,
                  note=f"{growth:+.1%} at target {tgt}")
        b_a, n_a = bm.get("accept_rate"), nm.get("accept_rate")
        if b_a and n_a is not None:
            shift = (n_a - b_a) / b_a
            entry("warning" if shift < -0.05 else "ok", rid,
                  "accept_rate", b_a, n_a, note=f"{shift:+.1%}")
        b_p, n_p = bm.get("minority_p99_ms"), nm.get("minority_p99_ms")
        if b_p and n_p is not None:
            shift = (n_p - b_p) / b_p
            entry("warning" if shift > 0.10 else "ok", rid,
                  "minority_p99_ms", b_p, n_p, note=f"{shift:+.1%}")
    for (rid, tgt) in sorted(set(base_rows) - set(new_rows), key=str):
        if not any(r == rid for r, _ in new_rows):
            entry("warning", rid, "presence",
                  note="row disappeared from the bench")
    return entries


# ------------------------------------------------------------------ ledger


def headline_metrics(payload: dict) -> dict | None:
    """One bench payload's headline datapoint for the multi-revision
    ledger: the frontier row the repo leads with, its error-target /
    trace key (comparability guard), GOPS/W and certificate."""
    bench = payload.get("bench")
    rows = payload.get("rows", [])
    if bench == "segserve":
        row = next((r for r in rows if r.get("name") == "adaptive"), None)
        if row:
            return dict(
                target=payload.get("target_rel_err"),
                gops_w=row.get("gops_w"),
                cert=payload.get("gate", {}).get("cert"),
            )
    if bench == "autotune":
        ht = payload.get("headline_target")
        row = next(
            (r for r in rows if r.get("name") == f"tuned-{ht}"), None
        )
        if row:
            return dict(target=ht, gops_w=row.get("gops_w"),
                        cert=row.get("cert"))
    if bench == "gateway":
        tr = payload.get("trace")
        target = f"{tr['name']}@v{tr['version']}" if tr else None
        row = next(
            (r for r in rows if r.get("policy") == "fair"), rows[0] if rows
            else None,
        )
        if row:
            out = dict(target=target, gops_w=row.get("gops_w"), cert=None)
            pc = row.get("per_class", {})
            if "interactive" in pc:
                out["interactive_p99_ms"] = pc["interactive"].get("p99_ms")
            # span-breakdown headline: where did the p99 request's time
            # go (queued / executing / preempted), from the event-bus
            # span block instrumented payloads carry (repro.obs.spans)
            p99 = (
                payload.get("spans", {})
                .get("per_class", {})
                .get("interactive", {})
                .get("p99")
            )
            if p99:
                out["p99_queued_ms"] = p99.get("queued_ms")
                out["p99_exec_ms"] = p99.get("exec_ms")
                out["p99_preempted_ms"] = p99.get("preempted_ms")
            return out
    if bench == "fabric":
        trs = payload.get("traces", {})
        target = ";".join(
            f"{t['name']}@v{t['version']}" for _, t in sorted(trs.items())
        ) or None
        n = payload.get("n_shards")
        row = next(
            (r for r in rows
             if r.get("router") == "deficit" and r.get("trace") == "x10"),
            rows[0] if rows else None,
        )
        if row:
            out = dict(target=target, gops_w=row.get("gops_w"), cert=None,
                       n_shards=n)
            pc = row.get("per_class", {})
            if "seg" in pc:
                out["seg_p99_ms"] = pc["seg"].get("p99_ms")
            return out
    if bench == "capacity":
        target = payload.get("key")
        frontier = payload.get("frontier", [])
        # the flagship operating point: tuned plan on the deficit router
        # under fair scheduling — the fleet the repo would actually run
        pt = next(
            (f for f in frontier
             if (f.get("plan"), f.get("router"), f.get("policy"))
             == ("tuned4", "deficit", "fair")),
            next((f for f in frontier if f.get("min_shards") is not None),
                 None),
        )
        if pt:
            uniform = next(
                (f.get("min_shards") for f in frontier
                 if (f.get("router"), f.get("policy"), f.get("plan"))
                 == (pt.get("router"), pt.get("policy"), "uniform8")),
                None,
            )
            return dict(target=target, gops_w=pt.get("gops_w"), cert=None,
                        min_shards=pt.get("min_shards"),
                        uniform_min_shards=uniform)
    if bench == "energy":
        target = payload.get("key")
        # the flagship operating point: the tuned plan under fair
        # scheduling at the smallest fleet — the best metered GOPS/W the
        # repo would actually run; accept-rate rides from the spec plan
        pt = next(
            (r for r in rows
             if r.get("plan") == "tuned4" and r.get("policy") == "fair"),
            rows[0] if rows else None,
        )
        if pt:
            out = dict(target=target, gops_w=pt.get("metered_gops_w"),
                       cert=None,
                       epr_pj=pt.get("energy_per_request_pj"))
            spec_row = next(
                (r for r in rows if r.get("spec")
                 and r["spec"].get("accept_rate") is not None),
                None,
            )
            if spec_row:
                out["accept_rate"] = spec_row["spec"]["accept_rate"]
            return out
    if bench == "specdecode":
        try:
            rid, target, metrics = next(iter(comparable_rows(payload)))
        except (KeyError, StopIteration):
            return None
        gate = payload.get("gate", {})
        return dict(target=target, gops_w=None, cert=None,
                    speedup=metrics.get("speedup"),
                    accept_rate=metrics.get("accept_rate"),
                    wasted_cycles=gate.get("wasted_cycles"))
    best = max((r for r in rows if r.get("gops_w")),
               key=lambda r: r["gops_w"], default=None)
    if best:
        return dict(target=None, gops_w=best["gops_w"],
                    cert=best.get("cert"))
    return None


def load_ledger(path: str) -> list[dict]:
    try:
        with open(path) as f:
            return [json.loads(line) for line in f if line.strip()]
    except FileNotFoundError:
        return []


def update_ledger(path: str, files, *, gops_w_tol: float) -> list[dict]:
    """Append this revision's headline datapoint (replacing an existing
    entry for the same revision — idempotent in CI retries) and run the
    trend check against the previous comparable entry per bench.  Returns
    diff-style entries (regressions fail the run, like the pairwise diff).
    A changed target/trace key is a target change: noted, never failed.
    """
    revision = (_git("rev-parse", "HEAD") or "unknown").strip()
    date = (_git("show", "-s", "--format=%cI", "HEAD") or "").strip()
    benches: dict[str, dict] = {}
    for f in files:
        try:
            with open(f) as fh:
                payload = json.load(fh)
        except (FileNotFoundError, json.JSONDecodeError):
            continue
        hm = headline_metrics(payload)
        if hm is not None:
            benches[payload.get("bench", f)] = hm
    history = [e for e in load_ledger(path) if e.get("revision") != revision]

    entries: list[dict] = []
    for bench, hm in benches.items():
        prev = next(
            (e["benches"][bench] for e in reversed(history)
             if bench in e.get("benches", {})),
            None,
        )
        if prev is None:
            entries.append(dict(file=path, row=bench, metric="ledger",
                                status="note", base=None,
                                new=hm.get("gops_w"),
                                note="first ledger datapoint"))
            continue
        if prev.get("target") != hm.get("target"):
            entries.append(dict(
                file=path, row=bench, metric="ledger", status="skipped",
                base=prev.get("gops_w"), new=hm.get("gops_w"),
                note=f"target changed {prev.get('target')} -> "
                     f"{hm.get('target')} — trend not comparable"))
            continue
        b_g, n_g = prev.get("gops_w"), hm.get("gops_w")
        if b_g and n_g is not None:
            drop = (b_g - n_g) / b_g
            status = "regression" if drop > gops_w_tol else "ok"
            entries.append(dict(file=path, row=bench, metric="ledger",
                                status=status, base=b_g, new=n_g,
                                note=f"{-drop:+.1%} vs previous ledger "
                                     f"entry"))
        b_s, n_s = prev.get("speedup"), hm.get("speedup")
        if b_s and n_s is not None:
            drop = (b_s - n_s) / b_s
            status = "regression" if drop > gops_w_tol else "ok"
            entries.append(dict(file=path, row=bench,
                                metric="ledger:speedup", status=status,
                                base=b_s, new=n_s,
                                note=f"{-drop:+.1%} vs previous ledger "
                                     f"entry"))
        # speculative accept-rate is a tracked headline column, not just
        # a pairwise warning: a drop beyond tolerance fails the trend
        # (fewer accepted drafts means more wasted full-digit verify
        # work — an energy regression the GOPS/W headline can mask)
        b_a, n_a = prev.get("accept_rate"), hm.get("accept_rate")
        if b_a and n_a is not None:
            drop = (b_a - n_a) / b_a
            status = "regression" if drop > gops_w_tol else "ok"
            entries.append(dict(file=path, row=bench,
                                metric="ledger:accept_rate", status=status,
                                base=b_a, new=n_a,
                                note=f"{-drop:+.1%} vs previous ledger "
                                     f"entry"))
    history.append(dict(revision=revision, date=date, benches=benches))
    with open(path, "w") as f:
        for e in history:
            f.write(json.dumps(e, sort_keys=True) + "\n")
    return entries


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--base-ref", default=None,
                    help="revision to diff against (default: merge-base "
                         "with origin/main)")
    ap.add_argument("--files", nargs="*", default=list(DEFAULT_FILES))
    ap.add_argument("--out", default="bench_diff.json")
    ap.add_argument("--gops-w-tol", type=float, default=0.05,
                    help="relative GOPS/W drop that fails (default 5%%)")
    ap.add_argument("--cert-tol", type=float, default=0.01,
                    help="relative certificate growth that fails (default 1%%)")
    ap.add_argument("--ledger", default=None, metavar="PATH",
                    help="append this revision's headline datapoint to a "
                         "JSONL ledger and trend-check it (e.g. "
                         "BENCH_LEDGER.jsonl)")
    args = ap.parse_args(argv)

    base_ref = resolve_base_ref(args.base_ref)
    entries: list[dict] = []
    if base_ref is None:
        entries.append(dict(file="*", row="*", metric="presence",
                            status="note", base=None, new=None,
                            note="no merge-base resolvable — nothing to diff"))
    else:
        for path in args.files:
            try:
                with open(path) as f:
                    new = json.load(f)
            except (FileNotFoundError, json.JSONDecodeError):
                new = None
            entries += diff_file(
                path, load_baseline(base_ref, path), new,
                gops_w_tol=args.gops_w_tol, cert_tol=args.cert_tol,
            )

    if args.ledger:
        entries += update_ledger(
            args.ledger, args.files, gops_w_tol=args.gops_w_tol
        )

    regressions = [e for e in entries if e["status"] == "regression"]
    report = dict(
        base_ref=base_ref,
        files=list(args.files),
        gops_w_tol=args.gops_w_tol,
        cert_tol=args.cert_tol,
        entries=entries,
        n_regressions=len(regressions),
        holds=not regressions,
    )
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")

    print(f"bench_diff vs {base_ref or '<none>'}")
    for e in entries:
        base_v = "-" if e["base"] is None else f"{e['base']:.4g}"
        new_v = "-" if e["new"] is None else f"{e['new']:.4g}"
        print(f"  [{e['status']:10s}] {e['file']} :: {e['row']} :: "
              f"{e['metric']}: {base_v} -> {new_v}  {e['note']}")
    if regressions:
        print(f"FAIL: {len(regressions)} frontier regression(s)")
        return 1
    print("ok: no frontier regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
