"""Cross-revision bench tracker: diff the committed BENCH_*.json baselines
at the merge-base against the freshly regenerated ones, and fail on
*frontier* regressions — not just invariant violations.

The per-revision benches already gate their own invariants (certificates
hold, tuned plans dominate, fair-share protects the minority class).  What
they cannot see is drift *between* revisions: a change that costs 6% of
GOPS/W at the same error target, or quietly loosens a certificate, passes
every in-revision assert and merges clean.  This script closes that hole:

  * **GOPS/W regression** — any row present in both revisions at an equal
    error target whose GOPS/W dropped by more than ``--gops-w-tol``
    (default 5%) fails the diff;
  * **certificate loosening** — any certified row at an equal target whose
    certified bound grew by more than ``--cert-tol`` (default 1%) fails
    (a *larger* certified error at the same target means the tuner now
    promises less);
  * rows whose error target changed are reported as not-comparable and
    skipped (a frontier at a different target is a different frontier);
  * latency shifts in the gateway bench are reported as warnings only
    (scheduling latency is a trade the gateway bench gates in-revision).

Baselines come from ``git show <merge-base>:<file>`` so the tracker needs
no external storage — the committed JSONs *are* the trajectory.  A file
with no baseline (new bench, first revision) passes with a note.

    python scripts/bench_diff.py [--base-ref REF] [--out bench_diff.json]

Exit status: 0 clean, 1 on any regression.  The JSON report (and the
human-readable table on stdout) is uploaded as a CI artifact either way.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys

DEFAULT_FILES = (
    "BENCH_segserve.json",
    "BENCH_autotune.json",
    "BENCH_gateway.json",
)


def _git(*args: str) -> str | None:
    try:
        out = subprocess.run(
            ["git", *args], capture_output=True, text=True, check=True
        )
    except (subprocess.CalledProcessError, FileNotFoundError):
        return None
    return out.stdout


def resolve_base_ref(explicit: str | None) -> str | None:
    """The revision to diff against: an explicit ref, else the merge-base
    with origin/main (falling back to local main)."""
    if explicit:
        return explicit
    for upstream in ("origin/main", "main"):
        mb = _git("merge-base", "HEAD", upstream)
        if mb:
            return mb.strip()
    return None


def load_baseline(ref: str, path: str) -> dict | None:
    blob = _git("show", f"{ref}:{path}")
    if blob is None:
        return None
    try:
        return json.loads(blob)
    except json.JSONDecodeError:
        return None


def comparable_rows(payload: dict):
    """Normalize one BENCH payload into (row_id, target, metrics) triples.

    ``target`` is the error target the row was produced at (None when the
    bench has no error axis, e.g. gateway rows); rows only compare across
    revisions when both id and target match.
    """
    bench = payload.get("bench", "?")
    if bench == "gateway":
        minority = payload.get("gate", {}).get("minority")
        for r in payload.get("rows", []):
            metrics = dict(gops_w=r.get("gops_w"))
            pc = r.get("per_class", {})
            if minority in pc and pc[minority].get("p99_ms") is not None:
                metrics["minority_p99_ms"] = pc[minority]["p99_ms"]
            yield f"policy:{r['policy']}", None, metrics
        return
    file_target = payload.get("target_rel_err")
    for r in payload.get("rows", []):
        target = r.get("target_rel_err", file_target)
        yield r.get("name", "?"), target, dict(
            gops_w=r.get("gops_w"), cert=r.get("cert")
        )


def diff_file(path: str, base: dict | None, new: dict | None,
              *, gops_w_tol: float, cert_tol: float) -> list[dict]:
    entries: list[dict] = []

    def entry(status, row, metric, base_v=None, new_v=None, note=""):
        entries.append(
            dict(file=path, row=row, metric=metric, status=status,
                 base=base_v, new=new_v, note=note)
        )

    if new is None:
        entry("regression", "*", "presence", note="bench output missing — "
              "the tracker cannot see this frontier any more")
        return entries
    if base is None:
        entry("note", "*", "presence", note="no baseline at merge-base "
              "(new bench) — nothing to diff")
        return entries

    base_rows = {(rid, tgt): m for rid, tgt, m in comparable_rows(base)}
    new_rows = {(rid, tgt): m for rid, tgt, m in comparable_rows(new)}
    base_ids = {rid for rid, _ in base_rows}
    for (rid, tgt), nm in sorted(new_rows.items(), key=lambda kv: str(kv[0])):
        if (rid, tgt) not in base_rows:
            if rid in base_ids:
                entry("skipped", rid, "target", note=f"error target changed "
                      f"(now {tgt}) — frontiers not comparable")
            else:
                entry("note", rid, "presence", note="new row")
            continue
        bm = base_rows[(rid, tgt)]
        b_g, n_g = bm.get("gops_w"), nm.get("gops_w")
        if b_g is not None and n_g is None:
            # a metric the tracker was watching vanished from the bench —
            # must not silently narrow the gate
            entry("warning", rid, "gops_w", b_g, None,
                  note="metric disappeared from the bench")
        elif b_g and n_g is not None:
            drop = (b_g - n_g) / b_g
            status = "regression" if drop > gops_w_tol else "ok"
            entry(status, rid, "gops_w", b_g, n_g,
                  note=f"{-drop:+.1%} at target {tgt}")
        b_c, n_c = bm.get("cert"), nm.get("cert")
        if b_c is not None and n_c is None:
            entry("warning", rid, "cert", b_c, None,
                  note="certified row lost its certificate")
        elif b_c is not None and n_c is not None:
            if b_c > 0:
                loosen = (n_c - b_c) / b_c
                status = "regression" if loosen > cert_tol else "ok"
                note = f"{loosen:+.1%} at target {tgt}"
            else:  # an exact (cert == 0) row may not grow a bound at all
                status = "regression" if n_c > 1e-12 else "ok"
                note = f"was exact at target {tgt}"
            entry(status, rid, "cert", b_c, n_c,
                  note=note + (" — certificate loosened"
                               if status == "regression" else ""))
        b_p, n_p = bm.get("minority_p99_ms"), nm.get("minority_p99_ms")
        if b_p and n_p is not None:
            shift = (n_p - b_p) / b_p
            entry("warning" if shift > 0.10 else "ok", rid,
                  "minority_p99_ms", b_p, n_p, note=f"{shift:+.1%}")
    for (rid, tgt) in sorted(set(base_rows) - set(new_rows), key=str):
        if not any(r == rid for r, _ in new_rows):
            entry("warning", rid, "presence",
                  note="row disappeared from the bench")
    return entries


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--base-ref", default=None,
                    help="revision to diff against (default: merge-base "
                         "with origin/main)")
    ap.add_argument("--files", nargs="*", default=list(DEFAULT_FILES))
    ap.add_argument("--out", default="bench_diff.json")
    ap.add_argument("--gops-w-tol", type=float, default=0.05,
                    help="relative GOPS/W drop that fails (default 5%%)")
    ap.add_argument("--cert-tol", type=float, default=0.01,
                    help="relative certificate growth that fails (default 1%%)")
    args = ap.parse_args(argv)

    base_ref = resolve_base_ref(args.base_ref)
    entries: list[dict] = []
    if base_ref is None:
        entries.append(dict(file="*", row="*", metric="presence",
                            status="note", base=None, new=None,
                            note="no merge-base resolvable — nothing to diff"))
    else:
        for path in args.files:
            try:
                with open(path) as f:
                    new = json.load(f)
            except (FileNotFoundError, json.JSONDecodeError):
                new = None
            entries += diff_file(
                path, load_baseline(base_ref, path), new,
                gops_w_tol=args.gops_w_tol, cert_tol=args.cert_tol,
            )

    regressions = [e for e in entries if e["status"] == "regression"]
    report = dict(
        base_ref=base_ref,
        files=list(args.files),
        gops_w_tol=args.gops_w_tol,
        cert_tol=args.cert_tol,
        entries=entries,
        n_regressions=len(regressions),
        holds=not regressions,
    )
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")

    print(f"bench_diff vs {base_ref or '<none>'}")
    for e in entries:
        base_v = "-" if e["base"] is None else f"{e['base']:.4g}"
        new_v = "-" if e["new"] is None else f"{e['new']:.4g}"
        print(f"  [{e['status']:10s}] {e['file']} :: {e['row']} :: "
              f"{e['metric']}: {base_v} -> {new_v}  {e['note']}")
    if regressions:
        print(f"FAIL: {len(regressions)} frontier regression(s)")
        return 1
    print("ok: no frontier regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
