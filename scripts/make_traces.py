"""Regenerate the canonical committed workload traces under ``traces/``.

Traces are pure functions of their seeds (counter-PRNG arrivals, payload
specs only — see ``repro.workload``), so this script is idempotent: the
committed JSON is exactly what it writes, and CI/benches replay the same
traffic forever.  Rerun it only to *change* a canonical workload, and
bump the trace name/seed when you do — the bench tracker keys gateway
rows by (trace name, schema version), so a silently edited trace would
poison cross-revision diffs.

    PYTHONPATH=src python scripts/make_traces.py [--outdir traces]

Canonical traces
----------------
``gateway_burst``
    The serving-gateway bench workload: a steady Poisson stream of
    ``interactive`` LM requests (short prompts, latency-sensitive), an
    on-off Markov-modulated burst of ``batch`` LM requests (long prompts
    — the atomic-prefill overdraft shape), and a sparse deterministic
    minority of segmentation images.  Arrival stamps assume the bench's
    800k-cycle rounds (8 ms at the paper's 100 MHz).

``gateway_burst_x10`` / ``gateway_burst_x100``
    The same traffic shape at 10x / 100x the arrival *rate* over the
    same span: per-stream counts scale up by the factor and the
    inter-arrival / intra-burst intervals compress by it (the on-off
    burst phase structure is preserved).  The x1 trace already offers
    ~1.4 chips of modeled work; the scaled variants are the fabric
    bench's saturation workloads — one gateway backlogs superlinearly,
    an N-shard fabric keeps per-class p99 near baseline.

``diurnal_smoke``
    One compressed diurnal period materialized from the *streaming*
    generators in ``repro.workload.diurnal`` (day-curve-thinned Poisson
    interactive + day-modulated on-off batch bursts + sparse seg, each
    with a deadline class) — the committed, replayable smoke slice of
    the capacity planner's workload family.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.workload import arrivals, diurnal, from_streams  # noqa: E402


def gateway_burst(seed: int = 20260729):
    """The canonical mixed-QoS burst trace (see module docstring)."""
    interactive = arrivals.poisson(
        20, mean_interval=400_000, seed=seed, start=50_000
    )
    batch = arrivals.on_off(
        12, seed=seed + 1, burst_interval=120_000,
        on_mean=800_000, off_mean=1_600_000, start=150_000,
    )
    seg = arrivals.deterministic(3, interval=2_500_000, start=600_000)
    return from_streams(
        "gateway_burst",
        seed,
        [
            dict(kind="lm", qos="interactive", arrivals=interactive,
                 payload=dict(prompt_len=4, max_new=8)),
            dict(kind="lm", qos="batch", arrivals=batch,
                 payload=dict(prompt_len=24, max_new=4)),
            dict(kind="seg", qos="seg", arrivals=seg,
                 payload=dict(h=96, w=80)),
        ],
        description=(
            "Majority interactive LM stream + on-off batch-LM prompt "
            "bursts + sparse seg minority; the preemptive-vs-atomic and "
            "fair-vs-fifo gate workload of benchmarks/gateway.py"
        ),
        meta=dict(
            source="generated",
            round_budget=800_000,
            # interactive gets headroom over its ~0.33 offered load (the
            # latency class must not be share-saturated, or queueing —
            # not preemption — dominates its p99); batch is deliberately
            # overloaded vs its share (the throughput class backlogs);
            # seg is a small minority with a protective slice.
            shares=dict(interactive=0.4, batch=0.3, seg=0.3),
            lm="minitron_4b smoke",
            seg="unet hw=(96,80) in_ch=4 base=8 depth=2 cps=1",
        ),
    )


def gateway_burst_scaled(factor: int, seed: int = 20260729):
    """``gateway_burst`` at ``factor``x the arrival rate, same span.

    Counts scale by ``factor`` and intervals compress by it; the on-off
    burst *periods* (``on_mean``/``off_mean``) stay fixed so the burst
    phase structure is the same traffic shape, just denser.  Seed is
    offset by the factor so the scaled streams are decorrelated from x1
    rather than a superset of it.
    """
    if factor < 2:
        raise ValueError(f"factor {factor} < 2: use gateway_burst for x1")
    seed = seed + factor
    interactive = arrivals.poisson(
        20 * factor, mean_interval=400_000 / factor, seed=seed,
        start=50_000,
    )
    batch = arrivals.on_off(
        12 * factor, seed=seed + 1, burst_interval=120_000 / factor,
        on_mean=800_000, off_mean=1_600_000, start=150_000,
    )
    seg = arrivals.deterministic(
        3 * factor, interval=max(2_500_000 // factor, 1), start=600_000
    )
    return from_streams(
        f"gateway_burst_x{factor}",
        seed,
        [
            dict(kind="lm", qos="interactive", arrivals=interactive,
                 payload=dict(prompt_len=4, max_new=8)),
            dict(kind="lm", qos="batch", arrivals=batch,
                 payload=dict(prompt_len=24, max_new=4)),
            dict(kind="seg", qos="seg", arrivals=seg,
                 payload=dict(h=96, w=80)),
        ],
        description=(
            f"gateway_burst traffic shape at {factor}x arrival rate over "
            f"the same span — the fabric saturation workload of "
            f"benchmarks/fabric.py"
        ),
        meta=dict(
            source="generated",
            round_budget=800_000,
            shares=dict(interactive=0.4, batch=0.3, seg=0.3),
            scale_factor=factor,
            base_trace="gateway_burst",
            lm="minitron_4b smoke",
            seg="unet hw=(96,80) in_ch=4 base=8 depth=2 cps=1",
        ),
    )


def diurnal_smoke(seed: int = 20260808):
    """A materialized slice of the streaming diurnal workload family
    (``repro.workload.diurnal``) — a committed, replayable smoke trace
    for the capacity planner's generators.  The capacity bench itself
    streams lazily and never materializes; this trace pins a small
    prefix of the same process family into schema v1 so the generators'
    output is itself under the trace round-trip + bench-tracker regime.
    """
    period = 9_600_000  # a compressed 12-round "day"
    span = period
    interactive = diurnal.take_until(
        diurnal.diurnal(seed=seed, peak_interval=150_000, period=period,
                        floor=0.2, start=50_000),
        span,
    )
    batch = diurnal.take_until(
        diurnal.modulate(
            diurnal.iter_on_off(seed=seed + 1, burst_interval=250_000,
                                on_mean=800_000, off_mean=1_600_000,
                                start=150_000),
            seed=seed + 1, period=period, floor=0.2,
        ),
        span,
    )
    seg = diurnal.take_until(
        diurnal.iter_poisson(seed=seed + 2, mean_interval=2_000_000,
                             start=600_000),
        span,
    )
    return from_streams(
        "diurnal_smoke",
        seed,
        [
            dict(kind="lm", qos="interactive", arrivals=list(interactive),
                 payload=dict(prompt_len=4, max_new=8),
                 deadline_cycles=400_000),
            dict(kind="lm", qos="batch", arrivals=list(batch),
                 payload=dict(prompt_len=24, max_new=4),
                 deadline_cycles=8_000_000),
            dict(kind="seg", qos="seg", arrivals=list(seg),
                 payload=dict(h=96, w=80), deadline_cycles=4_000_000),
        ],
        description=(
            "One compressed diurnal period (raised-cosine day curve over "
            "Poisson interactive + on-off batch bursts + sparse seg), "
            "materialized from the streaming generators the capacity "
            "planner drives lazily"
        ),
        meta=dict(
            source="generated",
            round_budget=800_000,
            shares=dict(interactive=0.4, batch=0.3, seg=0.3),
            period=period,
            floor=0.2,
            lm="minitron_4b smoke",
            seg="unet hw=(96,80) in_ch=4 base=8 depth=2 cps=1",
        ),
    )


BUILDERS = {
    "gateway_burst": gateway_burst,
    "gateway_burst_x10": lambda: gateway_burst_scaled(10),
    "gateway_burst_x100": lambda: gateway_burst_scaled(100),
    "diurnal_smoke": diurnal_smoke,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--outdir", default="traces")
    ap.add_argument("--only", nargs="*", default=None,
                    help="trace names to regenerate (default: all)")
    args = ap.parse_args(argv)
    names = args.only or sorted(BUILDERS)
    for name in names:
        trace = BUILDERS[name]()
        path = os.path.join(args.outdir, f"{name}.json")
        trace.save(path)
        print(f"wrote {path}: {trace.describe()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
