"""Sharded-fabric scale-out benchmark — ``BENCH_fabric.json``.

Replays the scaled canonical traces (``traces/gateway_burst_x10.json`` /
``_x100.json`` — same traffic shape as ``gateway_burst``, 10x/100x the
arrival rate over the same span) through a single modeled gateway and
through an N-shard :class:`repro.serve.Fabric`, open-loop via
``repro.workload.replay`` — the identical harness that drives the
single-gateway bench, routing at arrival injection.

All engines here are *modeled* (:mod:`repro.serve.modeled`): work is
priced with the same relation-(2) cycle model the real adapters use but
never executed, so a 100x trace across 16 shards replays in CI seconds
while everything under test — routing, stealing, per-class latency,
fleet-ledger arithmetic — is exercised for real.  The x1 trace already
offers ~1.4 chips of work, so the x10 point is deep saturation for one
gateway and ~0.9 utilization for 16 shards.

Gates (each raises, so CI fails loudly):

1. **Single-gateway saturation** — on the x10 trace the single gateway's
   minority-class (seg) p99 must grow *superlinearly* in the load factor
   (> 10x its x1 p99): the backlog dominates service time, which is what
   "one gateway is one chip" means.
2. **Fabric sub-linear scaling** — the 16-shard fabric's seg p99 on the
   same x10 trace must grow *sub-linearly* (< 10x the fabric's own x1
   p99): added load is absorbed by added shards, not queueing.
3. **Exact ledger additivity** — on every fabric run, the fleet ledger's
   incrementally-accumulated ops/cycles must equal the direct per-shard
   sums to the integer (``FleetLedger.additivity()['holds']``), per-class
   included — MINT's compounding-error lesson, gated.
4. **Completion conservation** — every run completes every request in
   its trace; nothing is dropped by routing or stealing.

The headline run (``deficit`` at x10) additionally carries a
:mod:`repro.obs` ``RecordingSink``: the payload's ``spans`` block
decomposes its per-class p50/p99 requests into queued / executing /
preempted cycles, and the run raises unless the stream's execution
attribution reconciles integer-exactly with every shard's
``RoundClock.worked_total`` *and* the ``FleetLedger`` totals.

Router comparison rows (``class`` / ``p2c`` / ``deficit``) are recorded
at x10; the headline fabric configuration is ``deficit`` routing with
work stealing on.  ``scripts/bench_diff.py`` diffs fabric rows by
(trace, config) and trend-checks fleet GOPS/W (power modeled as N chips).

    PYTHONPATH=src python -m benchmarks.run --section fabric
"""
from __future__ import annotations

import json
import os
import time

_ROOT = (
    os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if "__file__" in globals() else "."
)
TRACES = {
    "x1": os.path.join(_ROOT, "traces", "gateway_burst.json"),
    "x10": os.path.join(_ROOT, "traces", "gateway_burst_x10.json"),
    "x100": os.path.join(_ROOT, "traces", "gateway_burst_x100.json"),
}

ROUND_BUDGET = 800_000
N_SHARDS = 16
LM_BATCH = 20
LM_MAX_SEQ = 96
MINORITY = "seg"
FABRIC_SEED = 7


def _mk_gateway(shares, *, policy="fair"):
    from repro.configs import get_smoke_config
    from repro.serve.gateway import Gateway
    from repro.serve.modeled import ModeledLMAdapter, ModeledSegAdapter

    cfg = get_smoke_config("minitron_4b")
    return Gateway(
        [
            ModeledLMAdapter.from_config(cfg, batch=LM_BATCH,
                                         max_seq=LM_MAX_SEQ),
            ModeledSegAdapter.from_geometry(),
        ],
        policy=policy,
        round_budget=ROUND_BUDGET,
        shares=shares,
    )


def _replay(target, trace):
    from repro.serve.modeled import modeled_materializer
    from repro.workload import replay as replay_mod

    mats = {k: modeled_materializer() for k in trace.kinds}
    t0 = time.perf_counter()
    summary = replay_mod.replay(target, trace, mats, max_rounds=100_000)
    summary["wall_us"] = (time.perf_counter() - t0) * 1e6
    return summary


def _run_one(trace, shares, *, n_shards, router=None, record_spans=False):
    """One replay: single gateway (``n_shards=1``, ``router=None``) or an
    N-shard fabric.  Returns (summary, fabric-or-gateway)."""
    from repro.serve.fabric import Fabric

    if n_shards == 1 and router is None:
        gw = _mk_gateway(shares)
        return _replay(gw, trace), gw
    sink = None
    if record_spans:
        from repro.obs import RecordingSink

        sink = RecordingSink()
    fab = Fabric(
        [_mk_gateway(shares) for _ in range(n_shards)],
        router=router, seed=FABRIC_SEED, sink=sink,
    )
    summary = _replay(fab, trace)
    if record_spans:
        from repro.obs import assemble, breakdown, reconcile

        rec = reconcile(
            sink.events, [g.round_clock for g in fab.shards],
            ledger=fab.ledger,
        )
        if not rec["holds"]:
            raise RuntimeError(
                f"fleet span attribution does not reconcile: "
                f"{rec['total_exec']} exec-event cycles vs "
                f"{rec['total_worked']} worked cycles (ledger "
                f"{sum(rec.get('ledger_worked', []))})"
            )
        summary["spans"] = dict(
            per_class=breakdown(assemble(sink.events)),
            reconcile=rec,
            events=len(sink.events),
        )
    return summary, fab


def _check_completion(summary, trace, label):
    for qos, pc in summary["per_class"].items():
        if pc["n"] != pc["completed"]:
            raise RuntimeError(
                f"{label} dropped work: class {qos} completed "
                f"{pc['completed']}/{pc['n']} on {trace.name}"
            )


def _check_additivity(fab, label):
    add = fab.additivity()
    if not add["holds"]:
        raise RuntimeError(
            f"fleet ledger additivity violated on {label}: ledger "
            f"ops/worked {add['ledger_total_ops']}/"
            f"{add['ledger_total_worked']} vs direct "
            f"{add['direct_total_ops']}/{add['direct_total_worked']}"
        )
    return add


def run(*, json_path: str | None = "BENCH_fabric.json"):
    from repro.workload import Trace

    traces = {k: Trace.load(p) for k, p in TRACES.items()}
    shares = dict(traces["x1"].meta["shares"])

    summaries: dict[str, dict] = {}
    payload_rows = []
    rows: list[tuple[str, float, str]] = []

    plan = [
        # label, trace key, shards, router
        ("single/x1", "x1", 1, None),
        ("single/x10", "x10", 1, None),
        (f"fabric{N_SHARDS}-deficit/x1", "x1", N_SHARDS, "deficit"),
        (f"fabric{N_SHARDS}-deficit/x10", "x10", N_SHARDS, "deficit"),
        (f"fabric{N_SHARDS}-class/x10", "x10", N_SHARDS, "class"),
        (f"fabric{N_SHARDS}-p2c/x10", "x10", N_SHARDS, "p2c"),
        # informational scale point: 16 shards at x100 is itself ~9x
        # oversubscribed — the next capacity-planning datapoint
        (f"fabric{N_SHARDS}-deficit/x100", "x100", N_SHARDS, "deficit"),
    ]
    headline = f"fabric{N_SHARDS}-deficit/x10"
    for label, tkey, n_shards, router in plan:
        trace = traces[tkey]
        summary, target = _run_one(
            trace, shares, n_shards=n_shards, router=router,
            # telemetry rides the headline configuration only; the in-run
            # reconcile raise gates exec attribution == per-shard
            # RoundClock totals == FleetLedger totals, to the integer
            record_spans=label == headline,
        )
        _check_completion(summary, trace, label)
        extra = dict(label=label, trace=tkey, n_shards=n_shards,
                     router=router)
        if n_shards > 1:
            add = _check_additivity(target, label)
            extra.update(
                additivity_holds=add["holds"],
                stolen=target.stolen,
                dispatched=list(target.dispatched),
            )
        summaries[label] = summary
        per_c = ";".join(
            f"{q}_p99={pc['p99_ms']:.2f}"
            for q, pc in summary["per_class"].items()
            if pc["completed"]
        )
        rows.append(
            (
                f"fabric/{label}",
                summary["clock_cycles"] / 100e6 * 1e6,  # modeled us
                f"rounds={summary['rounds']};"
                f"gops_w={summary['gops_w']:.3f};{per_c}",
            )
        )
        payload_rows.append(
            dict(
                **extra,
                rounds=summary["rounds"],
                clock_cycles=summary["clock_cycles"],
                time_ms=summary["time_ms"],
                total_ops=summary["total_ops"],
                gops=summary["gops"],
                gops_w=summary["gops_w"],
                forced=summary["forced"],
                per_class=summary["per_class"],
                # wall_us deliberately not persisted (machine noise)
            )
        )

    def seg_p99(label):
        return summaries[label]["per_class"][MINORITY]["p99_ms"]

    # Gate 1: the single gateway saturates — superlinear p99 growth
    single_ratio = seg_p99("single/x10") / seg_p99("single/x1")
    if not single_ratio > 10.0:
        raise RuntimeError(
            f"single gateway did not saturate on the x10 trace: "
            f"{MINORITY} p99 grew only {single_ratio:.1f}x (expected "
            f"superlinear, > 10x) — the fabric bench's premise is gone"
        )

    # Gate 2: the fabric absorbs the same load sub-linearly
    fab1 = f"fabric{N_SHARDS}-deficit/x1"
    fab10 = f"fabric{N_SHARDS}-deficit/x10"
    fabric_ratio = seg_p99(fab10) / seg_p99(fab1)
    if not fabric_ratio < 10.0:
        raise RuntimeError(
            f"{N_SHARDS}-shard fabric scaled superlinearly on the x10 "
            f"trace: {MINORITY} p99 grew {fabric_ratio:.1f}x (gate: "
            f"< 10x, sub-linear in the load factor)"
        )

    if json_path:
        payload = dict(
            bench="fabric",
            traces={
                k: dict(name=t.name, version=t.version, seed=t.seed,
                        n_requests=len(t), span_cycles=t.span_cycles)
                for k, t in traces.items()
            },
            round_budget=ROUND_BUDGET,
            n_shards=N_SHARDS,
            shares=shares,
            spans=summaries[fab10]["spans"],
            rows=payload_rows,
            gate=dict(
                holds=True,  # every sub-gate raised above otherwise
                saturation=dict(
                    minority=MINORITY,
                    single_x1_p99_ms=seg_p99("single/x1"),
                    single_x10_p99_ms=seg_p99("single/x10"),
                    ratio=single_ratio,
                    holds=bool(single_ratio > 10.0),
                ),
                sublinear=dict(
                    minority=MINORITY,
                    fabric_x1_p99_ms=seg_p99(fab1),
                    fabric_x10_p99_ms=seg_p99(fab10),
                    ratio=fabric_ratio,
                    holds=bool(fabric_ratio < 10.0),
                ),
                additivity=dict(
                    holds=True,  # raised above otherwise, every fabric run
                    checked_runs=[
                        r["label"] for r in payload_rows
                        if r.get("additivity_holds")
                    ],
                ),
            ),
        )
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_fabric.json")
    args = ap.parse_args()
    for name, us, derived in run(json_path=args.json):
        print(f"{name},{us:.1f},{derived}")
