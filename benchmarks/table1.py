"""Paper Table 1 reproduction.

Rows:
  * proposed(model)      — relations (2)+(3) on the calibrated U-Net,
                           pipelined steady-state (matches time AND GOPS)
  * proposed(as-printed) — relation (2) verbatim (matches time only)
  * cascaded-msdf(model) — same datapath, un-merged delays (Sec. 3.2)
  * cpu(measured)        — our own quantized U-Net inference on this host
  * paper rows           — printed values, with derived-column consistency

Output CSV: name,us_per_call,derived  (us_per_call = inference time in us).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import cycle_model as cm


def paper_rows():
    out = []
    for name, r in cm.PAPER_TABLE1.items():
        power = r["gops"] / r["gops_w"]
        out.append((f"table1/{name}(paper)", r["time_ms"] * 1e3,
                    f"gops={r['gops']};gops_w={r['gops_w']};e_mj={r['e_mj']};power_w={power:.2f}"))
    return out


def model_rows():
    layers = cm.unet_conv_layers(**cm.CALIBRATED_UNET)
    rows = []
    # pipelined steady state (calibration target: time + GOPS jointly)
    tile = cm.pipelined_tile_cycles()
    cyc = cm.model_cycles(layers, tile_cycles=tile)
    t_ms = cyc / cm.FREQ_HZ * 1e3
    gops = cm.model_ops(layers) / (t_ms * 1e-3) / 1e9
    power = cm.PAPER_TABLE1["proposed"]["gops"] / cm.PAPER_TABLE1["proposed"]["gops_w"]
    rows.append(("table1/proposed(model-pipelined)", t_ms * 1e3,
                 f"gops={gops:.2f};gops_w={gops/power:.2f};e_mj={power*t_ms:.1f};"
                 f"err_t={abs(t_ms-53.25)/53.25*100:.1f}%;err_gops={abs(gops-52.95)/52.95*100:.1f}%"))
    # relation (2) exactly as printed
    row = cm.proposed_row(layers)
    rows.append(("table1/proposed(rel2-as-printed)", row.time_ms * 1e3,
                 f"gops={row.gops:.2f};gops_w={row.gops_per_w:.2f};e_mj={row.energy_mj:.1f}"))
    # cascaded baseline (the paper's own analytical comparison)
    c = cm.cascaded_row(layers)
    rows.append(("table1/cascaded-msdf(model)", c.time_ms * 1e3,
                 f"gops={c.gops:.2f};merged_speedup={c.time_ms/row.time_ms:.3f}x"))
    return rows


def measured_cpu_row(repeats: int = 3):
    """Quantized U-Net inference on this host CPU (per-image)."""
    from repro.configs.unet import config as unet_cfg
    from repro.models import unet as unet_mod
    import dataclasses

    cfg = dataclasses.replace(unet_cfg(), quant_mode="none")
    params = unet_mod.init_params(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((1, cfg.hw, cfg.hw, cfg.in_ch), jnp.float32)
    fwd = jax.jit(lambda p, a: unet_mod.forward(p, a, cfg))
    fwd(params, x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(repeats):
        fwd(params, x).block_until_ready()
    dt = (time.perf_counter() - t0) / repeats
    layers = cm.unet_conv_layers(cfg.hw, cfg.in_ch, cfg.base, cfg.depth,
                                 cfg.convs_per_stage)
    gops = cm.model_ops(layers) / dt / 1e9
    return [("table1/cpu(measured-here)", dt * 1e6, f"gops={gops:.2f}")]


def run() -> list[tuple[str, float, str]]:
    return model_rows() + measured_cpu_row() + paper_rows()
