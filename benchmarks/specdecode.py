"""Precision-speculative decoding benchmark — ``BENCH_specdecode.json``.

One model, two precisions: MSDF truncation makes a low-plane "draft"
forward a cheap prefix of the full-digit compute (same weights, same KV
cache, same kernels — ``repro.serve.specdecode``).  This bench measures
the modeled decode-throughput win of speculating under a truncated-plane
schedule and verifying with the certified full-digit schedule, and gates
the property that makes the mode safe to ship:

1. **Token identity** — for every prompt, the speculative engine's
   emitted stream must be *bit-identical* to a plain greedy engine's on
   the same weights and schedule.  Both run the digit-serial int8
   datapath (integer accumulation is associative, per-row activation
   scales keep slots isolated), so this is an exact equality gate, not a
   tolerance.
2. **Throughput** — modeled decode cycles per emitted token, with every
   draft and verify cycle charged (wasted speculation included), must
   beat the non-speculative baseline by ``MIN_SPEEDUP``x.  The baseline
   is priced exactly: one full-digit step per token.
3. **Cycle accounting** — per round, ``useful + wasted`` cycles from
   :func:`repro.core.cycle_model.lm_spec_step_cycles` must sum
   *integer-exactly* to the round's total.
4. **Serving integration** — the headline operating point is served
   through :class:`repro.serve.Gateway` behind
   :class:`~repro.serve.specdecode.SpecLMAdapter` with a
   :mod:`repro.obs` ``RecordingSink``: the run raises unless exec
   attribution reconciles integer-exactly with
   ``RoundClock.worked_total`` and the draft / verify / accept lifecycle
   events are present (rollback events are counted; they may be zero at
   full acceptance).

The operating point comes from :func:`repro.autotune.api.tune_spec`
extending a pinned full-digit LM plan (schema v3 ``spec_planes`` /
``spec_k``) — the bench exercises the real tuning path, trimmed to a
small grid for runtime.

The model is the smoke transformer deepened to ``N_LAYERS`` with tied
embeddings sharpened into a token attractor (greedy decode repeats its
input with a wide logit margin), so draft acceptance is high and
platform-stable — the throughput gate measures the *pricing*, not a
coin-flip acceptance rate.  ``scripts/bench_diff.py`` diffs the headline
speedup against the committed baseline at the merge-base.

    PYTHONPATH=src python -m benchmarks.run --section specdecode
"""
from __future__ import annotations

import json

N_LAYERS = 8  # deep enough that one pipeline interval << one full step
VOCAB = 128  # == d_model, so the tied identity table reads channels out
EMBED_SHARPEN = 64.0  # token-attractor gain on the tied embedding table
BATCH = 4
MAX_SEQ = 48
MAX_NEW = 24
N_PROMPTS = 6
PROMPT_LEN = 4
MIN_SPEEDUP = 1.5
ROUND_BUDGET = 100_000_000
# trimmed tune_spec grid: 2 draft budgets x 2 depths keeps the bench's
# jit-compile count (one draft executable per distinct budget) small
PLANE_CANDIDATES = (2, 4)
K_CANDIDATES = (2, 4)


def _build_model():
    """The bench transformer: smoke config deepened + tied embeddings
    replaced by a scaled identity — a structural repeat-the-token
    attractor."""
    import jax
    import jax.numpy as jnp

    from repro import models
    from repro.configs import get_smoke_config

    cfg = get_smoke_config("minitron_4b").replace(
        n_layers=N_LAYERS, tie_embeddings=True, vocab=VOCAB
    )
    params = models.build(cfg).init_params(jax.random.PRNGKey(0), cfg)
    # With ``vocab == d_model`` and the tied table a scaled identity,
    # embedding token X injects ``EMBED_SHARPEN`` into residual channel X
    # and the unembed reads the residual stream back out verbatim —
    # ``logits = EMBED_SHARPEN * residual``.  The injected channel
    # dominates every block's bounded (RMS-normed) output, so greedy
    # repeats its input token with a *relative* top-1 margin far wider
    # than any draft schedule's truncation error (which is a fixed
    # fraction of the per-row amax — scale-invariant, so the margin has
    # to be structural, not just large).
    params = dict(params)
    params["embed"] = {
        "table": (jnp.eye(VOCAB, cfg.d_model, dtype=jnp.float32)
                  * EMBED_SHARPEN).astype(jnp.bfloat16)
    }
    return cfg, params


def _pinned_plan(cfg, params):
    """A pinned full-digit LM plan (certified by construction — zero
    truncation error at 8 planes) for ``tune_spec`` to extend.  The
    params fingerprint binds it to the served weights so the gateway's
    admission check passes honestly."""
    from repro.autotune.calibrate import params_fingerprint
    from repro.autotune.plan import TunedPlan

    return TunedPlan(
        workload="lm",
        geometry=dict(family=cfg.family, n_layers=cfg.n_layers,
                      d_model=cfg.d_model),
        planes=(8,) * cfg.n_layers,
        target_rel_err=0.05,
        certificate=dict(
            cert=0.0, note="pinned full-digit bench plan (exact by "
            "construction: no planes truncated)",
        ),
        fingerprint="bench-pinned-" + "0" * 51,
        params_fingerprint=params_fingerprint(params),
    )


def _prompts(vocab):
    import numpy as np

    rng = np.random.default_rng(7)
    return [
        rng.integers(0, vocab, size=PROMPT_LEN).astype(np.int32)
        for _ in range(N_PROMPTS)
    ]


def _run_greedy(qcfg, params, prompts):
    """Non-speculative reference: token streams + exact modeled cycles
    (one full-digit step per emitted token)."""
    from repro.serve.engine import Engine, Request

    eng = Engine(qcfg, params, batch=BATCH, max_seq=MAX_SEQ)
    pending = [
        Request(rid=i, prompt=p, max_new=MAX_NEW)
        for i, p in enumerate(prompts)
    ]
    reqs = list(pending)
    while pending or eng.ready_slots():
        while pending and eng.admit(pending[0]):
            pending.pop(0)
        if not eng.ready_slots():
            break
        eng.step()
    return [list(r.out) for r in reqs]


def _run_spec(qcfg, params, prompts, *, draft_schedule, k, full_step,
              spec_price):
    """Speculative run: token streams + the full cycle ledger (draft,
    verify, useful, wasted — every round priced before acceptance is
    known, exactly as the serving adapter charges it)."""
    from repro.serve.engine import Request
    from repro.serve.specdecode import SpecEngine

    eng = SpecEngine(qcfg, params, batch=BATCH, max_seq=MAX_SEQ,
                     draft_schedule=draft_schedule, k=k)
    pending = [
        Request(rid=i, prompt=p, max_new=MAX_NEW)
        for i, p in enumerate(prompts)
    ]
    reqs = list(pending)
    ledger = dict(cycles=0, useful=0, wasted=0, emitted=0, accepted=0,
                  drafted=0, rounds=0, greedy_rounds=0)
    while pending or eng.ready_slots():
        while pending and eng.admit(pending[0]):
            pending.pop(0)
        slots = eng.ready_slots()
        if not slots:
            break
        _, rec = eng.spec_step()
        if rec is None:  # no speculation headroom: plain greedy round
            ledger["cycles"] += full_step * len(slots)
            ledger["useful"] += full_step * len(slots)
            ledger["emitted"] += len(slots)
            ledger["greedy_rounds"] += 1
            continue
        ledger["rounds"] += 1
        for s in rec["slots"]:
            acct = spec_price(k=rec["k"], accepted=s["accepted"])
            if acct["useful_cycles"] + acct["wasted_cycles"] \
                    != acct["total_cycles"]:
                raise RuntimeError(
                    f"spec cycle account does not close: useful "
                    f"{acct['useful_cycles']} + wasted "
                    f"{acct['wasted_cycles']} != total "
                    f"{acct['total_cycles']}"
                )
            ledger["cycles"] += acct["total_cycles"]
            ledger["useful"] += acct["useful_cycles"]
            ledger["wasted"] += acct["wasted_cycles"]
        ledger["emitted"] += rec["emitted"]
        ledger["accepted"] += rec["accepted"]
        ledger["drafted"] += rec["drafted"]
    return [list(r.out) for r in reqs], ledger


def _serve_through_gateway(qcfg, params, plan, prompts):
    """The serving-integration leg: the tuned operating point behind the
    gateway, with the telemetry reconcile gate live."""
    from repro.obs import RecordingSink, assemble, breakdown, reconcile
    from repro.serve import Gateway, SpecLMAdapter

    sink = RecordingSink()
    gw = Gateway(
        [SpecLMAdapter(qcfg, params, batch=BATCH, max_seq=MAX_SEQ,
                       plan=plan)],
        policy="fair",
        round_budget=ROUND_BUDGET,
        sink=sink,
    )
    for p in prompts:
        gw.submit("lm", p, max_new=MAX_NEW)
    gw.drain()
    rec = reconcile(sink.events, [gw.round_clock])
    if not rec["holds"]:
        raise RuntimeError(
            f"span execution attribution does not reconcile with the "
            f"round clock: {rec['total_exec']} exec-event cycles vs "
            f"{rec['total_worked']} worked cycles"
        )
    etypes: dict[str, int] = {}
    for ev in sink.events:
        etypes[ev.etype] = etypes.get(ev.etype, 0) + 1
    for required in ("draft", "verify", "accept"):
        if not etypes.get(required):
            raise RuntimeError(
                f"speculative lifecycle event {required!r} missing from "
                f"the gateway telemetry stream (saw {sorted(etypes)})"
            )
    streams = [list(g.handle.out) for g in gw.requests]
    return dict(
        rounds=gw.rounds,
        clock_cycles=gw.clock,
        total_ops=sum(a.total_ops for a in gw.adapters.values()),
        events=len(sink.events),
        spec_events={e: etypes.get(e, 0)
                     for e in ("draft", "verify", "accept", "rollback")},
        spans=breakdown(assemble(sink.events)),
        reconcile=rec,
    ), streams


def run(*, json_path: str | None = "BENCH_specdecode.json"
        ) -> list[tuple[str, float, str]]:
    import functools

    from repro.autotune.api import apply_plan_lm, tune_spec
    from repro.core import cycle_model as cm

    cfg, params = _build_model()
    base_plan = _pinned_plan(cfg, params)
    prompts = _prompts(cfg.vocab)

    # --- tune: the real search, on a trimmed grid ------------------------
    plan = tune_spec(
        params, cfg, prompts[:2], plan=base_plan,
        batch=BATCH, max_seq=MAX_SEQ, max_new=8,
        k_candidates=K_CANDIDATES, plane_candidates=PLANE_CANDIDATES,
    )
    draft_schedule = plan.spec_planes
    k = plan.spec_k

    qcfg = apply_plan_lm(cfg, plan)
    kw = dict(
        n_heads=cfg.n_heads, head_dim=cfg.hd, n_kv_heads=cfg.n_kv_heads,
        context=MAX_SEQ, n_experts=cfg.moe.n_experts, top_k=cfg.moe.top_k,
    )
    full_step = cm.lm_step_cycles(
        cfg.d_model, cfg.d_ff, cfg.n_layers, tuple(plan.planes), **kw
    )
    spec_price = functools.partial(
        cm.lm_spec_step_cycles, cfg.d_model, cfg.d_ff, cfg.n_layers,
        draft_schedule=draft_schedule, schedule=tuple(plan.planes), **kw
    )

    # --- headline: speculative vs greedy, engine level -------------------
    greedy_streams = _run_greedy(qcfg, params, prompts)
    spec_streams, ledger = _run_spec(
        qcfg, params, prompts, draft_schedule=draft_schedule, k=k,
        full_step=full_step, spec_price=spec_price,
    )

    # Gate 1: bit-identical emitted streams.
    if spec_streams != greedy_streams:
        bad = [i for i, (a, b) in
               enumerate(zip(spec_streams, greedy_streams)) if a != b]
        raise RuntimeError(
            f"speculative decode diverged from greedy on prompt(s) {bad}: "
            f"acceptance must be an exact-prefix property, never a "
            f"numerics coin flip"
        )

    # Gate 2: modeled decode throughput.
    baseline_cycles = ledger["emitted"] * full_step
    speedup = baseline_cycles / ledger["cycles"]
    if speedup < MIN_SPEEDUP:
        raise RuntimeError(
            f"speculative decode speedup {speedup:.3f}x under the "
            f"{MIN_SPEEDUP}x gate (draft@{list(draft_schedule)} k={k}, "
            f"acceptance {ledger['accepted']}/{ledger['drafted']})"
        )

    accept_rate = (ledger["accepted"] / ledger["drafted"]
                   if ledger["drafted"] else 0.0)

    # --- serving integration: gateway + telemetry gates ------------------
    served, served_streams = _serve_through_gateway(
        qcfg, params, plan, prompts
    )
    if served_streams != greedy_streams:
        raise RuntimeError(
            "gateway-served speculative streams diverged from greedy — "
            "adapter chunking must not change what is computed"
        )

    rows = [
        (
            "specdecode/greedy",
            ledger["emitted"] * full_step / 100.0,  # modeled us @ 100 MHz
            f"tokens={ledger['emitted']};cycles_per_tok={full_step}",
        ),
        (
            "specdecode/spec",
            ledger["cycles"] / 100.0,
            f"tokens={ledger['emitted']};speedup={speedup:.3f};"
            f"accept={accept_rate:.3f};k={k};"
            f"planes={draft_schedule[0]};wasted={ledger['wasted']}",
        ),
        (
            "specdecode/gateway",
            served["clock_cycles"] / 100.0,
            f"rounds={served['rounds']};events={served['events']};"
            f"accepts={served['spec_events']['accept']};"
            f"rollbacks={served['spec_events']['rollback']}",
        ),
    ]

    if json_path:
        payload = dict(
            bench="specdecode",
            model=dict(
                name=cfg.name, n_layers=cfg.n_layers, d_model=cfg.d_model,
                vocab=cfg.vocab, tie_embeddings=cfg.tie_embeddings,
                embed_sharpen=EMBED_SHARPEN,
            ),
            geometry=dict(batch=BATCH, max_seq=MAX_SEQ, max_new=MAX_NEW,
                          n_prompts=N_PROMPTS, prompt_len=PROMPT_LEN),
            plan=dict(
                planes=list(plan.planes),
                spec_planes=list(plan.spec_planes),
                spec_k=plan.spec_k,
                version=plan.version,
                tune_grid=plan.modeled["spec"]["grid"],
            ),
            ledger=ledger,
            gateway=dict(
                rounds=served["rounds"],
                clock_cycles=served["clock_cycles"],
                total_ops=served["total_ops"],
                events=served["events"],
                spec_events=served["spec_events"],
            ),
            # top-level spans block in the gateway-bench shape, so the
            # ledger report renders the breakdown + reconcile verdict
            spans=dict(
                per_class=served["spans"],
                reconcile=served["reconcile"],
                events=served["events"],
            ),
            gate=dict(
                min_speedup=MIN_SPEEDUP,
                speedup=speedup,
                accept_rate=accept_rate,
                baseline_cycles=int(baseline_cycles),
                spec_cycles=int(ledger["cycles"]),
                wasted_cycles=int(ledger["wasted"]),
                token_identical=True,  # gated above (raise on mismatch)
                gateway_token_identical=True,
                cycle_account_closes=True,
                holds=bool(speedup >= MIN_SPEEDUP),
            ),
        )
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_specdecode.json")
    args = ap.parse_args()
    for name, us, derived in run(json_path=args.json):
        print(f"{name},{us:.1f},{derived}")
