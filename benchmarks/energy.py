"""Metered fleet energy frontier — ``BENCH_energy.json``.

The capacity bench answers *how many chips* an SLO costs; this bench
answers *how many joules*.  It replays the same day-shaped streaming
workload (:mod:`benchmarks.capacity`'s generators, halved span) over a
plan x policy x shard grid of modeled fabrics with an armed
:class:`~repro.obs.energy.EnergyMeter`, and reports **metered** GOPS/W
and energy-per-request next to the analytic ``stats()`` figure:

* **uniform8** — full 8-plane schedules, the paper's headline datapath;
* **tuned4** — the autotune bench's certified 4-plane operating point
  (fewer cycles *and* a lower pJ/cycle switching rate);
* **spec2** — precision-speculative decode
  (:class:`~repro.serve.modeled.ModeledSpecLMAdapter`): 2-plane drafts
  verified by the full-digit datapath, drafts metered at the truncated
  draft-plane rate via the meter's accept-time rebate.

Metered vs analytic: ``stats()``'s analytic GOPS/W prices every elapsed
cycle at full chip power; the meter prices worked cycles at the plan's
plane rate and idle cycles at static power only, so metered GOPS/W is
an upper... strictly *higher* figure whenever the fleet idles — gated.

Gates (each raises, so CI fails loudly):

1. **Ledger reconciliation** — on the instrumented point the meter's
   integer-pJ invariants hold (additivity, per-request, per-class, spec
   closure) *and* the offline span-derived per-request joules equal the
   online attribution to the picojoule.
2. **Equal-error energy wins** — at every (policy, shards) point,
   ``tuned4`` and ``spec2`` strictly reduce metered energy-per-request
   vs ``uniform8``, overall and for the decode-heavy ``interactive``
   class; outputs are equal-error by construction (the tuned point is
   the certified autotune schedule; speculative drafts are verified by
   the full-digit datapath before emission).
3. **Metered >= analytic on uniform8** — idle cycles cost static power,
   not full chip power, so the metered figure can only improve on the
   analytic one.
4. **Feed purity** — every grid point replays the identical arrival
   stream (offered counts equal).

``scripts/bench_diff.py`` keys energy rows by the sweep-grid +
workload comparability key, so a grid change skips (never hard-fails)
the cross-revision diff, and fails on metered-GOPS/W regressions.

    PYTHONPATH=src python -m benchmarks.run --section energy
"""
from __future__ import annotations

import dataclasses
import json

from benchmarks import capacity as cap

ROUND_BUDGET = cap.ROUND_BUDGET
SPAN = cap.PERIOD // 2  # half a modeled day: energy trends saturate fast
SHARD_COUNTS = (2, 4)
ROUTER = "p2c"
POLICIES = ("fair", "edf")
PLANS = ("uniform8", "tuned4", "spec2")
DRAFT_PLANES = 2
SPEC_K = 4
# per-shard rolling power cap: just under the modeled full-width chip
# power (~3.50 W), so saturated uniform8 shards graze it — the cap
# telemetry has something to show without drowning the run in events
POWER_WATTS = 3.2
# the instrumented point the reconciliation gate rides (plan, policy, n)
RECONCILE_POINT = ("spec2", "fair", 4)

WORKLOAD = dict(cap.WORKLOAD, span=SPAN)

# QoS classes gate 2 additionally holds *strictly* per class.  The
# batch class is deliberately absent: its short decodes (max_new=4 vs
# k=4 drafts) make speculation roughly break-even there — over-drafted
# tokens past the request's end are wasted draft work — which the per-
# class rows report rather than gate.
GATE_CLASSES = ("interactive",)


def _power_spec():
    from repro.obs.energy import PowerSpec

    return PowerSpec(watts=POWER_WATTS)


def _plan_setup(plan: str):
    """(gateway factory kwargs, meter rates, meter draft rates)."""
    from repro.core import energy_model as em

    if plan == "uniform8":
        return dict(lm_planes=8, seg_planes=8, spec=False), {
            "lm": em.active_rate_pj(8), "seg": em.active_rate_pj(8),
        }, None
    if plan == "tuned4":
        return dict(lm_planes=4, seg_planes=4, spec=False), {
            "lm": em.active_rate_pj(4), "seg": em.active_rate_pj(4),
        }, None
    if plan == "spec2":
        return dict(lm_planes=8, seg_planes=8, spec=True), {
            "lm": em.active_rate_pj(8), "seg": em.active_rate_pj(8),
        }, {"lm": em.active_rate_pj(DRAFT_PLANES)}
    raise ValueError(f"unknown plan {plan!r}; one of {PLANS}")


def _mk_gateway(plan: str, policy: str):
    from repro.configs import get_smoke_config
    from repro.serve.gateway import Gateway
    from repro.serve.modeled import (
        ModeledLMAdapter,
        ModeledSegAdapter,
        ModeledSpecLMAdapter,
    )

    setup, _, _ = _plan_setup(plan)
    cfg = get_smoke_config("minitron_4b")
    if setup["lm_planes"] != 8:
        cfg = dataclasses.replace(
            cfg, quant=dataclasses.replace(
                cfg.quant, plane_schedule=(setup["lm_planes"],)
            )
        )
    if setup["spec"]:
        lm = ModeledSpecLMAdapter.from_config(
            cfg, batch=cap.LM_BATCH, max_seq=cap.LM_MAX_SEQ,
            draft_schedule=(DRAFT_PLANES,), k=SPEC_K,
        )
    else:
        lm = ModeledLMAdapter.from_config(
            cfg, batch=cap.LM_BATCH, max_seq=cap.LM_MAX_SEQ
        )
    return Gateway(
        [lm, ModeledSegAdapter.from_geometry(planes=setup["seg_planes"])],
        policy=policy,
        round_budget=ROUND_BUDGET,
        shares=dict(cap.SHARES),
    )


def _run_point(plan, policy, n_shards, *, workload=WORKLOAD,
               record=False, max_rounds=400_000):
    """One grid point: fabric + armed EnergyMeter, streamed feed.
    Returns (summary, fabric, meter, recording-sink-or-None)."""
    from repro.obs import RecordingSink, TeeSink
    from repro.obs.energy import EnergyMeter
    from repro.serve.fabric import Fabric
    from repro.workload.replay import replay_stream

    _, rates, draft_rates = _plan_setup(plan)
    meter = EnergyMeter(rates, draft_rates=draft_rates,
                        power=_power_spec())
    rec = RecordingSink() if record else None
    sink = TeeSink([rec, meter]) if record else meter
    fab = Fabric(
        [_mk_gateway(plan, policy) for _ in range(n_shards)],
        router=ROUTER, seed=7, sink=sink,
    )
    label = f"{plan}/{policy}/s{n_shards}"
    summary = replay_stream(fab, cap.mk_feed(workload), label=label,
                            max_rounds=max_rounds)
    return summary, fab, meter, rec


def _check_reconcile(meter, rec, label):
    """Gate 1: the integer-pJ ledger closes, online == offline."""
    from repro.obs import assemble
    from repro.obs.energy import attach_joules

    spans = attach_joules(assemble(rec.events), meter)
    r = meter.reconcile(spans)
    if not r["holds"]:
        raise RuntimeError(
            f"energy ledger reconciliation failed on {label}: "
            f"{r['checks']} (additivity {r['additivity']}, "
            f"spans {r.get('spans')})"
        )
    # the span-attached joules are the same attribution, re-keyed
    span_pj = sum(sp.pj for sp in spans if sp.done)
    if span_pj != r["spans"]["online_pj"]:
        raise RuntimeError(
            f"attach_joules diverges from the online attribution on "
            f"{label}: {span_pj} vs {r['spans']['online_pj']}"
        )
    return r


def run(*, json_path: str | None = "BENCH_energy.json",
        shard_counts=SHARD_COUNTS, policies=POLICIES, plans=PLANS,
        workload=WORKLOAD):
    from repro.workload.trace import TRACE_VERSION

    key = (
        f"{workload['generator']}:{workload['seed']}"
        f":p{workload['period']}:u{workload['span']}@v{TRACE_VERSION}"
        f";grid=s{list(shard_counts)}xp{list(policies)}"
        f"xpl{list(plans)};r={ROUTER}"
        f";dp{DRAFT_PLANES}k{SPEC_K};w{POWER_WATTS}"
    )

    # the instrumented point: the default when the grid covers it, else
    # the last-shard point of the first plan so reduced grids (tests,
    # ad-hoc sweeps) still exercise the reconciliation gate
    rpoint = RECONCILE_POINT
    if not (rpoint[0] in plans and rpoint[1] in policies
            and rpoint[2] in shard_counts):
        rpoint = (
            ("spec2" if "spec2" in plans else list(plans)[0]),
            list(policies)[0], list(shard_counts)[-1],
        )

    rows = []
    payload_rows = []
    n_offered = None
    reconcile_out = None
    for plan in plans:
        for policy in policies:
            for n in shard_counts:
                record = (plan, policy, n) == rpoint
                summary, fab, meter, rec = _run_point(
                    plan, policy, n, workload=workload, record=record,
                )
                label = f"{plan}/{policy}/s{n}"
                fed = summary["stream"]["n_requests"]
                if n_offered is None:
                    n_offered = fed
                elif fed != n_offered:
                    raise RuntimeError(
                        f"feed diverged across grid points: {label} fed "
                        f"{fed} vs {n_offered} — the generators are not "
                        f"pure"
                    )
                if record:
                    reconcile_out = _check_reconcile(meter, rec, label)
                e = summary["energy"]
                if e["completions"] == 0:
                    raise RuntimeError(f"no completions on {label}")
                epr = e["total_pj"] / e["completions"]
                payload_rows.append(dict(
                    label=label, plan=plan, policy=policy, shards=n,
                    rounds=summary["rounds"],
                    clock_cycles=summary["clock_cycles"],
                    gops=summary["gops"],
                    analytic_gops_w=e["analytic_gops_w"],
                    metered_gops_w=e["metered_gops_w"],
                    total_mj=e["total_mj"],
                    active_mj=e["active_mj"],
                    idle_mj=e["idle_mj"],
                    completions=e["completions"],
                    energy_per_request_pj=epr,
                    per_class={
                        q: dict(
                            mj=c["mj"],
                            requests=c["requests"],
                            mean_request_pj=c["mean_request_pj"],
                            p50_request_pj=c["p50_request_pj"],
                            p99_request_pj=c["p99_request_pj"],
                        )
                        for q, c in e["per_class"].items()
                    },
                    spec=e["spec"],
                    power=e["power"],
                ))
                rows.append((
                    f"energy/{label}",
                    e["total_mj"] * 1e3,  # derived-metric column: uJ
                    f"metered_gops_w={e['metered_gops_w']:.3f};"
                    f"analytic={e['analytic_gops_w']:.3f};"
                    f"mj={e['total_mj']:.1f};"
                    f"epr_uj={epr * 1e-6:.1f};"
                    f"cap_violations={e['power']['violations']}",
                ))

    by_point = {
        (r["plan"], r["policy"], r["shards"]): r for r in payload_rows
    }

    # Gate 2: tuned/spec strictly reduce metered energy per request vs
    # uniform8, per LM class and overall, at every (policy, shards)
    wins = []
    for policy in policies:
        for n in shard_counts:
            base = by_point.get(("uniform8", policy, n))
            if base is None:
                continue
            for plan in plans:
                if plan == "uniform8":
                    continue
                r = by_point[(plan, policy, n)]
                if r["energy_per_request_pj"] >= \
                        base["energy_per_request_pj"]:
                    raise RuntimeError(
                        f"{plan} does not reduce metered energy per "
                        f"request vs uniform8 at ({policy}, s{n}): "
                        f"{r['energy_per_request_pj']:.0f} vs "
                        f"{base['energy_per_request_pj']:.0f} pJ"
                    )
                for q in GATE_CLASSES:
                    a = r["per_class"][q]["mean_request_pj"]
                    b = base["per_class"][q]["mean_request_pj"]
                    if a is None or b is None or a >= b:
                        raise RuntimeError(
                            f"{plan} does not reduce {q} mean request "
                            f"energy vs uniform8 at ({policy}, s{n}): "
                            f"{a} vs {b} pJ"
                        )
                wins.append(dict(
                    plan=plan, policy=policy, shards=n,
                    epr_pj=r["energy_per_request_pj"],
                    uniform_epr_pj=base["energy_per_request_pj"],
                ))

    # Gate 3: metered >= analytic on uniform8 (idle is static-only)
    for r in payload_rows:
        if r["plan"] == "uniform8" and \
                r["metered_gops_w"] < r["analytic_gops_w"]:
            raise RuntimeError(
                f"metered GOPS/W below analytic on {r['label']}: "
                f"{r['metered_gops_w']:.3f} < "
                f"{r['analytic_gops_w']:.3f} — idle pricing is broken"
            )

    if reconcile_out is None:
        raise RuntimeError(
            f"instrumented point {rpoint} never ran — the "
            f"reconciliation gate did not fire"
        )

    if json_path:
        from repro.core import energy_model as em

        payload = dict(
            bench="energy",
            key=key,
            grid=dict(shards=list(shard_counts), router=ROUTER,
                      policies=list(policies), plans=list(plans)),
            workload=dict(workload, n_offered=n_offered,
                          trace_schema=TRACE_VERSION),
            power=_power_spec().to_dict(),
            rates=dict(
                pj_plane_cycle=em.PJ_PLANE_CYCLE,
                pj_static_cycle=em.PJ_STATIC_CYCLE,
                pj_full_cycle=em.PJ_FULL_CYCLE,
                draft_planes=DRAFT_PLANES,
            ),
            calibration=em.calibration(),
            rows=payload_rows,
            gate=dict(
                holds=True,  # every sub-gate raised above otherwise
                reconcile=reconcile_out,
                equal_error_energy_wins=wins,
                metered_ge_analytic=True,
            ),
        )
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_energy.json")
    args = ap.parse_args()
    for name, us, derived in run(json_path=args.json):
        print(f"{name},{us:.1f},{derived}")
