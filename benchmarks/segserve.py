"""Segmentation-serving benchmark: tiled U-Net with content-adaptive tile
precision, the bench the tracker ingests (``BENCH_segserve.json``).

A synthetic medical-style image (quiet background + a bright structure)
is served three ways through :class:`repro.segserve.SegEngine`:

  * ``full-8``   — every tile at full 8-plane precision, at the *tuned*
                   tile geometry with per-tile quantization (the reference
                   the tuned certificate is defined against);
  * ``uniform``  — the analytic ``from_weights`` per-layer schedule at the
                   legacy fixed tile (the PR-2 operating point, kept as the
                   baseline the autotuner must dominate);
  * ``adaptive`` — a certified :class:`repro.autotune.TunedPlan`: measured
                   per-layer budgets, calibrated budget-class thresholds,
                   tile size from the cycle-model search.

Reported per row: relation-(2) cycles, modeled time, GOPS, GOPS/W and
energy at the paper's implied accelerator power, plus the measured max
relative error against the full-8 run.  The tuned row also reports its
certified bound; the bench **fails** (raises, exits non-zero) if the
measured error exceeds the certificate or the certificate exceeds the
target — that is the CI gate on the autotuner's promise, and it replaces
the old silent target miss (0.356 measured against a 0.05 target).

    PYTHONPATH=src python -m benchmarks.run --section segserve
"""
from __future__ import annotations

import dataclasses
import json
import time

# Small-but-real default geometry: calibrated depth, reduced width so the
# CI smoke stays fast.  --full in __main__ runs the calibrated base.  The
# canvas is large relative to the halo (24 px at depth 3) so background
# tiles exist whose *windows* clear the structure — the content-adaptive
# case the bench exists to price.
GEOMETRY = dict(depth=3, base=16, in_ch=4, n_classes=4)
IMAGE_HW = (160, 128)
TILE = 32  # legacy fixed tile of the uniform baseline
TARGET_REL_ERR = 0.05


def run(
    *,
    base: int | None = None,
    image_hw: tuple[int, int] = IMAGE_HW,
    tile: int = TILE,
    target_rel_err: float = TARGET_REL_ERR,
    json_path: str | None = "BENCH_segserve.json",
    n_calib: int = 2,
) -> list[tuple[str, float, str]]:
    import jax

    from repro import autotune
    from repro.models import unet as unet_mod
    from repro.segserve import SegEngine
    from repro.segserve.synth import phantom_image

    geo = dict(GEOMETRY)
    if base is not None:
        geo["base"] = base
    cfg = unet_mod.UNetConfig(
        hw=image_hw[0], in_ch=geo["in_ch"], base=geo["base"],
        depth=geo["depth"], convs_per_stage=1, n_classes=geo["n_classes"],
        quant_mode="mma_int8", impl="xla",
    )
    params = unet_mod.init_params(jax.random.PRNGKey(0), cfg)
    sched = unet_mod.schedule_from_params(params, target_rel_err)
    scfg = dataclasses.replace(cfg, plane_schedule=tuple(sched.planes))
    image = phantom_image(*image_hw, geo["in_ch"])
    # calibration set: the served image's distribution, served image first
    calib_images = [
        phantom_image(*image_hw, geo["in_ch"], seed=s) for s in range(n_calib)
    ]

    t0 = time.perf_counter()
    plan = autotune.tune_unet(
        params, cfg, calib_images, target_rel_err=target_rel_err
    )
    tune_us = (time.perf_counter() - t0) * 1e6

    def timed(make_engine):
        eng = make_engine()
        t0 = time.perf_counter()
        res = eng.run([image])[0]
        return res, (time.perf_counter() - t0) * 1e6

    res_full, wall_full = timed(
        lambda: autotune.engine_from_plan(
            cfg, params, autotune.reference_plan(plan)
        )
    )
    res_uni, wall_uni = timed(
        lambda: SegEngine(scfg, params, tile=tile, batch=4, adaptive=False)
    )
    res_ad, wall_ad = timed(
        lambda: autotune.engine_from_plan(cfg, params, plan)
    )

    variants = [
        ("full-8", res_full, wall_full),
        ("uniform", res_uni, wall_uni),
        ("adaptive", res_ad, wall_ad),
    ]
    ref = res_full.logits
    cert = float(plan.certificate["cert"])

    rows = []
    payload_rows = []
    for name, r, wall_us in variants:
        rel_err = autotune.rel_err(r.logits, ref)
        certified = cert if name == "adaptive" else None
        rows.append(
            (
                f"segserve/{name}",
                r.time_ms * 1e3,  # modeled us, like precision_sweep
                f"cycles={r.cycles};tiles={r.n_tiles};"
                f"classes={'/'.join(f'{k}:{v}' for k, v in r.class_counts.items())};"
                f"gops={r.gops:.2f};gops_w={r.gops_per_w:.2f};"
                f"e_mj={r.energy_mj:.1f};rel_err={rel_err:.4g}"
                + (f";cert={certified:.4g}" if certified is not None else ""),
            )
        )
        payload_rows.append(
            dict(
                name=name,
                cycles=r.cycles,
                ops=r.ops,
                n_tiles=r.n_tiles,
                class_counts={str(k): v for k, v in r.class_counts.items()},
                time_ms=r.time_ms,
                gops=r.gops,
                gops_w=r.gops_per_w,
                energy_mj=r.energy_mj,
                rel_err=rel_err,
                cert=certified,
                wall_us=wall_us,
            )
        )

    by_name = {row["name"]: row for row in payload_rows}
    measured_ad = by_name["adaptive"]["rel_err"]
    # The CI gate (satellite): certified means *checked*.  A silent target
    # miss — the old behavior — must now fail the bench loudly.
    if measured_ad > cert:
        raise RuntimeError(
            f"certificate violated: adaptive measured rel_err {measured_ad:.4g}"
            f" > certified bound {cert:.4g} "
            f"(fingerprint {plan.fingerprint[:12]})"
        )
    if cert > target_rel_err:
        raise RuntimeError(
            f"certified bound {cert:.4g} exceeds target {target_rel_err:g} — "
            f"the tuned plan failed to meet the error budget"
        )

    if json_path:
        payload = dict(
            bench="segserve",
            geometry=dict(geo, image_h=image_hw[0], image_w=image_hw[1],
                          tile=tile, halo=_halo(geo["depth"])),
            target_rel_err=target_rel_err,
            schedule=list(sched.planes),
            plan=plan.to_json(),
            tune_wall_us=tune_us,
            rows=payload_rows,
            adaptive_speedup_vs_uniform=(
                res_uni.cycles / res_ad.cycles
            ),
            gate=dict(
                measured=measured_ad,
                cert=cert,
                target=target_rel_err,
                holds=bool(measured_ad <= cert <= target_rel_err),
            ),
        )
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return rows


def _halo(depth: int) -> int:
    from repro.segserve import halo_for

    return halo_for(depth, 1)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="calibrated base-48 width (slow on CPU)")
    ap.add_argument("--json", default="BENCH_segserve.json")
    args = ap.parse_args()
    for name, us, derived in run(
        base=48 if args.full else None, json_path=args.json
    ):
        print(f"{name},{us:.1f},{derived}")
