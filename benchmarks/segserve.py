"""Segmentation-serving benchmark: tiled U-Net with content-adaptive tile
precision, the bench the tracker ingests (``BENCH_segserve.json``).

A synthetic medical-style image (quiet background + a bright structure)
is served three ways through :class:`repro.segserve.SegEngine`:

  * ``full-8``   — every tile at full 8-plane precision (baseline);
  * ``uniform``  — the certified per-layer schedule, same for every tile;
  * ``adaptive`` — the same layer schedule refined per tile budget class
                   (flat background tiles consume fewer MSB digits).

Reported per row: relation-(2) cycles, modeled time, GOPS, GOPS/W and
energy at the paper's implied accelerator power, plus the measured max
relative error against the full-8 run.  The headline the tracker watches:
``adaptive`` cycles < ``uniform`` cycles at the same certified target.

    PYTHONPATH=src python -m benchmarks.run --section segserve
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

# Small-but-real default geometry: calibrated depth, reduced width so the
# CI smoke stays fast.  --full in __main__ runs the calibrated base.  The
# canvas is large relative to the halo (24 px at depth 3) so background
# tiles exist whose *windows* clear the structure — the content-adaptive
# case the bench exists to price.
GEOMETRY = dict(depth=3, base=16, in_ch=4, n_classes=4)
IMAGE_HW = (160, 128)
TILE = 32
TARGET_REL_ERR = 0.05


def run(
    *,
    base: int | None = None,
    image_hw: tuple[int, int] = IMAGE_HW,
    tile: int = TILE,
    target_rel_err: float = TARGET_REL_ERR,
    json_path: str | None = "BENCH_segserve.json",
) -> list[tuple[str, float, str]]:
    import jax

    from repro.models import unet as unet_mod
    from repro.segserve import SegEngine
    from repro.segserve.synth import phantom_image

    geo = dict(GEOMETRY)
    if base is not None:
        geo["base"] = base
    cfg = unet_mod.UNetConfig(
        hw=image_hw[0], in_ch=geo["in_ch"], base=geo["base"],
        depth=geo["depth"], convs_per_stage=1, n_classes=geo["n_classes"],
        quant_mode="mma_int8", impl="xla",
    )
    params = unet_mod.init_params(jax.random.PRNGKey(0), cfg)
    sched = unet_mod.schedule_from_params(params, target_rel_err)
    scfg = dataclasses.replace(cfg, plane_schedule=tuple(sched.planes))
    image = phantom_image(*image_hw, geo["in_ch"])

    variants = [
        ("full-8", cfg, False),
        ("uniform", scfg, False),
        ("adaptive", scfg, True),
    ]
    results = {}
    wall_us = {}
    for name, vcfg, adapt in variants:
        eng = SegEngine(vcfg, params, tile=tile, batch=4, adaptive=adapt)
        t0 = time.perf_counter()
        results[name] = eng.run([image])[0]
        wall_us[name] = (time.perf_counter() - t0) * 1e6

    ref = results["full-8"].logits
    denom = max(float(np.max(np.abs(ref))), 1e-8)
    rows = []
    payload_rows = []
    for name, _, _ in variants:
        r = results[name]
        rel_err = float(np.max(np.abs(r.logits - ref))) / denom
        rows.append(
            (
                f"segserve/{name}",
                r.time_ms * 1e3,  # modeled us, like precision_sweep
                f"cycles={r.cycles};tiles={r.n_tiles};"
                f"classes={'/'.join(f'{k}:{v}' for k, v in r.class_counts.items())};"
                f"gops={r.gops:.2f};gops_w={r.gops_per_w:.2f};"
                f"e_mj={r.energy_mj:.1f};rel_err={rel_err:.4g}",
            )
        )
        payload_rows.append(
            dict(
                name=name,
                cycles=r.cycles,
                ops=r.ops,
                n_tiles=r.n_tiles,
                class_counts={str(k): v for k, v in r.class_counts.items()},
                time_ms=r.time_ms,
                gops=r.gops,
                gops_w=r.gops_per_w,
                energy_mj=r.energy_mj,
                rel_err=rel_err,
                wall_us=wall_us[name],
            )
        )

    if json_path:
        payload = dict(
            bench="segserve",
            geometry=dict(geo, image_h=image_hw[0], image_w=image_hw[1],
                          tile=tile, halo=_halo(geo["depth"])),
            target_rel_err=target_rel_err,
            schedule=list(sched.planes),
            rows=payload_rows,
            adaptive_speedup_vs_uniform=(
                results["uniform"].cycles / results["adaptive"].cycles
            ),
        )
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return rows


def _halo(depth: int) -> int:
    from repro.segserve import halo_for

    return halo_for(depth, 1)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="calibrated base-48 width (slow on CPU)")
    ap.add_argument("--json", default="BENCH_segserve.json")
    args = ap.parse_args()
    for name, us, derived in run(
        base=48 if args.full else None, json_path=args.json
    ):
        print(f"{name},{us:.1f},{derived}")
