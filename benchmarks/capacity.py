"""SLO-driven fleet capacity planner — ``BENCH_capacity.json``.

The paper's headline is an efficiency *frontier* (GOPS/W); serving turns
that into a **cost-per-SLO** question: how many chips does a given
objective cost, and when an objective burns, *why*?  This bench answers
both by sweeping shard count x router x policy x plan over jax-free
modeled adapters (:mod:`repro.serve.modeled`) under one day-shaped
streaming workload:

* **Workload** — :mod:`repro.workload.diurnal` generators (Poisson at a
  raised-cosine day curve + day-modulated on-off batch bursts + a sparse
  seg minority), streamed through
  :func:`repro.workload.replay.replay_stream` — the feed is lazy, so the
  same harness scales to million-request days without materializing a
  trace.  Every grid point replays the *identical* feed (pure counter-
  PRNG generators, same seed).
* **SLOs** — declarative :class:`~repro.obs.slo.SloSpec` per class; an
  online :class:`~repro.obs.slo.SloMonitor` rides every run and yields
  per-point miss rates, burn rates and the queued / preempted / service
  / overdraft attribution of every miss.
* **Plans** — ``uniform8`` prices the full 8-plane schedule; ``tuned4``
  prices a 4-plane tuned schedule (the autotune bench's certified
  operating point) — the MINT story: precision schedules move the fleet
  bill, not just the per-chip frontier.

Frontier: per (router, policy, plan), the minimum shard count meeting
every SLO — the cost-per-SLO curve the payload leads with.

Gates (each raises, so CI fails loudly):

1. **Online/offline reconciliation** — on the designated instrumented
   point, the SloMonitor's cumulative per-class miss counts *and*
   attribution histograms equal the offline span-derived ones
   (:mod:`repro.obs.attrib` over a ``RecordingSink`` stream) to the
   integer, and both equal ``fabric.stats()``'s ``deadline_misses``.
2. **Queueing-share sanity** — at fixed load, adding shards never
   *increases* the attributed queueing share (queued-dominant misses
   over offered requests; the denominator is fixed by the shared feed,
   so the share is monotone exactly when the counts are).
3. **Frontier exists** — at least one grid point meets every SLO.
4. **Tuned plan is never costlier** — per (router, policy), the tuned
   plan's minimum SLO-meeting shard count is <= the uniform plan's.

``scripts/bench_diff.py`` keys capacity rows by the sweep-grid +
workload comparability key, so a grid change skips (never hard-fails)
the cross-revision diff.

    PYTHONPATH=src python -m benchmarks.run --section capacity
"""
from __future__ import annotations

import dataclasses
import json

ROUND_BUDGET = 800_000
SEED = 20260809
PERIOD = 38_400_000  # one modeled "day": 48 rounds of 800k cycles
SPAN = PERIOD  # simulate one full period
SHARD_COUNTS = (2, 4, 8)
ROUTERS = ("p2c", "deficit")
POLICIES = ("fair", "edf")
PLANS = ("uniform8", "tuned4")
LM_BATCH = 20
LM_MAX_SEQ = 96
SHARES = dict(interactive=0.4, batch=0.3, seg=0.3)
WINDOWS = (3_200_000, 16_000_000)  # 4-round fast / 20-round slow burn
# the instrumented point the reconciliation gate rides
RECONCILE_POINT = ("uniform8", "deficit", "fair", 4)

WORKLOAD = dict(
    generator="diurnal",
    seed=SEED,
    period=PERIOD,
    span=SPAN,
    floor=0.15,
    interactive=dict(peak_interval=55_000, deadline_cycles=400_000,
                     payload=dict(prompt_len=4, max_new=8)),
    batch=dict(burst_interval=200_000, on_mean=2_000_000,
               off_mean=4_000_000, deadline_cycles=8_000_000,
               payload=dict(prompt_len=24, max_new=4)),
    seg=dict(mean_interval=3_000_000, deadline_cycles=4_000_000,
             payload=dict(h=96, w=80)),
)


def slo_specs():
    from repro.obs.slo import SloSpec

    return [
        SloSpec("interactive", pct=99, latency_target_ms=6.0,
                miss_budget=0.05),
        SloSpec("batch", pct=99, miss_budget=0.15),
        SloSpec("seg", pct=99, miss_budget=0.25),
    ]


def mk_feed(workload=WORKLOAD):
    """The day-shaped streaming feed — a fresh lazy generator each call,
    identical arrivals every time (pure counter-PRNG)."""
    from repro.workload import diurnal

    w = workload
    seed, period, floor = w["seed"], w["period"], w["floor"]
    inter, batch, seg = w["interactive"], w["batch"], w["seg"]
    return diurnal.stream_requests(
        [
            dict(kind="lm", qos="interactive",
                 arrivals=diurnal.diurnal(
                     seed=seed, peak_interval=inter["peak_interval"],
                     period=period, floor=floor, start=50_000),
                 payload=dict(inter["payload"]),
                 deadline_cycles=inter["deadline_cycles"]),
            dict(kind="lm", qos="batch",
                 arrivals=diurnal.modulate(
                     diurnal.iter_on_off(
                         seed=seed + 1,
                         burst_interval=batch["burst_interval"],
                         on_mean=batch["on_mean"],
                         off_mean=batch["off_mean"], start=150_000),
                     seed=seed + 1, period=period, floor=floor),
                 payload=dict(batch["payload"]),
                 deadline_cycles=batch["deadline_cycles"]),
            dict(kind="seg", qos="seg",
                 arrivals=diurnal.iter_poisson(
                     seed=seed + 2,
                     mean_interval=seg["mean_interval"], start=600_000),
                 payload=dict(seg["payload"]),
                 deadline_cycles=seg["deadline_cycles"]),
        ],
        until=w["span"],
    )


def _mk_gateway(plan: str, policy: str):
    from repro.configs import get_smoke_config
    from repro.serve.gateway import Gateway
    from repro.serve.modeled import ModeledLMAdapter, ModeledSegAdapter

    cfg = get_smoke_config("minitron_4b")
    if plan == "tuned4":
        # price the tuned operating point: a uniform 4-plane schedule,
        # the shape the autotune bench certifies at the smoke target
        cfg = dataclasses.replace(
            cfg, quant=dataclasses.replace(cfg.quant, plane_schedule=(4,))
        )
        seg_planes = 4
    elif plan == "uniform8":
        seg_planes = 8
    else:
        raise ValueError(f"unknown plan {plan!r}; one of {PLANS}")
    return Gateway(
        [
            ModeledLMAdapter.from_config(cfg, batch=LM_BATCH,
                                         max_seq=LM_MAX_SEQ),
            ModeledSegAdapter.from_geometry(planes=seg_planes),
        ],
        policy=policy,
        round_budget=ROUND_BUDGET,
        shares=dict(SHARES),
    )


def _run_point(plan, router, policy, n_shards, *, workload=WORKLOAD,
               record=False, max_rounds=400_000):
    """One grid point: fabric + armed SloMonitor, streamed feed.
    Returns (summary, fabric, monitor, recording-sink-or-None)."""
    from repro.obs import RecordingSink, TeeSink
    from repro.obs.slo import SloMonitor
    from repro.serve.fabric import Fabric
    from repro.workload.replay import replay_stream

    mon = SloMonitor(slo_specs(), windows=WINDOWS)
    rec = RecordingSink() if record else None
    sink = TeeSink([rec, mon]) if record else mon
    fab = Fabric(
        [_mk_gateway(plan, policy) for _ in range(n_shards)],
        router=router, seed=7, sink=sink,
    )
    label = f"{plan}/{router}-{policy}/s{n_shards}"
    summary = replay_stream(fab, mk_feed(workload), label=label,
                            max_rounds=max_rounds)
    return summary, fab, mon, rec


def _slo_met(summary, specs) -> bool:
    """Every class meets its objective: miss rate within budget, and the
    exact-order-statistic percentile within the latency target."""
    pc = summary["per_class"]
    for spec in specs:
        c = pc.get(spec.qos)
        if c is None or not c["completed"]:
            continue
        if c["deadline_misses"] / c["completed"] > spec.miss_budget:
            return False
        if spec.latency_target_ms is not None:
            p = c.get(f"p{int(spec.pct)}_ms")
            if p is not None and p > spec.latency_target_ms:
                return False
    return True


def _check_reconcile(summary, fab, mon, rec, label):
    """Gate 1: online == offline == stats(), to the integer."""
    from repro.obs import assemble
    from repro.obs.slo import FLEET

    spans = assemble(rec.events)
    r = mon.reconcile(spans)
    if not r["holds"]:
        raise RuntimeError(
            f"online/offline SLO miss reconciliation failed on {label}: "
            f"online {r['online']} vs span-derived {r['offline']} "
            f"(attribution {r['online_attribution']} vs "
            f"{r['offline_attribution']})"
        )
    stats_misses = {
        q: c["deadline_misses"]
        for q, c in summary["per_class"].items() if c["deadline_misses"]
    }
    if stats_misses != mon.miss_counts(FLEET):
        raise RuntimeError(
            f"stats() deadline_misses diverge from the SloMonitor on "
            f"{label}: {stats_misses} vs {mon.miss_counts(FLEET)}"
        )
    return r


def run(*, json_path: str | None = "BENCH_capacity.json",
        shard_counts=SHARD_COUNTS, routers=ROUTERS, policies=POLICIES,
        plans=PLANS, workload=WORKLOAD):
    from repro.obs.attrib import ATTRIB_CLASSES
    from repro.obs.slo import FLEET
    from repro.workload.trace import TRACE_VERSION

    specs = slo_specs()
    key = (
        f"{workload['generator']}:{workload['seed']}"
        f":p{workload['period']}:u{workload['span']}@v{TRACE_VERSION}"
        f";grid=s{list(shard_counts)}xr{list(routers)}"
        f"xp{list(policies)}xpl{list(plans)}"
    )

    rows = []
    payload_rows = []
    n_offered = None
    reconcile_out = None
    for plan in plans:
        for router in routers:
            for policy in policies:
                for n in shard_counts:
                    record = (plan, router, policy, n) == RECONCILE_POINT
                    summary, fab, mon, rec = _run_point(
                        plan, router, policy, n, workload=workload,
                        record=record,
                    )
                    label = f"{plan}/{router}-{policy}/s{n}"
                    fed = summary["stream"]["n_requests"]
                    if n_offered is None:
                        n_offered = fed
                    elif fed != n_offered:
                        raise RuntimeError(
                            f"feed diverged across grid points: {label} "
                            f"fed {fed} vs {n_offered} — the generators "
                            f"are not pure"
                        )
                    if record:
                        reconcile_out = _check_reconcile(
                            summary, fab, mon, rec, label
                        )
                    fleet = mon.summary(scope=FLEET)
                    queued_misses = sum(
                        c["attribution"]["queued"]
                        for c in fleet["per_class"].values()
                    )
                    total_misses = summary["deadline_misses"]
                    met = _slo_met(summary, specs)
                    payload_rows.append(dict(
                        label=label, plan=plan, router=router,
                        policy=policy, shards=n,
                        rounds=summary["rounds"],
                        clock_cycles=summary["clock_cycles"],
                        gops=summary["gops"],
                        gops_w=summary["gops_w"],
                        per_class=summary["per_class"],
                        deadline_misses=total_misses,
                        queued_misses=queued_misses,
                        # fixed-load share: offered count is the shared
                        # denominator, so monotonicity is integer-exact
                        queue_share=queued_misses / n_offered,
                        slo=dict(
                            met=met,
                            per_class={
                                q: dict(
                                    miss_rate=c["miss_rate"],
                                    burn=c["burn"],
                                    attribution=c["attribution"],
                                    attribution_shares=c[
                                        "attribution_shares"],
                                )
                                for q, c in fleet["per_class"].items()
                            },
                        ),
                        router_stats=fab.stats()["router_stats"],
                        stolen=fab.stolen,
                    ))
                    pc = summary["per_class"]
                    rows.append((
                        f"capacity/{label}",
                        summary["clock_cycles"] / 100e6 * 1e6,
                        f"met={int(met)};misses={total_misses};"
                        f"queued={queued_misses};"
                        f"gops_w={summary['gops_w']:.3f};"
                        f"int_p99={pc['interactive']['p99_ms']:.2f}",
                    ))

    # Gate 2: queueing share never worsens with added shards
    for plan in plans:
        for router in routers:
            for policy in policies:
                series = [
                    r for r in payload_rows
                    if (r["plan"], r["router"], r["policy"])
                    == (plan, router, policy)
                ]
                series.sort(key=lambda r: r["shards"])
                for a, b in zip(series, series[1:]):
                    if b["queue_share"] > a["queue_share"]:
                        raise RuntimeError(
                            f"queueing share worsened with more shards: "
                            f"{a['label']} {a['queue_share']:.4f} -> "
                            f"{b['label']} {b['queue_share']:.4f} at "
                            f"fixed load"
                        )

    # Frontier: per (router, policy, plan), min shards meeting every SLO
    frontier = []
    for plan in plans:
        for router in routers:
            for policy in policies:
                meeting = sorted(
                    r["shards"] for r in payload_rows
                    if (r["plan"], r["router"], r["policy"])
                    == (plan, router, policy) and r["slo"]["met"]
                )
                point = None
                if meeting:
                    point = next(
                        r for r in payload_rows
                        if (r["plan"], r["router"], r["policy"],
                            r["shards"])
                        == (plan, router, policy, meeting[0])
                    )
                frontier.append(dict(
                    plan=plan, router=router, policy=policy,
                    min_shards=meeting[0] if meeting else None,
                    gops_w=point["gops_w"] if point else None,
                    attribution_shares={
                        q: c["attribution_shares"]
                        for q, c in point["slo"]["per_class"].items()
                    } if point else None,
                ))

    # Gate 3: the frontier exists
    if not any(f["min_shards"] is not None for f in frontier):
        raise RuntimeError(
            "no grid point meets every SLO — the capacity frontier is "
            "empty; the workload or grid is mis-sized"
        )

    # Gate 4: the tuned plan never needs more shards than uniform
    tuned_wins = []
    if "tuned4" in plans and "uniform8" in plans:
        for router in routers:
            for policy in policies:
                by_plan = {
                    f["plan"]: f["min_shards"] for f in frontier
                    if (f["router"], f["policy"]) == (router, policy)
                }
                u, t = by_plan.get("uniform8"), by_plan.get("tuned4")
                if u is not None and (t is None or t > u):
                    raise RuntimeError(
                        f"tuned plan costs more fleet than uniform at "
                        f"({router}, {policy}): tuned min_shards {t} vs "
                        f"uniform {u}"
                    )
                tuned_wins.append(dict(router=router, policy=policy,
                                       uniform=u, tuned=t))

    if json_path:
        payload = dict(
            bench="capacity",
            key=key,
            grid=dict(shards=list(shard_counts), routers=list(routers),
                      policies=list(policies), plans=list(plans)),
            workload=dict(workload, n_offered=n_offered,
                          trace_schema=TRACE_VERSION),
            slo=[s.to_dict() for s in specs],
            windows=list(WINDOWS),
            attrib_classes=list(ATTRIB_CLASSES),
            rows=payload_rows,
            frontier=frontier,
            gate=dict(
                holds=True,  # every sub-gate raised above otherwise
                reconcile=reconcile_out,
                queue_share_monotone=True,
                frontier_nonempty=True,
                tuned_never_costlier=tuned_wins,
            ),
        )
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_capacity.json")
    args = ap.parse_args()
    for name, us, derived in run(json_path=args.json):
        print(f"{name},{us:.1f},{derived}")
