"""Roofline table from results/dryrun/*.json (run launch/dryrun.py first).

Also exports the markdown tables embedded in EXPERIMENTS.md §Dry-run and
§Roofline via ``markdown_tables()``.
"""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load(mesh: str = "16x16", tag: str | None = None) -> list[dict]:
    rows = []
    for p in sorted(RESULTS.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("mesh") != mesh:
            continue
        parts = p.stem.split("__")
        r["_tag"] = parts[3] if len(parts) > 3 else ""
        if (tag or "") != r["_tag"]:
            continue
        rows.append(r)
    return rows


def run() -> list[tuple[str, float, str]]:
    out = []
    for mesh in ("16x16", "2x16x16"):
        for r in load(mesh):
            roof = r["roofline"]
            name = f"roofline/{r['arch']}/{r['shape']}/{mesh}"
            out.append((
                name,
                roof["step_time_lower_bound_s"] * 1e6,
                f"dom={roof['dominant']};compute_ms={roof['compute_s']*1e3:.2f};"
                f"mem_ms={roof['memory_s']*1e3:.2f};coll_ms={roof['collective_s']*1e3:.2f};"
                f"useful={r['useful_flops_fraction']:.2f}",
            ))
    return out


def markdown_tables(mesh: str = "16x16", tag: str | None = None) -> str:
    rows = load(mesh, tag)
    lines = [
        "| arch | shape | kind | compute (ms) | memory (ms) | collective (ms) "
        "| dominant | bound (ms) | MODEL/HLO flops | compile (s) |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        roof = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {roof['compute_s']*1e3:.2f} | {roof['memory_s']*1e3:.2f} "
            f"| {roof['collective_s']*1e3:.2f} | **{roof['dominant']}** "
            f"| {roof['step_time_lower_bound_s']*1e3:.2f} "
            f"| {r['useful_flops_fraction']:.2f} | {r['compile_s']:.0f} |"
        )
    return "\n".join(lines)


def dryrun_table(mesh: str = "16x16") -> str:
    rows = load(mesh)
    lines = [
        "| arch | shape | params | per-chip HLO flops | HBM model bytes/chip "
        "| collective bytes/chip | collectives (count) | serve mode |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        coll = r["collectives"]["counts_by_kind"]
        cstr = ",".join(f"{k.split('-')[-1] if '-' in k else k}:{v}"
                        for k, v in sorted(coll.items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['params']/1e9:.1f}B "
            f"| {r['cost']['flops']:.2e} | {r['hbm_traffic_model']['total']:.2e} "
            f"| {r['cost']['coll_bytes']:.2e} | {cstr} | {r.get('serve_mode','-')} |"
        )
    return "\n".join(lines)
