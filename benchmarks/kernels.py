"""MMA kernel benchmarks: (a) the merged-vs-cascaded structural claim on the
lowered HLO (HBM-materialized intermediates — the TPU analogue of the
initial-delay accounting), (b) CPU wall-time of each datapath at a
representative layer shape, (c) early-termination scaling with planes.
"""
from __future__ import annotations

import re
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitplane, mma


def _count_hbm_intermediates(fn, *args) -> dict:
    """Ops in the optimized HLO whose results are plausibly materialized:
    we count dots and the total bytes of dot outputs (the cascade writes one
    full (M,N) partial per plane; the merged path writes one)."""
    text = jax.jit(fn).lower(*args).compile().as_text()
    dots = re.findall(r"=\s*([a-z0-9]+)\[([\d,]*)\][^=]*\bdot\(", text)
    nbytes = 0
    for dtype, dims in dots:
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes += n * {"f32": 4, "s32": 4, "bf16": 2, "s8": 1}.get(dtype, 4)
    return {"dot_count": len(dots), "dot_out_bytes": nbytes}


def _time(fn, *args, repeats=5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeats


def run() -> list[tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    m, k, n = 256, 2304, 256  # one KPB-worth: k = 9 taps x 256 channels
    x = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
    rows = []

    merged = jax.jit(lambda a, b: bitplane.bitplane_matmul(a, b))
    cascade = jax.jit(lambda a, b: bitplane.bitplane_matmul_cascade(a, b))
    int8 = jax.jit(lambda a, b: mma.mma_dot(a, b, impl="int8"))

    # Structural merged-vs-cascade claim: the MERGED implementation is the
    # Pallas kernel (one fused call, Horner residual in VMEM — ONE output
    # tensor ever touches HBM); the cascade materializes one full (M,N)
    # partial product per plane.  We count materialized dot outputs in the
    # optimized HLO of each.
    from repro.kernels import ops

    sm = _count_hbm_intermediates(
        lambda a, b: ops.mma_matmul(a, b, interpret=True), x, w)
    sc = _count_hbm_intermediates(
        lambda a, b: bitplane.bitplane_matmul_cascade(a, b), x, w)
    m_out_bytes = x.shape[0] * w.shape[1] * 4  # the single fused output
    # NOTE: interpret mode inlines the kernel body, so its 8 per-plane dots
    # appear as XLA dots here; on TPU the pallas_call is ONE custom call and
    # only out_specs' (M,N) int32 tile ever reaches HBM (by construction).
    rows.append(("kernels/merged_pallas_hlo", 0.0,
                 f"inlined_interpret_dots={sm['dot_count']};"
                 f"hbm_out_bytes={m_out_bytes} (single out_specs tile)"))
    rows.append(("kernels/cascade_hlo", _time(cascade, x, w) * 1e6,
                 f"dots={sc['dot_count']};dot_bytes={sc['dot_out_bytes']};"
                 f"hbm_bytes_ratio={sc['dot_out_bytes']/m_out_bytes:.2f}x"))
    rows.append(("kernels/merged_xla_horner", _time(merged, x, w) * 1e6,
                 "unrolled Horner (XLA fuses adds, still 8 plane dots)"))
    rows.append(("kernels/int8_direct", _time(int8, x, w) * 1e6, "bit-parallel baseline"))

    t = _time(lambda a, b: ops.mma_matmul(a, b, interpret=True), x[:32], w[:, :128],
              repeats=2)
    rows.append(("kernels/pallas_interpret", t * 1e6, "interpret-mode (CPU)"))

    # early termination: flops scale ~ planes/8
    for planes in (8, 6, 4, 2):
        fn = jax.jit(lambda a, b, p=planes: bitplane.bitplane_matmul(a, b, planes=p))
        flops = float(
            (jax.jit(lambda a, b, p=planes: bitplane.bitplane_matmul(a, b, planes=p))
             .lower(x, w).compile().cost_analysis() or {}).get("flops", 0)
        )
        rows.append((f"kernels/planes_{planes}", _time(fn, x, w) * 1e6,
                     f"hlo_flops={flops:.3e}"))
    return rows
