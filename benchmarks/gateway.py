"""Gateway open-loop replay benchmark — ``BENCH_gateway.json``, the
serving datapoint of the bench tracker.

The committed canonical trace ``traces/gateway_burst.json`` (regenerate
with ``scripts/make_traces.py`` — a steady ``interactive`` LM stream, an
on-off burst of long-prompt ``batch`` LM requests, and a sparse ``seg``
minority) is replayed *open-loop* through :class:`repro.serve.Gateway`:
arrivals are injected mid-round at their stamped modeled cycles, never
waiting for completions.  Runs:

* ``fair`` + preemptive chunked execution — the headline configuration;
* ``fair`` + atomic execution (PR 4 semantics: prefill charged wholesale
  at admission, micro-steps overdraft their budget) — the baseline the
  preemption gate compares against;
* ``fifo`` and ``edf`` (both preemptive) — the policy comparison.

Gates (each raises, so CI fails loudly):

1. **Preemption** — chunked execution must *strictly* improve the
   interactive class's p99 modeled latency over the atomic path at equal
   aggregate GOPS/W (within ``GOPS_W_EQUALITY_TOL``), with zero forced
   overdraft steps;
2. **Bit-identity** — the preemptive and atomic runs must produce
   bit-identical segmentation logits and exactly conserved LM work
   (identical per-request token counts and total modeled ops): the
   scheduler moves *when* work is charged, never *what* is computed.  The
   seg claim is gated bitwise because the MSDF int8 datapath's integer
   accumulation is associative — reordering micro-batches cannot move a
   single bit (per-tile activation scales via the pinned tuned plan keep
   quantization batch-composition independent).  The float LM smoke
   path's greedy token *values* are additionally compared and recorded
   (``lm_token_streams_identical``) but not gated: XLA CPU float matmuls
   jitter in the last ulp between runs regardless of scheduling (two
   identical atomic runs can emit different tokens once argmax feedback
   amplifies a tied logit), so token values measure the backend, not the
   scheduler.  The LM engine's per-slot cache index keeps each request's
   computation a function of its own tokens either way;
3. **Fair-share** — fair must strictly beat FIFO on the minority (seg)
   class's p99 on the open-loop trace;
4. **Progressive emission** — per request, streamed tile classes never
   decrease (structure before background).

The headline (fair, preemptive) run carries a :mod:`repro.obs`
``RecordingSink``: the payload's ``spans`` block decomposes the exact
per-class p50/p99 requests into queued / executing / preempted cycles,
and the run raises unless the stream's execution attribution reconciles
*integer-exactly* with ``RoundClock.worked_total``.

``scripts/bench_diff.py`` additionally diffs the GOPS/W of every row
against the committed baseline at the merge-base, keying gateway rows by
(trace name, trace schema version) so a schema bump reads as a target
change, not a regression.

    PYTHONPATH=src python -m benchmarks.run --section gateway
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

TRACE_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if "__file__" in globals() else ".", "traces", "gateway_burst.json"
)
TRACE_PATH = os.path.relpath(TRACE_PATH)
ROUND_BUDGET = 800_000  # modeled cycles per scheduling round (8 ms)
# Enough LM slots that the burst never exhausts the slot table: the bench
# isolates *cycle-budget* scheduling (quantum protection), not slot-table
# head-of-line blocking, which would otherwise dominate interactive p99.
# (Preemptive chunked prefill holds batch-class slots across several
# rounds by design — the slot table must absorb that pile-up.)
LM_BATCH = 20
LM_MAX_SEQ = 32
SEG_TILE = 28  # smallest viable tile for the 24px-halo depth-2 geometry
GOPS_W_EQUALITY_TOL = 0.03  # "equal aggregate GOPS/W" tolerance


def _pinned_plan(seg_cfg, seg_params, sched):
    """A hand-pinned v2 TunedPlan binding the bench's certified layer
    schedule to the served weights.  Serving through a plan switches the
    quantized datapath to *per-tile* activation scales, which is what
    makes seg numerics independent of micro-batch composition — the
    preemptive and atomic runs then stitch bit-identical logits no matter
    how scheduling reorders tiles.  Classes refine the layer budgets by
    amplitude octave (the PR 2 heuristic table, pinned)."""
    from repro.autotune.calibrate import params_fingerprint
    from repro.autotune.plan import TunedPlan
    from repro.segserve import adaptive

    planes = tuple(int(b) for b in sched.planes)
    thresholds = (1.0, 2.0**-2, 2.0**-4)
    class_planes = tuple(
        tuple(adaptive.class_schedule(sched, k).planes)
        for k in range(len(thresholds))
    )
    return TunedPlan(
        workload="unet",
        geometry=dict(
            depth=seg_cfg.depth, convs_per_stage=seg_cfg.convs_per_stage
        ),
        planes=planes,
        target_rel_err=float(sched.target_rel_err or 0.05),
        certificate=dict(cert=None, note="pinned bench plan (uncertified)"),
        fingerprint="bench-pinned-" + "0" * 51,
        params_fingerprint=params_fingerprint(seg_params),
        tile=SEG_TILE,
        halo=12,
        class_thresholds=thresholds,
        class_planes=class_planes,
    )


def _build_models(trace):
    import jax

    from repro import models
    from repro.configs import get_smoke_config
    from repro.models import unet as unet_mod

    lm_cfg = get_smoke_config("minitron_4b")
    lm_params = models.build(lm_cfg).init_params(jax.random.PRNGKey(0), lm_cfg)
    seg_spec = next(r for r in trace.requests if r.kind == "seg").payload
    seg_cfg = unet_mod.UNetConfig(
        hw=int(seg_spec["h"]), in_ch=4, base=8, depth=2, convs_per_stage=1,
        n_classes=3, quant_mode="mma_int8", impl="xla",
    )
    seg_params = unet_mod.init_params(jax.random.PRNGKey(1), seg_cfg)
    sched = unet_mod.schedule_from_params(seg_params, 0.05)
    seg_cfg = dataclasses.replace(seg_cfg, plane_schedule=tuple(sched.planes))
    plan = _pinned_plan(seg_cfg, seg_params, sched)
    return lm_cfg, lm_params, seg_cfg, seg_params, plan


def _replay_once(trace, models_bundle, *, policy, preemptive, shares,
                 round_budget, record_spans=False):
    from repro.serve import Gateway, LMAdapter, SegAdapter
    from repro.workload import lm_materializer, replay, seg_materializer

    lm_cfg, lm_params, seg_cfg, seg_params, plan = models_bundle
    sink = None
    if record_spans:
        from repro.obs import RecordingSink

        sink = RecordingSink()
    gw = Gateway(
        [
            LMAdapter(lm_cfg, lm_params, batch=LM_BATCH, max_seq=LM_MAX_SEQ,
                      preemptive=preemptive),
            SegAdapter(seg_cfg, seg_params, plan=plan, batch=4, max_active=2,
                       preemptive=preemptive),
        ],
        policy=policy,
        round_budget=round_budget,
        shares=shares,
        sink=sink,
    )
    t0 = time.perf_counter()
    summary = replay.replay(
        gw, trace,
        {"lm": lm_materializer(lm_cfg.vocab),
         "seg": seg_materializer(seg_cfg.in_ch)},
        max_rounds=10_000,
    )
    summary["wall_us"] = (time.perf_counter() - t0) * 1e6
    summary["preemptive"] = preemptive
    # per-request emitted tile classes: the progressive-emission property
    by_rid: dict[int, list[int]] = {}
    for ev in gw.tile_events:
        by_rid.setdefault(ev.rid, []).append(ev.klass)
    summary["structure_first"] = all(
        ks == sorted(ks) for ks in by_rid.values()
    )
    summary["tile_events"] = len(gw.tile_events)
    # outputs for the bit-identity gate: LM token streams by submission
    # order, seg logits by submission order
    outputs = dict(
        lm=[list(g.handle.out) for g in gw.requests if g.kind == "lm"],
        seg=[g.handle.result.logits for g in gw.requests if g.kind == "seg"],
    )
    if record_spans:
        from repro.obs import assemble, breakdown, reconcile

        rec = reconcile(sink.events, [gw.round_clock])
        if not rec["holds"]:
            raise RuntimeError(
                f"span execution attribution does not reconcile with the "
                f"round clock: {rec['total_exec']} exec-event cycles vs "
                f"{rec['total_worked']} worked cycles"
            )
        summary["spans"] = dict(
            per_class=breakdown(assemble(sink.events)),
            reconcile=rec,
            events=len(sink.events),
        )
    return summary, outputs


def run(*, trace_path: str = TRACE_PATH,
        json_path: str | None = "BENCH_gateway.json",
        round_budget: int = ROUND_BUDGET) -> list[tuple[str, float, str]]:
    import numpy as np

    from repro.workload import Trace

    trace = Trace.load(trace_path)
    shares = dict(trace.meta.get(
        "shares", {q: 1.0 / len(trace.qos_classes) for q in trace.qos_classes}
    ))
    models_bundle = _build_models(trace)

    runs = [
        ("fair", True),
        ("fair", False),  # the PR 4 atomic baseline
        ("fifo", True),
        ("edf", True),
    ]
    summaries: dict[tuple[str, bool], dict] = {}
    outputs: dict[tuple[str, bool], dict] = {}
    rows: list[tuple[str, float, str]] = []
    for policy, preemptive in runs:
        summary, outs = _replay_once(
            trace, models_bundle, policy=policy, preemptive=preemptive,
            shares=shares, round_budget=round_budget,
            # telemetry rides the headline run only: the span breakdown in
            # the payload decomposes *that* configuration's p50/p99, and
            # the in-run reconcile raise is the bench's integer-exactness
            # gate (exec attribution == RoundClock.worked_total)
            record_spans=(policy, preemptive) == ("fair", True),
        )
        summaries[(policy, preemptive)] = summary
        outputs[(policy, preemptive)] = outs
        mode = "" if preemptive else ":atomic"
        per_c = ";".join(
            f"{q}_p99={pc['p99_ms']:.2f}"
            for q, pc in summary["per_class"].items()
            if pc["completed"]
        )
        rows.append(
            (
                f"gateway/{policy}{mode}",
                summary["clock_cycles"] / 100e6 * 1e6,  # modeled us
                f"rounds={summary['rounds']};gops_w={summary['gops_w']:.3f};"
                f"forced={summary['forced']};{per_c}",
            )
        )
        if not summary["structure_first"]:
            raise RuntimeError(
                f"progressive emission broken under {policy}{mode}: a "
                f"request's background tiles were emitted before its "
                f"structure tiles"
            )

    pre = summaries[("fair", True)]
    atom = summaries[("fair", False)]

    # Gate 1: preemption — strict interactive-p99 win at equal GOPS/W,
    # with no forced overdrafts on the chunked path.
    p99_pre = pre["per_class"]["interactive"]["p99_ms"]
    p99_atom = atom["per_class"]["interactive"]["p99_ms"]
    if not p99_pre < p99_atom:
        raise RuntimeError(
            f"preemptive chunked execution lost its interactive-class win: "
            f"p99 {p99_pre:.2f} ms preemptive vs {p99_atom:.2f} ms atomic"
        )
    gops_gap = abs(pre["gops_w"] - atom["gops_w"]) / max(atom["gops_w"], 1e-12)
    if gops_gap > GOPS_W_EQUALITY_TOL:
        raise RuntimeError(
            f"preemption is no longer throughput-neutral: aggregate GOPS/W "
            f"{pre['gops_w']:.3f} preemptive vs {atom['gops_w']:.3f} atomic "
            f"({gops_gap:.1%} > {GOPS_W_EQUALITY_TOL:.0%})"
        )
    if pre["forced"] != 0:
        raise RuntimeError(
            f"preemptive replay needed {pre['forced']} forced overdraft "
            f"step(s): a micro-step outgrew the round budget"
        )
    if pre["total_ops"] != atom["total_ops"]:
        raise RuntimeError(
            f"preemption changed total emitted work: {pre['total_ops']} "
            f"vs {atom['total_ops']} modeled ops"
        )

    # Gate 2: bit-identity — scheduling must not change what is computed.
    # Seg logits: gated bitwise (integer MSDF datapath — associative
    # accumulation, per-tile scales).  LM: gated on exactly conserved
    # work (per-request token counts); token values recorded only (float
    # CPU backend jitter is schedule-independent — see module docstring).
    o_pre, o_atom = outputs[("fair", True)], outputs[("fair", False)]
    if len(o_pre["seg"]) != len(o_atom["seg"]):
        raise RuntimeError("preemptive vs atomic completed different "
                           "seg request sets")
    for a, b in zip(o_pre["seg"], o_atom["seg"]):
        if not np.array_equal(a, b):
            raise RuntimeError(
                "preemptive vs atomic seg logits differ — per-tile "
                "quantization no longer isolates micro-batch composition"
            )
    lm_counts_pre = [len(t) for t in o_pre["lm"]]
    lm_counts_atom = [len(t) for t in o_atom["lm"]]
    if lm_counts_pre != lm_counts_atom:
        raise RuntimeError(
            f"preemptive chunking changed emitted LM work: per-request "
            f"token counts {lm_counts_pre} vs {lm_counts_atom}"
        )
    lm_identical = o_pre["lm"] == o_atom["lm"]

    # Gate 3: fair-share protects the minority class, open-loop.
    minority = "seg"
    fifo_p99 = summaries[("fifo", True)]["per_class"][minority]["p99_ms"]
    fair_p99 = pre["per_class"][minority]["p99_ms"]
    if not fair_p99 < fifo_p99:
        raise RuntimeError(
            f"cycle-budget fair-share lost its minority-class win: "
            f"{minority} p99 {fair_p99:.2f} ms under fair vs "
            f"{fifo_p99:.2f} ms under fifo"
        )

    if json_path:
        payload_rows = []
        for (policy, preemptive), s in summaries.items():
            payload_rows.append(
                dict(
                    policy=policy + ("" if preemptive else ":atomic"),
                    preemptive=preemptive,
                    rounds=s["rounds"],
                    clock_cycles=s["clock_cycles"],
                    time_ms=s["time_ms"],
                    gops=s["gops"],
                    gops_w=s["gops_w"],
                    forced=s["forced"],
                    per_class=s["per_class"],
                    tile_events=s["tile_events"],
                    structure_first=s["structure_first"],
                    # wall_us deliberately not persisted: machine/run noise
                    # would dirty the committed artifact on every regen
                )
            )
        payload = dict(
            bench="gateway",
            trace=pre["trace"],
            round_budget=round_budget,
            shares=shares,
            spans=pre["spans"],
            rows=payload_rows,
            gate=dict(
                preemption=dict(
                    interactive_p99_ms_preemptive=p99_pre,
                    interactive_p99_ms_atomic=p99_atom,
                    speedup=p99_atom / p99_pre,
                    gops_w_gap=gops_gap,
                    bit_identical=True,  # seg logits, gated above
                    lm_token_streams_identical=bool(lm_identical),
                    holds=bool(p99_pre < p99_atom),
                ),
                minority=minority,
                fifo_p99_ms=fifo_p99,
                fair_p99_ms=fair_p99,
                speedup=fifo_p99 / fair_p99,
                holds=bool(fair_p99 < fifo_p99),
            ),
        )
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_gateway.json")
    ap.add_argument("--trace", default=TRACE_PATH)
    args = ap.parse_args()
    for name, us, derived in run(json_path=args.json, trace_path=args.trace):
        print(f"{name},{us:.1f},{derived}")
