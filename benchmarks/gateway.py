"""Gateway mixed-traffic benchmark — ``BENCH_gateway.json``, the serving
datapoint of the bench tracker.

One fixed traffic trace — a majority burst of LM decode requests with a
minority of segmentation images behind it — is replayed through
:class:`repro.serve.Gateway` under each admission policy (FIFO,
cycle-budget fair-share, EDF) at the same shared per-round modeled cycle
budget.  Reported per policy: per-class p50/p99 modeled latency (the
relation-(2) cycle clock at the paper's 100 MHz), aggregate GOPS/W at the
paper's implied accelerator power, rounds to drain, and the progressive
tile stream's structure-first property.

The gate (raises, so CI fails loudly): cycle-budget fair-share must beat
FIFO *strictly* on the minority class's p99 modeled latency — that is the
whole point of admission control, and a scheduling regression that lets
the majority burst starve the minority again must not merge clean.
``scripts/bench_diff.py`` additionally diffs the GOPS/W of every row
against the committed baseline at the merge-base.

    PYTHONPATH=src python -m benchmarks.run --section gateway
"""
from __future__ import annotations

import dataclasses
import json
import time

# Majority LM burst ahead of a seg minority: the FIFO head-of-line shape.
N_LM = 10
N_SEG = 3
LM_PROMPT = 4
LM_MAX_NEW = 8
SEG_HW = (96, 80)
ROUND_BUDGET = 1_500_000  # modeled cycles per scheduling round (15 ms)
POLICIES = ("fifo", "fair", "edf")


def run(
    *,
    n_lm: int = N_LM,
    n_seg: int = N_SEG,
    seg_hw: tuple[int, int] = SEG_HW,
    round_budget: int = ROUND_BUDGET,
    json_path: str | None = "BENCH_gateway.json",
) -> list[tuple[str, float, str]]:
    import jax
    import numpy as np

    from repro import models
    from repro.configs import get_smoke_config
    from repro.models import unet as unet_mod
    from repro.segserve.synth import phantom_image
    from repro.serve import Gateway, LMAdapter, SegAdapter

    lm_cfg = get_smoke_config("minitron_4b")
    lm_params = models.build(lm_cfg).init_params(jax.random.PRNGKey(0), lm_cfg)
    seg_cfg = unet_mod.UNetConfig(
        hw=seg_hw[0], in_ch=4, base=8, depth=2, convs_per_stage=1,
        n_classes=3, quant_mode="mma_int8", impl="xla",
    )
    seg_params = unet_mod.init_params(jax.random.PRNGKey(1), seg_cfg)
    sched = unet_mod.schedule_from_params(seg_params, 0.05)
    seg_cfg = dataclasses.replace(seg_cfg, plane_schedule=tuple(sched.planes))

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, lm_cfg.vocab, size=LM_PROMPT) for _ in range(n_lm)]
    images = [phantom_image(*seg_hw, 4, seed=s) for s in range(n_seg)]
    minority = "seg" if n_seg < n_lm else "lm"

    rows = []
    payload_rows = []
    for policy in POLICIES:
        gw = Gateway(
            [
                LMAdapter(lm_cfg, lm_params, batch=3, max_seq=32),
                SegAdapter(
                    seg_cfg, seg_params, tile=16, batch=4, max_active=2
                ),
            ],
            policy=policy,
            round_budget=round_budget,
        )
        # the trace: the LM burst arrives first, the seg minority behind it
        t0 = time.perf_counter()
        for p in prompts:
            gw.submit("lm", p, max_new=LM_MAX_NEW)
        for im in images:
            gw.submit("seg", im)
        gw.drain(max_rounds=10_000)
        wall_us = (time.perf_counter() - t0) * 1e6
        st = gw.stats()

        # progressive property along the ride: per request, emitted tile
        # classes never decrease (structure before background)
        by_rid: dict[int, list[int]] = {}
        for ev in gw.tile_events:
            by_rid.setdefault(ev.rid, []).append(ev.klass)
        structure_first = all(
            ks == sorted(ks) for ks in by_rid.values()
        )

        payload_rows.append(
            dict(
                policy=policy,
                rounds=st["rounds"],
                clock_cycles=st["clock_cycles"],
                time_ms=st["clock_cycles"] / 100e6 * 1e3,
                gops=st["gops"],
                gops_w=st["gops_w"],
                per_class=st["per_class"],
                tile_events=len(gw.tile_events),
                structure_first=structure_first,
                wall_us=wall_us,
            )
        )
        per_c = ";".join(
            f"{k}_p50={v['p50_ms']:.2f};{k}_p99={v['p99_ms']:.2f}"
            for k, v in st["per_class"].items()
        )
        rows.append(
            (
                f"gateway/{policy}",
                st["clock_cycles"] / 100e6 * 1e6,  # modeled us, like segserve
                f"rounds={st['rounds']};gops_w={st['gops_w']:.3f};{per_c}",
            )
        )
        if not structure_first:
            raise RuntimeError(
                f"progressive emission broken under {policy}: a request's "
                f"background tiles were emitted before its structure tiles"
            )

    by_policy = {r["policy"]: r for r in payload_rows}
    fifo_p99 = by_policy["fifo"]["per_class"][minority]["p99_ms"]
    fair_p99 = by_policy["fair"]["per_class"][minority]["p99_ms"]
    # The headline gate: fair-share must protect the minority class.
    if not fair_p99 < fifo_p99:
        raise RuntimeError(
            f"cycle-budget fair-share lost its minority-class win: "
            f"{minority} p99 {fair_p99:.2f} ms under fair vs "
            f"{fifo_p99:.2f} ms under fifo"
        )

    if json_path:
        payload = dict(
            bench="gateway",
            traffic=dict(
                n_lm=n_lm, n_seg=n_seg, lm_prompt=LM_PROMPT,
                lm_max_new=LM_MAX_NEW, seg_h=seg_hw[0], seg_w=seg_hw[1],
                minority=minority,
            ),
            round_budget=round_budget,
            rows=payload_rows,
            gate=dict(
                minority=minority,
                fifo_p99_ms=fifo_p99,
                fair_p99_ms=fair_p99,
                speedup=fifo_p99 / fair_p99,
                holds=bool(fair_p99 < fifo_p99),
            ),
        )
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_gateway.json")
    args = ap.parse_args()
    for name, us, derived in run(json_path=args.json):
        print(f"{name},{us:.1f},{derived}")
