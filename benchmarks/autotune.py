"""Autotune frontier benchmark — ``BENCH_autotune.json``, the tracker's
precision-frontier datapoint.

For a sweep of error targets, three families of operating points on the
same geometry and calibration images, all in one schema (shared with
``benchmarks/precision_sweep.py``):

  * ``frontier/*``     — the analytic whole-image ``from_weights`` frontier
                         (``precision_sweep.frontier_rows``): what the
                         weight-only bound *predicts* the trade to be;
  * ``from_weights/*`` — that schedule actually *served* (PR-2 operating
                         point: fixed tile, octave-heuristic adaptivity)
                         with its measured end-to-end error — the baseline
                         the autotuner must dominate;
  * ``tuned/*``        — :func:`repro.autotune.tune_unet` plans (measured
                         sensitivities, calibrated classes, searched tile)
                         served through the engine, with the certified
                         bound next to the measured error.

The dominance gate (raises, so CI fails loudly): at the headline target the
tuned plan must cost fewer modeled cycles than the served ``from_weights``
baseline at equal-or-lower measured error, and its certificate must hold.

    PYTHONPATH=src python -m benchmarks.run --section autotune
"""
from __future__ import annotations

import dataclasses
import json
import time

from benchmarks.segserve import GEOMETRY, IMAGE_HW, TILE

TARGETS = (0.1, 0.05, 0.02)
HEADLINE_TARGET = 0.05


def run(
    *,
    base: int | None = None,
    image_hw: tuple[int, int] = IMAGE_HW,
    tile_baseline: int = TILE,
    targets: tuple[float, ...] = TARGETS,
    headline: float = HEADLINE_TARGET,
    json_path: str | None = "BENCH_autotune.json",
    n_calib: int = 2,
) -> list[tuple[str, float, str]]:
    import jax

    from benchmarks import precision_sweep
    from repro import autotune
    from repro.models import unet as unet_mod
    from repro.segserve import SegEngine
    from repro.segserve.synth import phantom_image

    geo = dict(GEOMETRY)
    if base is not None:
        geo["base"] = base
    cfg = unet_mod.UNetConfig(
        hw=image_hw[0], in_ch=geo["in_ch"], base=geo["base"],
        depth=geo["depth"], convs_per_stage=1, n_classes=geo["n_classes"],
        quant_mode="mma_int8", impl="xla",
    )
    params = unet_mod.init_params(jax.random.PRNGKey(0), cfg)
    image = phantom_image(*image_hw, geo["in_ch"])
    calib_images = [
        phantom_image(*image_hw, geo["in_ch"], seed=s) for s in range(n_calib)
    ]
    calibration = autotune.calibrate_unet(params, cfg, calib_images)
    rel_err = autotune.rel_err  # the subsystem's one error metric

    payload_rows: list[dict] = []
    csv_rows: list[tuple[str, float, str]] = []

    def emit(kind, name, res, *, rel, cert=None, tile=None, planes=None,
             target=None, wall_us=None, extra=""):
        payload_rows.append(dict(
            kind=kind, name=name, target_rel_err=target,
            cycles=res.cycles, ops=res.ops, n_tiles=res.n_tiles,
            time_ms=res.time_ms, gops=res.gops, gops_w=res.gops_per_w,
            energy_mj=res.energy_mj, rel_err=rel, cert=cert,
            tile=tile, planes=None if planes is None else list(planes),
            wall_us=wall_us,
        ))
        csv_rows.append((
            f"autotune/{name}", res.time_ms * 1e3,
            f"cycles={res.cycles};gops_w={res.gops_per_w:.2f};"
            f"rel_err={rel:.4g}"
            + (f";cert={cert:.4g}" if cert is not None else "") + extra,
        ))

    # ---- analytic whole-image frontier (shared schema) ------------------
    frontier = precision_sweep.frontier_rows(
        params, cfg, (None,) + tuple(targets),
        x=None,
    )
    for r in frontier:
        payload_rows.append(dict(r, kind="frontier", name=f"frontier/{r['name']}"))

    # ---- served baseline: from_weights @ fixed tile (PR-2 ship) ---------
    ref_classic = SegEngine(
        dataclasses.replace(cfg, plane_schedule=None, planes=8), params,
        tile=tile_baseline, batch=4, adaptive=False,
    ).run([image])[0]
    baselines: dict[float, dict] = {}
    for tgt in targets:
        sched = unet_mod.schedule_from_params(params, tgt)
        scfg = dataclasses.replace(cfg, plane_schedule=tuple(sched.planes))
        res = SegEngine(
            scfg, params, tile=tile_baseline, batch=4, adaptive=True
        ).run([image])[0]
        rel = rel_err(res.logits, ref_classic.logits)
        baselines[tgt] = dict(cycles=res.cycles, rel_err=rel)
        emit("from_weights", f"from_weights-{tgt:g}", res, rel=rel,
             tile=tile_baseline, planes=sched.planes, target=tgt)

    # ---- tuned plans ----------------------------------------------------
    tuned: dict[float, dict] = {}
    for tgt in targets:
        t0 = time.perf_counter()
        plan = autotune.tune_unet(
            params, cfg, calib_images, target_rel_err=tgt,
            calibration=calibration, sound_bound=(tgt == headline),
        )
        wall = (time.perf_counter() - t0) * 1e6
        res = autotune.engine_from_plan(cfg, params, plan).run([image])[0]
        ref = autotune.engine_from_plan(
            cfg, params, autotune.reference_plan(plan)
        ).run([image])[0]
        rel = rel_err(res.logits, ref.logits)
        cert = float(plan.certificate["cert"])
        tuned[tgt] = dict(cycles=res.cycles, rel_err=rel, cert=cert,
                          plan=plan.to_json())
        emit("tuned", f"tuned-{tgt:g}", res, rel=rel, cert=cert,
             tile=plan.tile, planes=plan.planes, target=tgt, wall_us=wall,
             extra=f";tile={plan.tile}")
        if rel > cert:
            raise RuntimeError(
                f"certificate violated at target {tgt:g}: measured "
                f"{rel:.4g} > cert {cert:.4g}"
            )
        if cert > tgt:
            raise RuntimeError(
                f"tuned plan missed its budget at target {tgt:g}: "
                f"cert {cert:.4g} > target"
            )

    # ---- the dominance gate --------------------------------------------
    tb, bb = tuned[headline], baselines[headline]
    dominates = tb["cycles"] < bb["cycles"] and tb["rel_err"] <= bb["rel_err"]
    if not dominates:
        raise RuntimeError(
            f"tuned plan does not dominate from_weights at target "
            f"{headline:g}: tuned (cycles={tb['cycles']}, "
            f"rel_err={tb['rel_err']:.4g}) vs baseline "
            f"(cycles={bb['cycles']}, rel_err={bb['rel_err']:.4g})"
        )

    if json_path:
        payload = dict(
            bench="autotune",
            geometry=dict(geo, image_h=image_hw[0], image_w=image_hw[1],
                          tile_baseline=tile_baseline),
            targets=list(targets),
            headline_target=headline,
            calibration=dict(
                fingerprint=calibration.fingerprint,
                n_images=calibration.n_images,
                thresholds=list(calibration.class_thresholds),
                octave_hist=list(calibration.octave_hist),
                layer_gain=list(calibration.layer_gain),
            ),
            rows=payload_rows,
            dominance=dict(
                target=headline,
                tuned_cycles=tb["cycles"],
                from_weights_cycles=bb["cycles"],
                tuned_rel_err=tb["rel_err"],
                from_weights_rel_err=bb["rel_err"],
                speedup=bb["cycles"] / tb["cycles"],
                holds=dominates,
            ),
        )
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return csv_rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="calibrated base-48 width (slow on CPU)")
    ap.add_argument("--json", default="BENCH_autotune.json")
    args = ap.parse_args()
    for name, us, derived in run(
        base=48 if args.full else None, json_path=args.json
    ):
        print(f"{name},{us:.1f},{derived}")
