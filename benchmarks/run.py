"""Benchmark harness — one section per paper table/claim.

    PYTHONPATH=src python -m benchmarks.run \
        [--section table1|kernels|roofline|msdf|precision|segserve|autotune|gateway|replay|fabric|capacity|energy|specdecode]

Prints ``name,us_per_call,derived`` CSV rows.  The segserve, autotune,
gateway, fabric and specdecode sections also write machine-readable
``BENCH_segserve.json`` / ``BENCH_autotune.json`` /
``BENCH_gateway.json`` / ``BENCH_fabric.json`` /
``BENCH_specdecode.json`` for the bench tracker
(``scripts/bench_diff.py`` diffs them across revisions).  ``replay`` is
the open-loop trace-replay bench — an alias for the gateway section,
which replays the committed canonical trace ``traces/gateway_burst.json``
through ``repro.workload.replay``.  ``fabric`` replays the scaled
``gateway_burst_x10``/``_x100`` traces through a single modeled gateway
and an N-shard sharded fabric (``repro.serve.Fabric``) and gates
scale-out p99 behavior plus exact fleet-ledger additivity.  ``capacity``
is the SLO-driven fleet capacity planner: it streams a diurnal workload
over a shard x router x policy x plan grid of modeled fabrics and writes
the cost-per-SLO frontier to ``BENCH_capacity.json``.  ``energy`` meters
the same workload with the joule-exact :class:`repro.obs.energy`
telemetry (plan x policy x shard grid) and writes the metered GOPS/W and
energy-per-request frontier to ``BENCH_energy.json``.
"""
from __future__ import annotations

import argparse
import time


def msdf_rows():
    """Cycle-count claims from the MSDF simulator (paper Sec. 3.2)."""
    import numpy as np

    from repro.core.msdf import MMAUnit, kpb_inner_product
    from repro.core import cycle_model as cm

    rng = np.random.default_rng(0)
    a = rng.integers(0, 256, 32).astype(np.uint8)
    w = rng.integers(-128, 128, 32)
    unit = MMAUnit(w, t_n=32)
    t0 = time.perf_counter()
    _, cycles = unit.run(a)
    dt = time.perf_counter() - t0
    rows = [("msdf/mma_unit_sim", dt * 1e6,
             f"cycles={cycles};relation2_inner={cm.mma_tile_cycles()};"
             f"cascaded={cm.cascaded_tile_cycles()}")]
    a9 = rng.integers(0, 256, (9, 32)).astype(np.uint8)
    w9 = rng.integers(-128, 128, (9, 32))
    t0 = time.perf_counter()
    _, kcyc = kpb_inner_product(a9, w9)
    rows.append(("msdf/kpb_sim", (time.perf_counter() - t0) * 1e6,
                 f"cycles={kcyc};taps=9;t_n=32"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all")
    args = ap.parse_args()

    rows: list[tuple[str, float, str]] = []
    if args.section in ("all", "msdf"):
        rows += msdf_rows()
    if args.section in ("all", "table1"):
        from benchmarks import table1

        rows += table1.run()
    if args.section in ("all", "kernels"):
        from benchmarks import kernels

        rows += kernels.run()
    if args.section in ("all", "roofline"):
        from benchmarks import roofline

        rows += roofline.run()
    if args.section in ("all", "precision"):
        from benchmarks import precision_sweep

        rows += precision_sweep.run()
    if args.section in ("all", "segserve"):
        from benchmarks import segserve

        rows += segserve.run()
    if args.section in ("all", "autotune"):
        from benchmarks import autotune

        rows += autotune.run()
    if args.section in ("all", "gateway", "replay"):
        from benchmarks import gateway

        rows += gateway.run()
    if args.section in ("all", "fabric"):
        from benchmarks import fabric

        rows += fabric.run()
    if args.section in ("all", "capacity"):
        from benchmarks import capacity

        rows += capacity.run()
    if args.section in ("all", "energy"):
        from benchmarks import energy

        rows += energy.run()
    if args.section in ("all", "specdecode"):
        from benchmarks import specdecode

        rows += specdecode.run()

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
