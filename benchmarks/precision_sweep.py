"""Dynamic-precision frontier: schedule vs (GOPS/W, relative error).

For a sweep of error targets, build the per-layer :class:`PlaneSchedule`
from the calibrated U-Net's actual weights, then report both sides of the
trade the schedule buys:

  * analytic cost — relation-(2) cycles recomputed layer-by-layer under the
    schedule (``cycle_model.schedule_cycles``), hence time, GOPS, GOPS/W
    (constant accelerator power) and energy;
  * measured accuracy — max relative error of the scheduled U-Net forward
    against the full 8-plane datapath, plus the per-layer analytic bound the
    schedule was chosen against.

Output CSV rows: name,us_per_call,derived — us_per_call is the modeled
inference time; derived carries the frontier columns.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import cycle_model as cm

# None = full precision (the Table-1 operating point); floats are
# worst-case per-layer relative-error targets for PlaneSchedule.from_weights.
TARGETS = (None, 0.05, 0.02, 0.01, 0.005, 0.001)


def frontier_rows(params, cfg, targets=TARGETS, *, x=None) -> list[dict]:
    """Structured frontier datapoints (the tracker schema shared with
    ``BENCH_autotune.json``): one dict per error target with the per-layer
    schedule, relation-(2) account and measured whole-image error.  ``cfg``
    must be a quantized ``UNetConfig``; ``x`` defaults to a fixed-PRNG
    normal input at the config geometry."""
    from repro.models import unet as unet_mod

    layers = cfg.conv_layers()
    if x is None:
        x = jax.random.normal(
            jax.random.PRNGKey(1), (1, cfg.hw, cfg.hw, cfg.in_ch)
        )
    power = cm.PAPER_TABLE1["proposed"]["gops"] / cm.PAPER_TABLE1["proposed"]["gops_w"]
    ops = cm.model_ops(layers)

    rows = []
    for tgt in targets:
        if tgt is None:
            sched = dataclasses.replace(
                cfg, plane_schedule=None
            ).schedule()  # uniform 8
            name = "full-8"
        else:
            sched = unet_mod.schedule_from_params(params, tgt)
            name = f"target-{tgt:g}"
        cyc = cm.schedule_cycles(layers, sched)
        t_ms = cyc / cm.FREQ_HZ * 1e3
        gops = ops / (t_ms * 1e-3) / 1e9
        scfg = dataclasses.replace(cfg, plane_schedule=tuple(sched.planes))
        out_s, out_f, adv = unet_mod.forward_with_error_bound(params, x, scfg)
        emp = float(jnp.max(jnp.abs(out_s - out_f))
                    / jnp.maximum(jnp.max(jnp.abs(out_f)), 1e-8))
        rows.append(dict(
            name=name,
            target_rel_err=tgt,
            planes=list(sched.planes),
            kept=sched.arithmetic_fraction(),
            cycles=cyc,
            ops=ops,
            time_ms=t_ms,
            gops=gops,
            gops_w=gops / power,
            energy_mj=power * t_ms,
            layer_bound=sched.rel_err_bound(),
            sound_bound=float(adv),
            rel_err=emp,
        ))
    return rows


def run(targets=TARGETS, *, hw: int | None = None) -> list[tuple[str, float, str]]:
    from repro.models import unet as unet_mod

    cfg = unet_mod.UNetConfig(quant_mode="mma_int8", impl="xla")
    if hw is not None:
        cfg = dataclasses.replace(cfg, hw=hw)
    params = unet_mod.init_params(jax.random.PRNGKey(0), cfg)

    rows = []
    for r in frontier_rows(params, cfg, targets):
        rows.append((
            f"precision/{r['name']}",
            r["time_ms"] * 1e3,
            f"planes={'/'.join(map(str, r['planes']))};"
            f"kept={r['kept']:.3f};"
            f"gops={r['gops']:.2f};gops_w={r['gops_w']:.2f};"
            f"e_mj={r['energy_mj']:.1f};"
            f"layer_bound={r['layer_bound']:.4g};"
            f"rel_err={r['rel_err']:.4g}",
        ))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
