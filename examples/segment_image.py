"""Segment an arbitrary-size image through the tiled serving engine, with
content-adaptive MSDF tile precision and an energy account per image.

A synthetic medical-style image (quiet background, one bright structure)
is tiled with the receptive-field-exact halo, tiles are micro-batched
through the quantized U-Net under the certified per-layer plane schedule,
flat-background tiles drop extra digits (budget classes), and the result
is stitched seamlessly and priced in relation-(2) cycles / GOPS/W.

    PYTHONPATH=src python examples/segment_image.py \
        [--height 160] [--width 128] [--tile 32] [--target-rel-err 0.05]
        [--no-adaptive] [--float]
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.models import unet
from repro.segserve import SegEngine, halo_for
from repro.segserve.synth import phantom_image


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--height", type=int, default=160)
    ap.add_argument("--width", type=int, default=128)
    ap.add_argument("--depth", type=int, default=3)
    ap.add_argument("--base", type=int, default=16)
    ap.add_argument("--tile", type=int, default=32)
    ap.add_argument("--target-rel-err", type=float, default=0.05)
    ap.add_argument("--no-adaptive", action="store_true",
                    help="uniform per-layer schedule for every tile")
    ap.add_argument("--float", action="store_true", dest="float_mode",
                    help="float datapath (bit-comparable to whole-image "
                         "forward; no precision/energy story)")
    args = ap.parse_args()

    cfg = unet.UNetConfig(
        hw=args.height, in_ch=4, base=args.base, depth=args.depth,
        convs_per_stage=1, n_classes=4,
        quant_mode="none" if args.float_mode else "mma_int8", impl="xla",
    )
    params = unet.init_params(jax.random.PRNGKey(0), cfg)
    if not args.float_mode:
        sched = unet.schedule_from_params(params, args.target_rel_err)
        cfg = dataclasses.replace(cfg, plane_schedule=tuple(sched.planes))
        print(f"layer schedule: {sched.describe()}")

    image = phantom_image(args.height, args.width, cfg.in_ch)
    eng = SegEngine(cfg, params, tile=args.tile,
                    adaptive=not args.no_adaptive)
    res = eng.run([image])[0]

    mask = np.argmax(res.logits, axis=-1)
    print(f"image {args.height}x{args.width} -> mask {mask.shape}, "
          f"classes present {sorted(np.unique(mask).tolist())}")
    print(f"tiles={res.n_tiles} (halo {halo_for(args.depth, 1)} px), "
          f"budget classes {res.class_counts}")
    print(f"modeled: {res.cycles} cycles = {res.time_ms:.2f} ms @100MHz, "
          f"{res.gops:.2f} GOPS, {res.gops_per_w:.2f} GOPS/W, "
          f"{res.energy_mj:.1f} mJ")


if __name__ == "__main__":
    main()
