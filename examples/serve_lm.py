"""Batched LM serving through the continuous-batching engine, with the MMA
int8 datapath and MSDF-style progressive precision.

    PYTHONPATH=src python examples/serve_lm.py [--arch yi_6b] [--quant]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import QuantConfig
from repro.models import build
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--quant", action="store_true")
    ap.add_argument("--planes", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if args.quant:
        cfg = cfg.replace(quant=QuantConfig(mode="mma_int8", planes=args.planes))
    mod = build(cfg)
    params = (mod.init_params(jax.random.PRNGKey(0), cfg, max_dec_pos=128)
              if cfg.family == "encdec"
              else mod.init_params(jax.random.PRNGKey(0), cfg))

    eng = Engine(cfg, params, batch=4, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=rng.integers(3, 9)),
                max_new=8)
        for i in range(6)
    ]
    done = eng.run(reqs)
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")
    assert len(done) == len(reqs) and all(len(r.out) == 8 for r in done)
    print(f"served {len(done)} requests, quant={'mma_int8' if args.quant else 'none'}"
          f" planes={args.planes}")


if __name__ == "__main__":
    main()
