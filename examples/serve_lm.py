"""Batched LM serving through the continuous-batching engine, with the MMA
int8 datapath and MSDF dynamic precision: either a uniform plane budget
(--planes) or a per-layer schedule derived from the served weights at an
error target (--target-rel-err, overrides --planes).

    PYTHONPATH=src python examples/serve_lm.py [--arch yi_6b] [--quant]
        [--planes 6 | --target-rel-err 0.01]
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import QuantConfig
from repro.models import build
from repro.serve.engine import Engine, Request, lm_schedule_from_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--quant", action="store_true")
    ap.add_argument("--planes", type=int, default=8)
    ap.add_argument("--target-rel-err", type=float, default=None,
                    help="build a per-layer PlaneSchedule from the weights")
    args = ap.parse_args()
    if args.target_rel_err is not None and not args.quant:
        ap.error("--target-rel-err requires --quant (schedules drive the "
                 "mma_int8 datapath)")

    cfg = get_smoke_config(args.arch)
    if args.quant:
        cfg = cfg.replace(quant=QuantConfig(mode="mma_int8", planes=args.planes))
    mod = build(cfg)
    params = (mod.init_params(jax.random.PRNGKey(0), cfg, max_dec_pos=128)
              if cfg.family == "encdec"
              else mod.init_params(jax.random.PRNGKey(0), cfg))

    sched_desc = f"planes={args.planes}"
    if args.quant and args.target_rel_err is not None:
        sched = lm_schedule_from_params(params, cfg, args.target_rel_err)
        cfg = cfg.replace(quant=dataclasses.replace(
            cfg.quant, plane_schedule=tuple(sched.planes)))
        sched_desc = sched.describe()

    eng = Engine(cfg, params, batch=4, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=rng.integers(3, 9)),
                max_new=8)
        for i in range(6)
    ]
    done = eng.run(reqs)
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")
    assert len(done) == len(reqs) and all(len(r.out) == 8 for r in done)
    print(f"served {len(done)} requests, quant={'mma_int8' if args.quant else 'none'}"
          f" {sched_desc}")


if __name__ == "__main__":
    main()
