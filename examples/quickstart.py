"""Quickstart: the paper's MMA datapath in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. bit-plane merged multiply-add vs exact int8 matmul (bit-exact),
2. MSDF early termination (progressive precision),
3. a quantized linear layer inside a tiny LM forward.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitplane, early_term, mma, quant
from repro.kernels import ops, ref


def main():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-128, 128, (64, 256)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (256, 32)), jnp.int8)

    exact = ref.mma_matmul_ref(x, w)
    merged = mma.mma_dot(x, w, impl="xla")
    pallas = ops.mma_matmul(x, w, interpret=True)
    print("merged == exact:", bool(jnp.array_equal(merged, exact)))
    print("pallas == exact:", bool(jnp.array_equal(pallas, exact)))

    print("\nMSDF progressive precision (planes -> max relative error):")
    for planes in range(1, 9):
        approx = mma.mma_dot(x, w, planes=planes)
        err = float(early_term.empirical_rel_err(exact, approx))
        bound = float(jnp.max(early_term.truncation_bound(w, planes, midpoint=False))
                      / jnp.maximum(jnp.max(jnp.abs(exact)), 1))
        print(f"  planes={planes}: measured={err:.4f}  worst-case-bound={bound:.4f}")

    print("\nquantized linear (float in/out through the int8 MMA path):")
    xf = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
    wf = jnp.asarray(rng.standard_normal((256, 32)) * 0.05, jnp.float32)
    yq = mma.mma_linear(xf, wf)
    y = xf @ wf
    rel = float(jnp.max(jnp.abs(y - yq)) / jnp.max(jnp.abs(y)))
    print(f"  rel error vs float: {rel:.4f} (int8 dynamic quantization)")


if __name__ == "__main__":
    main()
