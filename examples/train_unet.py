"""End-to-end driver: train U-Net on synthetic segmentation, quantize, and
evaluate through the MMA int8 datapath — the paper's full deployment story.

    PYTHONPATH=src python examples/train_unet.py [--steps 120] [--full]

``--full`` uses the Table-1-calibrated geometry (slow on CPU); the default
is a reduced config that trains in ~2 minutes on one core.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import unet
from repro.optim import adamw
from repro.checkpoint.ckpt import Checkpointer


def synth_batch(cfg, step, b=4):
    """Blob segmentation: classes = concentric intensity rings."""
    rng = np.random.default_rng(step)
    img = rng.standard_normal((b, cfg.hw, cfg.hw, cfg.in_ch)).astype(np.float32)
    cy, cx = rng.integers(8, cfg.hw - 8, 2)
    yy, xx = np.mgrid[: cfg.hw, : cfg.hw]
    d = np.sqrt((yy - cy) ** 2 + (xx - cx) ** 2)
    mask = np.clip(d // 6, 0, cfg.n_classes - 1).astype(np.int32)
    mask = np.broadcast_to(mask, (b, cfg.hw, cfg.hw))
    img[..., 0] += (mask == 0) * 2.0  # signal channel
    return {"image": jnp.asarray(img), "mask": jnp.asarray(mask)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/unet_ckpt")
    args = ap.parse_args()

    cfg = unet.UNetConfig() if args.full else unet.UNetConfig(
        hw=32, in_ch=2, base=8, depth=2, n_classes=3
    )
    params = unet.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)

    @jax.jit
    def step_fn(params, opt, batch):
        (loss, m), g = jax.value_and_grad(unet.loss_fn, has_aux=True)(
            params, batch, cfg
        )
        params, opt, om = adamw.update(params, g, opt, lr=3e-3, weight_decay=0.0)
        return params, opt, loss

    ck = Checkpointer(args.ckpt, keep=2)
    losses = []
    t0 = time.time()
    for s in range(args.steps):
        params, opt, loss = step_fn(params, opt, synth_batch(cfg, s))
        losses.append(float(loss))
        if s % 20 == 0:
            print(f"step {s:4d} loss {losses[-1]:.4f}")
        if (s + 1) % 50 == 0:
            ck.save_async(s + 1, {"params": params})
    ck.wait()
    print(f"trained {args.steps} steps in {time.time()-t0:.1f}s; "
          f"loss {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f}")
    assert np.mean(losses[-10:]) < losses[0], "loss must decrease"

    # deploy: evaluate float vs MMA-int8 (the FPGA datapath)
    batch = synth_batch(cfg, 10_000)
    logits_f = unet.forward(params, batch["image"], cfg)
    acc_f = float((jnp.argmax(logits_f, -1) == batch["mask"]).mean())
    qcfg = dataclasses.replace(cfg, quant_mode="mma_int8", impl="xla")
    logits_q = unet.forward(params, batch["image"], qcfg)
    acc_q = float((jnp.argmax(logits_q, -1) == batch["mask"]).mean())
    print(f"accuracy float={acc_f:.3f}  mma_int8={acc_q:.3f}")
    for planes in (6, 4):
        pcfg = dataclasses.replace(qcfg, planes=planes)
        lp = unet.forward(params, batch["image"], pcfg)
        acc = float((jnp.argmax(lp, -1) == batch["mask"]).mean())
        print(f"accuracy mma_int8 planes={planes}: {acc:.3f}  (early termination)")


if __name__ == "__main__":
    main()
