"""End-to-end autotuning demo: calibrate -> search -> certify -> serve.

Builds a quantized U-Net, calibrates it on a handful of synthetic
medical-style images, lets the autotuner derive a certified
precision/tile plan (``repro.autotune.tune_unet``), round-trips the plan
through JSON, and serves an image through :class:`repro.segserve.SegEngine`
at the tuned operating point — printing the measured error against the
certificate and the modeled relation-(2) account against the uniform
``from_weights`` baseline the tuner must beat.

    PYTHONPATH=src python examples/tune_unet.py \
        [--height 160] [--width 128] [--depth 3] [--base 16]
        [--target-rel-err 0.05] [--plan-path tuned_plan.json]
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro import autotune
from repro.models import unet
from repro.segserve import SegEngine
from repro.segserve.synth import phantom_image


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--height", type=int, default=160)
    ap.add_argument("--width", type=int, default=128)
    ap.add_argument("--depth", type=int, default=3)
    ap.add_argument("--base", type=int, default=16)
    ap.add_argument("--target-rel-err", type=float, default=0.05)
    ap.add_argument("--n-calib", type=int, default=2)
    ap.add_argument("--plan-path", default=None,
                    help="write the certified plan JSON here")
    args = ap.parse_args()

    cfg = unet.UNetConfig(
        hw=args.height, in_ch=4, base=args.base, depth=args.depth,
        convs_per_stage=1, n_classes=4, quant_mode="mma_int8", impl="xla",
    )
    params = unet.init_params(jax.random.PRNGKey(0), cfg)
    images = [
        phantom_image(args.height, args.width, cfg.in_ch, seed=s)
        for s in range(args.n_calib)
    ]

    # ---- calibrate + search + certify (one call) ------------------------
    plan = autotune.tune_unet(
        params, cfg, images, target_rel_err=args.target_rel_err
    )
    print(plan.describe())
    cert = plan.certificate
    print(f"certificate: measured {cert['measured_rel_err']:.4g} * margin "
          f"{cert['margin']:g} = {cert['cert']:.4g} <= target "
          f"{cert['target_rel_err']:g}  (sound interval bound "
          f"{cert.get('sound_bound', float('nan')):.3g})")
    print(f"calibrated classes: thresholds {plan.class_thresholds}")
    print(f"fingerprint: {plan.fingerprint[:16]}…")

    if args.plan_path:
        plan.save(args.plan_path)
        plan = autotune.TunedPlan.load(args.plan_path)  # JSON round trip
        print(f"plan saved to {args.plan_path}")

    # ---- serve at the tuned operating point -----------------------------
    image = images[0]
    eng = autotune.engine_from_plan(cfg, params, plan)
    res = eng.run([image])[0]
    ref = autotune.engine_from_plan(
        cfg, params, autotune.reference_plan(plan)
    ).run([image])[0]
    err = float(np.max(np.abs(res.logits - ref.logits))) / max(
        float(np.max(np.abs(ref.logits))), 1e-8
    )
    print(f"served {args.height}x{args.width}: tiles={res.n_tiles} "
          f"(tile {plan.tile}, halo {plan.halo}), classes {res.class_counts}")
    print(f"measured rel err {err:.4g} <= cert {cert['cert']:.4g}: "
          f"{err <= cert['cert']}")

    # ---- vs the analytic from_weights baseline --------------------------
    sched = unet.schedule_from_params(params, args.target_rel_err)
    bcfg = dataclasses.replace(cfg, plane_schedule=tuple(sched.planes))
    base = SegEngine(bcfg, params, tile=32, adaptive=True).run([image])[0]
    print(f"modeled: tuned {res.cycles} cycles ({res.gops_per_w:.2f} GOPS/W)"
          f" vs from_weights@tile32 {base.cycles} cycles "
          f"({base.gops_per_w:.2f} GOPS/W) -> "
          f"{base.cycles / res.cycles:.2f}x fewer cycles")


if __name__ == "__main__":
    main()
