"""MSDF early termination on a real LM: sweep the per-layer plane budget and
measure logit fidelity + arithmetic savings — the paper's 'future work'
(early termination) realized as a serving knob.

    PYTHONPATH=src python examples/progressive_decode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import QuantConfig
from repro.core import early_term
from repro.models import build


def main():
    cfg = get_smoke_config("yi_6b")
    mod = build(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (4, 48)), jnp.int32)

    ref = mod.forward(params, tokens, cfg).astype(jnp.float32)
    ref_top1 = jnp.argmax(ref, -1)

    print("planes | arithmetic kept | top1 agreement | max rel logit err")
    for planes in (8, 7, 6, 5, 4, 3):
        qcfg = cfg.replace(quant=QuantConfig(mode="mma_int8", planes=planes))
        out = mod.forward(params, tokens, qcfg).astype(jnp.float32)
        agree = float((jnp.argmax(out, -1) == ref_top1).mean())
        rel = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
        print(f"  {planes}    |      {planes}/8        |     {agree:.3f}      | {rel:.4f}")

    # per-layer plane choice from the analytic bound
    w = np.asarray(params["blocks"]["mlp"]["w_up"]["w"][0], np.float32)
    wq = jnp.asarray(np.clip(np.round(w / (np.abs(w).max() / 127)), -127, 127),
                     jnp.int8)
    for tgt in (0.05, 0.01, 0.001):
        b = early_term.choose_planes(wq, tgt)
        print(f"target rel err {tgt}: choose_planes -> {b} planes")


if __name__ == "__main__":
    main()
