"""MSDF dynamic precision on a real LM: per-layer plane schedules instead of
one global knob — the paper's 'future work' (early termination) plus MINT's
per-layer precision assignment, realized as a serving feature.

Builds a :class:`PlaneSchedule` from the served weights at several error
targets, installs it via ``cfg.quant.plane_schedule`` (it rides the layer
scan as data), and measures logit fidelity vs digit-serial work kept.

    PYTHONPATH=src python examples/progressive_decode.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import QuantConfig
from repro.core.plane_schedule import PlaneSchedule
from repro.models import build
from repro.serve.engine import lm_schedule_from_params


def main():
    cfg = get_smoke_config("yi_6b")
    mod = build(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (4, 48)), jnp.int32)

    ref = mod.forward(params, tokens, cfg).astype(jnp.float32)
    ref_top1 = jnp.argmax(ref, -1)

    def fidelity(qcfg):
        out = mod.forward(params, tokens, qcfg).astype(jnp.float32)
        agree = float((jnp.argmax(out, -1) == ref_top1).mean())
        rel = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
        return agree, rel

    print("== uniform schedules (the old global knob, as a schedule) ==")
    print("planes | digit work kept | top1 agreement | max rel logit err")
    for planes in (8, 6, 4, 3):
        sched = PlaneSchedule.uniform(planes, cfg.n_layers)
        qcfg = cfg.replace(
            quant=QuantConfig(mode="mma_int8", plane_schedule=tuple(sched.planes))
        )
        agree, rel = fidelity(qcfg)
        print(f"  {planes}    |      {sched.arithmetic_fraction():.2f}       "
              f"|     {agree:.3f}      | {rel:.4f}")

    print("== per-layer schedules from the served weights ==")
    print("target | schedule | digit work kept | top1 | max rel logit err")
    for tgt in (0.05, 0.01, 0.001):
        sched = lm_schedule_from_params(params, cfg, tgt)
        qcfg = cfg.replace(
            quant=dataclasses.replace(
                QuantConfig(mode="mma_int8"), plane_schedule=tuple(sched.planes)
            )
        )
        agree, rel = fidelity(qcfg)
        print(f" {tgt:<6}| {list(sched.planes)} | {sched.arithmetic_fraction():.2f} "
              f"| {agree:.3f} | {rel:.4f}")


if __name__ == "__main__":
    main()
