"""Autotune subsystem: calibration determinism, the search's error budget
(property, over randomized geometries), TunedPlan JSON round-trip, the
tile-geometry guard, tiled-vs-whole equivalence at tuned (non-32) tiles,
and the certified benches' smoke paths."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import autotune
from repro.autotune import TunedPlan, calibrate_unet, tune_unet
from repro.models import unet
from repro.segserve import SegEngine, tiling
from repro.segserve.adaptive import budget_class_from_thresholds


@functools.lru_cache(maxsize=8)
def _qnet(depth=1, base=4, in_ch=3, n_classes=3):
    cfg = unet.UNetConfig(
        hw=16, in_ch=in_ch, base=base, depth=depth, convs_per_stage=1,
        n_classes=n_classes, quant_mode="mma_int8", impl="xla",
    )
    return cfg, unet.init_params(jax.random.PRNGKey(0), cfg)


def _calib_image(seed=0, h=48, w=40, c=3):
    rng = np.random.default_rng(seed)
    img = rng.normal(0.0, 0.01, (h, w, c))
    img[4:16, 4:16] += rng.normal(0.0, 1.0, (12, 12, c))
    return img.astype(np.float32)


@functools.lru_cache(maxsize=4)
def _tuned(target_milli=100):
    cfg, params = _qnet()
    plan = tune_unet(
        params, cfg, [_calib_image()], target_rel_err=target_milli / 1000.0
    )
    return cfg, params, plan


# ------------------------------------------------------------ calibration


def test_calibration_deterministic():
    """Same PRNG, same weights, same images -> bitwise-identical statistics
    and fingerprint (the property a plan's fingerprint is built on)."""
    cfg, params = _qnet()
    images = [_calib_image(0), _calib_image(1)]
    a = calibrate_unet(params, cfg, images)
    b = calibrate_unet(params, cfg, images)
    assert a == b
    assert a.fingerprint == b.fingerprint
    # different calibration inputs -> different fingerprint
    c = calibrate_unet(params, cfg, [_calib_image(2)])
    assert c.fingerprint != a.fingerprint
    # structure: one sensitivity row per conv, thresholds descend from 1.0
    assert a.n_layers == len(cfg.conv_layers())
    assert a.class_thresholds[0] == 1.0
    assert all(
        x > y for x, y in zip(a.class_thresholds, a.class_thresholds[1:])
    )
    assert len(a.class_ratios) == len(a.class_thresholds)
    for row in a.sensitivity:
        assert row[-1] == 0.0  # 8 planes == reference
    assert sum(a.class_counts) == sum(a.octave_hist)


def test_calibration_rejects_float_config():
    cfg, params = _qnet()
    with pytest.raises(ValueError, match="mma_int8"):
        calibrate_unet(
            params, dataclasses.replace(cfg, quant_mode="none"),
            [_calib_image()],
        )
    with pytest.raises(ValueError, match="at least one image"):
        calibrate_unet(params, cfg, [])


# ------------------------------------------------- the search's guarantee


@given(st.integers(0, 2**31 - 1), st.integers(8, 30))
@settings(max_examples=4, deadline=None)
def test_search_respects_error_budget(seed, target_centi):
    """The acceptance property: on randomized geometry/content/target, the
    tuned plan's measured error fits its certificate, the certificate fits
    the target, and the plan beats the uniform-8 datapath on cycles
    (or matches it when nothing was droppable)."""
    rng = np.random.default_rng(seed)
    depth = int(rng.integers(1, 3))
    base = int(rng.integers(2, 5))
    in_ch = int(rng.integers(1, 3))
    target = target_centi / 100.0
    cfg = unet.UNetConfig(
        hw=16, in_ch=in_ch, base=base, depth=depth, convs_per_stage=1,
        n_classes=2, quant_mode="mma_int8", impl="xla",
    )
    params = unet.init_params(jax.random.PRNGKey(seed % 1000), cfg)
    h, w = int(rng.integers(16, 40)), int(rng.integers(16, 40))
    img = rng.normal(0.0, 0.01, (h, w, in_ch)).astype(np.float32)
    img[: h // 2, : w // 2] += rng.normal(
        0.0, 1.0, (h // 2, w // 2, in_ch)
    ).astype(np.float32)
    plan = tune_unet(
        params, cfg, [img], target_rel_err=target, sound_bound=False
    )
    cert = plan.certificate
    assert cert["measured_rel_err"] <= cert["cert"] <= target
    assert cert["holds"]
    assert all(1 <= b <= 8 for b in plan.planes)
    assert plan.tile >= cfg.min_viable_tile()
    for cp in plan.class_planes:
        assert all(1 <= b <= 8 for b in cp)
        assert all(r <= b for r, b in zip(cp, (8,) * len(cp)))
    # cycles never exceed the uniform-8 account at the same geometry
    assert plan.modeled["cycles_calib"] <= plan.modeled["full8_cycles_calib"]
    # the served path reproduces the certified measurement exactly
    eng = autotune.engine_from_plan(cfg, params, plan)
    ref = autotune.engine_from_plan(cfg, params, autotune.reference_plan(plan))
    got = eng.run([img])[0].logits
    want = ref.run([img])[0].logits
    denom = max(float(np.max(np.abs(want))), 1e-8)
    measured = float(np.max(np.abs(got - want))) / denom
    assert measured <= cert["cert"] + 1e-12


def test_sound_bound_covers_measurement():
    """The per-tile interval extension is sound: it upper-bounds the
    measured error of the exact per-tile-quantized serving path."""
    cfg, params, plan = _tuned()
    assert plan.certificate["sound_bound"] >= plan.certificate["measured_rel_err"]


# --------------------------------------------------------- plan round trip


def test_tuned_plan_json_round_trip(tmp_path):
    cfg, params, plan = _tuned()
    assert TunedPlan.from_json(plan.to_json()) == plan
    path = tmp_path / "plans" / "unet.json"
    plan.save(path)
    assert TunedPlan.load(path) == plan
    # a newer plan version must not be silently misread
    newer = dict(plan.to_json(), version=plan.version + 1)
    with pytest.raises(ValueError, match="newer"):
        TunedPlan.from_json(newer)


def test_plan_validation():
    cfg, params, plan = _tuned()
    with pytest.raises(ValueError):
        dataclasses.replace(plan, planes=(0,) * len(plan.planes))
    with pytest.raises(ValueError):
        dataclasses.replace(plan, workload="vae")
    with pytest.raises(ValueError):  # thresholds must start at 1.0
        dataclasses.replace(
            plan, class_thresholds=(0.5,) + plan.class_thresholds[1:]
        )
    with pytest.raises(ValueError, match="minimum viable tile"):
        dataclasses.replace(plan, tile=2 * plan.halo)


# ------------------------------------------------------ tile geometry guard


def test_unet_config_tile_validation():
    """The satellite guard: tiles the halo walk proves degenerate are
    rejected with the minimum viable tile named."""
    cfg = unet.UNetConfig(depth=3, convs_per_stage=1)
    assert cfg.min_viable_tile() == 56  # halo 24 at depth 3
    assert cfg.validate_tile(56) == 56
    with pytest.raises(ValueError, match="minimum viable tile for this "
                                         "geometry is 56"):
        cfg.validate_tile(48)
    with pytest.raises(ValueError, match="multiple of 2\\*\\*depth"):
        cfg.validate_tile(30)
    # an explicitly smaller halo relaxes the guard; halo=0 disables it
    assert cfg.validate_tile(32, halo=8) == 32
    assert cfg.validate_tile(8, halo=0) == 8
    with pytest.raises(ValueError):
        cfg.validate_tile(16, halo=8)
    # depth-1 geometry: halo 6 -> minimum viable 14
    assert unet.UNetConfig(depth=1, convs_per_stage=1).min_viable_tile() == 14


def test_engine_rejects_degenerate_plan_tile():
    cfg, params, plan = _tuned()
    bad = plan.to_json()
    bad["tile"] = 2 * plan.halo  # resurrect an invalid tile
    with pytest.raises(ValueError, match="minimum viable tile"):
        TunedPlan.from_json(bad)


# ------------------------------------- tiled-vs-whole under the tuned tile


def _whole_ref(params, image, cfg):
    mult = 2**cfg.depth
    h, w = image.shape[:2]
    pad = np.pad(image, ((0, -h % mult), (0, -w % mult), (0, 0)))
    out = unet.forward(params, jnp.asarray(pad[None]), cfg)
    return np.asarray(out[0])[:h, :w]


def test_tiled_matches_whole_under_tuned_tile():
    """Equivalence holds at the tuned (non-32) tile: the float datapath
    through a plan-driven engine equals the whole-image forward."""
    cfg, params, plan = _tuned()
    assert plan.tile != 32  # the tuner picked its own geometry
    fcfg = dataclasses.replace(cfg, quant_mode="none")
    image = np.asarray(
        jax.random.normal(jax.random.PRNGKey(7), (37, 29, cfg.in_ch))
    )
    eng = SegEngine(fcfg, params, plan=plan)
    assert eng.tile == plan.tile and eng.halo == plan.halo
    res = eng.run([image])[0]
    np.testing.assert_allclose(
        res.logits, _whole_ref(params, image, fcfg), rtol=1e-4, atol=1e-4
    )
    # and an explicit non-32 tile through the classic engine, for contrast
    res24 = SegEngine(fcfg, params, tile=24).run([image])[0]
    np.testing.assert_allclose(
        res24.logits, _whole_ref(params, image, fcfg), rtol=1e-4, atol=1e-4
    )


# ----------------------------------------------- calibrated budget classes


def test_budget_class_from_thresholds():
    th = (1.0, 0.25, 0.015625)
    assert budget_class_from_thresholds(1.0, th) == 0
    assert budget_class_from_thresholds(0.3, th) == 0
    assert budget_class_from_thresholds(0.25, th) == 1
    assert budget_class_from_thresholds(0.02, th) == 1
    assert budget_class_from_thresholds(0.01, th) == 2
    assert budget_class_from_thresholds(0.0, th) == 2
    with pytest.raises(ValueError):
        budget_class_from_thresholds(1.5, th)
    with pytest.raises(ValueError):
        budget_class_from_thresholds(0.5, (0.9, 0.1))
    # monotone: quieter never gets a louder class
    ks = [budget_class_from_thresholds(r, th)
          for r in (1.0, 0.5, 0.25, 0.1, 0.01, 0.0)]
    assert ks == sorted(ks)


def test_plan_class_refinement_stays_inside_certificate():
    """Every calibrated class schedule refines the base schedule under the
    sound per-layer inequality at the class's recorded ratio bound."""
    cfg, params, plan = _tuned()
    for c, cp in enumerate(plan.class_planes):
        for b_base, b_ref in zip(plan.planes, cp):
            assert 1 <= b_ref <= b_base or b_ref == b_base == 8
            assert b_ref <= b_base


# ------------------------------------------------------------------ LM path


def test_tune_lm_certifies_and_installs():
    from repro.configs import get_smoke_config
    from repro.serve.engine import lm_schedule_from_plan

    cfg = get_smoke_config("yi_6b")
    from repro import models

    mod = models.build(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    toks = np.random.default_rng(0).integers(0, cfg.vocab, (2, 8))
    plan = autotune.tune_lm(params, cfg, toks, target_rel_err=0.5)
    assert plan.workload == "lm"
    assert len(plan.planes) == cfg.n_layers
    cert = plan.certificate
    assert cert["measured_rel_err"] <= cert["cert"] <= 0.5
    sched = lm_schedule_from_plan(plan, cfg)
    assert sched.planes == plan.planes
    qcfg = autotune.apply_plan_lm(cfg, plan)
    assert qcfg.quant.plane_schedule == plan.planes
    out = mod.forward(params, jnp.asarray(toks, jnp.int32), qcfg)
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
    with pytest.raises(ValueError):
        lm_schedule_from_plan(plan, cfg.replace(n_layers=cfg.n_layers + 1))
    with pytest.raises(ValueError):
        autotune.apply_plan(unet.UNetConfig(), plan)


# ------------------------------------------------------------ bench smoke


def test_autotune_bench_smoke(tmp_path):
    """The registered frontier bench emits the tracker datapoint, the
    certificates hold, and the tuned plan dominates the served
    from_weights baseline."""
    import json

    from benchmarks import autotune as bench

    path = tmp_path / "BENCH_autotune.json"
    rows = bench.run(
        base=4, image_hw=(80, 64), targets=(0.1, 0.05), headline=0.05,
        n_calib=1, json_path=str(path),
    )
    assert any(name.startswith("autotune/tuned-") for name, _, _ in rows)
    data = json.loads(path.read_text())
    assert data["dominance"]["holds"]
    assert data["dominance"]["speedup"] > 1.0
    kinds = {r["kind"] for r in data["rows"]}
    assert kinds == {"frontier", "from_weights", "tuned"}
    for r in data["rows"]:
        if r["kind"] == "tuned":
            assert r["rel_err"] <= r["cert"] <= r["target_rel_err"]
        assert "cycles" in r and "gops_w" in r and "rel_err" in r


# -------------------------------------------------- amortized repair loop


def test_repair_sequence_matches_one_at_a_time_rule():
    """The precomputed sequence replays exactly the old loop's choice:
    the fixable layer with the largest sensitivity contribution, updated
    after every re-add."""
    from repro.autotune.search import repair_sequence

    sens = (
        (0.5, 0.3, 0.1, 0.05, 0.02, 0.01, 0.005, 0.0),
        (0.9, 0.2, 0.15, 0.08, 0.04, 0.02, 0.01, 0.0),
        (0.1, 0.05, 0.02, 0.01, 0.005, 0.002, 0.001, 0.0),
    )
    planes = [2, 2, 7]
    seq = repair_sequence(planes, sens, cap=100)
    # replay the old rule step by step
    p = list(planes)
    for step, l_got in enumerate(seq):
        l_old = max(
            (l for l in range(len(p)) if p[l] < 8),
            key=lambda l: sens[l][p[l] - 1],
        )
        assert l_got == l_old, f"step {step}"
        p[l_old] += 1
    assert all(b == 8 for b in p)  # cap 100 > total headroom: runs dry
    assert repair_sequence([8, 8, 8], sens, cap=100) == []
    assert len(repair_sequence(planes, sens, cap=3)) == 3


@given(st.integers(1, 40))
@settings(max_examples=25, deadline=None)
def test_bisect_repair_equal_or_fewer_measurements(seq_len):
    """The satellite guarantee, stated over the whole repair landscape:
    for every monotone repair depth the amortized driver finds the same
    minimal depth the one-at-a-time loop found, within a logarithmic
    measurement bound, *and summed over all depths it spends equal-or-
    fewer calibration forwards than the linear loop* (each measurement is
    a full engine replay of the calibration set — the dominant tune cost).
    Shallow repairs (depth <= 2, the common case) pay exactly the linear
    price, so no workload regresses in aggregate."""
    import math

    from repro.autotune.search import bisect_repair

    def run(tstar):
        calls = 0

        def measure(t):
            nonlocal calls
            calls += 1
            return 1.0 if t < tstar else 0.01

        t, measured, reported = bisect_repair(measure, seq_len, budget=0.05)
        assert t == tstar and measured == 0.01
        assert reported == calls
        return calls

    total_bisect = total_linear = 0
    for tstar in range(seq_len + 1):
        calls = run(tstar)
        linear_calls = tstar + 1  # the old loop: one serve per re-add
        if tstar <= 2:
            assert calls == linear_calls  # shallow: exactly the old price
        assert calls <= linear_calls + 1  # never more than one extra replay
        # and always within the logarithmic amortization bound
        assert calls <= 2 * math.ceil(math.log2(max(tstar, 1) + 1)) + 2
        total_bisect += calls
        total_linear += linear_calls
    # summed over the landscape: at worst one extra replay (a +-1 at the
    # first gallop boundary), strictly fewer once repairs can run deep
    assert total_bisect <= total_linear + 1
    if seq_len >= 8:
        assert total_bisect < total_linear


def test_bisect_repair_exhausted_sequence_serves_best_point():
    """When even full repair misses the budget (the old cap/dry break),
    the driver returns the full depth so the certificate records the miss
    from the actually-served vector."""
    from repro.autotune.search import bisect_repair

    t, measured, calls = bisect_repair(lambda t: 0.5, 5, budget=0.01)
    assert t == 5 and measured == 0.5
    assert calls <= 6


def test_tune_unet_certificate_records_amortized_repair():
    """End to end: the certify loop reports its repair depth and its
    measurement count, and the measurement count never exceeds what the
    one-at-a-time loop would have spent (repairs + 1 engine replays)."""
    cfg, params, plan = _tuned()
    cert = plan.certificate
    assert "repairs" in cert and "measure_calls" in cert
    # the documented bound: at most one replay over the linear loop's
    # repairs+1 (and exactly equal for repair depths <= 2)
    assert cert["measure_calls"] <= cert["repairs"] + 2
    if cert["repairs"] <= 2:
        assert cert["measure_calls"] == cert["repairs"] + 1
    assert cert["measured_rel_err"] <= cert["cert"] <= plan.target_rel_err
    # and the plan carries the weights-only binding the gateway verifies
    from repro.autotune.calibrate import params_fingerprint

    assert plan.params_fingerprint == params_fingerprint(params)
    from repro.autotune.plan import PLAN_VERSION
    assert plan.version == PLAN_VERSION
