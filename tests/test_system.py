"""End-to-end system behaviour: serving engine, quantized-serving params,
int8 KV cache, sharding-rule fallbacks, U-Net paper pipeline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import QuantConfig
from repro.models import build

pytestmark = pytest.mark.slow  # CI runs these in the non-blocking slow job


def test_serve_engine_continuous_batching():
    from repro.serve.engine import Engine, Request

    cfg = get_smoke_config("yi_6b")
    mod = build(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, batch=3, max_seq=48)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=4), max_new=5)
            for i in range(5)]  # more requests than slots -> queueing
    done = eng.run(reqs)
    assert len(done) == 5
    assert all(len(r.out) == 5 for r in done)


def test_serve_engine_ssm_and_encdec():
    """The same engine loop drives O(1)-state (rwkv) and enc-dec (whisper)
    families."""
    from repro.serve.engine import Engine, Request

    rng = np.random.default_rng(0)
    # rwkv6: recurrent state instead of a KV cache
    cfg = get_smoke_config("rwkv6_3b")
    mod = build(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, batch=2, max_seq=32)
    done = eng.run([Request(rid=0, prompt=rng.integers(0, cfg.vocab, 3), max_new=4)])
    assert len(done) == 1 and len(done[0].out) == 4

    # whisper: encoder memory provided at engine construction
    cfg = get_smoke_config("whisper_large_v3")
    mod = build(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg, max_dec_pos=32)
    frames = jnp.asarray(rng.standard_normal((2, cfg.enc_seq, cfg.d_model)),
                         jnp.bfloat16)
    memory = mod.encode(params, frames, cfg)
    eng = Engine(cfg, params, batch=2, max_seq=32, extras={"memory": memory})
    done = eng.run([Request(rid=0, prompt=rng.integers(0, cfg.vocab, 3), max_new=4)])
    assert len(done) == 1 and len(done[0].out) == 4


def test_whisper_cross_kv_cache_equivalence():
    """Decoding with precomputed cross-attention K/V must match the
    recompute-every-token path exactly."""
    from repro.models import whisper

    cfg = get_smoke_config("whisper_large_v3")
    params = whisper.init_params(jax.random.PRNGKey(0), cfg, max_dec_pos=32)
    rng = np.random.default_rng(0)
    frames = jnp.asarray(rng.standard_normal((2, cfg.enc_seq, cfg.d_model)),
                         jnp.bfloat16)
    memory = whisper.encode(params, frames, cfg)
    xkv = whisper.precompute_cross_kv(params, memory, cfg)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 4)), jnp.int32)

    def run(cross_kv):
        cache = whisper.init_cache(cfg, 2, 16)
        outs = []
        for i in range(4):
            lg, cache = whisper.decode_step(
                params, tokens[:, i:i+1], cache, i, cfg, memory=memory,
                cross_kv=cross_kv,
            )
            outs.append(lg[:, 0])
        return jnp.stack(outs, 1).astype(jnp.float32)

    a, b = run(None), run(xkv)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-2, rtol=1e-2)


def test_quantize_params_int8_transform():
    from repro.core.quant import quantize_params_int8

    cfg = get_smoke_config("yi_6b").replace(d_model=256, d_ff=512, n_heads=4,
                                            n_kv_heads=2, head_dim=64)
    mod = build(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    qp = quantize_params_int8(params, min_dim=256)
    # big linears quantized, embeddings/norms untouched
    assert "w_q" in qp["blocks"]["mlp"]["w_up"]
    assert qp["blocks"]["mlp"]["w_up"]["w_q"].dtype == jnp.int8
    assert "w" in qp["embed"] or "table" in qp["embed"]
    # dequantized weight close to original
    w = params["blocks"]["mlp"]["w_up"]["w"].astype(jnp.float32)
    deq = (qp["blocks"]["mlp"]["w_up"]["w_q"].astype(jnp.float32)
           * qp["blocks"]["mlp"]["w_up"]["w_scale"])
    assert float(jnp.max(jnp.abs(w - deq))) <= float(jnp.max(jnp.abs(w))) / 127 + 1e-6


def test_quantized_serving_forward():
    """Forward through pre-quantized int8 weights ~ float forward."""
    from repro.core.quant import quantize_params_int8

    cfg = get_smoke_config("yi_6b").replace(d_model=256, d_ff=512, n_heads=4,
                                            n_kv_heads=2, head_dim=64, vocab=512)
    mod = build(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    f = mod.forward(params, tokens, cfg).astype(jnp.float32)
    qp = quantize_params_int8(params, min_dim=256)
    q = mod.forward(qp, tokens, cfg).astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(f - q)) / (jnp.max(jnp.abs(f)) + 1e-6))
    assert rel < 0.35, rel
    agree = float((jnp.argmax(f, -1) == jnp.argmax(q, -1)).mean())
    assert agree > 0.9, agree


def test_int8_kv_cache_decode():
    """Decode through an int8 KV cache tracks the bf16-cache decode."""
    cfg = get_smoke_config("yi_6b")
    mod = build(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)

    def run(dtype):
        cache = mod.init_cache(cfg, 2, 16, dtype=dtype)
        outs = []
        for i in range(8):
            lg, cache = mod.decode_step(params, tokens[:, i:i+1], cache, i, cfg)
            outs.append(lg[:, 0])
        return jnp.stack(outs, 1).astype(jnp.float32)

    a = run(jnp.bfloat16)
    b = run(jnp.int8)
    agree = float((jnp.argmax(a, -1) == jnp.argmax(b, -1)).mean())
    assert agree > 0.85, agree


def test_spec_prefix_fallback():
    """Non-divisible dims fall back to the longest dividing axis prefix."""
    import subprocess, sys

    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from jax.sharding import PartitionSpec as P
from repro.parallel import sharding as shd

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
with shd.use_mesh(mesh, shd.EP_DP_RULES):
    # batch 8 divides pod*data*model=8 -> all three
    assert shd.spec_for(("batch",), (8,)) == P(("pod", "data", "model"))
    # batch 4 falls back to ('pod','data')
    assert shd.spec_for(("batch",), (4,)) == P(("pod", "data"))
    # batch 2 falls back to ('pod',)
    assert shd.spec_for(("batch",), (2,)) == P("pod")
    # batch 3 -> replicated
    assert shd.spec_for(("batch",), (3,)) == P(None)
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300, cwd="/root/repo",
                       env={"PYTHONPATH": "src", "HOME": "/root",
                            "PATH": "/usr/bin:/bin:/usr/local/bin",
                            # forced-host-device test: skip TPU probing,
                            # which can hang for minutes in a stripped env
                            "JAX_PLATFORMS": "cpu"})
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_unet_paper_pipeline():
    """Train-quantize-deploy: int8 MMA inference matches float within quant
    error on the paper's application."""
    from repro.models import unet

    cfg = unet.UNetConfig(hw=16, in_ch=2, base=8, depth=2, n_classes=3)
    params = unet.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 2))
    f = unet.forward(params, x, cfg)
    for impl in ("xla", "cascade", "int8"):
        qcfg = dataclasses.replace(cfg, quant_mode="mma_int8", impl=impl)
        q = unet.forward(params, x, qcfg)
        rel = float(jnp.max(jnp.abs(f - q)) / (jnp.max(jnp.abs(f)) + 1e-6))
        assert rel < 0.2, (impl, rel)


def test_cycle_model_cross_check_simulator():
    """Relation (2)'s inner term vs the cycle-exact simulator: the analytical
    latency (delta + p_out) matches the measured MMA unit cycles."""
    import numpy as np

    from repro.core import cycle_model as cm
    from repro.core.msdf import DELTA_MMA, MMAUnit

    w = np.arange(-16, 16, dtype=np.int64)
    unit = MMAUnit(w, t_n=32)
    _, cycles = unit.run(np.arange(32, dtype=np.uint8))
    assert cycles == DELTA_MMA + cm.p_out()
    assert cm.mma_tile_cycles() == cycles + 5  # + ceil(log2 T_N) tree fill
