"""Core library: bit-plane decomposition, quantization, early termination,
the FPGA cycle model vs the paper's Table 1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import bitplane, early_term, quant
from repro.core import cycle_model as cm


# --------------------------------------------------------------- bit planes


@given(st.lists(st.integers(-128, 127), min_size=1, max_size=64))
@settings(max_examples=100, deadline=None)
def test_decompose_recombine_roundtrip(vals):
    x = jnp.asarray(vals, jnp.int8)
    planes = bitplane.decompose(x)
    assert planes.shape == (8, len(vals))
    assert set(np.unique(np.asarray(planes))) <= {0, 1}
    back = bitplane.recombine(planes)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x, np.int32))


def test_bitplane_matmul_exact():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-128, 128, (13, 57)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (57, 11)), jnp.int8)
    want = x.astype(jnp.int32) @ w.astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(bitplane.bitplane_matmul(x, w)), np.asarray(want))
    np.testing.assert_array_equal(
        np.asarray(bitplane.bitplane_matmul_cascade(x, w)), np.asarray(want)
    )


@given(st.integers(1, 8))
@settings(max_examples=8, deadline=None)
def test_truncation_error_within_bound(planes):
    rng = np.random.default_rng(planes)
    x = jnp.asarray(rng.integers(-128, 128, (8, 96)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (96, 8)), jnp.int8)
    exact = x.astype(jnp.int32) @ w.astype(jnp.int32)
    approx = bitplane.bitplane_matmul(x, w, planes=planes, correction="midpoint")
    bound = early_term.truncation_bound(w, planes, midpoint=True)
    err = jnp.abs(exact - approx)
    assert bool(jnp.all(err <= bound[None, :] + 1))


def test_progressive_precision_monotone():
    """MSDF property: error (worst-case bound) shrinks as planes increase."""
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.integers(-128, 128, (128, 16)), jnp.int8)
    bounds = [float(jnp.max(early_term.truncation_bound(w, b))) for b in range(1, 9)]
    assert all(b1 >= b2 for b1, b2 in zip(bounds, bounds[1:]))
    assert bounds[-1] == 0.0


def test_choose_planes():
    rng = np.random.default_rng(4)
    w = jnp.asarray(rng.integers(-128, 128, (256, 32)), jnp.int8)
    assert early_term.choose_planes(w, 1.0) == 1
    assert early_term.choose_planes(w, 0.0) == 8
    b = early_term.choose_planes(w, 0.01)
    assert 1 <= b <= 8


# ------------------------------------------------------------ quantization


def test_quant_roundtrip_accuracy():
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    q = quant.quantize_weights(w)
    err = jnp.max(jnp.abs(quant.dequantize(q) - w))
    assert float(err) <= float(jnp.max(jnp.abs(w))) / 127.0 + 1e-6


def test_fake_quant_gradient_passthrough():
    w = jnp.linspace(-1, 1, 32)
    g = jax.grad(lambda x: jnp.sum(quant.fake_quant(x) ** 2))(w)
    # STE: gradient equals that of the quantized value wrt itself (2*q)
    assert g.shape == w.shape
    assert bool(jnp.all(jnp.isfinite(g)))


# ------------------------------------------------------------- cycle model


def test_relation2_constants():
    assert cm.p_out() == 21  # 2*8 + log2(32)
    assert cm.mma_tile_cycles() == 28  # 2 + 21 + 5
    assert cm.cascaded_tile_cycles() == 34  # 3 + 2*5 + 21
    # the merged unit's claim: strictly fewer cycles than cascaded
    assert cm.mma_tile_cycles() < cm.cascaded_tile_cycles()


def test_relation3_conv_count():
    l = cm.ConvLayerSpec(h=16, w=16, cin=64, cout=32)
    assert l.out_h == 16 and l.out_w == 16
    assert l.n_conv() == 16 * 16 * 32  # T_M = 1


def test_calibrated_unet_matches_table1():
    layers = cm.unet_conv_layers(**cm.CALIBRATED_UNET)
    tile = cm.pipelined_tile_cycles()
    cyc = cm.model_cycles(layers, tile_cycles=tile)
    t_ms = cyc / cm.FREQ_HZ * 1e3
    gops = cm.model_ops(layers) / (t_ms * 1e-3) / 1e9
    assert abs(t_ms - 53.25) / 53.25 < 0.02, t_ms
    assert abs(gops - 52.95) / 52.95 < 0.02, gops


def test_proposed_row_energy_consistency():
    layers = cm.unet_conv_layers(**cm.CALIBRATED_UNET)
    row = cm.proposed_row(layers)
    # energy = power * time must hold by construction
    assert abs(row.energy_mj - row.power_w * row.time_ms) < 1e-6


def test_paper_table1_internal_consistency():
    """energy ~= (GOPS/(GOPS/W)) * time holds for 5 of 6 printed rows.

    Reproduction finding (EXPERIMENTS.md §Table1): the paper's MSDF row is
    internally inconsistent — 21.05/3.01 = 6.99 W gives 936.7 mJ, the table
    prints 1644.77 mJ (implying 12.28 W).  We assert the consistency of the
    other rows and pin the known discrepancy so a silent change is caught.
    """
    for name, r in cm.PAPER_TABLE1.items():
        power = r["gops"] / r["gops_w"]
        energy = power * r["time_ms"]
        if name == "msdf":
            assert energy / r["e_mj"] == pytest.approx(0.569, abs=0.01)
        else:
            assert abs(energy - r["e_mj"]) / r["e_mj"] < 0.02, (name, energy)


def test_merged_vs_cascaded_speedup():
    layers = cm.unet_conv_layers(**cm.CALIBRATED_UNET)
    merged = cm.model_cycles(layers)
    casc = cm.model_cycles(layers, tile_cycles=cm.cascaded_tile_cycles())
    assert casc / merged == pytest.approx(34 / 28, rel=1e-6)
