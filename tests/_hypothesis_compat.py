"""Hypothesis with a fallback: the real library when installed, else a
minimal deterministic shim implementing exactly the subset this suite uses
(``st.integers``, ``st.lists``, ``st.sampled_from``, ``st.data``;
``@given``; ``@settings``).

The shim draws a fixed number of pseudo-random examples per test (seeded by
the test name, so runs are reproducible) instead of hypothesis' adaptive
search + shrinking.  Weaker at finding new counterexamples, but it keeps the
properties *executing* on machines without the dev extra — tier-1 must
collect and run everywhere.  Install the real thing with

    pip install -r requirements-dev.txt
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    _MAX_EXAMPLES_CAP = 25  # the shim has no shrinker; keep runs quick

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rnd: random.Random):
            return self._draw_fn(rnd)

    class _DataObject:
        """Shim of hypothesis' interactive ``data()`` draw handle."""

        def __init__(self, rnd: random.Random):
            self._rnd = rnd

        def draw(self, strategy: _Strategy):
            return strategy.draw(self._rnd)

    class st:  # noqa: N801 - mirrors `strategies as st`
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

        @staticmethod
        def lists(elements: _Strategy, *, min_size: int = 0, max_size: int = 10) -> _Strategy:
            def draw(rnd):
                n = rnd.randint(min_size, max_size)
                return [elements.draw(rnd) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def sampled_from(options) -> _Strategy:
            seq = list(options)
            return _Strategy(lambda rnd: seq[rnd.randrange(len(seq))])

        @staticmethod
        def data() -> _Strategy:
            return _Strategy(lambda rnd: _DataObject(rnd))

    def settings(*, max_examples: int = 20, deadline=None, **_kw):
        def deco(fn):
            fn._compat_max_examples = min(max_examples, _MAX_EXAMPLES_CAP)
            return fn

        return deco

    def given(*strategies: _Strategy, **kw_strategies: _Strategy):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                n = getattr(fn, "_compat_max_examples", 20)
                for i in range(n):
                    rnd = random.Random(f"{fn.__module__}.{fn.__qualname__}:{i}")
                    args = [s.draw(rnd) for s in strategies]
                    kwargs = {k: s.draw(rnd) for k, s in kw_strategies.items()}
                    fn(*args, **kwargs)

            # strategy-filled params must not look like pytest fixtures
            wrapper.__signature__ = inspect.Signature()
            del wrapper.__wrapped__
            return wrapper

        return deco
