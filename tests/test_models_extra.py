"""Deeper model-layer coverage: flash attention vs naive reference (causal,
SWA, GQA, cache offsets), MoE routing invariants, load-balance loss, rope."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import layers


def naive_attention(q, k, v, *, causal=True, window=0, q_offset=0):
    b, s, h, d = q.shape
    _, t, kv, _ = k.shape
    g = h // kv
    qf = q.astype(jnp.float32).reshape(b, s, kv, g, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qf, kf) / np.sqrt(d)
    q_pos = jnp.arange(s) + q_offset
    k_pos = jnp.arange(t)
    ok = jnp.ones((s, t), bool)
    if causal:
        ok = k_pos[None, :] <= q_pos[:, None]
    if window:
        ok = ok & (k_pos[None, :] > q_pos[:, None] - window)
    scores = jnp.where(ok[None, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, vf)
    return out.reshape(b, s, h, d)


@pytest.mark.parametrize("s,t,h,kv,window,chunk", [
    (16, 16, 4, 4, 0, 8),     # MHA causal, chunked
    (16, 16, 8, 2, 0, 8),     # GQA
    (16, 16, 4, 1, 0, 16),    # MQA
    (32, 32, 4, 2, 12, 8),    # sliding window
    (8, 8, 4, 4, 0, 64),      # single chunk (chunk > t)
    (1, 24, 4, 2, 0, 8),      # decode-style short query (direct path)
])
def test_flash_vs_naive(s, t, h, kv, window, chunk):
    rng = np.random.default_rng(0)
    d = 16
    q = jnp.asarray(rng.standard_normal((2, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, t, kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, t, kv, d)), jnp.float32)
    off = t - s  # align causal diag to the end of the key range
    got = layers.flash_attention(q, k, v, causal=True, window=window,
                                 chunk=chunk, q_offset=off)
    want = naive_attention(q, k, v, causal=True, window=window, q_offset=off)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_non_causal():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 12, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 20, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 20, 2, 8)), jnp.float32)
    got = layers.flash_attention(q, k, v, causal=False, chunk=8)
    want = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_rope_relative_property():
    """RoPE: <q_i, k_j> depends only on i - j (shift invariance)."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)

    def dot_at(i, j):
        qi = layers.rope(x, jnp.array([[i]]), 10_000.0)
        kj = layers.rope(y, jnp.array([[j]]), 10_000.0)
        return float(jnp.sum(qi * kj))

    assert dot_at(5, 3) == pytest.approx(dot_at(105, 103), abs=1e-4)
    assert dot_at(7, 7) == pytest.approx(dot_at(0, 0), abs=1e-4)


def test_moe_router_weights_sum_to_one_and_capacity():
    from repro.configs import get_smoke_config
    from repro.models import moe as moe_lib

    cfg = get_smoke_config("olmoe_1b_7b")
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16, cfg.d_model)),
                    jnp.bfloat16)
    y = moe_lib.moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))
    # dropless => output must equal the capacity-free dense-equivalent
    import dataclasses as dc

    big = cfg.replace(moe=dc.replace(cfg.moe, capacity_factor=64.0))
    y2 = moe_lib.moe_ffn(p, x, big)
    # zero-init load means drops only shave tokens; dropless reference finite
    assert bool(jnp.all(jnp.isfinite(y2.astype(jnp.float32))))


def test_load_balance_loss_range():
    from repro.configs import get_smoke_config
    from repro.models import moe as moe_lib

    cfg = get_smoke_config("olmoe_1b_7b")
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((2, 64, cfg.d_model)),
                    jnp.bfloat16)
    lb = float(moe_lib.load_balance_loss(p, x, cfg))
    # Switch aux loss: 1.0 at perfect balance, E at total collapse
    assert 0.9 <= lb <= cfg.moe.n_experts, lb


def test_kv_cache_scale_saturation():
    """Int8 cache write must not saturate for typical post-norm magnitudes."""
    rng = np.random.default_rng(3)
    k = rng.standard_normal(10_000) * 1.0  # ~N(0,1) typical of rmsnorm nets
    q = np.clip(np.round(k / layers.KV_CACHE_SCALE), -127, 127)
    saturated = np.mean(np.abs(q) >= 127)
    assert saturated < 0.01, saturated