"""Workload subsystem: seeded counter-PRNG arrival processes, the
versioned trace schema, open-loop replay through the gateway's mid-round
admission path, preemptive chunked execution properties (no quantum
overdraft, work totals identical to the atomic path), QoS classes
decoupled from engine kind, and plan hot-reload at a round boundary."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from test_gateway import FakeAdapter

from repro.serve.gateway import Gateway, StalePlanError
from repro.workload import Trace, TraceRequest, arrivals, from_streams
from repro.workload import replay as replay_mod


# ------------------------------------------------------------- arrivals


def test_deterministic_process():
    assert arrivals.deterministic(3, interval=100, start=50) == [50, 150, 250]
    with pytest.raises(ValueError):
        arrivals.deterministic(3, interval=0)


def test_poisson_is_pure_monotone_and_prefix_stable():
    a = arrivals.poisson(50, mean_interval=1_000, seed=7)
    assert a == arrivals.poisson(50, mean_interval=1_000, seed=7)
    assert a == sorted(a) and len(a) == 50
    # arrival i is a pure function of (seed, i): extending n never
    # reshuffles the prefix
    assert arrivals.poisson(10, mean_interval=1_000, seed=7) == a[:10]
    # a different seed decorrelates
    assert a != arrivals.poisson(50, mean_interval=1_000, seed=8)


def test_poisson_mean_interval_calibrated():
    a = arrivals.poisson(4_000, mean_interval=500, seed=1)
    gaps = np.diff([0] + a)
    assert abs(gaps.mean() - 500) / 500 < 0.1


def test_on_off_pure_monotone_and_prefix_stable():
    kw = dict(seed=3, burst_interval=100, on_mean=500, off_mean=2_000)
    b = arrivals.on_off(40, **kw)
    assert b == arrivals.on_off(40, **kw)
    assert b == sorted(b) and len(b) == 40
    assert arrivals.on_off(12, **kw) == b[:12]
    # bursty: the gap distribution is bimodal — some gaps far exceed the
    # in-burst interval (OFF dwells), most sit near it
    gaps = np.diff(b)
    assert gaps.max() > 5 * 100
    assert np.median(gaps) < 3 * 100


def test_counter_uniform_pure_and_in_range():
    us = [arrivals.counter_uniform(5, i) for i in range(1_000)]
    assert all(0.0 <= u < 1.0 for u in us)
    assert len(set(us)) == len(us)  # no collisions at this scale
    assert arrivals.counter_uniform(5, 17) == arrivals.counter_uniform(5, 17)
    assert arrivals.counter_uniform(5, 17) != arrivals.counter_uniform(6, 17)


def test_generate_dispatch():
    assert arrivals.generate("deterministic", 2, interval=10) == [0, 10]
    with pytest.raises(ValueError, match="unknown arrival process"):
        arrivals.generate("lognormal", 2)


# ----------------------------------------------------------------- trace


def _mini_trace(seed=5):
    return from_streams(
        "mini", seed,
        [
            dict(kind="a", qos="gold", arrivals=[100, 2_300],
                 payload=dict(cost=1_000)),
            dict(kind="a", qos="a", arrivals=[700],
                 payload=dict(cost=2_000), deadline_cycles=50_000),
        ],
    )


def test_trace_round_trip_and_props(tmp_path):
    tr = _mini_trace()
    assert len(tr) == 3
    assert tr.qos_classes == ["gold", "a"]  # first-arrival order
    assert tr.kinds == ["a"]
    assert tr.span_cycles == 2_300
    # requests sorted by arrival regardless of builder order
    assert [r.arrival_cycle for r in tr.requests] == [100, 700, 2_300]
    path = tmp_path / "mini.json"
    tr.save(path)
    tr2 = Trace.load(path)
    assert tr2 == tr
    assert tr2.requests[1].deadline_cycles == 50_000


def test_trace_version_guard(tmp_path):
    tr = _mini_trace()
    d = tr.to_json()
    d["version"] = d["version"] + 1
    with pytest.raises(ValueError, match="newer than this code"):
        Trace.from_json(d)
    d["version"] = 1
    d["schema"] = "something.else"
    with pytest.raises(ValueError, match="not a workload trace"):
        Trace.from_json(d)


def test_captured_trace_marker_and_version_guard_round_trip(tmp_path):
    """A trace captured from a live run (repro.obs.capture) is a
    first-class schema-v1 citizen: it carries ``source: captured``,
    saves/loads through the same version guard as generated traces, and
    replays to the same completions."""
    from repro.obs.capture import CaptureSink

    tr = _mini_trace()
    cap = CaptureSink()
    gw = _fake_gateway()
    live = replay_mod.replay(gw, tr, {"a": _cost_mat}, capture=cap)

    captured = cap.to_trace("mini-captured", seed=tr.seed)
    assert captured.meta["source"] == "captured"
    assert captured.version == tr.version == 1
    path = tmp_path / "captured.json"
    captured.save(path)
    loaded = Trace.load(path)
    assert loaded == captured
    assert loaded.meta["source"] == "captured"
    # the version guard still bites on a captured trace
    d = loaded.to_json()
    d["version"] += 1
    with pytest.raises(ValueError, match="newer than this code"):
        Trace.from_json(d)
    # and the loaded capture replays to the original per-class outcomes
    rep = replay_mod.replay(_fake_gateway(), loaded, {"a": _cost_mat})
    for qos in ("gold", "a"):
        assert live["per_class"][qos]["p99_ms"] \
            == rep["per_class"][qos]["p99_ms"]
    # generated traces are now marked too — the two sources are
    # distinguishable downstream
    assert tr.meta.get("source") != "captured"


def test_payload_spec_validation():
    with pytest.raises(ValueError, match="missing"):
        TraceRequest(kind="lm", qos="lm", arrival_cycle=0,
                     payload=dict(prompt_len=4))
    with pytest.raises(ValueError, match="< 1"):
        TraceRequest(kind="seg", qos="seg", arrival_cycle=0,
                     payload=dict(h=0, w=32))
    with pytest.raises(ValueError, match="arrival_cycle"):
        TraceRequest(kind="a", qos="a", arrival_cycle=-1, payload={})
    # non-engine kinds pass through unvalidated (synthetic adapters)
    TraceRequest(kind="a", qos="a", arrival_cycle=0, payload=dict(cost=1))


def test_from_streams_callable_payload():
    tr = from_streams(
        "fn", 0,
        [dict(kind="a", arrivals=[10, 20],
              payload=lambda i: dict(cost=100 * (i + 1)))],
    )
    assert [r.payload["cost"] for r in tr.requests] == [100, 200]
    assert tr.requests[0].qos == "a"  # qos defaults to kind


def test_canonical_trace_committed_and_regenerable():
    """The committed canonical trace is exactly what its builder builds
    (idempotent generation — a silently edited trace would poison the
    bench tracker's cross-revision keying)."""
    import importlib.util
    import pathlib

    root = pathlib.Path(__file__).resolve().parent.parent
    spec = importlib.util.spec_from_file_location(
        "make_traces", root / "scripts" / "make_traces.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    committed = Trace.load(root / "traces" / "gateway_burst.json")
    assert committed == mod.gateway_burst()
    assert set(committed.qos_classes) == {"interactive", "batch", "seg"}
    assert set(committed.meta["shares"]) == set(committed.qos_classes)


# ------------------------------------------------------- open-loop replay


def _cost_mat(treq, seed, idx):
    return treq.payload["cost"], {}


def _fake_gateway(policy="fair", **kw):
    kw.setdefault("round_budget", 1_000)
    kw.setdefault("shares", {"a": 0.5, "gold": 0.5})
    return Gateway([FakeAdapter("a", slots=4, unit=200)], policy=policy, **kw)


def test_replay_stamps_arrivals_and_admits_midround():
    gw = _fake_gateway()
    out = replay_mod.replay(gw, _mini_trace(), {"a": _cost_mat})
    assert all(g.done for g in gw.requests)
    # arrival stamped at the trace cycle, not the round boundary
    gold = [g for g in gw.requests if g.qos == "gold"]
    assert [g.arrival for g in gold] == [100, 2_300]
    assert gold[0].admitted_round == 0  # injected inside round 0
    # causality: nothing finishes before it arrives
    assert all(g.finished >= g.arrival for g in gw.requests)
    assert out["per_class"]["gold"]["completed"] == 2
    assert out["trace"]["name"] == "mini"
    assert out["rows"]


def test_replay_rejects_undeclared_class_and_missing_kind():
    gw = Gateway([FakeAdapter("a")], round_budget=1_000,
                 shares={"a": 1.0})
    with pytest.raises(ValueError, match="QoS classes"):
        replay_mod.replay(gw, _mini_trace(), {"a": _cost_mat})
    tr = from_streams("k", 0, [dict(kind="zzz", arrivals=[0],
                                    payload=dict(cost=1))])
    gw = _fake_gateway()
    with pytest.raises(ValueError, match="adapters for kinds"):
        replay_mod.replay(gw, tr, {"a": _cost_mat})


def test_replay_deterministic_per_class_percentiles():
    """The satellite determinism contract: the same seed + trace replays
    to *identical* per-class p50/p99 — modeled time has no noise."""
    tr = from_streams(
        "det", 11,
        [
            dict(kind="a", qos="gold",
                 arrivals=arrivals.poisson(15, mean_interval=700, seed=11),
                 payload=lambda i: dict(cost=300 + 100 * (i % 4))),
            dict(kind="a", qos="a",
                 arrivals=arrivals.on_off(10, seed=12, burst_interval=150,
                                          on_mean=800, off_mean=2_500),
                 payload=dict(cost=1_500)),
        ],
    )

    def once():
        gw = _fake_gateway()
        return replay_mod.replay(gw, tr, {"a": _cost_mat})

    a, b = once(), once()
    for qos in ("gold", "a"):
        assert a["per_class"][qos]["p50_ms"] == b["per_class"][qos]["p50_ms"]
        assert a["per_class"][qos]["p99_ms"] == b["per_class"][qos]["p99_ms"]
    assert a["clock_cycles"] == b["clock_cycles"]


def test_step_round_rejects_out_of_window_arrivals():
    """A future-stamped arrival admitted early could finish before it
    'arrived'; the round rejects anything stamped at/past its end."""
    gw = _fake_gateway()
    with pytest.raises(ValueError, match="outside this round"):
        gw.step_round(arrivals=[(gw.clock + gw.round_budget, "a", 100, {})])


def test_outsized_step_forces_progress_even_while_others_busy():
    """Per-class liveness: a class whose only micro-step exceeds the whole
    round budget must not starve behind a busy neighbor — after the stall
    limit it gets one forced (overdrafting) step, and everything drains."""
    big = FakeAdapter("big", slots=1, unit=5_000)  # indivisible 5k step
    small = FakeAdapter("small", slots=2, unit=200)
    gw = Gateway([big, small], policy="fair", round_budget=1_000)
    r_big = gw.submit("big", 5_000)
    smalls = [gw.submit("small", 2_000) for _ in range(6)]
    gw.drain(max_rounds=60)
    assert r_big.done and all(s.done for s in smalls)
    assert gw.stats()["forced"] >= 1  # the escape fired, and was counted


def test_advance_to_runs_idle_rounds():
    gw = _fake_gateway()
    gw.advance_to(3_500)
    assert gw.clock >= 3_500
    assert gw.rounds == 4


# ----------------------------------------- preemption properties (fair)


@given(
    st.lists(st.integers(100, 4_000), min_size=1, max_size=10),
    st.lists(st.integers(100, 4_000), min_size=1, max_size=10),
    st.integers(600, 4_000),
)
@settings(max_examples=25, deadline=None)
def test_chunked_execution_never_overdrafts_a_class_quantum(
    costs_a, costs_b, budget,
):
    """The acceptance property: under preemptive chunked execution no
    work() call consumes more than the budget it was offered (unless the
    liveness escape forced it — which must not fire when every micro-step
    fits a round), and class quanta never go negative."""
    a = FakeAdapter("a", slots=3, unit=500)
    b = FakeAdapter("b", slots=3, unit=500)
    gw = Gateway([a, b], policy="fair", round_budget=budget)
    for c in costs_a:
        gw.submit("a", c)
    for c in costs_b:
        gw.submit("b", c)
    bound = 4 + len(costs_a) + len(costs_b) + sum(
        -(-c // 500) for c in costs_a + costs_b
    )
    while gw.pending():
        assert gw.rounds < bound
        gw.step_round()
        # the quantum is never driven negative by chunked execution
        assert all(d >= 0 for d in gw._deficit.values())
    assert gw.stats()["forced"] == 0  # unit 500 <= round_budget always
    for adapter in (a, b):
        for budget_offered, consumed, forced in adapter.work_calls:
            assert forced is False
            assert consumed <= budget_offered


@given(
    st.lists(st.integers(100, 4_000), min_size=1, max_size=8),
    st.integers(600, 3_000),
)
@settings(max_examples=25, deadline=None)
def test_total_emitted_work_identical_to_atomic_path(costs, budget):
    """Chunked execution changes *when* cycles are charged, never how many:
    total ops, completions and per-request service are identical to the
    atomic path on the same trace."""
    tr = from_streams(
        "w", 0,
        [dict(kind="a", arrivals=[i * 137 for i in range(len(costs))],
              payload=lambda i: dict(cost=costs[i]))],
    )

    def once(preemptive):
        ad = FakeAdapter("a", slots=4, unit=500, preemptive=preemptive)
        gw = Gateway([ad], policy="fair", round_budget=budget,
                     shares={"a": 1.0})
        replay_mod.replay(gw, tr, {"a": _cost_mat})
        return ad, gw

    ad_p, gw_p = once(True)
    ad_a, gw_a = once(False)
    assert ad_p.total_ops == ad_a.total_ops == sum(costs)
    assert sum(g.done for g in gw_p.requests) == len(costs)
    assert sum(g.done for g in gw_a.requests) == len(costs)


# ------------------------------------------------- QoS class decoupling


def test_qos_classes_decoupled_from_kind_protect_interactive():
    """Two QoS classes behind ONE adapter kind: a backlogged bulk class
    must not starve the interactive class's quantum — the fair share is
    keyed by class, not by engine."""
    ad = FakeAdapter("a", slots=8, unit=200)
    gw = Gateway([ad], policy="fair", round_budget=1_000,
                 shares={"gold": 0.5, "bulk": 0.5})
    bulk = [gw.submit("a", 4_000, qos="bulk") for _ in range(4)]
    gold = [gw.submit("a", 400, qos="gold") for _ in range(3)]
    gw.drain()
    st_ = gw.stats()
    assert st_["per_class"]["gold"]["completed"] == 3
    assert st_["per_class"]["bulk"]["completed"] == 4
    # every gold request finished rounds before the bulk backlog drained:
    # its 500-cycle/round quantum served it despite 16k cycles of bulk
    assert max(g.finished_round for g in gold) \
        < max(b.finished_round for b in bulk)
    # ... and gold latency is bounded by its own work / share, not by the
    # bulk backlog (which alone needs 16 rounds of full budget)
    assert all(g.latency_cycles <= 3 * 1_000 for g in gold)


# ------------------------------------------------------- plan hot-reload


class SwappablePlan:
    def __init__(self, tag, params_fp):
        self.tag = tag
        self.params_fingerprint = params_fp
        self.fingerprint = f"plan-{tag}"


class SwappableAdapter(FakeAdapter):
    """FakeAdapter + the plan surface: verify/install like the real ones."""

    def __init__(self, kind, **kw):
        super().__init__(kind, **kw)
        self.params = {"w": np.arange(4, dtype=np.float32)}
        self.plan = None
        self.installed = []

    def install_plan(self, plan):
        if self.has_work():
            raise RuntimeError("install_plan with requests in flight")
        self.plan = plan
        self.installed.append(plan.tag)


def _fp(adapter):
    from repro.autotune.calibrate import params_fingerprint

    return params_fingerprint(adapter.params)


def test_swap_plan_rejects_stale_fingerprint_immediately():
    ad = SwappableAdapter("a")
    gw = Gateway([ad], round_budget=1_000)
    with pytest.raises(StalePlanError) as exc:
        gw.swap_plan("a", SwappablePlan("v2", "0" * 64))
    assert "0" * 64 in str(exc.value)
    assert _fp(ad) in str(exc.value)
    assert not gw._pending_swap  # nothing queued


def test_swap_plan_installs_at_round_boundary_against_midstream_traffic():
    """The hot-reload property: a swap requested mid-stream (in-flight +
    queued requests) holds admission for its kind, lets in-flight work
    drain under the old plan, installs at a round boundary, then serves
    later requests under the new plan.  Every request completes."""
    ad = SwappableAdapter("a", slots=2, unit=500)
    gw = Gateway([ad], policy="fair", round_budget=1_000)
    early = [gw.submit("a", 3_000) for _ in range(3)]  # 2 admit, 1 queued
    gw.step_round()
    assert any(g.admitted is not None and not g.done for g in early)
    plan = SwappablePlan("v2", _fp(ad))
    gw.swap_plan("a", plan)
    assert gw._pending_swap  # busy: deferred, not installed
    late = [gw.submit("a", 800) for _ in range(2)]
    gw.drain()
    assert ad.installed == ["v2"] and ad.plan is plan
    assert all(g.done for g in early + late)
    [swap] = gw.plan_swaps
    assert swap["kind"] == "a" and swap["fingerprint"] == "plan-v2"
    # admission was held: nothing admitted into the old plan after the
    # swap request; later requests were admitted at/after the install
    assert all(g.admitted_round >= swap["round"] for g in late)
    assert gw.stats()["plan_swaps"] == gw.plan_swaps


@given(st.lists(st.integers(200, 3_000), min_size=1, max_size=6),
       st.integers(1, 5))
@settings(max_examples=20, deadline=None)
def test_swap_plan_midstream_property(costs, swap_at):
    """Property sweep: whatever the traffic shape and swap timing, the
    swap installs exactly once, at a boundary where the adapter is idle,
    and every request (before and after) completes."""
    ad = SwappableAdapter("a", slots=2, unit=400)
    gw = Gateway([ad], policy="fair", round_budget=900)
    for c in costs:
        gw.submit("a", c)
    for _ in range(swap_at):
        if gw.pending():
            gw.step_round()
    gw.swap_plan("a", SwappablePlan("v2", _fp(ad)))
    post = gw.submit("a", 600)
    gw.drain(max_rounds=200)
    assert ad.installed == ["v2"]
    assert all(g.done for g in gw.requests)
    assert post.done


def test_swap_plan_on_real_seg_adapter():
    """End to end with the real engine: hot-swap a fresh tuned plan onto
    an idle SegAdapter; the engine rebuilds onto the plan's schedule and
    serves the next request under it."""
    from test_gateway import _plan_for, _small_unet

    from repro.serve.gateway import SegAdapter

    cfg, params = _small_unet()
    adapter = SegAdapter(cfg, params, batch=2)
    gw = Gateway([adapter], policy="fair", round_budget=50_000_000)
    r0 = gw.submit("seg", np.ones((32, 32, 2), np.float32))
    gw.drain()
    assert r0.done and adapter.plan is None
    plan = _plan_for(params, stale=False)
    gw.swap_plan("seg", plan)
    assert adapter.plan is plan  # idle: installed immediately
    assert adapter.engine.base_schedule.planes == tuple(plan.planes)
    r1 = gw.submit("seg", np.ones((32, 32, 2), np.float32))
    gw.drain()
    assert r1.done and r1.handle.result is not None
    with pytest.raises(StalePlanError):
        gw.swap_plan("seg", _plan_for(params, stale=True))


# --------------------------------- chunked prefill, slot-isolated engine


def test_engine_chunked_prefill_work_equivalent_to_atomic():
    """Chunked prefill (admit_slot + metered prefill + ready-gated decode)
    emits exactly the atomic path's *work*: same prompts prefilled to
    completion, same number of decode steps per request, completions
    intact, with decode never running a mid-prefill slot.  Token values
    are deliberately not compared: XLA CPU float matmuls jitter in the
    last ulp run-to-run regardless of scheduling (greedy argmax over
    random-init logits amplifies ties into different tokens even between
    two *atomic* runs), so value identity measures the backend, not the
    engine — the gateway bench gates value-level bit-identity on the
    integer seg datapath instead, where accumulation is associative."""
    import jax

    from repro import models
    from repro.configs import get_smoke_config
    from repro.serve.engine import Engine, Request

    cfg = get_smoke_config("minitron_4b")
    params = models.build(cfg).init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=n) for n in (5, 3)]

    eng = Engine(cfg, params, batch=2, max_seq=24)
    r0 = Request(rid=0, prompt=prompts[0], max_new=4)
    r1 = Request(rid=1, prompt=prompts[1], max_new=4)
    assert eng.admit_slot(r0) and eng.admit_slot(r1)
    assert r0.prefill_remaining == 5 and not r0.ready
    assert eng.prefill(r0, 2) == 2
    assert r0.prefill_pos == 2 and not r0.ready
    assert eng.prefill(r1) == 3 and r1.ready
    # decode skips the mid-prefill slot: only r1 steps
    assert eng.ready_slots() == [(1, r1)]
    assert eng.step() == []  # r1 not done yet, nothing completes
    assert len(r1.out) == 1 and len(r0.out) == 0  # r0 untouched
    assert eng.prefill(r0) == 3 and r0.ready  # catch up
    done = []
    while len(done) < 2:
        done.extend(eng.step())
    assert {r.rid for r in done} == {0, 1}
    assert [len(r.out) for r in (r0, r1)] == [4, 4]
    assert r0.prefill_remaining == 0
    # the atomic surface emits the same work shape
    eng2 = Engine(cfg, params, batch=2, max_seq=24)
    done2 = eng2.run([Request(rid=i, prompt=p, max_new=4)
                      for i, p in enumerate(prompts)])
    assert [len(r.out) for r in sorted(done2, key=lambda r: r.rid)] == [4, 4]


def test_seg_group_scoped_stepping_bit_identical():
    """The value-level half of the preemption bit-identity claim, on the
    datapath where it is provable: QoS-group-scoped micro-batch stepping
    (what the gateway's class quanta drive) stitches logits bit-identical
    to plain global stepping — the MSDF int8 datapath's integer
    accumulation is associative and the tuned plan's per-tile activation
    scales make numerics batch-composition independent."""
    from test_gateway import _plan_for, _small_unet

    from repro.segserve.engine import SegEngine

    cfg, params = _small_unet()
    plan = _plan_for(params, stale=False)
    imgs = [
        np.linspace(0, 1, 32 * 32 * 2, dtype=np.float32).reshape(32, 32, 2),
        np.linspace(1, -1, 32 * 32 * 2, dtype=np.float32).reshape(32, 32, 2),
    ]

    def serve(grouped: bool):
        eng = SegEngine(cfg, params, plan=plan, batch=2)
        reqs = [
            eng.submit(im, group=(f"g{i}" if grouped else None))
            for i, im in enumerate(imgs)
        ]
        eng.queue.pump(eng.slots, eng._admit)
        if grouped:
            # interleave group-scoped steps the way class quanta would
            while eng.has_work():
                for g in ("g1", "g0"):
                    eng.step(group=g)
        else:
            while eng.has_work():
                eng.step()
        assert all(r.done for r in reqs)
        return [r.result.logits for r in reqs]

    for a, b in zip(serve(True), serve(False)):
        assert np.array_equal(a, b)

def test_fifo_swap_hold_does_not_block_other_kinds():
    """Regression (swap-hold head-of-line leak): under policy='fifo', a
    pending plan swap holds admission for its own kind only.  Before the
    fix the held request at the queue head froze the whole FIFO scan, so
    traffic for every other kind queued behind it starved until the swap
    drained — here, LM-like 'a' is mid-swap while seg-like 'b' arrives."""
    a = SwappableAdapter("a", slots=1, unit=500)
    b = FakeAdapter("b", slots=2, unit=500)
    gw = Gateway([a, b], policy="fifo", round_budget=2_000)
    inflight = gw.submit("a", 3_000)  # occupies the only 'a' slot
    gw.step_round()
    assert inflight.admitted is not None and not inflight.done
    gw.swap_plan("a", SwappablePlan("v2", _fp(a)))
    assert gw._pending_swap  # busy: swap deferred, kind 'a' held
    held = gw.submit("a", 1_000)  # FIFO head among queued, held kind
    others = [gw.submit("b", 500) for _ in range(3)]
    gw.step_round()
    # the held 'a' stays queued; 'b' traffic behind it fills its slots
    assert held.admitted is None
    assert sum(g.admitted is not None for g in others) == b.slots
    assert any(g.done for g in others)
    gw.drain(max_rounds=100)
    assert a.installed == ["v2"]
    assert all(g.done for g in [inflight, held] + others)
    # the held request was admitted only once the swap had installed
    [swap] = gw.plan_swaps
    assert held.admitted_round >= swap["round"]


# ------------------------------------------------- on_off boundary behavior


def _fixed_gap(on_dwell, off_dwell, arrival):
    """A deterministic stand-in for arrivals._exp_gap keyed by domain
    tag, for pinning on_off's window-edge arithmetic exactly."""
    def gap(seed, mean, tag, counter):
        if tag == 0x00FFDEAD:  # ON dwell
            return float(on_dwell)
        if tag == 0x0FF0FF00:  # OFF dwell
            return float(off_dwell)
        return float(arrival)  # in-burst arrival gap
    return gap


def test_on_off_arrival_exactly_at_window_edge_included(monkeypatch):
    """An arrival landing exactly at the ON-window boundary belongs to
    the burst (the <= comparison): ON dwell 100, gaps 50 puts arrival 2
    at t=100 == on_end — emitted, not deferred past the OFF dwell."""
    monkeypatch.setattr(arrivals, "_exp_gap", _fixed_gap(100, 1_000, 50))
    got = arrivals.on_off(4, seed=0, burst_interval=1, on_mean=1,
                          off_mean=1)
    # burst 1: 50, 100 (edge); then the residual gap is exactly 0, so
    # the next window's arrivals sit at off_end+50 and its own edge
    assert got == [50, 100, 1150, 1200]


def test_on_off_zero_length_off_phase_is_seamless(monkeypatch):
    """A zero-length OFF dwell degenerates to back-to-back ON windows:
    the straddling-gap residual carries exactly, so arrivals are the
    pure gap cumsum — window boundaries leave no seam."""
    monkeypatch.setattr(arrivals, "_exp_gap", _fixed_gap(100, 0, 30))
    got = arrivals.on_off(8, seed=0, burst_interval=1, on_mean=1,
                          off_mean=1)
    assert got == [30 * (i + 1) for i in range(8)]


# ------------------------------------------------- diurnal streaming twins


def test_iter_twins_prefix_identical_to_list_builders():
    from itertools import islice

    from repro.workload import diurnal

    kw = dict(seed=11, burst_interval=150, on_mean=700, off_mean=2_500)
    assert list(islice(diurnal.iter_on_off(**kw), 30)) == \
        arrivals.on_off(30, **kw)
    assert list(islice(
        diurnal.iter_poisson(seed=11, mean_interval=800, start=40), 25
    )) == arrivals.poisson(25, mean_interval=800, seed=11, start=40)


def test_diurnal_prefix_stable_under_seed_reuse():
    """Re-instantiating the generator from the same seed reproduces the
    identical prefix, and a longer read never reshuffles a shorter one
    — the counter-PRNG contract extended through thinning."""
    from itertools import islice

    from repro.workload import diurnal

    def mk():
        return diurnal.diurnal(seed=42, peak_interval=500,
                               period=200_000, floor=0.2)

    a = list(islice(mk(), 40))
    assert a == sorted(a) and len(set(a)) >= 38  # monotone, ~unique
    assert list(islice(mk(), 40)) == a
    assert list(islice(mk(), 15)) == a[:15]
    # thinning is keyed by candidate index: the accepted stream is a
    # subsequence of the unthinned candidates
    base = list(islice(diurnal.iter_poisson(seed=42, mean_interval=500),
                       400))
    assert set(a) <= set(base)
    # a different thinning seed accepts a different subsequence
    b = list(islice(diurnal.modulate(
        diurnal.iter_poisson(seed=42, mean_interval=500),
        seed=43, period=200_000, floor=0.2), 40))
    assert b != a


def test_day_curve_shape_and_validation():
    from repro.workload import diurnal

    P = 1_000
    assert diurnal.day_curve(0, period=P, floor=0.15) == pytest.approx(0.15)
    assert diurnal.day_curve(P // 2, period=P, floor=0.15) == \
        pytest.approx(1.0)
    assert diurnal.day_curve(P, period=P, floor=0.15) == pytest.approx(0.15)
    with pytest.raises(ValueError):
        diurnal.day_curve(0, period=0)
    with pytest.raises(ValueError):
        diurnal.day_curve(0, period=P, floor=1.5)


def test_merge_tags_streams_by_index():
    """Regression: merge() must bind each stream's index at generator
    creation (a late-bound closure tags every arrival with the last
    index, collapsing all classes into one)."""
    from repro.workload import diurnal

    merged = list(diurnal.merge(iter([10, 30]), iter([20]), iter([40])))
    assert merged == [(10, 0), (20, 1), (30, 0), (40, 2)]


def test_stream_requests_compose_until_and_payload_callable():
    from repro.workload import diurnal

    feed = list(diurnal.stream_requests(
        [
            dict(kind="a", arrivals=iter([5, 15, 25]),
                 payload=lambda i: dict(cost=100 * (i + 1)),
                 deadline_cycles=50),
            dict(kind="b", qos="bulk", arrivals=iter([10]),
                 payload=dict(cost=7)),
        ],
        until=20,
    ))
    assert [t for t, *_ in feed] == [5, 10, 15]
    assert feed[0][3] == dict(qos="a", deadline_cycles=50)
    assert feed[1][1] == "b" and feed[1][3] == dict(qos="bulk")
    # per-stream payload index, not the merged index
    assert feed[2][2] == dict(cost=200)
    with pytest.raises(ValueError, match="kind/arrivals/payload"):
        list(diurnal.stream_requests([dict(kind="a")]))
    limited = list(diurnal.stream_requests(
        [dict(kind="a", arrivals=iter(range(100)), payload=dict())],
        limit=3,
    ))
    assert len(limited) == 3
