"""Dynamic-precision plane schedules: analytic-bound properties, builder
invariants, static/traced equivalence across every MMA datapath, and the
end-to-end U-Net + LM guarantees the serving knob advertises."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import bitplane, early_term, mma
from repro.core.plane_schedule import PlaneSchedule, layer_rel_bound
from repro.kernels import ref


# ------------------------------------------------------------- bound property


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_empirical_err_within_bound_all_plane_counts(seed):
    """For random int8 weights, the measured relative error of truncation is
    within the analytic bound at *every* plane count 1..8."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-128, 128, (6, 48)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (48, 5)), jnp.int8)
    exact = ref.mma_matmul_ref(x, w)
    denom = jnp.maximum(jnp.max(jnp.abs(exact.astype(jnp.float32))), 1.0)
    for planes in range(1, bitplane.N_BITS + 1):
        approx = ref.mma_matmul_ref(x, w, planes=planes, midpoint=True)
        emp = float(early_term.empirical_rel_err(exact, approx))
        bound = early_term.truncation_bound(w, planes, midpoint=True)
        rel_bound = float((jnp.max(bound).astype(jnp.float32) + 1) / denom)
        assert emp <= rel_bound, (planes, emp, rel_bound)
        # absolute, per-column form as well (the sharper statement)
        assert bool(jnp.all(jnp.abs(exact - approx) <= bound[None, :] + 1))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_choose_planes_monotone_in_target(seed):
    """A looser error target never requires more planes."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.integers(-128, 128, (64, 8)), jnp.int8)
    targets = (0.5, 0.2, 0.1, 0.05, 0.02, 0.01, 0.005, 0.001, 1e-4, 0.0)
    # midpoint=False is the schedule-builder form (matches the deployed
    # uncorrected datapaths and layer_rel_bound)
    picks = [early_term.choose_planes(w, t, midpoint=False) for t in targets]
    assert picks == sorted(picks)  # targets descend -> planes ascend
    # and each pick actually meets its target (or is the 8-plane max)
    for t, b in zip(targets, picks):
        if b < bitplane.N_BITS:
            assert layer_rel_bound(w, b) <= t
    # the midpoint form (for midpoint-corrected consumers) is monotone too
    picks_mid = [early_term.choose_planes(w, t) for t in targets]
    assert picks_mid == sorted(picks_mid)
    assert all(a <= b for a, b in zip(picks_mid, picks))  # half-sized bound


def test_layer_rel_bound_decreases_with_planes():
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.integers(-128, 128, (128, 16)), jnp.int8)
    bounds = [layer_rel_bound(w, b) for b in range(1, 9)]
    assert all(a > b for a, b in zip(bounds, bounds[1:]))
    assert bounds[-1] == 0.0


# ---------------------------------------------------------------- the policy


def test_builders_and_validation():
    s = PlaneSchedule.uniform(4, 3)
    assert s.planes == (4, 4, 4)
    assert len(s) == 3 and list(s) == [4, 4, 4] and s[1] == 4
    assert s.arithmetic_fraction() == pytest.approx(0.5)
    assert PlaneSchedule.from_list([8, 3, 1]).planes == (8, 3, 1)
    with pytest.raises(ValueError):
        PlaneSchedule.from_list([])
    with pytest.raises(ValueError):
        PlaneSchedule.from_list([0, 4])
    with pytest.raises(ValueError):
        PlaneSchedule.uniform(9, 2)
    # clamping for deeper stacks
    assert PlaneSchedule.from_list([8, 4]).planes_for(17) == 4
    assert PlaneSchedule.uniform(6, 2).as_array().dtype == jnp.int32


def test_from_weights_meets_target():
    rng = np.random.default_rng(11)
    ws = [jnp.asarray(rng.integers(-128, 128, (72, 9)), jnp.int8)
          for _ in range(4)]
    tgt = 0.02
    s = PlaneSchedule.from_weights(ws, tgt)
    assert len(s) == 4
    assert s.target_rel_err == tgt
    assert s.layer_bounds is not None
    for w, b, lb in zip(ws, s.planes, s.layer_bounds):
        assert lb == pytest.approx(layer_rel_bound(w, b))
        if b < bitplane.N_BITS:
            assert lb <= tgt
    assert s.rel_err_bound() == pytest.approx(sum(s.layer_bounds))


# --------------------------------------------- static/traced plane equivalence


@pytest.mark.parametrize("impl", ["xla", "cascade", "int8", "pallas"])
@pytest.mark.parametrize("planes", [8, 6, 3, 1])
def test_traced_planes_match_static(impl, planes):
    """A schedule entry riding a scan (traced scalar) must be bit-identical
    to the statically specialized kernel at the same budget."""
    rng = np.random.default_rng(planes)
    x = jnp.asarray(rng.integers(-128, 128, (7, 64)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (64, 10)), jnp.int8)
    kw = dict(interpret=True) if impl == "pallas" else {}
    static = mma.mma_dot(x, w, planes=planes, impl=impl, **kw)
    traced = jax.jit(
        lambda a, p: mma.mma_dot(a, w, planes=p, impl=impl, **kw)
    )(x, jnp.int32(planes))
    np.testing.assert_array_equal(np.asarray(static), np.asarray(traced))
    want = ref.mma_matmul_ref(x, w, planes=planes)
    np.testing.assert_array_equal(np.asarray(static), np.asarray(want))


def test_truncate_to_planes_identity():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-128, 128, (5, 32)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (32, 6)), jnp.int8)
    for planes in range(1, 9):
        xt = bitplane.truncate_to_planes(x, planes)
        full = mma.mma_dot(xt, w, planes=8, impl="int8")
        np.testing.assert_array_equal(
            np.asarray(full),
            np.asarray(ref.mma_matmul_ref(x, w, planes=planes)),
        )
    # planes=8 is the identity
    np.testing.assert_array_equal(
        np.asarray(bitplane.truncate_to_planes(x, 8)), np.asarray(x)
    )


def test_pallas_plane_variants_are_cached():
    from repro.kernels import mma_matmul as mk, ops

    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.integers(-128, 128, (8, 32)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (32, 8)), jnp.int8)
    before = mk.plane_variant.cache_info()
    for _ in range(3):
        ops.mma_matmul(x, w, planes=3, interpret=True)
    after = mk.plane_variant.cache_info()
    # one new specialization, then cache hits — no retrace per call
    assert after.misses <= before.misses + 1
    assert after.hits >= before.hits + 2


# --------------------------------------------------------------- end to end


def _unet_setup():
    from repro.models import unet as um

    cfg = um.UNetConfig(
        hw=16, in_ch=3, base=4, depth=2, convs_per_stage=1,
        quant_mode="mma_int8", impl="xla",
    )
    params = um.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16, 3))
    return um, cfg, params, x


def test_unet_schedule_within_advertised_bound():
    """Acceptance: a scheduled U-Net forward stays within the advertised
    (interval-propagated, worst-case sound) bound of the full-precision
    datapath, and the bound tightens monotonically with the target."""
    um, cfg, params, x = _unet_setup()
    prev_planes = 0
    for tgt in (0.05, 0.01, 0.001):
        sched = um.schedule_from_params(params, tgt)
        assert len(sched) == len(cfg.conv_layers())
        assert sum(sched.planes) >= prev_planes  # tighter target, >= planes
        prev_planes = sum(sched.planes)
        scfg = dataclasses.replace(cfg, plane_schedule=tuple(sched.planes))
        out_s, out_f, adv = um.forward_with_error_bound(params, x, scfg)
        emp = float(
            jnp.max(jnp.abs(out_s - out_f))
            / jnp.maximum(jnp.max(jnp.abs(out_f)), 1e-8)
        )
        assert np.isfinite(adv)
        assert emp <= adv, (tgt, emp, adv)
    # uniform 8 planes == the full-precision path exactly, bound collapses
    scfg = dataclasses.replace(cfg, plane_schedule=(8,) * 5)
    out_s, out_f, adv = um.forward_with_error_bound(params, x, scfg)
    assert adv == 0.0
    np.testing.assert_array_equal(np.asarray(out_s), np.asarray(out_f))


def test_unet_uniform_schedule_equals_global_knob():
    """schedule=(b,)*L must be bit-identical to the old global planes=b."""
    um, cfg, params, x = _unet_setup()
    for b in (6, 3):
        g = um.forward(params, x, dataclasses.replace(cfg, planes=b))
        s = um.forward(
            params, x, dataclasses.replace(cfg, plane_schedule=(b,) * 5)
        )
        np.testing.assert_array_equal(np.asarray(g), np.asarray(s))


def test_lm_schedule_matches_global_knob_when_uniform():
    """The schedule riding the layer scan (traced, bit-mask form) equals the
    static global knob on a scan-rolled transformer — same numerics, so the
    serving engine can swap knob for schedule with zero quality change."""
    from repro.configs import get_smoke_config
    from repro.configs.base import QuantConfig

    from repro import models

    cfg = get_smoke_config("yi_6b")
    mod = models.build(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (2, 12)), jnp.int32
    )
    for b in (8, 5):
        g = mod.forward(
            params, toks, cfg.replace(quant=QuantConfig(mode="mma_int8", planes=b))
        )
        # plane_schedule governs the block stack; `planes` still governs
        # non-block linears (the lm head), so set both for exact equality
        s = mod.forward(
            params, toks,
            cfg.replace(
                quant=QuantConfig(
                    mode="mma_int8", planes=b,
                    plane_schedule=(b,) * cfg.n_layers,
                )
            ),
        )
        np.testing.assert_allclose(
            np.asarray(g, np.float32), np.asarray(s, np.float32), atol=0, rtol=0
        )


def test_lm_schedule_from_params_end_to_end():
    from repro.configs import get_smoke_config
    from repro.configs.base import QuantConfig
    from repro.serve.engine import lm_schedule_from_params

    from repro import models

    cfg = get_smoke_config("yi_6b")
    mod = models.build(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    sched = lm_schedule_from_params(params, cfg, 0.01)
    assert len(sched) == cfg.n_layers
    assert all(1 <= b <= 8 for b in sched.planes)
    toks = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab, (2, 12)), jnp.int32
    )
    qcfg = cfg.replace(
        quant=QuantConfig(mode="mma_int8", plane_schedule=tuple(sched.planes))
    )
    out = mod.forward(params, toks, qcfg)
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
