"""Distribution-layer tests that need >1 device: run in a SUBPROCESS with
forced host devices (conftest keeps the main test process at 1 device).

Covers: logical sharding rules + divisibility fallback, param-spec
derivation, grad-compression collective (error feedback across steps), and
a tiny end-to-end sharded train step on a 4x2 mesh.
"""
import json
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # CI runs these in the non-blocking slow job

SUB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_host_mesh
from repro.parallel import sharding as shd
from repro.parallel import param_specs as pspecs

mesh = make_host_mesh(model=2)  # (4, 2) data x model

# --- rule resolution + divisibility fallback
with shd.use_mesh(mesh):
    spec = shd.spec_for(("batch", None, "heads"), (8, 3, 4))
    assert spec == P("data", None, "model"), spec
    # kv=3 not divisible by model=2 -> dropped
    spec = shd.spec_for(("batch", None, "kv_heads"), (8, 3, 3))
    assert spec == P("data", None, None), spec
    # duplicate axis use prevented
    spec = shd.spec_for(("heads", "ffn"), (4, 4))
    assert spec == P("model", None), spec

# --- param specs on a smoke model
from repro.configs import get_smoke_config
from repro.models import build
cfg = get_smoke_config("yi_6b")
mod = build(cfg)
ab = jax.eval_shape(lambda: mod.init_params(jax.random.PRNGKey(0), cfg))
sh = pspecs.named_shardings(ab, cfg, mesh)
wq = sh["blocks"]["attn"]["wq"]["w"]
assert wq.spec == P(None, None, "model"), wq.spec  # (L, d, heads*hd)
wo = sh["blocks"]["attn"]["wo"]["w"]
assert wo.spec == P(None, "model", None), wo.spec  # row-parallel
emb = sh["embed"]["table"]
assert emb.spec == P("model", None), emb.spec      # vocab-sharded

# --- grad compression: compressed mean-allreduce with error feedback
from repro.optim import grad_compress as gc
mesh1 = jax.make_mesh((8,), ("data",))
f = gc.compressed_psum_shardmap(mesh1, ("data",))
rng = np.random.default_rng(0)
g_local = jnp.asarray(rng.standard_normal((8, 128)), jnp.float32)  # per-shard
err = jnp.zeros((8, 128), jnp.float32)
exact_mean = jnp.mean(g_local, axis=0)
total_err_first = None
acc = jnp.zeros((128,), jnp.float32)
acc_exact = jnp.zeros((128,), jnp.float32)
for step in range(20):
    synced, err = f(g_local, err)
    acc = acc + synced[0]
    acc_exact = acc_exact + exact_mean
    if step == 0:
        total_err_first = float(jnp.max(jnp.abs(synced[0] - exact_mean)))
# single-step error is bounded by the int8 quant step
assert total_err_first < float(jnp.max(jnp.abs(g_local))) / 127 * 1.01 + 1e-6
# error feedback keeps the ACCUMULATED estimate tight (no drift)
drift = float(jnp.max(jnp.abs(acc - acc_exact)))
assert drift < float(jnp.max(jnp.abs(g_local))) / 127 * 2.5, drift

# --- end-to-end sharded train step on the 4x2 mesh
from repro.train import train_step as ts
ab_state = ts.abstract_state(cfg)
st_sh = ts.state_shardings(ab_state, cfg, mesh)
batch = {"tokens": jax.ShapeDtypeStruct((8, 33), jnp.int32)}
b_sh = ts.batch_shardings(batch, mesh)
params = mod.init_params(jax.random.PRNGKey(0), cfg)
from repro.optim import adamw
state = {"params": params, "opt": adamw.init(params)}
state = jax.device_put(state, st_sh)
tok = jax.device_put(jnp.asarray(rng.integers(0, cfg.vocab, (8, 33)), jnp.int32),
                     b_sh["tokens"])
def step_fn(st, b):
    with shd.use_mesh(mesh):
        return ts.train_step(st, b, cfg)
jitted = jax.jit(step_fn, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None))
new_state, metrics = jitted(state, {"tokens": tok})
loss = float(metrics["loss"])
assert np.isfinite(loss), loss

# --- sharded result must equal single-device result
state1 = {"params": params, "opt": adamw.init(params)}
new1, m1 = jax.jit(lambda st, b: ts.train_step(st, b, cfg))(state1, {"tokens": tok})
assert abs(loss - float(m1["loss"])) < 5e-2, (loss, float(m1["loss"]))

# --- elastic restart: save sharded under mesh A, restore under mesh B
import tempfile
from repro.checkpoint.ckpt import Checkpointer
from jax.sharding import NamedSharding
meshA = jax.make_mesh((4, 2), ("data", "model"))
meshB = jax.make_mesh((2, 4), ("data", "model"))
with tempfile.TemporaryDirectory() as td:
    ck = Checkpointer(td)
    w = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)
    wA = jax.device_put(w, NamedSharding(meshA, P("data", "model")))
    ck.save(1, {"w": wA})
    shB = {"w": NamedSharding(meshB, P("data", "model"))}
    restored, _ = ck.restore(jax.eval_shape(lambda: {"w": w}), shardings=shB)
    assert restored["w"].sharding == shB["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))

# --- EP MoE (shard_map all-to-all) == GSPMD dispatch path, dropless
import dataclasses as dc
from repro.models import moe as moe_lib

mcfg = get_smoke_config("olmoe_1b_7b")
mcfg = mcfg.replace(moe=dc.replace(mcfg.moe, capacity_factor=64.0, ep=True))
mp = moe_lib.init_moe(jax.random.PRNGKey(3), mcfg)
xm = jnp.asarray(rng.standard_normal((4, 16, mcfg.d_model)) * 0.1, jnp.bfloat16)
with shd.use_mesh(mesh):
    y_plain = jax.jit(lambda p_, x_: moe_lib.moe_ffn(p_, x_, mcfg))(mp, xm)
    y_ep = jax.jit(lambda p_, x_: moe_lib.moe_ffn_ep(p_, x_, mcfg))(mp, xm)
diff = float(jnp.max(jnp.abs(y_plain.astype(jnp.float32) - y_ep.astype(jnp.float32))))
scale = float(jnp.max(jnp.abs(y_plain.astype(jnp.float32)))) + 1e-6
assert diff / scale < 0.02, (diff, scale)

print("SUBPROCESS_OK")
"""


def test_distributed_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", SUB],
        capture_output=True, text=True, timeout=900,
        # JAX_PLATFORMS=cpu: these are forced-host-device tests; without it
        # jax probes for a TPU backend in the stripped env and can hang for
        # minutes before falling back.
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert "SUBPROCESS_OK" in r.stdout, r.stdout + "\n" + r.stderr
