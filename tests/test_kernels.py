"""Pallas MMA kernel vs the pure-jnp oracle: shape/dtype/plane sweeps in
interpret mode, plus the XLA and cascade datapaths (all must be bit-exact)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import bitplane, mma
from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _rand_i8(shape):
    return jnp.asarray(RNG.integers(-128, 128, shape), jnp.int8)


@pytest.mark.parametrize("m,k,n", [
    (4, 32, 8), (32, 128, 32), (128, 512, 128), (37, 100, 65),
    (1, 7, 3), (256, 1024, 256), (64, 300, 90),
])
@pytest.mark.parametrize("planes", [8, 5, 2])
def test_pallas_matmul_vs_oracle(m, k, n, planes):
    x, w = _rand_i8((m, k)), _rand_i8((k, n))
    got = ops.mma_matmul(x, w, planes=planes, interpret=True)
    want = ref.mma_matmul_ref(x, w, planes=planes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("impl", ["xla", "cascade", "int8"])
def test_other_impls_vs_oracle(impl):
    x, w = _rand_i8((24, 96)), _rand_i8((96, 48))
    got = mma.mma_dot(x, w, impl=impl)
    want = ref.mma_matmul_ref(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_batched_leading_dims():
    x, w = _rand_i8((2, 3, 40)), _rand_i8((40, 16))
    got = ops.mma_matmul(x, w, interpret=True)
    want = ref.mma_matmul_ref(x.reshape(6, 40), w).reshape(2, 3, 16)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_custom_blocks():
    x, w = _rand_i8((64, 256)), _rand_i8((256, 64))
    got = ops.mma_matmul(x, w, interpret=True, block=(32, 128, 64))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.mma_matmul_ref(x, w)))


def test_conv2d_vs_oracle():
    x = _rand_i8((2, 12, 12, 16))
    w = _rand_i8((3, 3, 16, 24))
    got = ops.mma_conv2d(x, w, interpret=True)
    want = ref.mma_conv2d_ref(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv2d_stride2():
    x = _rand_i8((1, 16, 16, 8))
    w = _rand_i8((3, 3, 8, 8))
    got = ops.mma_conv2d(x, w, stride=2, interpret=True)
    want = ref.mma_conv2d_ref(x, w, stride=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(1, 8))
@settings(max_examples=8, deadline=None)
def test_plane_truncation_matches_masked_oracle(planes):
    x, w = _rand_i8((16, 64)), _rand_i8((64, 16))
    got = ops.mma_matmul(x, w, planes=planes, interpret=True)
    want = ref.mma_matmul_ref(x, w, planes=planes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_unsigned_mode():
    x = jnp.asarray(RNG.integers(0, 256, (16, 64)), jnp.int32).astype(jnp.uint8)
    # kernel path uses int8 views; emulate unsigned via signed=False
    xi = x.astype(jnp.int32).astype(jnp.int8)  # reinterpret bits
    w = _rand_i8((64, 16))
    got = ops.mma_matmul(xi, w, signed=False, interpret=True)
    want = jax.lax.dot_general(
        x.astype(jnp.int32) % 256, w.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,k,n", [(16, 96, 40), (64, 256, 128), (3, 50, 7)])
@pytest.mark.parametrize("planes", [8, 5])
def test_scaled_epilogue_kernel(m, k, n, planes):
    """Fused dequant epilogue == int32 kernel then scale (bit-exact in f32)."""
    x, w = _rand_i8((m, k)), _rand_i8((k, n))
    xs = jnp.float32(0.0173)
    ws = jnp.asarray(RNG.uniform(1e-3, 1e-2, n), jnp.float32)
    got = ops.mma_matmul_scaled(x, w, xs, ws, planes=planes, interpret=True)
    want = ref.mma_matmul_ref(x, w, planes=planes).astype(jnp.float32) * xs * ws
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_quantized_linear_pallas_path():
    """layers.linear dispatches w_q through the fused-scale Pallas kernel."""
    from repro.configs.base import QuantConfig
    from repro.models import layers as L

    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.standard_normal((256, 320)) * 0.02, jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, 256)), jnp.float32)
    from repro.core.quant import quantize_params_int8

    p = quantize_params_int8({"w": w}, min_dim=256)
    out_p = L.linear(p, x, QuantConfig(mode="mma_int8", impl="pallas"))
    out_x = L.linear(p, x, QuantConfig(mode="mma_int8", impl="xla"))
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_x), rtol=1e-5,
                               atol=1e-5)


def test_horner_equals_cascade_hlo_structure():
    """The merged path must contain ZERO intermediate HBM round-trips for
    plane partials: structurally, the cascade lowers >= 8 separate dots of
    full output size; the merged kernel is a single pallas_call."""
    x, w = _rand_i8((32, 128)), _rand_i8((128, 32))
    merged = jax.jit(lambda a, b: ops.mma_matmul(a, b, interpret=True))
    text = merged.lower(x, w).as_text()
    assert "custom_call_target" in text or "pallas" in text.lower()
